//! Long-context scenario at paper scale: simulate a 2M-token request on a
//! 128-GPU Medha 3D deployment (tp=8, spp=4, kvp=4) and print the dynamic
//! KVP onboarding timeline (the paper's Fig. 19 scenario), plus the SLO
//! verdicts.
//!
//! Run: `cargo run --release --example long_context_sim [--ctx 2M] [--model llama3-8b]`

use medha::config::DeploymentConfig;
use medha::sim::{SimOptions, Simulation};
use medha::util::args::Args;
use medha::util::stats::{fmt_duration, fmt_tokens};
use medha::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[], false);
    let ctx = args.u64_or("ctx", 2_000_000);
    let model = args.str_or("model", "llama3-8b");
    let mut dep = match model {
        "llama3-70b" => DeploymentConfig::llama3_70b_tp8(),
        _ => DeploymentConfig::llama3_8b_tp8(),
    }
    .with_parallel(8, 4, 4);
    dep.scheduler.kvp_onboard_threshold = ctx / 4;
    dep.validate()?;

    println!(
        "simulating a {} request on {} ({} = {} GPUs)",
        fmt_tokens(ctx),
        dep.model.name,
        dep.parallel.label(),
        dep.total_gpus()
    );

    let w = workload::long_plus_decodes(ctx, 8, 1_000, 2_000);
    let slo = dep.slo;
    let mut sim = Simulation::new(dep, w, SimOptions::default());
    sim.run();

    println!("\nKVP onboarding timeline (Fig. 19):");
    for (t, id, g) in sim.kvp_onboard_log() {
        println!("  t={:>9}  request {id} onboards group {g}", fmt_duration(*t));
    }

    println!("\nGPU staircase (sampled):");
    let iters = &sim.metrics.iters;
    let step = (iters.len() / 10).max(1);
    println!("  {:>10} {:>6} {:>12} {:>8}", "time", "gpus", "iter time", "chunk");
    for rec in iters.iter().step_by(step) {
        println!(
            "  {:>10} {:>6} {:>12} {:>8}",
            fmt_duration(rec.t),
            rec.active_gpus,
            fmt_duration(rec.dur_s),
            rec.chunk.map(|c| c.to_string()).unwrap_or_default()
        );
    }

    let long = sim.request(0).unwrap();
    let ttft = long.ttft().unwrap();
    let mut m = sim.metrics;
    let s = m.summary();
    println!("\nresults:");
    println!(
        "  long-request TTFT: {}  (TTFT SLO {}: {})",
        fmt_duration(ttft),
        fmt_duration(slo.ttft_s),
        if ttft <= slo.ttft_s { "MET" } else { "missed (expected beyond ~2M; see paper sec 7)" }
    );
    println!(
        "  P95 TBT (batched decodes): {}  (TBT SLO {}: {})",
        fmt_duration(s.tbt_p95),
        fmt_duration(slo.tbt_s),
        if s.tbt_p95 <= slo.tbt_s { "MET" } else { "missed" }
    );
    println!("  decode throughput: {:.1} tok/s over the run", s.decode_tps);
    Ok(())
}
