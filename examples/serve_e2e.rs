//! End-to-end validation driver (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real workload:
//!   1. **Correctness gate** — the Rust engine must reproduce the pure-JAX
//!      golden generation token-for-token through the AOT artifacts.
//!   2. **Mixed serving** — a long-context request plus short requests are
//!      served through a real multi-threaded SPP pipeline (one PJRT client
//!      per stage), with chunked prefill interleaving; reports TTFT / TBT /
//!      throughput.
//!   3. **SPP speedup** — the same workload on 1 vs 2 vs 4 stages, showing
//!      dense pipelining's wall-clock win on real hardware.
//!   4. **KVP numerics** — sharded decode attention + online-softmax merge
//!      equals monolithic attention through the runtime.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`

use medha::engine::pipeline::{serve, ServeRequest};
use medha::engine::{tokenize, Engine};
use medha::util::rng::Rng;
use medha::util::stats::{fmt_duration, percentile_nearest_rank};

fn main() -> anyhow::Result<()> {
    let dir = "artifacts";
    anyhow::ensure!(
        std::path::Path::new(dir).join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );

    // ---- 1. correctness gate -------------------------------------------
    println!("== 1. golden-generation gate (Rust+PJRT vs pure-JAX reference) ==");
    let engine = Engine::load(dir, 8)?;
    let n = engine.verify_golden()?;
    println!("   PASS: {n}/{n} tokens match the JAX reference\n");

    // ---- 2. mixed serving through the SPP pipeline ----------------------
    println!("== 2. mixed workload through a 2-stage SPP pipeline ==");
    let long_prompt: String = std::iter::repeat(
        "The quadratic cost of attention dominates long context inference. ",
    )
    .take(12)
    .collect();
    let reqs = vec![
        ServeRequest {
            prompt: tokenize(&long_prompt), // ~780 tokens: the "long" request
            max_new_tokens: 24,
        },
        ServeRequest {
            prompt: tokenize("short req A"),
            max_new_tokens: 24,
        },
        ServeRequest {
            prompt: tokenize("short req B: the weather"),
            max_new_tokens: 24,
        },
        ServeRequest {
            prompt: tokenize("short req C!"),
            max_new_tokens: 24,
        },
    ];
    let rep = serve(dir, 2, 64, &reqs)?;
    println!(
        "   {} requests, wall {}; decode {:.1} tok/s, total {:.1} tok/s",
        rep.requests.len(),
        fmt_duration(rep.wall_s),
        rep.decode_tps(),
        rep.total_tps()
    );
    for (i, r) in rep.requests.iter().enumerate() {
        let p95 = percentile_nearest_rank(&r.tbt_s, 95.0);
        println!(
            "   req{i}: prompt={:>4} ttft={:>9} p95 tbt={:>9} generated={}",
            r.prompt_len,
            fmt_duration(r.ttft_s),
            fmt_duration(p95),
            r.generated.len()
        );
    }
    // Short requests must not be HOL-blocked behind the long prefill. The
    // max is total_cmp-based so a NaN TTFT surfaces as a failure instead of
    // being silently dropped, and the check is a hard gate like the others.
    let long_ttft = rep.requests[0].ttft_s;
    let short_ttft_max = rep.requests[1..]
        .iter()
        .map(|r| r.ttft_s)
        .max_by(f64::total_cmp)
        .unwrap_or(f64::NAN);
    println!(
        "   HOL check: worst short-request TTFT {} vs long request {}",
        fmt_duration(short_ttft_max),
        fmt_duration(long_ttft),
    );
    anyhow::ensure!(
        short_ttft_max < long_ttft,
        "HOL blocking: worst short TTFT {short_ttft_max:.4}s >= long-request TTFT {long_ttft:.4}s"
    );
    println!("   PASS — no HOL blocking\n");

    // ---- 3. SPP pipeline overhead on real wall clocks --------------------
    // NOTE: on a single CPU, one PJRT client already saturates every core
    // with intra-op parallelism, so adding pipeline stages cannot add
    // compute (each stage spawns its own client + thread pool, and they
    // contend). The paper's SPP speedup needs one *machine* per stage —
    // reproduced on the simulated substrate (Fig. 15). What this measures
    // on real hardware is that the dense pipeline schedule is *correct*
    // and its coordination overhead is modest.
    println!("== 3. SPP pipeline execution (1 vs 2 stages, same workload) ==");
    let prefill_heavy = vec![ServeRequest {
        prompt: tokenize(&long_prompt.repeat(2)), // ~1560 tokens
        max_new_tokens: 2,
    }];
    let mut t1 = 0.0;
    for stages in [1usize, 2] {
        let rep = serve(dir, stages, 256, &prefill_heavy)?;
        if stages == 1 {
            t1 = rep.wall_s;
        }
        println!(
            "   {stages} stage(s): wall {} (relative {:.2}x; >0.7x = bounded overhead)",
            fmt_duration(rep.wall_s),
            t1 / rep.wall_s
        );
    }
    println!("   (scaling with real per-stage machines: see Fig. 15 / the simulator)\n");

    // ---- 4. KVP shard/merge numerics ------------------------------------
    println!("== 4. KVP sharded decode == monolithic (runtime orchestration) ==");
    let spec = engine.spec;
    let row = spec.hkv * spec.d_head;
    let mut rng = Rng::new(42);
    let mut gen = |len: usize| -> Vec<f32> {
        (0..len).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect()
    };
    let q = gen(spec.hq * spec.d_head);
    let k = gen(1024 * row);
    let v = gen(1024 * row);
    let mono = engine.monolithic_decode_attention(&q, &k, &v, 1000, 1024)?;
    let shard = engine.kvp_decode_attention(&q, &k, &v, 1000, 512, 2)?;
    let max_err = mono
        .iter()
        .zip(&shard)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("   max |mono - sharded| = {max_err:.2e} (2 shards x 512)");
    anyhow::ensure!(max_err < 2e-5, "KVP mismatch");
    println!("   PASS\n");

    println!("all end-to-end checks passed.");
    Ok(())
}
