//! Mixed batching deep-dive: adaptive vs static chunking under a mixed
//! workload (the paper's Fig. 8 Pareto story), plus the page-table
//! delta-update ablation from section 5.
//!
//! Run: `cargo run --release --example mixed_batching [--ctx 1M] [--decodes 8]`

use medha::config::DeploymentConfig;
use medha::kvcache::{BlockPool, KvManager};
use medha::sim::{SimOptions, Simulation};
use medha::util::args::Args;
use medha::util::stats::{fmt_duration, fmt_tokens};
use medha::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[], false);
    let ctx = args.u64_or("ctx", 1_000_000);
    let n_decodes = args.usize_or("decodes", 8);

    println!(
        "mixed workload: one {} prefill + {n_decodes} decoding requests (Llama-3 8B, tp=8)",
        fmt_tokens(ctx)
    );
    println!("\n{:<18} {:>12} {:>14} {:>12}", "chunk policy", "TTFT", "P95 TBT", "TBT SLO");

    let run = |adaptive: bool, chunk: u64| -> (f64, f64, bool) {
        let mut dep = DeploymentConfig::llama3_8b_tp8();
        dep.scheduler.adaptive_chunking = adaptive;
        dep.scheduler.static_chunk = chunk;
        let slo_tbt = dep.slo.tbt_s;
        let w = workload::long_plus_decodes(ctx, n_decodes, 1_000, 2_000);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        sim.run();
        let ttft = sim.request(0).unwrap().ttft().unwrap();
        let p95 = sim.metrics.tbt.p95();
        (ttft, p95, p95 <= slo_tbt)
    };

    for &c in &[32u64, 128, 512, 2048, 4096] {
        let (ttft, p95, ok) = run(false, c);
        println!(
            "{:<18} {:>12} {:>14} {:>12}",
            format!("static {c}"),
            fmt_duration(ttft),
            fmt_duration(p95),
            if ok { "met" } else { "MISSED" }
        );
    }
    let (ttft, p95, ok) = run(true, 0);
    println!(
        "{:<18} {:>12} {:>14} {:>12}",
        "adaptive",
        fmt_duration(ttft),
        fmt_duration(p95),
        if ok { "met" } else { "MISSED" }
    );

    // ---- section 5 ablation: page-table delta updates -------------------
    println!("\npage-table shipping over a {} prefill (section 5 ablation):", fmt_tokens(ctx));
    let mut kv = KvManager::new(BlockPool::new(16, ctx / 16 + 1));
    kv.onboard(0);
    let chunk = 2048;
    let mut done = 0;
    while done < ctx {
        let c = chunk.min(ctx - done);
        kv.append(0, c).unwrap();
        kv.account_table_shipment(&[0]);
        done += c;
    }
    let delta_mb = kv.delta_entries_shipped as f64 * 8.0 / 1e6;
    let full_mb = kv.full_entries_shipped as f64 * 8.0 / 1e6;
    println!("  delta updates (Medha):   {delta_mb:>10.1} MB shipped");
    println!("  full copies (baseline):  {full_mb:>10.1} MB shipped");
    println!("  reduction: {:.0}x", full_mb / delta_mb);
    Ok(())
}
