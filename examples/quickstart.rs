//! Quickstart: the 60-second tour.
//!
//! 1. Load the AOT artifacts and generate text with the tiny real model on
//!    the CPU PJRT runtime (chunked prefill + greedy decode).
//! 2. Ask the perf model a deployment question (what does a 1M-token
//!    request cost on a DGX-H100 fleet?).
//!
//! Run: `cargo run --release --example quickstart`
//! (needs `make artifacts` first; skips step 1 gracefully if missing)

use medha::config::DeploymentConfig;
use medha::engine::{detokenize, tokenize, Engine};
use medha::perfmodel::PerfModel;
use medha::util::stats::fmt_duration;

fn main() -> anyhow::Result<()> {
    // --- 1. real model on CPU PJRT -------------------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        println!("== serving the tiny real model (CPU PJRT) ==");
        let engine = Engine::load("artifacts", 8)?;
        let prompt = "Attention is all";
        let t0 = std::time::Instant::now();
        let out = engine.generate(&tokenize(prompt), 16, 64)?;
        println!("prompt:    {prompt:?}");
        println!("generated: {:?}", detokenize(&out));
        println!("({} tokens in {})\n", out.len(), fmt_duration(t0.elapsed().as_secs_f64()));
    } else {
        println!("(artifacts/ not built — run `make artifacts` for the real-model demo)\n");
    }

    // --- 2. deployment planning with the perf model ---------------------
    println!("== planning a 1M-token deployment (Llama-3 8B) ==");
    for (tp, spp, kvp) in [(8, 1, 1), (8, 4, 1), (8, 4, 4)] {
        let dep = DeploymentConfig::llama3_8b_tp8().with_parallel(tp, spp, kvp);
        dep.validate()?;
        let pm = PerfModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
        let ctx = 1_000_000;
        println!(
            "  {:<16} {:>4} GPUs: TTFT {:>8}, TBT {:>8}, fits: {}",
            dep.parallel.label(),
            dep.total_gpus(),
            fmt_duration(pm.prefill_time_spp(ctx, 4096)),
            fmt_duration(pm.decode_tbt(ctx)),
            pm.fits_memory(ctx)
        );
    }
    println!("\nnext: `medha reproduce --figure all`, `cargo run --release --example serve_e2e`");
    Ok(())
}
