"""L2: GQA Llama-style transformer in JAX, built on the L1 Pallas kernels.

This is the *compile-path* model. `aot.py` lowers the entry points below to
HLO text once; the Rust engine (rust/src/engine) loads the artifacts and owns
all serving-time state (KV caches live as PJRT buffers managed from Rust).

The model is deliberately pipeline-stage-shaped: the transformer is split
into stages of `layers_per_stage` layers, and `stage_forward` is the unit the
Rust SPP scheduler executes — chunk i+1 can enter stage 0 while chunk i is in
stage 1, which is exactly the paper's Sequence Pipeline Parallelism (Fig. 9b).

Entry points (all static-shape, AOT-lowered per chunk-size bucket):
  embed(tokens[C], emb[V,D])                       -> h[C, D]
  stage_forward(h[C,D], ck, cv, start, *weights)   -> (h', ck', cv')
  lm_head(h[C,D], norm_w[D], emb[V,D])             -> logits[C, V]
  kvp_partial(q, k_shard, v_shard, qs, ss, sl)     -> (o, m, l)
  kvp_merge(os, ms, ls)                            -> o

Weight values are inputs (not baked constants) so artifacts stay small and
Rust can keep weights resident as device buffers across calls.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.chunked_prefill import chunked_prefill_attention
from .kernels.kvp import kvp_merge, kvp_partial_attention


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Architecture hyper-parameters (mirrored by rust/src/config presets)."""

    vocab: int = 256  # byte-level tokenizer
    d_model: int = 512
    n_layers: int = 8
    hq: int = 8
    hkv: int = 2  # GQA group of 4, like Llama-3's 8:1 shape scaled down
    d_head: int = 64
    d_ff: int = 1408
    rope_theta: float = 10000.0
    max_seq: int = 2048
    norm_eps: float = 1e-5

    @property
    def params_per_layer(self) -> int:
        dm, dh = self.d_model, self.d_head
        return (
            dm * self.hq * dh  # wq
            + 2 * dm * self.hkv * dh  # wk, wv
            + self.hq * dh * dm  # wo
            + 3 * dm * self.d_ff  # gate, up, down
            + 2 * dm  # two rmsnorm gains
        )

    @property
    def n_params(self) -> int:
        return self.vocab * self.d_model + self.n_layers * self.params_per_layer + self.d_model


# Canonical per-layer weight order — MUST match rust/src/engine/weights.rs.
LAYER_WEIGHT_NAMES = (
    "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down",
)


def layer_weight_shapes(spec: ModelSpec):
    dm, dh, hq, hkv, ff = spec.d_model, spec.d_head, spec.hq, spec.hkv, spec.d_ff
    return {
        "attn_norm": (dm,),
        "wq": (dm, hq * dh),
        "wk": (dm, hkv * dh),
        "wv": (dm, hkv * dh),
        "wo": (hq * dh, dm),
        "mlp_norm": (dm,),
        "w_gate": (dm, ff),
        "w_up": (dm, ff),
        "w_down": (ff, dm),
    }


def init_params(spec: ModelSpec, seed: int = 0):
    """Deterministic random init (scaled normal) — the 'small real model'."""
    key = jax.random.PRNGKey(seed)
    shapes = layer_weight_shapes(spec)
    params = {"embed": None, "final_norm": jnp.ones((spec.d_model,), jnp.float32), "layers": []}
    key, sub = jax.random.split(key)
    params["embed"] = (jax.random.normal(sub, (spec.vocab, spec.d_model)) * 0.02).astype(jnp.float32)
    for _ in range(spec.n_layers):
        layer = {}
        for name in LAYER_WEIGHT_NAMES:
            key, sub = jax.random.split(key)
            shp = shapes[name]
            if name.endswith("norm"):
                layer[name] = jnp.ones(shp, jnp.float32)
            else:
                scale = 0.02 if name != "wo" and name != "w_down" else 0.02 / (2 * spec.n_layers) ** 0.5
                layer[name] = (jax.random.normal(sub, shp) * scale).astype(jnp.float32)
        params["layers"].append(layer)
    return params


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x [n, h, d] at absolute `positions` [n]."""
    n, h, d = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [n, half]
    cos = jnp.cos(angles)[:, None, :]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(q, k_cache, v_cache, q_start, kv_len, use_kernel, spec):
    if use_kernel:
        # Perf (EXPERIMENTS.md §Perf L1): grid-step count dominates
        # interpret-mode CPU latency, so use the largest tiles the shapes
        # allow. block_k=512 (vs the 128 default) quarters the KV grid and
        # is still VMEM-trivial on real TPU (256 KiB/operand block);
        # decode (c=1) uses a single full-cache KV tile; block_q=64 merges
        # prefill query blocks (64x64 q-tile).
        c = q.shape[0]
        block_k = spec.max_seq if c == 1 else 512
        return chunked_prefill_attention(
            q, k_cache, v_cache, q_start, kv_len,
            block_q=min(64, c), block_k=block_k,
        )
    return kref.attention_ref(q, k_cache, v_cache, q_start, kv_len)


def layer_forward(h, ck, cv, start, lw, spec: ModelSpec, use_kernel: bool = True):
    """One transformer layer over a chunk.

    h [C, D]; ck, cv [M, hkv, dh] this layer's cache; start = global position
    of h[0]. Returns (h', ck', cv') with the chunk's K/V written at
    [start, start+C).
    """
    c = h.shape[0]
    positions = start + jnp.arange(c)
    x = rmsnorm(h, lw["attn_norm"], spec.norm_eps)
    q = (x @ lw["wq"]).reshape(c, spec.hq, spec.d_head)
    k = (x @ lw["wk"]).reshape(c, spec.hkv, spec.d_head)
    v = (x @ lw["wv"]).reshape(c, spec.hkv, spec.d_head)
    q = rope(q, positions, spec.rope_theta)
    k = rope(k, positions, spec.rope_theta)
    ck = jax.lax.dynamic_update_slice(ck, k, (start, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v, (start, 0, 0))
    attn = _attention(q, ck, cv, start, start + c, use_kernel, spec)
    h = h + attn.reshape(c, spec.hq * spec.d_head) @ lw["wo"]
    x = rmsnorm(h, lw["mlp_norm"], spec.norm_eps)
    h = h + (jax.nn.silu(x @ lw["w_gate"]) * (x @ lw["w_up"])) @ lw["w_down"]
    return h, ck, cv


def embed(tokens: jnp.ndarray, emb: jnp.ndarray) -> jnp.ndarray:
    """tokens [C] i32 -> h [C, D]."""
    return emb[tokens]


def stage_forward(h, ck, cv, start, layer_weights, spec: ModelSpec, use_kernel: bool = True):
    """Run `len(layer_weights)` layers over a chunk.

    ck, cv: [Lps, M, hkv, dh] — this stage's slice of the KV cache.
    """
    n = len(layer_weights)
    cks, cvs = [], []
    for i in range(n):
        h, cki, cvi = layer_forward(h, ck[i], cv[i], start, layer_weights[i], spec, use_kernel)
        cks.append(cki)
        cvs.append(cvi)
    return h, jnp.stack(cks), jnp.stack(cvs)


def lm_head(h: jnp.ndarray, norm_w: jnp.ndarray, emb: jnp.ndarray, spec: ModelSpec) -> jnp.ndarray:
    """h [C, D] -> logits [C, V] (tied embedding)."""
    return rmsnorm(h, norm_w, spec.norm_eps) @ emb.T


# ---------------------------------------------------------------------------
# Full-model reference paths (used by tests and to produce golden outputs for
# the Rust end-to-end check; never AOT-compiled).
# ---------------------------------------------------------------------------

def empty_cache(spec: ModelSpec):
    shape = (spec.n_layers, spec.max_seq, spec.hkv, spec.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def forward_chunk(params, tokens, ck, cv, start, spec: ModelSpec, use_kernel=True):
    """Full model over one chunk: returns (logits [C, V], ck', cv')."""
    h = embed(tokens, params["embed"])
    h, ck, cv = stage_forward(h, ck, cv, start, params["layers"], spec, use_kernel)
    return lm_head(h, params["final_norm"], params["embed"], spec), ck, cv


def generate_greedy(params, prompt, n_new, spec: ModelSpec, chunk_size=16, use_kernel=False):
    """Chunked prefill + greedy decode; the golden path for the Rust e2e test."""
    ck, cv = empty_cache(spec)
    pos = 0
    logits = None
    prompt = jnp.asarray(prompt, jnp.int32)
    while pos < len(prompt):
        c = min(chunk_size, len(prompt) - pos)
        logits, ck, cv = forward_chunk(params, prompt[pos:pos + c], ck, cv, pos, spec, use_kernel)
        pos += c
    out = []
    tok = jnp.argmax(logits[-1]).astype(jnp.int32)
    for _ in range(n_new):
        out.append(int(tok))
        logits, ck, cv = forward_chunk(params, tok[None], ck, cv, pos, spec, use_kernel)
        pos += 1
        tok = jnp.argmax(logits[-1]).astype(jnp.int32)
    return out


# Re-exports for aot.py
__all__ = [
    "ModelSpec", "LAYER_WEIGHT_NAMES", "layer_weight_shapes", "init_params",
    "embed", "stage_forward", "lm_head", "forward_chunk", "generate_greedy",
    "empty_cache", "kvp_partial_attention", "kvp_merge",
]
