"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Everything here is deliberately written in the most direct way possible —
full materialized attention matrices, explicit masks — so that the Pallas
kernels (flash-style, tiled, online-softmax) can be validated against an
implementation whose correctness is obvious.

Conventions (shared with the kernels and the L2 model):
  * q        : [nq, hq, d]     query chunk (hq query heads)
  * k, v     : [nkv, hkv, d]   KV cache (hkv KV heads; GQA group = hq // hkv)
  * q_start  : global position of q[0] in the sequence (chunked prefill)
  * kv_len   : number of *valid* rows in k/v (the rest is padding)
Causal rule: query at global position p attends to KV positions <= p.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = float("-inf")


def _expand_gqa(x: jnp.ndarray, hq: int) -> jnp.ndarray:
    """[nkv, hkv, d] -> [nkv, hq, d] by repeating each KV head hq//hkv times."""
    nkv, hkv, d = x.shape
    assert hq % hkv == 0, f"hq={hq} not divisible by hkv={hkv}"
    group = hq // hkv
    return jnp.repeat(x, group, axis=1)


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_start,
    kv_len,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    """Causal GQA attention of a query chunk against a (padded) KV cache.

    Returns [nq, hq, d].
    """
    nq, hq, d = q.shape
    nkv = k.shape[0]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kx = _expand_gqa(k, hq)  # [nkv, hq, d]
    vx = _expand_gqa(v, hq)
    # scores[h, i, j]
    scores = jnp.einsum("ihd,jhd->hij", q, kx) * sm_scale
    q_pos = q_start + jnp.arange(nq)[:, None]  # [nq, 1]
    kv_pos = jnp.arange(nkv)[None, :]  # [1, nkv]
    mask = (kv_pos <= q_pos) & (kv_pos < kv_len)  # [nq, nkv]
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("hij,jhd->ihd", probs, vx)
    return out


def partial_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_start,
    shard_start,
    shard_len,
    sm_scale: float | None = None,
):
    """KVP partial attention over one KV shard.

    The shard holds KV positions [shard_start, shard_start + shard_len) of the
    global sequence (k/v may be padded beyond shard_len). Returns the
    *locally normalized* output together with the online-softmax statistics
    needed to merge shards:

      o : [nq, hq, d]  softmax(local scores) @ V   (normalized by local l)
      m : [nq, hq]     local max score (NEG_INF where shard fully masked)
      l : [nq, hq]     local sum of exp(score - m)
    """
    nq, hq, d = q.shape
    nkv = k.shape[0]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kx = _expand_gqa(k, hq)
    vx = _expand_gqa(v, hq)
    scores = jnp.einsum("ihd,jhd->hij", q, kx) * sm_scale
    q_pos = q_start + jnp.arange(nq)[:, None]
    kv_pos = shard_start + jnp.arange(nkv)[None, :]
    local = jnp.arange(nkv)[None, :]
    mask = (kv_pos <= q_pos) & (local < shard_len)
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)  # [hq, nq]
    # Guard fully-masked rows: exp(-inf - -inf) would be NaN.
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(scores - safe_m[:, :, None])
    p = jnp.where(mask[None, :, :], p, 0.0)
    l = jnp.sum(p, axis=-1)  # [hq, nq]
    o = jnp.einsum("hij,jhd->ihd", p, vx)
    denom = jnp.where(l == 0.0, 1.0, l)
    o = o / denom.T[:, :, None]
    return o, m.T, l.T  # [nq,hq,d], [nq,hq], [nq,hq]


def merge_partials_ref(os_, ms, ls):
    """Merge KVP shard partials with online softmax.

    os_ : [S, nq, hq, d]  locally-normalized partial outputs
    ms  : [S, nq, hq]     local maxima
    ls  : [S, nq, hq]     local exp-sums
    Returns [nq, hq, d] — identical to monolithic softmax attention.
    """
    m_glob = jnp.max(ms, axis=0)  # [nq, hq]
    safe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
    w = jnp.exp(jnp.where(jnp.isfinite(ms), ms, NEG_INF) - safe[None]) * ls
    denom = jnp.sum(w, axis=0)  # [nq, hq]
    denom = jnp.where(denom == 0.0, 1.0, denom)
    out = jnp.sum(os_ * w[..., None], axis=0) / denom[..., None]
    return out


def decode_attention_ref(q, k, v, kv_len, sm_scale=None):
    """Single-token decode attention: q [nq, hq, d] over kv_len valid rows."""
    nq = q.shape[0]
    return attention_ref(q, k, v, q_start=kv_len - nq, kv_len=kv_len, sm_scale=sm_scale)
