"""Decode attention — single-token query over a long (padded) KV cache.

Decode is the memory-bound phase (paper section 3.1): one query token scans
the whole prefix. Structurally this is the KVP partial kernel with a single
shard covering the full cache; the KV-tile grid axis is the FlashDecoding
"parallelize over KV" dimension that keeps long-context decode efficient.
"""

from __future__ import annotations

import jax.numpy as jnp

from .flash import flash_attention


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kv_len,
    *,
    sm_scale: float | None = None,
    block_k: int = 128,
) -> jnp.ndarray:
    """q [nq, hq, d] (the trailing nq tokens of the sequence), k/v padded.

    kv_len counts the valid KV rows *including* the query tokens' own
    entries. Returns [nq, hq, d].
    """
    nq = q.shape[0]
    o, _, _ = flash_attention(
        q, k, v, kv_len - nq, 0, kv_len,
        sm_scale=sm_scale, block_q=min(16, nq), block_k=block_k,
    )
    return o
