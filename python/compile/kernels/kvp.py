"""KV-cache parallelism (KVP) kernels — paper section 4.4.

KVP shards the KV cache of a single long request across worker groups along
the sequence dimension. Each worker computes *partial* attention of the
(replicated) query against its local shard, emitting the online-softmax
statistics (m, l); the coordinator merges the partials exactly. The merge
communication volume depends only on the number of query tokens — never on
the context length — which is what bounds TBT for multi-million contexts.

`kvp_partial_attention` runs on each shard; `kvp_merge` combines shard
outputs. Both are Pallas kernels validated against kernels/ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .flash import flash_attention

NEG_INF = -1e30


def kvp_partial_attention(
    q: jnp.ndarray,
    k_shard: jnp.ndarray,
    v_shard: jnp.ndarray,
    q_start,
    shard_start,
    shard_len,
    *,
    sm_scale: float | None = None,
    block_q: int = 16,
    block_k: int = 128,
):
    """Partial attention of q against one KV shard.

    q : [nq, hq, d] replicated query tokens (global positions q_start + i).
    k_shard, v_shard : [shard_cap, hkv, d]; rows [0, shard_len) hold global
        KV positions [shard_start, shard_start + shard_len).
    Returns (o [nq, hq, d] locally normalized, m [nq, hq], l [nq, hq]).
    """
    return flash_attention(
        q, k_shard, v_shard, q_start, shard_start, shard_len,
        sm_scale=sm_scale, block_q=block_q, block_k=block_k,
    )


def _merge_kernel(o_ref, m_ref, l_ref, out_ref, *, num_shards: int):
    """Single-block merge: refs hold the full [S, nq, hq(,d)] arrays."""
    m = m_ref[...]  # [S, nq, hq]
    l = l_ref[...]
    o = o_ref[...]  # [S, nq, hq, d]
    m_glob = jnp.max(m, axis=0)  # [nq, hq]
    w = jnp.exp(m - m_glob[None]) * l  # [S, nq, hq]; exp(NEG_INF-m)=0 for dead shards
    denom = jnp.sum(w, axis=0)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    out_ref[...] = jnp.sum(o * w[..., None], axis=0) / denom[..., None]


def kvp_merge(os_: jnp.ndarray, ms: jnp.ndarray, ls: jnp.ndarray) -> jnp.ndarray:
    """Merge S shard partials: os_ [S, nq, hq, d], ms/ls [S, nq, hq].

    Exactly reproduces monolithic softmax attention (ref.merge_partials_ref).
    The payload per shard is O(nq * hq * d) — independent of context length.
    """
    s, nq, hq, d = os_.shape
    kernel = functools.partial(_merge_kernel, num_shards=s)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((nq, hq, d), jnp.float32),
        interpret=True,
    )(os_.astype(jnp.float32), ms.astype(jnp.float32), ls.astype(jnp.float32))
