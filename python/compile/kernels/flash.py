"""Core Pallas flash-attention kernel with a 2D (query-block x KV-block) grid.

This is the paper's L1 hot-spot, adapted from CUDA/FlashInfer to the TPU
Pallas model (DESIGN.md `Hardware-Adaptation`):

  * the grid axis over KV tiles is the TPU analogue of FlashDecoding's
    "parallelize across KV tokens" — it is what makes *chunked prefill*
    efficient when the query chunk is tiny but the KV prefix is huge
    (paper section 4.1, Fig. 7);
  * BlockSpecs express the HBM->VMEM staging the paper obtains with CUDA
    threadblock tiling;
  * online softmax (Milakov & Gimelshein) carries (m, l) across KV tiles,
    and the same (m, l) statistics are exported so KV-parallel (KVP) shards
    can be merged exactly (paper section 4.4).

The kernel is always lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers to plain HLO
that the Rust runtime can run. Real-TPU efficiency is estimated structurally
in DESIGN.md / EXPERIMENTS.md section Perf.

Shapes (kernel-internal layout is head-major; wrappers transpose):
  q : [hq, nq, d]      k, v : [hkv, nkv, d]
  scalars (passed as (1,1) i32 arrays): q_start, kv_offset, kv_valid
Returns (o [hq, nq, d], m [hq, nq], l [hq, nq]) where o is locally
normalized and (m, l) are the online-softmax statistics over this KV range.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # finite stand-in for -inf: keeps exp() exact-zero and avoids NaN


def _flash_kernel(
    q_start_ref,
    kv_offset_ref,
    kv_valid_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    *,
    sm_scale: float,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
):
    _, i, j = (
        pl.program_id(0),
        pl.program_id(1),
        pl.program_id(2),
    )
    q_start = q_start_ref[0, 0]
    kv_offset = kv_offset_ref[0, 0]
    kv_valid = kv_valid_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Global positions of this tile's queries and keys.
    q_pos = q_start + i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kv_local = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    kv_pos = kv_offset + kv_local

    # Causal skip: if every key in the tile is beyond every query, do nothing.
    tile_live = (kv_offset + j * block_k) <= (q_start + i * block_q + block_q - 1)

    @pl.when(tile_live)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # [block_q, d]
        k = k_ref[0].astype(jnp.float32)  # [block_k, d]
        v = v_ref[0].astype(jnp.float32)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [block_q, block_k]
        mask = (kv_pos <= q_pos) & (kv_local < kv_valid)
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_ref[0]  # [block_q]
        l_prev = l_ref[0]
        m_cur = jnp.max(scores, axis=-1)  # [block_q]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # exp(NEG_INF - NEG_INF) = 1, but l_prev = 0 there
        p = jnp.exp(scores - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = o_ref[0] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        o_ref[0] = acc
        m_ref[0] = m_new
        l_ref[0] = l_new

    # Final KV tile: normalize the accumulator by l (guard empty rows).
    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = o_ref[0] / denom[:, None]


def flash_attention_hmajor(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_start: jnp.ndarray,
    kv_offset: jnp.ndarray,
    kv_valid: jnp.ndarray,
    *,
    sm_scale: float | None = None,
    block_q: int = 16,
    block_k: int = 128,
):
    """Head-major flash attention; see module docstring for semantics.

    q [hq, nq, d], k/v [hkv, nkv, d]; nq % block_q == 0, nkv % block_k == 0
    (use the `chunked_prefill` / `kvp` wrappers for padding + layout).
    Scalars may be Python ints or i32 arrays; they are reshaped to (1, 1).
    """
    hq, nq, d = q.shape
    hkv, nkv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    assert nq % block_q == 0, f"nq={nq} % block_q={block_q}"
    assert nkv % block_k == 0, f"nkv={nkv} % block_k={block_k}"
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    num_q_blocks = nq // block_q
    num_kv_blocks = nkv // block_k

    def scal(x):
        return jnp.asarray(x, jnp.int32).reshape(1, 1)

    grid = (hq, num_q_blocks, num_kv_blocks)
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=float(sm_scale),
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=num_kv_blocks,
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, i, j: (0, 0)),  # q_start
            pl.BlockSpec((1, 1), lambda h, i, j: (0, 0)),  # kv_offset
            pl.BlockSpec((1, 1), lambda h, i, j: (0, 0)),  # kv_valid
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j: (h // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, block_q), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((hq, nq, d), jnp.float32),
            jax.ShapeDtypeStruct((hq, nq), jnp.float32),
            jax.ShapeDtypeStruct((hq, nq), jnp.float32),
        ],
        interpret=True,
    )(scal(q_start), scal(kv_offset), scal(kv_valid), q, k, v)
    return o, m, l


def _pad_axis(x, axis, multiple):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_start,
    kv_offset,
    kv_valid,
    *,
    sm_scale: float | None = None,
    block_q: int = 16,
    block_k: int = 128,
):
    """Sequence-major convenience wrapper.

    q [nq, hq, d]; k, v [nkv, hkv, d]. Pads nq/nkv up to the block sizes,
    transposes to head-major, runs the kernel, and slices the padding off.
    Returns (o [nq, hq, d], m [nq, hq], l [nq, hq]).
    """
    nq = q.shape[0]
    block_q = min(block_q, max(1, nq)) if nq < block_q else block_q
    qh = jnp.transpose(q, (1, 0, 2))
    kh = jnp.transpose(k, (1, 0, 2))
    vh = jnp.transpose(v, (1, 0, 2))
    qh, _ = _pad_axis(qh, 1, block_q)
    kh, _ = _pad_axis(kh, 1, block_k)
    vh, _ = _pad_axis(vh, 1, block_k)
    # Padded queries are harmless (extra rows are discarded); padded KV rows
    # are masked out because kv_valid only covers real rows.
    o, m, l = flash_attention_hmajor(
        qh, kh, vh, q_start, kv_offset, kv_valid,
        sm_scale=sm_scale, block_q=block_q, block_k=block_k,
    )
    o = jnp.transpose(o, (1, 0, 2))[:nq]
    m = jnp.transpose(m, (1, 0))[:nq]
    l = jnp.transpose(l, (1, 0))[:nq]
    return o, m, l
