"""Chunked-prefill attention (paper section 4.1-4.2) on top of the flash kernel.

A prefill *chunk* of C query tokens attends to the whole KV prefix computed
so far (which already includes the chunk's own K/V). The paper's key insight
is that the arithmetic intensity of this operation depends only on C (Eq. 7),
so even tiny chunks stay compute-bound — this kernel is the code path that
makes that true, by parallelizing over both query and KV tiles.
"""

from __future__ import annotations

import jax.numpy as jnp

from .flash import flash_attention


def chunked_prefill_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_start,
    kv_len,
    *,
    sm_scale: float | None = None,
    block_q: int = 16,
    block_k: int = 128,
) -> jnp.ndarray:
    """Causal GQA attention of one prefill chunk against the KV prefix.

    q : [C, hq, d] chunk queries; q[i] sits at global position q_start + i.
    k, v : [max_kv, hkv, d] padded KV cache; rows [0, kv_len) are valid and
        must already contain this chunk's keys/values
        (kv_len >= q_start + C).
    Returns [C, hq, d].
    """
    o, _, _ = flash_attention(
        q, k, v, q_start, 0, kv_len,
        sm_scale=sm_scale, block_q=block_q, block_k=block_k,
    )
    return o
