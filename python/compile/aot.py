"""AOT compiler: lower every L2 entry point to HLO text for the Rust runtime.

Run once at build time (`make artifacts`). Python never runs at serve time.

Interchange format is **HLO text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under --out-dir (default ../artifacts):
  <entry>.hlo.txt        one per entry point / shape bucket
  weights.bin            all model weights, f32 LE, concatenated
  manifest.json          entry signatures, weight table, model spec, golden
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# Chunk-size buckets the Rust adaptive-chunking policy can schedule.
CHUNK_BUCKETS = (1, 16, 64, 256)
# Layers-per-stage buckets -> SPP degrees {1, 2, 4} for an 8-layer model.
STAGE_BUCKETS = (8, 4, 2)
# KVP shard capacities (rows) and shard counts for the merge entry.
KVP_SHARD_CAPS = (512, 1024)
KVP_MERGE_COUNTS = (2, 4)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


def _sig(args):
    return [{"shape": list(a.shape), "dtype": _dtype_name(a.dtype)} for a in args]


class Emitter:
    def __init__(self, out_dir: str, spec: M.ModelSpec):
        self.out_dir = out_dir
        self.spec = spec
        self.entries = {}

    def emit(self, name: str, fn, example_args, outputs_doc: str):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.entries[name] = {
            "file": fname,
            "inputs": _sig(example_args),
            "doc": outputs_doc,
        }
        print(f"  {name:28s} {len(text)/1e6:6.2f} MB  ({time.time()-t0:.1f}s)")


def shape_struct(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def emit_entries(em: Emitter):
    spec = em.spec
    V, D, dh, hq, hkv, Mx = spec.vocab, spec.d_model, spec.d_head, spec.hq, spec.hkv, spec.max_seq
    f32, i32 = jnp.float32, jnp.int32

    for c in CHUNK_BUCKETS:
        em.emit(
            f"embed_c{c}",
            lambda tokens, emb: (M.embed(tokens, emb),),
            (shape_struct((c,), i32), shape_struct((V, D))),
            "h[C,D]",
        )
        em.emit(
            f"lm_head_c{c}",
            lambda h, norm_w, emb: (M.lm_head(h, norm_w, emb, spec),),
            (shape_struct((c, D)), shape_struct((D,)), shape_struct((V, D))),
            "logits[C,V]",
        )

    lw_shapes = M.layer_weight_shapes(spec)

    for lps in STAGE_BUCKETS:
        for c in CHUNK_BUCKETS:
            def stage_fn(h, ck, cv, start, *flat, _lps=lps):
                lws = []
                per = len(M.LAYER_WEIGHT_NAMES)
                for i in range(_lps):
                    lws.append(dict(zip(M.LAYER_WEIGHT_NAMES, flat[i * per:(i + 1) * per])))
                h, ck, cv = M.stage_forward(h, ck, cv, start[0], lws, spec, use_kernel=True)
                return h, ck, cv

            weight_args = []
            for _ in range(lps):
                for nm in M.LAYER_WEIGHT_NAMES:
                    weight_args.append(shape_struct(lw_shapes[nm]))
            em.emit(
                f"stage_c{c}_l{lps}",
                stage_fn,
                (
                    shape_struct((c, D)),
                    shape_struct((lps, Mx, hkv, dh)),
                    shape_struct((lps, Mx, hkv, dh)),
                    shape_struct((1,), i32),
                    *weight_args,
                ),
                "(h'[C,D], ck'[Lps,M,hkv,dh], cv')",
            )

    # KVP attention-level entries (decode path: C=1 replicated query).
    for cap in KVP_SHARD_CAPS:
        em.emit(
            f"kvp_partial_c1_s{cap}",
            lambda q, k, v, qs, ss, sl: M.kvp_partial_attention(
                q, k, v, qs[0], ss[0], sl[0], block_k=512
            ),
            (
                shape_struct((1, hq, dh)),
                shape_struct((cap, hkv, dh)),
                shape_struct((cap, hkv, dh)),
                shape_struct((1,), i32),
                shape_struct((1,), i32),
                shape_struct((1,), i32),
            ),
            "(o[1,hq,dh], m[1,hq], l[1,hq])",
        )
    for s in KVP_MERGE_COUNTS:
        em.emit(
            f"kvp_merge_s{s}_c1",
            lambda os_, ms, ls: (M.kvp_merge(os_, ms, ls),),
            (
                shape_struct((s, 1, hq, dh)),
                shape_struct((s, 1, hq)),
                shape_struct((s, 1, hq)),
            ),
            "o[1,hq,dh]",
        )


def flatten_weights(params, spec: M.ModelSpec):
    """Canonical flat weight order — MUST match rust/src/engine/weights.rs."""
    tensors = [("embed", params["embed"]), ("final_norm", params["final_norm"])]
    for i, layer in enumerate(params["layers"]):
        for nm in M.LAYER_WEIGHT_NAMES:
            tensors.append((f"layers.{i}.{nm}", layer[nm]))
    return tensors


def write_weights(out_dir: str, params, spec: M.ModelSpec):
    tensors = flatten_weights(params, spec)
    table = []
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name, t in tensors:
            arr = np.asarray(t, dtype="<f4")
            data = arr.tobytes()
            table.append({
                "name": name, "shape": list(arr.shape),
                "offset": offset, "size": len(data),
            })
            f.write(data)
            offset += len(data)
    return table


def golden_generation(params, spec: M.ModelSpec):
    prompt = list(b"The quadratic cost of attention ")
    t0 = time.time()
    generated = M.generate_greedy(params, prompt, 24, spec, chunk_size=16, use_kernel=True)
    print(f"  golden generation ({len(generated)} tokens, {time.time()-t0:.1f}s)")
    return {"prompt": prompt, "chunk_size": 16, "generated": generated}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", "--out-dir", dest="out_dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    spec = M.ModelSpec()
    print(f"model: {spec.n_params/1e6:.1f}M params, {spec.n_layers} layers, "
          f"hq={spec.hq} hkv={spec.hkv} d={spec.d_model} max_seq={spec.max_seq}")
    em = Emitter(args.out_dir, spec)
    emit_entries(em)

    params = M.init_params(spec, args.seed)
    table = write_weights(args.out_dir, params, spec)
    golden = None if args.skip_golden else golden_generation(params, spec)

    manifest = {
        "spec": {
            "vocab": spec.vocab, "d_model": spec.d_model, "n_layers": spec.n_layers,
            "hq": spec.hq, "hkv": spec.hkv, "d_head": spec.d_head, "d_ff": spec.d_ff,
            "rope_theta": spec.rope_theta, "max_seq": spec.max_seq,
            "norm_eps": spec.norm_eps, "n_params": spec.n_params,
        },
        "chunk_buckets": list(CHUNK_BUCKETS),
        "stage_buckets": list(STAGE_BUCKETS),
        "kvp_shard_caps": list(KVP_SHARD_CAPS),
        "kvp_merge_counts": list(KVP_MERGE_COUNTS),
        "layer_weight_names": list(M.LAYER_WEIGHT_NAMES),
        "entries": em.entries,
        "weights": {"file": "weights.bin", "tensors": table},
        "golden": golden,
        "seed": args.seed,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(em.entries)} entries + weights + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
