"""AOT artifacts: manifest consistency and HLO round-trip sanity.

These tests run against ../artifacts if `make artifacts` has been executed;
otherwise they are skipped (the kernel/model tests above are the gating
correctness signal and never skip).
"""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built (run `make artifacts`)"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_all_entry_files_exist(manifest):
    for name, e in manifest["entries"].items():
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), f"missing artifact for {name}"
        assert os.path.getsize(path) > 100


def test_entry_coverage(manifest):
    """Every (bucket x stage) combination the Rust engine schedules exists."""
    names = set(manifest["entries"])
    for c in manifest["chunk_buckets"]:
        assert f"embed_c{c}" in names
        assert f"lm_head_c{c}" in names
        for lps in manifest["stage_buckets"]:
            assert f"stage_c{c}_l{lps}" in names
    for cap in manifest["kvp_shard_caps"]:
        assert f"kvp_partial_c1_s{cap}" in names
    for s in manifest["kvp_merge_counts"]:
        assert f"kvp_merge_s{s}_c1" in names


def test_stage_buckets_cover_model(manifest):
    n_layers = manifest["spec"]["n_layers"]
    for lps in manifest["stage_buckets"]:
        assert n_layers % lps == 0, "stage bucket must tile the layer stack"


def test_weights_table(manifest):
    wb = manifest["weights"]
    path = os.path.join(ART, wb["file"])
    total = os.path.getsize(path)
    end = 0
    for t in wb["tensors"]:
        assert t["offset"] == end, "weight table must be contiguous"
        assert t["size"] == int(np.prod(t["shape"])) * 4
        end = t["offset"] + t["size"]
    assert end == total
    # spec param count == bytes/4
    assert total // 4 == manifest["spec"]["n_params"]


def test_weight_order_matches_contract(manifest):
    """rust/src/engine/weights.rs depends on this exact order."""
    names = [t["name"] for t in manifest["weights"]["tensors"]]
    assert names[0] == "embed"
    assert names[1] == "final_norm"
    lw = manifest["layer_weight_names"]
    i = 2
    for layer in range(manifest["spec"]["n_layers"]):
        for nm in lw:
            assert names[i] == f"layers.{layer}.{nm}"
            i += 1
    assert i == len(names)


def test_stage_entry_signature(manifest):
    spec = manifest["spec"]
    e = manifest["entries"]["stage_c16_l2"]
    ins = e["inputs"]
    assert ins[0] == {"shape": [16, spec["d_model"]], "dtype": "f32"}
    assert ins[1]["shape"] == [2, spec["max_seq"], spec["hkv"], spec["d_head"]]
    assert ins[3] == {"shape": [1], "dtype": "i32"}
    assert len(ins) == 4 + 2 * len(manifest["layer_weight_names"])


def test_golden_generation_present(manifest):
    g = manifest["golden"]
    assert g is not None
    assert len(g["generated"]) >= 8
    assert all(0 <= t < manifest["spec"]["vocab"] for t in g["generated"])


def test_hlo_text_parseable_header(manifest):
    """HLO text must start with an HloModule header (what the Rust loader
    feeds HloModuleProto::from_text_file)."""
    for name, e in list(manifest["entries"].items())[:5]:
        with open(os.path.join(ART, e["file"])) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), name
