"""L1 correctness: flash kernel vs pure-jnp oracle (pytest + hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.chunked_prefill import chunked_prefill_attention
from compile.kernels.decode import decode_attention
from compile.kernels.flash import flash_attention

TOL = dict(rtol=2e-5, atol=2e-5)


def mk(nq, max_kv, hq, hkv, d, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((nq, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((max_kv, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((max_kv, hkv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("nq", [1, 3, 16, 33])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (8, 1)])
def test_flash_matches_ref_basic(nq, hq, hkv):
    d, max_kv = 32, 256
    q, k, v = mk(nq, max_kv, hq, hkv, d, seed=nq * 10 + hq)
    kv_len = 100 + nq
    q_start = kv_len - nq
    o, _, _ = flash_attention(q, k, v, q_start, 0, kv_len)
    o_ref = ref.attention_ref(q, k, v, q_start, kv_len)
    np.testing.assert_allclose(o, o_ref, **TOL)


@pytest.mark.parametrize("block_q,block_k", [(1, 32), (8, 64), (16, 128), (32, 256)])
def test_flash_block_shapes(block_q, block_k):
    q, k, v = mk(32, 512, 8, 2, 64, seed=7)
    o, _, _ = flash_attention(q, k, v, 200, 0, 232, block_q=block_q, block_k=block_k)
    o_ref = ref.attention_ref(q, k, v, 200, 232)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_first_chunk_at_origin():
    """q_start=0: each query i attends only to positions 0..i."""
    q, k, v = mk(16, 128, 8, 2, 32, seed=3)
    o, _, _ = flash_attention(q, k, v, 0, 0, 16)
    o_ref = ref.attention_ref(q, k, v, 0, 16)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_kv_len_masks_padding():
    """Garbage beyond kv_len must not affect the output."""
    q, k, v = mk(8, 256, 8, 2, 32, seed=4)
    kv_len = 64
    o1, _, _ = flash_attention(q, k, v, kv_len - 8, 0, kv_len)
    k2 = k.at[kv_len:].set(1e6)
    v2 = v.at[kv_len:].set(-1e6)
    o2, _, _ = flash_attention(q, k2, v2, kv_len - 8, 0, kv_len)
    np.testing.assert_allclose(o1, o2, rtol=0, atol=0)


def test_causality_future_kv_ignored():
    """Perturbing KV rows in (q_pos, kv_len) ... i.e. future rows for early
    queries ... must not change those queries' outputs."""
    nq, kv_len = 8, 40
    q, k, v = mk(nq, 128, 4, 2, 32, seed=5)
    q_start = kv_len - nq
    o1, _, _ = flash_attention(q, k, v, q_start, 0, kv_len)
    # Row kv_len-1 is visible only to the last query.
    k2 = k.at[kv_len - 1].add(3.0)
    o2, _, _ = flash_attention(q, k2, v, q_start, 0, kv_len)
    np.testing.assert_allclose(o1[:-1], o2[:-1], rtol=0, atol=0)
    assert not np.allclose(o1[-1], o2[-1])


def test_chunked_prefill_equals_monolithic():
    """Processing a prompt in chunks == processing it in one shot (Fig. 6)."""
    n, hq, hkv, d = 96, 8, 2, 32
    q, k, v = mk(n, n, hq, hkv, d, seed=6)
    mono = ref.attention_ref(q, k, v, 0, n)
    got = []
    for start in range(0, n, 32):
        got.append(chunked_prefill_attention(q[start:start + 32], k, v, start, start + 32))
    np.testing.assert_allclose(jnp.concatenate(got), mono, **TOL)


def test_decode_attention_wrapper():
    q, k, v = mk(1, 256, 8, 2, 64, seed=8)
    o = decode_attention(q, k, v, 200)
    o_ref = ref.decode_attention_ref(q, k, v, 200)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_scale_override():
    q, k, v = mk(4, 128, 4, 2, 32, seed=9)
    o, _, _ = flash_attention(q, k, v, 60, 0, 64, sm_scale=0.5)
    o_ref = ref.attention_ref(q, k, v, 60, 64, sm_scale=0.5)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_stats_match_ref_partials():
    q, k, v = mk(4, 128, 4, 2, 32, seed=10)
    o, m, l = flash_attention(q, k, v, 60, 0, 64)
    o_r, m_r, l_r = ref.partial_attention_ref(q, k, v, 60, 0, 64)
    np.testing.assert_allclose(o, o_r, **TOL)
    np.testing.assert_allclose(m, m_r, **TOL)
    np.testing.assert_allclose(l, l_r, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    nq=st.integers(1, 24),
    extra_kv=st.integers(0, 150),
    hq_group=st.sampled_from([(4, 4), (8, 2), (4, 1)]),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_flash_hypothesis_sweep(nq, extra_kv, hq_group, d, seed):
    """Randomized shape/position sweep: kernel == oracle everywhere."""
    hq, hkv = hq_group
    kv_len = nq + extra_kv
    max_kv = kv_len + (seed % 7)  # arbitrary padding
    q, k, v = mk(nq, max_kv, hq, hkv, d, seed=seed)
    q_start = kv_len - nq
    o, _, _ = flash_attention(q, k, v, q_start, 0, kv_len)
    o_ref = ref.attention_ref(q, k, v, q_start, kv_len)
    np.testing.assert_allclose(o, o_ref, rtol=5e-5, atol=5e-5)


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([np.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_flash_dtypes(dtype, seed):
    """bf16 inputs: kernel accumulates in f32; compare against f32 oracle
    at bf16-appropriate tolerance."""
    q, k, v = mk(8, 128, 8, 2, 32, seed=seed, dtype=np.float32)
    qd, kd, vd = (x.astype(dtype) for x in (q, k, v))
    o, _, _ = flash_attention(qd, kd, vd, 56, 0, 64)
    o_ref = ref.attention_ref(q, k, v, 56, 64)
    tol = 5e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32), o_ref, rtol=tol, atol=tol)
