"""KVP kernels: shard partials + online-softmax merge == monolithic attention."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.kvp import kvp_merge, kvp_partial_attention

TOL = dict(rtol=3e-5, atol=3e-5)


def mk(nq, max_kv, hq, hkv, d, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((nq, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((max_kv, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((max_kv, hkv, d)), jnp.float32)
    return q, k, v


def shard_and_merge(q, k, v, q_start, kv_len, shard_cap, n_shards):
    """The exact orchestration the Rust KVP manager performs."""
    parts = []
    for s in range(n_shards):
        lo = s * shard_cap
        ks, vs = k[lo:lo + shard_cap], v[lo:lo + shard_cap]
        slen = int(np.clip(kv_len - lo, 0, shard_cap))
        parts.append(kvp_partial_attention(q, ks, vs, q_start, lo, slen))
    os_ = jnp.stack([p[0] for p in parts])
    ms = jnp.stack([p[1] for p in parts])
    ls = jnp.stack([p[2] for p in parts])
    return kvp_merge(os_, ms, ls)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_kvp_equals_monolithic(n_shards):
    shard_cap = 128
    q, k, v = mk(1, shard_cap * n_shards, 8, 2, 64, seed=n_shards)
    kv_len = shard_cap * n_shards - 17
    o = shard_and_merge(q, k, v, kv_len - 1, kv_len, shard_cap, n_shards)
    o_ref = ref.attention_ref(q, k, v, kv_len - 1, kv_len)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_kvp_dead_shard():
    """A shard entirely beyond kv_len contributes nothing (dynamic onboarding:
    freshly added workers start empty)."""
    q, k, v = mk(1, 256, 8, 2, 32, seed=9)
    kv_len = 100  # shard 1 (rows 128..256) completely invalid
    o = shard_and_merge(q, k, v, kv_len - 1, kv_len, 128, 2)
    o_ref = ref.attention_ref(q, k, v, kv_len - 1, kv_len)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_kvp_multi_query_chunk():
    """KVP also applies to prefill chunks (paper Eq. 10)."""
    q, k, v = mk(16, 256, 8, 2, 32, seed=11)
    kv_len = 230
    o = shard_and_merge(q, k, v, kv_len - 16, kv_len, 128, 2)
    o_ref = ref.attention_ref(q, k, v, kv_len - 16, kv_len)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_merge_matches_ref_merge():
    q, k, v = mk(4, 256, 4, 2, 32, seed=12)
    parts = [
        kvp_partial_attention(q, k[:128], v[:128], 251, 0, 128),
        kvp_partial_attention(q, k[128:], v[128:], 251, 128, 124),
    ]
    os_ = jnp.stack([p[0] for p in parts])
    ms = jnp.stack([p[1] for p in parts])
    ls = jnp.stack([p[2] for p in parts])
    got = kvp_merge(os_, ms, ls)
    want = ref.merge_partials_ref(os_, ms, ls)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_merge_is_permutation_invariant():
    """Shard order must not matter — the coordinator may receive partials
    out of order."""
    q, k, v = mk(2, 256, 4, 2, 32, seed=13)
    parts = [
        kvp_partial_attention(q, k[:128], v[:128], 255, 0, 128),
        kvp_partial_attention(q, k[128:], v[128:], 255, 128, 128),
    ]
    fwd = kvp_merge(
        jnp.stack([parts[0][0], parts[1][0]]),
        jnp.stack([parts[0][1], parts[1][1]]),
        jnp.stack([parts[0][2], parts[1][2]]),
    )
    rev = kvp_merge(
        jnp.stack([parts[1][0], parts[0][0]]),
        jnp.stack([parts[1][1], parts[0][1]]),
        jnp.stack([parts[1][2], parts[0][2]]),
    )
    np.testing.assert_allclose(fwd, rev, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    kv_len=st.integers(1, 500),
    n_shards=st.integers(1, 4),
    nq=st.sampled_from([1, 2, 8]),
    seed=st.integers(0, 2**16),
)
def test_kvp_hypothesis_sweep(kv_len, n_shards, nq, seed):
    """Any (kv_len, shard count, query count): sharded == monolithic."""
    shard_cap = 128
    kv_len = max(kv_len, nq)
    max_kv = shard_cap * n_shards
    if kv_len > max_kv:
        kv_len = max_kv
    q, k, v = mk(nq, max_kv, 8, 2, 32, seed=seed)
    o = shard_and_merge(q, k, v, kv_len - nq, kv_len, shard_cap, n_shards)
    o_ref = ref.attention_ref(q, k, v, kv_len - nq, kv_len)
    np.testing.assert_allclose(o, o_ref, rtol=5e-5, atol=5e-5)
