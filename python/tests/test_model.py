"""L2 model: shape checks, kernel-vs-ref path equivalence, chunking and
stage-composition invariants (what SPP relies on)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

SPEC = M.ModelSpec(max_seq=256, n_layers=4, d_model=128, d_ff=352, hq=4, hkv=2, d_head=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(SPEC, seed=0)


def toks(n, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(0, SPEC.vocab, n), jnp.int32)


def test_param_count_matches_spec(params):
    total = params["embed"].size + params["final_norm"].size
    for layer in params["layers"]:
        total += sum(w.size for w in layer.values())
    assert total == SPEC.n_params


def test_forward_shapes(params):
    ck, cv = M.empty_cache(SPEC)
    logits, ck, cv = M.forward_chunk(params, toks(16), ck, cv, 0, SPEC)
    assert logits.shape == (16, SPEC.vocab)
    assert ck.shape == (SPEC.n_layers, SPEC.max_seq, SPEC.hkv, SPEC.d_head)
    assert jnp.all(jnp.isfinite(logits))


def test_kernel_path_matches_ref_path(params):
    ck, cv = M.empty_cache(SPEC)
    l_kern, ck1, cv1 = M.forward_chunk(params, toks(32), ck, cv, 0, SPEC, use_kernel=True)
    ck, cv = M.empty_cache(SPEC)
    l_ref, ck2, cv2 = M.forward_chunk(params, toks(32), ck, cv, 0, SPEC, use_kernel=False)
    np.testing.assert_allclose(l_kern, l_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(ck1, ck2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunks", [[48], [16, 16, 16], [32, 16], [1] * 8 + [40]])
def test_chunked_prefill_invariance(params, chunks):
    """Any chunking of the prompt yields the same final logits — the
    correctness property adaptive chunking (section 4.2) depends on."""
    if sum(chunks) != 48:
        chunks = chunks + [48 - sum(chunks)]
    t = toks(48, seed=2)
    ck, cv = M.empty_cache(SPEC)
    full, _, _ = M.forward_chunk(params, t, ck, cv, 0, SPEC)
    ck, cv = M.empty_cache(SPEC)
    pos = 0
    last = None
    for c in chunks:
        last, ck, cv = M.forward_chunk(params, t[pos:pos + c], ck, cv, pos, SPEC)
        pos += c
    np.testing.assert_allclose(last[-1], full[-1], rtol=2e-4, atol=2e-4)


def test_stage_composition_equals_full_model(params):
    """Running the model as 2 stages of 2 layers == monolithic forward —
    the invariant SPP staging relies on."""
    t = toks(16, seed=3)
    ck, cv = M.empty_cache(SPEC)
    full, ckf, cvf = M.forward_chunk(params, t, ck, cv, 0, SPEC)

    h = M.embed(t, params["embed"])
    shape = (2, SPEC.max_seq, SPEC.hkv, SPEC.d_head)
    ck0, cv0 = jnp.zeros(shape), jnp.zeros(shape)
    ck1, cv1 = jnp.zeros(shape), jnp.zeros(shape)
    h, ck0, cv0 = M.stage_forward(h, ck0, cv0, 0, params["layers"][:2], SPEC)
    h, ck1, cv1 = M.stage_forward(h, ck1, cv1, 0, params["layers"][2:], SPEC)
    logits = M.lm_head(h, params["final_norm"], params["embed"], SPEC)
    np.testing.assert_allclose(logits, full, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(jnp.concatenate([ck0, ck1]), ckf, rtol=1e-5, atol=1e-5)


def test_decode_step_consistency(params):
    """Prefill of n+1 tokens == prefill of n tokens + one decode step."""
    t = toks(17, seed=4)
    ck, cv = M.empty_cache(SPEC)
    full, _, _ = M.forward_chunk(params, t, ck, cv, 0, SPEC)
    ck, cv = M.empty_cache(SPEC)
    _, ck, cv = M.forward_chunk(params, t[:16], ck, cv, 0, SPEC)
    dec, _, _ = M.forward_chunk(params, t[16:], ck, cv, 16, SPEC)
    np.testing.assert_allclose(dec[-1], full[-1], rtol=2e-4, atol=2e-4)


def test_generate_greedy_deterministic(params):
    out1 = M.generate_greedy(params, list(b"hello"), 8, SPEC)
    out2 = M.generate_greedy(params, list(b"hello"), 8, SPEC)
    assert out1 == out2
    assert all(0 <= t < SPEC.vocab for t in out1)


def test_rope_is_relative(params):
    """RoPE: shifting both q and k positions by the same delta preserves
    q.k dot products (the property that makes cache-relative positions
    work)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((4, 2, 32)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((4, 2, 32)), jnp.float32)
    p = jnp.arange(4)
    a = M.rope(x, p, 10000.0)
    b = M.rope(y, p, 10000.0)
    a2 = M.rope(x, p + 100, 10000.0)
    b2 = M.rope(y, p + 100, 10000.0)
    dots1 = jnp.einsum("nhd,nhd->nh", a, b)
    dots2 = jnp.einsum("nhd,nhd->nh", a2, b2)
    np.testing.assert_allclose(dots1, dots2, rtol=1e-4, atol=1e-4)


def test_rmsnorm_scale_invariance():
    x = jnp.asarray(np.random.default_rng(6).standard_normal((3, 16)), jnp.float32)
    w = jnp.ones((16,))
    n1 = M.rmsnorm(x, w, 0.0)
    n2 = M.rmsnorm(5.0 * x, w, 0.0)
    np.testing.assert_allclose(n1, n2, rtol=1e-5, atol=1e-5)
