//! Reference simulator: the pre-arena simulator core, kept verbatim in
//! spirit as a semantics oracle.
//!
//! This is the map-based, allocate-per-iteration implementation the
//! optimized core in [`super`] replaced: requests live in a
//! `BTreeMap<RequestId, Request>`, every iteration builds fresh
//! `BatchPlan`/`BatchShape` vectors, the decode-context list for the long
//! request's chunk policy is rebuilt by scanning every request, finished
//! decodes are dropped with an O(n·m) `contains` retain, and idle instants
//! advance time by 1e-6 s bumps.
//!
//! It exists for two reasons:
//! * **golden equivalence** — `tests/sim_golden.rs` asserts the optimized
//!   simulator reproduces this implementation's `Metrics` bit-for-bit on
//!   fixed workloads (the refactor changed the engineering, not the
//!   simulated semantics);
//! * **before/after measurement** — `benches/hotpath.rs` times both cores
//!   on the same workloads and records the ratio in `BENCH_sim.json`.
//!
//! Keep this file boring: it should only ever change when the *simulated
//! semantics* deliberately change, in lockstep with the optimized core.

use std::collections::{BTreeMap, VecDeque};

use super::SimOptions;
use crate::config::{DeploymentConfig, SloConfig};
use crate::coordinator::chunking::ChunkPolicy;
use crate::coordinator::request::{Phase, Request};
use crate::coordinator::spp::PipelineTimeline;
use crate::coordinator::{
    AdaptiveChunk, KvpManager, Router, RoutingMode, SchedPolicyKind, Slot, StaticChunk, Topology,
};
use crate::kvcache::RequestId;
use crate::metrics::{IterRecord, Metrics};
use crate::perfmodel::{BatchShape, DecodeWork, PerfModel, PrefillWork};
use crate::workload::RequestSpec;

/// The pre-arena scheduler: map-keyed, allocating fresh plan vectors every
/// iteration, O(n·m) finished-retain.
struct RefScheduler {
    policy: Box<dyn ChunkPolicy>,
    max_batch: usize,
    prefill_queue: VecDeque<RequestId>,
    decoding: Vec<RequestId>,
}

#[derive(Debug, Clone, PartialEq)]
struct RefBatchPlan {
    prefill: Option<(RequestId, u64)>,
    decodes: Vec<RequestId>,
}

impl RefBatchPlan {
    fn is_empty(&self) -> bool {
        self.prefill.is_none() && self.decodes.is_empty()
    }
}

impl RefScheduler {
    fn new(policy: Box<dyn ChunkPolicy>, max_batch: usize) -> RefScheduler {
        RefScheduler {
            policy,
            max_batch,
            prefill_queue: VecDeque::new(),
            decoding: Vec::new(),
        }
    }

    fn enqueue(&mut self, id: RequestId) {
        self.prefill_queue.push_back(id);
    }

    fn has_work(&self) -> bool {
        !self.prefill_queue.is_empty() || !self.decoding.is_empty()
    }

    fn next_batch<F: Fn(&Request) -> u64>(
        &mut self,
        requests: &BTreeMap<RequestId, Request>,
        pm: &PerfModel,
        slo: &SloConfig,
        now: f64,
        local_kv: F,
    ) -> RefBatchPlan {
        let decodes: Vec<RequestId> = self
            .decoding
            .iter()
            .copied()
            .take(self.max_batch)
            .collect();
        let decode_ctxs: Vec<u64> = decodes
            .iter()
            .map(|id| local_kv(&requests[id]).max(1))
            .collect();
        let prefill = self.prefill_queue.front().and_then(|&id| {
            let r = &requests[&id];
            let remaining = r.remaining_prefill();
            if remaining == 0 {
                return None;
            }
            let c = self.policy.next_chunk(
                r.kv_len(),
                remaining,
                &decode_ctxs,
                r.deadline_remaining_s(now),
                pm,
                slo,
            );
            Some((id, c.max(1).min(remaining)))
        });
        RefBatchPlan { prefill, decodes }
    }

    fn batch_shape<F: Fn(&Request) -> u64>(
        &self,
        plan: &RefBatchPlan,
        requests: &BTreeMap<RequestId, Request>,
        local_kv: F,
    ) -> BatchShape {
        let mut shape = BatchShape::default();
        if let Some((id, c)) = plan.prefill {
            let r = &requests[&id];
            shape.prefills.push(PrefillWork {
                chunk: c,
                kv_len: local_kv(r) + c,
            });
        }
        for id in &plan.decodes {
            shape.decodes.push(DecodeWork {
                kv_len: local_kv(&requests[id]).max(1),
            });
        }
        shape
    }

    fn complete_iteration(
        &mut self,
        plan: &RefBatchPlan,
        requests: &mut BTreeMap<RequestId, Request>,
        t: f64,
    ) -> Vec<RequestId> {
        let mut finished = Vec::new();
        if let Some((id, c)) = plan.prefill {
            let r = requests.get_mut(&id).expect("prefill req");
            r.complete_chunk(c, t);
            match r.phase {
                Phase::Decoding => {
                    self.prefill_queue.pop_front();
                    self.decoding.push(id);
                }
                Phase::Finished => {
                    self.prefill_queue.pop_front();
                    finished.push(id);
                }
                _ => {}
            }
        }
        for &id in &plan.decodes {
            let r = requests.get_mut(&id).expect("decode req");
            r.complete_decode(t);
            if r.is_finished() {
                finished.push(id);
            }
        }
        // the quadratic retain the optimized scheduler replaced
        self.decoding.retain(|id| !finished.contains(id));
        finished
    }
}

/// The pre-arena simulator. External `RequestId`s double as the slot
/// handles handed to the (slot-keyed) router and KVP manager, so workloads
/// must use ids < `u32::MAX` — true of every generator in this repo.
pub struct ReferenceSimulation {
    pub dep: DeploymentConfig,
    pub opts: SimOptions,
    pm: PerfModel,
    layers_per_stage: u32,
    policy: Box<dyn ChunkPolicy>,
    topo: Topology,

    requests: BTreeMap<RequestId, Request>,
    pending: VecDeque<RequestSpec>,
    scheds: Vec<RefScheduler>,
    timelines: Vec<PipelineTimeline>,
    long_queue: VecDeque<RequestId>,
    active_long: Option<RequestId>,
    kvp_mgr: KvpManager,
    router: Router,
    pub metrics: Metrics,
    now: f64,
}

fn slot_of(id: RequestId) -> Slot {
    debug_assert!(id < u32::MAX as u64, "reference sim needs small ids");
    id as Slot
}

impl ReferenceSimulation {
    pub fn new(
        dep: DeploymentConfig,
        workload: Vec<RequestSpec>,
        opts: SimOptions,
    ) -> ReferenceSimulation {
        dep.validate().expect("invalid deployment");
        // The oracle preserves the pre-policy semantics: strict FCFS. Fail
        // fast rather than silently comparing against the wrong scheduler.
        assert_eq!(
            dep.scheduler.policy,
            SchedPolicyKind::Fcfs,
            "ReferenceSimulation implements FCFS only"
        );
        assert_eq!(
            dep.scheduler.routing,
            RoutingMode::Blind,
            "ReferenceSimulation implements blind least-loaded routing only"
        );
        let pm = PerfModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
        let kvp_groups = dep.parallel.kvp.max(1);
        let policy: Box<dyn ChunkPolicy> = if dep.scheduler.adaptive_chunking {
            Box::new(AdaptiveChunk::new(dep.scheduler.chunk_sizes.clone()))
        } else {
            Box::new(StaticChunk(dep.scheduler.static_chunk))
        };
        let mut pending: Vec<RequestSpec> = workload;
        // (arrival, id) tie-break, in lockstep with the optimized core.
        pending.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
        let layers_per_stage = dep.model.n_layers / dep.parallel.spp.max(1);
        let topo = Topology::new(dep.parallel, &dep.hardware);
        ReferenceSimulation {
            pm,
            layers_per_stage,
            policy,
            topo,
            requests: BTreeMap::new(),
            pending: pending.into(),
            scheds: (0..kvp_groups)
                .map(|_| {
                    RefScheduler::new(
                        Box::new(StaticChunk(dep.scheduler.static_chunk)),
                        dep.scheduler.max_batch_size,
                    )
                })
                .collect(),
            timelines: (0..kvp_groups)
                .map(|_| PipelineTimeline::new(dep.parallel.spp.max(1) as usize, 0.0))
                .collect(),
            long_queue: VecDeque::new(),
            active_long: None,
            kvp_mgr: KvpManager::new(dep.scheduler.kvp_onboard_threshold, kvp_groups),
            router: Router::new(kvp_groups),
            metrics: {
                let mut m = Metrics::new();
                m.tbt_slo_s = dep.slo.tbt_s;
                m
            },
            now: 0.0,
            dep,
            opts,
        }
    }

    fn admit_arrivals(&mut self) {
        while let Some(spec) = self.pending.front() {
            if spec.arrival_s > self.now {
                break;
            }
            let spec = self.pending.pop_front().unwrap();
            // identical admission-time SLO state to the optimized core
            let est = super::est_prefill_s(&self.pm, spec.prompt_len);
            let deadline = spec.arrival_s + self.dep.slo.ttft_deadline_for(est);
            let r = Request::new(spec.id, spec.prompt_len, spec.max_new_tokens, spec.arrival_s)
                .with_slo(est, deadline);
            if spec.prompt_len > self.opts.long_threshold {
                let g = self.router.route(slot_of(spec.id), spec.prompt_len);
                self.kvp_mgr
                    .onboard_request(slot_of(spec.id), spec.id, g, self.now);
                self.long_queue.push_back(spec.id);
            } else {
                let g = self.router.route(slot_of(spec.id), spec.prompt_len);
                self.scheds[g as usize].enqueue(spec.id);
            }
            self.requests.insert(spec.id, r);
        }
        if self.active_long.is_none() {
            self.active_long = self.long_queue.pop_front();
        }
    }

    fn has_work(&self) -> bool {
        self.active_long.is_some()
            || !self.long_queue.is_empty()
            || self.scheds.iter().any(|s| s.has_work())
    }

    fn short_local_kv(r: &Request) -> u64 {
        r.kv_len().max(1)
    }

    pub fn run(&mut self) -> f64 {
        loop {
            self.admit_arrivals();
            if !self.has_work() {
                match self.pending.front() {
                    Some(spec) => {
                        self.now = spec.arrival_s;
                        for tl in &mut self.timelines {
                            tl.advance_to(self.now);
                        }
                        continue;
                    }
                    None => break,
                }
            }
            if self.now > self.opts.horizon_s {
                break;
            }
            self.step();
        }
        self.now
    }

    fn step(&mut self) {
        let n_groups = self.scheds.len();
        let slo = self.dep.slo;

        // ---- long-request work selection -------------------------------
        let long_id = self.active_long;
        let mut long_chunk: Option<u64> = None;
        let mut long_decode = false;
        if let Some(id) = long_id {
            let r = &self.requests[&id];
            match r.phase {
                Phase::Queued | Phase::Prefilling => {
                    // rebuilt every step by scanning all requests, in
                    // group-major id order
                    let decode_ctxs: Vec<u64> = (0..n_groups)
                        .flat_map(|g| self.group_decode_ctxs(g))
                        .collect();
                    let c = self.policy.next_chunk(
                        r.kv_len(),
                        r.remaining_prefill(),
                        &decode_ctxs,
                        r.deadline_remaining_s(self.now),
                        &self.pm,
                        &slo,
                    );
                    long_chunk = Some(c.max(1).min(r.remaining_prefill()));
                }
                Phase::Decoding => long_decode = true,
                Phase::Finished => {}
            }
        }
        let long_nq = long_chunk.unwrap_or(if long_decode { 1 } else { 0 });
        let participating: Vec<(u32, u64)> = match long_id {
            Some(id) if long_nq > 0 => self.kvp_mgr.local_lengths(slot_of(id)),
            _ => Vec::new(),
        };

        // ---- per-group batch formation (fresh vectors every step) --------
        let mut group_plans = Vec::with_capacity(n_groups);
        for g in 0..n_groups {
            let plan = self.scheds[g].next_batch(
                &self.requests,
                &self.pm,
                &slo,
                self.now,
                Self::short_local_kv,
            );
            group_plans.push(plan);
        }

        // ---- build shapes and flow through pipelines ---------------------
        let mut any_decode = long_decode;
        let mut exits = vec![self.now; n_groups];
        let mut max_stage0_exit = self.now;
        let mut worked = false;
        let mut combined = BatchShape::default();
        for g in 0..n_groups {
            let mut shape =
                self.scheds[g].batch_shape(&group_plans[g], &self.requests, Self::short_local_kv);
            if let Some(&(_, local)) = participating.iter().find(|&&(gg, _)| gg as usize == g) {
                if let Some(c) = long_chunk {
                    shape.prefills.push(PrefillWork {
                        chunk: c,
                        kv_len: local + c,
                    });
                } else if long_decode {
                    shape.decodes.push(DecodeWork {
                        kv_len: local.max(1),
                    });
                }
            }
            if shape.is_empty() {
                continue;
            }
            worked = true;
            any_decode |= !shape.decodes.is_empty();
            combined.prefills.extend(shape.prefills.iter().copied());
            combined.decodes.extend(shape.decodes.iter().copied());
            let st = self.pm.stage_time(&shape, self.layers_per_stage).total();
            let hop = self.pm.stage_hop_s(shape.tokens());
            let dense_ok = shape.decodes.is_empty();
            let ready = if dense_ok {
                self.timelines[g].stage0_free().max(self.now)
            } else {
                self.now
            };
            let res = self.timelines[g].flow(ready, |_| st, hop);
            max_stage0_exit = max_stage0_exit.max(res.first_stage_exit());
            exits[g] = res.exit();
            // per-group utilization split, in lockstep with the optimized
            // core's accounting (asserted bit-identical by sim_golden)
            let prefill_toks: u64 = shape.prefills.iter().map(|p| p.chunk).sum();
            self.metrics.record_group_iter(
                g,
                res.exit() - self.now,
                prefill_toks,
                shape.decodes.len() as u64,
            );
        }

        if !worked {
            // the degenerate busy-wait the optimized core replaced
            self.now += 1e-6;
            return;
        }

        let mut iter_end = exits.iter().cloned().fold(self.now, f64::max);
        if participating.len() > 1 && long_nq > 0 {
            iter_end += self.pm.kvp_merge_s(long_nq);
        }

        let t_next = if any_decode { iter_end } else { max_stage0_exit };
        let dur = iter_end - self.now;

        // ---- bookkeeping --------------------------------------------------
        for g in 0..n_groups {
            let plan = group_plans[g].clone();
            if plan.is_empty() {
                continue;
            }
            let finished = self.scheds[g].complete_iteration(&plan, &mut self.requests, iter_end);
            for id in finished {
                let r = &self.requests[&id];
                self.metrics.record_finished_request(r);
                self.router.release(slot_of(id), r.prompt_len);
            }
        }
        if let Some(id) = long_id {
            if let Some(c) = long_chunk {
                let r = self.requests.get_mut(&id).unwrap();
                r.complete_chunk(c, iter_end);
                self.kvp_mgr.append_tokens(slot_of(id), c, iter_end);
                // TTFT recorded once, at finish, via record_finished_request
                // (kept in lockstep with the optimized core's fix of the
                // decode-entry double count)
            } else if long_decode {
                let r = self.requests.get_mut(&id).unwrap();
                r.complete_decode(iter_end);
                self.kvp_mgr.append_tokens(slot_of(id), 1, iter_end);
            }
            let r = &self.requests[&id];
            if r.is_finished() {
                self.metrics.record_finished_request(r);
                let prompt_len = r.prompt_len;
                self.kvp_mgr.release(slot_of(id));
                self.router.release(slot_of(id), prompt_len);
                self.active_long = None;
            }
        }

        let active_gpus = match long_id {
            Some(id) => self
                .topo
                .gpus_active(self.kvp_mgr.active_groups(slot_of(id)).max(1)),
            None => self.topo.parallel.workers_per_replica(),
        };
        if dur > 0.0 {
            self.metrics
                .mfu
                .add(self.pm.mfu(&combined, dur, active_gpus.max(1)));
            self.metrics
                .mbu
                .add(self.pm.mbu(&combined, dur, active_gpus.max(1)));
        }
        self.metrics.record_iter(IterRecord {
            t: iter_end,
            dur_s: dur,
            chunk: long_chunk.or_else(|| {
                group_plans
                    .iter()
                    .find_map(|p| p.prefill.map(|(_, c)| c))
            }),
            n_decodes: combined.decodes.len(),
            active_gpus,
        });
        self.now = t_next;
    }

    /// Decoding requests resident on group `g`, in id order (the map-scan
    /// the optimized core replaced with incremental tracking).
    fn group_decode_ctxs(&self, g: usize) -> Vec<u64> {
        let mut v = Vec::new();
        for (id, r) in &self.requests {
            if r.phase == Phase::Decoding && self.router.group_of(slot_of(*id)) == Some(g as u32) {
                v.push(r.kv_len().max(1));
            }
        }
        v
    }

    pub fn request(&self, id: RequestId) -> Option<&Request> {
        self.requests.get(&id)
    }

    pub fn kvp_onboard_log(&self) -> &[(f64, RequestId, u32)] {
        &self.kvp_mgr.onboard_log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    #[test]
    fn reference_still_simulates() {
        let dep = DeploymentConfig::llama3_8b_tp8();
        let w = workload::long_plus_decodes(100_000, 4, 1_000, 16);
        let mut sim = ReferenceSimulation::new(dep, w, SimOptions::default());
        sim.run();
        assert_eq!(sim.metrics.finished_requests, 5);
        assert!(sim.request(0).unwrap().is_finished());
    }
}
