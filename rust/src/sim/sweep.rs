//! Concurrent evaluation sweep: the full scheduling-policy
//! (FCFS/SRPT/EDF/LARS) × routing (blind/round-robin/routed) × load-level
//! grid over the shared kvp_convoy scenario, one independent simulation
//! per threadpool worker, reduced to a **Pareto frontier** over goodput
//! (maximize) vs short-request p99 TTFT (minimize) vs capacity deferrals
//! (minimize) — the tradeoff surface the paper's evaluation walks.
//!
//! Determinism: the grid is enumerated in a fixed order (policy-major,
//! then routing, then load), each cell's workload seed is derived from
//! `(base_seed, cell_index)` via SplitMix64, and cell results land in
//! submission-order slots ([`crate::util::threadpool::ThreadPool::map`]
//! joins handles in submit order) — so the outcome vector is bit-identical
//! whatever the worker count or completion order, and identical to the
//! serial (`threads = 1`) run. [`SweepOutcome`] deliberately carries no
//! host wall-clock; [`SweepOutcome::fingerprint`] renders every float as
//! its raw bit pattern for exact cross-run comparison (asserted by the
//! tests here and exercised by `medha sweep` / `reproduce --figure
//! sweep` / the `sim/sweep` bench).
//!
//! Wall-clock note: D2-allowlisted (`medha lint`) — `Instant` only times
//! the sweep for the report line; cell outcomes never see it.

use std::time::Instant;

use super::{kvp_convoy_dep, kvp_convoy_ttft_split, SimOptions, Simulation};
use crate::coordinator::{RoutingMode, SchedPolicyKind};
use crate::util::json::Json;
use crate::util::rng::SplitMix64;
use crate::util::threadpool::ThreadPool;
use crate::workload::{self, KvpConvoyConfig};

/// Sweep grid + execution configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Base seed; each cell derives its own workload stream from
    /// `(base_seed, cell_index)` — see [`cell_seed`].
    pub base_seed: u64,
    /// Multipliers applied to the trace's short-request arrival rate; one
    /// grid layer per level.
    pub load_levels: Vec<f64>,
    /// Worker threads running whole cells concurrently (1 = serial). Does
    /// not change any result, only wall-clock.
    pub threads: usize,
    /// Per-group KV capacity for every cell. Finite — unlike the
    /// capacity-blind kvp_convoy default — so routed placement actually
    /// refuses and defers under load, giving the deferrals Pareto axis a
    /// signal.
    pub kvp_capacity_tokens: u64,
    /// The kvp_convoy trace template each cell scales.
    pub trace: KvpConvoyConfig,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            base_seed: 42,
            load_levels: vec![0.5, 1.0, 2.0],
            threads: 1,
            // ~1.5 document shards per group: enough for the convoy, tight
            // enough that routed mode defers under the 2x load level.
            kvp_capacity_tokens: 768_000,
            trace: KvpConvoyConfig::default(),
        }
    }
}

impl SweepConfig {
    /// Down-scaled grid for CI smoke runs (`MEDHA_BENCH_SMOKE`): one load
    /// level and a short horizon with small documents — the full 12-cell
    /// policy × routing matrix still runs.
    pub fn smoke() -> SweepConfig {
        SweepConfig {
            load_levels: vec![1.0],
            trace: KvpConvoyConfig {
                rate_per_s: 4.0,
                horizon_s: 5.0,
                doc_prompt: 64_000,
                n_docs: 2,
                doc_start_s: 1.0,
                doc_stagger_s: 2.0,
                ..KvpConvoyConfig::default()
            },
            ..SweepConfig::default()
        }
    }

    /// Enumerate the grid in its canonical order: policy-major, then
    /// routing, then load level. A cell's index — and therefore its
    /// derived seed — never depends on execution.
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out =
            Vec::with_capacity(SchedPolicyKind::ALL.len() * RoutingMode::ALL.len() * self.load_levels.len());
        for policy in SchedPolicyKind::ALL {
            for routing in RoutingMode::ALL {
                for &load in &self.load_levels {
                    let index = out.len();
                    out.push(SweepCell {
                        index,
                        policy,
                        routing,
                        load,
                        seed: cell_seed(self.base_seed, index),
                    });
                }
            }
        }
        out
    }
}

/// One grid cell, fully determined by the config and its index.
#[derive(Debug, Clone, Copy)]
pub struct SweepCell {
    pub index: usize,
    pub policy: SchedPolicyKind,
    pub routing: RoutingMode,
    /// Short-request arrival-rate multiplier.
    pub load: f64,
    pub seed: u64,
}

/// Derive a cell's workload seed from `(base_seed, cell_index)`:
/// SplitMix64 over the mixed pair, so neighbouring cells get decorrelated
/// streams and any cell is reproducible in isolation.
pub fn cell_seed(base_seed: u64, cell_index: usize) -> u64 {
    let mut sm = SplitMix64::new(base_seed ^ (cell_index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    sm.next_u64()
}

/// One cell's deterministic outcome. Every field is a pure function of
/// the cell definition; host wall-clock is deliberately *not* here (the
/// sweep reports it separately), so fingerprints compare bit-exactly
/// across worker counts.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub cell: SweepCell,
    pub finished: u64,
    /// SLO-attaining request throughput (the goodput Pareto axis, max).
    pub goodput_rps: f64,
    /// Interactive-class p99 TTFT (the latency Pareto axis, min; NaN when
    /// no short request finished — never on the frontier).
    pub short_p99_ttft_s: f64,
    /// Document-class worst TTFT (reported, not a frontier axis).
    pub doc_max_ttft_s: f64,
    pub ttft_attainment: f64,
    /// Capacity-refused admissions (the deferrals Pareto axis, min).
    pub deferrals: u64,
    pub n_deferred: u64,
    pub preemptions: u64,
    /// Non-dominated over (goodput, short p99 TTFT, deferrals) — set by
    /// [`mark_pareto_frontier`].
    pub on_frontier: bool,
}

impl SweepOutcome {
    /// Bit-exact serialization — floats as raw bit patterns — for the
    /// determinism assertions (serial vs threaded, double-run).
    pub fn fingerprint(&self) -> String {
        format!(
            "cell={} policy={} routing={} load={:016x} seed={} finished={} goodput={:016x} \
             short_p99={:016x} doc_max={:016x} attain={:016x} deferrals={} n_deferred={} \
             preempt={} frontier={}",
            self.cell.index,
            self.cell.policy.name(),
            self.cell.routing.name(),
            self.cell.load.to_bits(),
            self.cell.seed,
            self.finished,
            self.goodput_rps.to_bits(),
            self.short_p99_ttft_s.to_bits(),
            self.doc_max_ttft_s.to_bits(),
            self.ttft_attainment.to_bits(),
            self.deferrals,
            self.n_deferred,
            self.preemptions,
            self.on_frontier,
        )
    }

    pub fn to_json(&self) -> Json {
        // NaN is not valid JSON — latency axes go Null when no request of
        // that class finished. The derived 64-bit seed is rendered as a
        // string so it round-trips without f64 precision loss.
        let num_or_null = |x: f64| if x.is_finite() { Json::num(x) } else { Json::Null };
        Json::obj(vec![
            ("cell", self.cell.index.into()),
            ("policy", Json::str(self.cell.policy.name())),
            ("routing", Json::str(self.cell.routing.name())),
            ("load", Json::num(self.cell.load)),
            ("seed", Json::str(&self.cell.seed.to_string())),
            ("finished", self.finished.into()),
            ("goodput_rps", num_or_null(self.goodput_rps)),
            ("short_p99_ttft_s", num_or_null(self.short_p99_ttft_s)),
            ("doc_max_ttft_s", num_or_null(self.doc_max_ttft_s)),
            ("ttft_attainment", num_or_null(self.ttft_attainment)),
            ("deferrals", self.deferrals.into()),
            ("n_deferred", self.n_deferred.into()),
            ("preemptions", self.preemptions.into()),
            ("on_frontier", Json::Bool(self.on_frontier)),
        ])
    }
}

/// Run one cell: scale the trace to the cell's load level, build the
/// shared kvp_convoy deployment for its policy × routing (with the
/// sweep's finite capacity), simulate, and distill the outcome.
pub fn run_cell(cfg: &SweepConfig, cell: &SweepCell) -> SweepOutcome {
    let mut trace_cfg = cfg.trace.clone();
    trace_cfg.rate_per_s = cfg.trace.rate_per_s * cell.load;
    let mut dep = kvp_convoy_dep(cell.policy, cell.routing, &trace_cfg);
    dep.scheduler.kvp_capacity_tokens = cfg.kvp_capacity_tokens;
    let w = workload::kvp_convoy(&trace_cfg, cell.seed);
    let mut sim = Simulation::new(dep, w, SimOptions::default());
    sim.run();
    let (mut short, mut docs) = kvp_convoy_ttft_split(&sim, &trace_cfg);
    let s = sim.metrics.summary();
    SweepOutcome {
        cell: *cell,
        finished: s.finished,
        goodput_rps: s.goodput_rps,
        short_p99_ttft_s: short.p99(),
        doc_max_ttft_s: docs.max(),
        ttft_attainment: s.ttft_attainment,
        deferrals: s.routing_refusals,
        n_deferred: s.n_deferred,
        preemptions: s.preemptions,
        on_frontier: false,
    }
}

/// Run the whole grid — `cfg.threads > 1` fans whole cells out across a
/// threadpool, each an independent simulation — mark the Pareto frontier,
/// and return the outcomes (in canonical cell order, worker-count
/// invariant) plus total host wall-clock seconds.
pub fn run_sweep(cfg: &SweepConfig) -> (Vec<SweepOutcome>, f64) {
    let cells = cfg.cells();
    let t0 = Instant::now();
    let mut outcomes: Vec<SweepOutcome> = if cfg.threads > 1 && cells.len() > 1 {
        let pool = ThreadPool::new(cfg.threads.min(cells.len()));
        let cfg2 = cfg.clone();
        // One cell per job: a cell is seconds of simulated work, so the
        // per-job overhead `map_chunks` amortizes is irrelevant and the
        // finest granularity balances the queue best.
        pool.map(cells, move |cell| run_cell(&cfg2, &cell))
    } else {
        cells.iter().map(|c| run_cell(cfg, c)).collect()
    };
    let wall_s = t0.elapsed().as_secs_f64();
    mark_pareto_frontier(&mut outcomes);
    (outcomes, wall_s)
}

/// Mark the non-dominated set over (goodput max, short p99 TTFT min,
/// deferrals min). `a` dominates `b` when it is no worse on all three
/// axes and strictly better on at least one. A NaN latency (no short
/// request finished) is never on the frontier and — NaN comparisons being
/// false — never dominates anything.
pub fn mark_pareto_frontier(outcomes: &mut [SweepOutcome]) {
    fn key(o: &SweepOutcome) -> (f64, f64, u64) {
        (o.goodput_rps, o.short_p99_ttft_s, o.deferrals)
    }
    fn dominates(a: (f64, f64, u64), b: (f64, f64, u64)) -> bool {
        a.0 >= b.0 && a.1 <= b.1 && a.2 <= b.2 && (a.0 > b.0 || a.1 < b.1 || a.2 < b.2)
    }
    for i in 0..outcomes.len() {
        let ki = key(&outcomes[i]);
        let dominated = !ki.0.is_finite()
            || !ki.1.is_finite()
            || outcomes
                .iter()
                .enumerate()
                .any(|(j, o)| j != i && dominates(key(o), ki));
        outcomes[i].on_frontier = !dominated;
    }
}

/// Render the sweep as the table `medha sweep` and `reproduce --figure
/// sweep` print: one row per cell, `*` marking Pareto-frontier members.
pub fn print_table(outcomes: &[SweepOutcome], wall_s: f64, threads: usize) {
    println!(
        "sweep: {} cells ({} policies x {} routings x loads), {threads} worker thread(s), {wall_s:.2}s wall",
        outcomes.len(),
        SchedPolicyKind::ALL.len(),
        RoutingMode::ALL.len(),
    );
    println!(
        "{:<2} {:<6} {:<12} {:>5} {:>10} {:>14} {:>12} {:>10}",
        "", "policy", "routing", "load", "goodput/s", "short p99 TTFT", "doc max TTFT", "deferrals"
    );
    for o in outcomes {
        println!(
            "{:<2} {:<6} {:<12} {:>5.2} {:>10.3} {:>13.3}s {:>11.2}s {:>10}",
            if o.on_frontier { "*" } else { "" },
            o.cell.policy.name(),
            o.cell.routing.name(),
            o.cell.load,
            o.goodput_rps,
            o.short_p99_ttft_s,
            o.doc_max_ttft_s,
            o.deferrals,
        );
    }
    let n_front = outcomes.iter().filter(|o| o.on_frontier).count();
    println!("Pareto frontier (goodput vs short p99 TTFT vs deferrals): {n_front} of {} cells", outcomes.len());
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A grid small enough for a unit test: the full 12-cell policy ×
    /// routing matrix at one load level on a short two-document trace.
    fn tiny_cfg(threads: usize) -> SweepConfig {
        SweepConfig {
            threads,
            load_levels: vec![1.0],
            trace: KvpConvoyConfig {
                rate_per_s: 4.0,
                horizon_s: 2.5,
                doc_prompt: 48_000,
                n_docs: 1,
                doc_start_s: 0.5,
                doc_stagger_s: 1.0,
                ..KvpConvoyConfig::default()
            },
            ..SweepConfig::default()
        }
    }

    #[test]
    fn grid_enumeration_is_canonical() {
        let cfg = SweepConfig::default();
        let cells = cfg.cells();
        assert_eq!(cells.len(), 4 * 3 * 3);
        // indexes are dense, policy-major
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
            assert_eq!(c.seed, cell_seed(cfg.base_seed, i));
        }
        assert_eq!(cells[0].policy, SchedPolicyKind::Fcfs);
        assert_eq!(cells[0].routing, RoutingMode::Blind);
        // same config, same cells; different base seed, different streams
        let again = cfg.cells();
        assert!(cells.iter().zip(&again).all(|(a, b)| a.seed == b.seed));
        let other = SweepConfig {
            base_seed: 43,
            ..SweepConfig::default()
        };
        assert_ne!(other.cells()[0].seed, cells[0].seed);
        // neighbouring cells get distinct streams
        assert!(cells.windows(2).all(|w| w[0].seed != w[1].seed));
    }

    #[test]
    fn pareto_marks_non_dominated() {
        let cell = SweepCell {
            index: 0,
            policy: SchedPolicyKind::Fcfs,
            routing: RoutingMode::Blind,
            load: 1.0,
            seed: 1,
        };
        let mk = |goodput: f64, p99: f64, deferrals: u64| SweepOutcome {
            cell,
            finished: 0,
            goodput_rps: goodput,
            short_p99_ttft_s: p99,
            doc_max_ttft_s: 0.0,
            ttft_attainment: 1.0,
            deferrals,
            n_deferred: 0,
            preemptions: 0,
            on_frontier: false,
        };
        let mut outs = vec![
            mk(10.0, 1.0, 0),     // frontier: best goodput and latency
            mk(5.0, 2.0, 0),      // dominated by the first on two axes
            mk(10.0, 2.0, 0),     // dominated (same goodput, worse p99)
            mk(8.0, 0.5, 5),      // frontier: best p99 (deferrals traded)
            mk(10.0, 1.0, 0),     // duplicate of the first: also frontier
            mk(2.0, f64::NAN, 0), // no shorts finished: never on frontier
        ];
        mark_pareto_frontier(&mut outs);
        let flags: Vec<bool> = outs.iter().map(|o| o.on_frontier).collect();
        assert_eq!(flags, vec![true, false, false, true, true, false]);
    }

    #[test]
    fn cell_runs_are_reproducible() {
        let cfg = tiny_cfg(1);
        let cell = cfg.cells()[5];
        let a = run_cell(&cfg, &cell);
        let b = run_cell(&cfg, &cell);
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert!(a.finished > 0, "tiny sweep cell must finish work");
    }

    /// The sweep tentpole's determinism contract: identical fingerprints
    /// for every cell whatever the worker count — serial, fewer workers
    /// than cells (queueing, arbitrary completion order), more workers
    /// than cells — and across a double run in-process.
    #[test]
    fn sweep_is_worker_count_invariant() {
        let serial = run_sweep(&tiny_cfg(1)).0;
        assert_eq!(serial.len(), 12);
        let serial_fp: Vec<String> = serial.iter().map(|o| o.fingerprint()).collect();
        let again: Vec<String> = run_sweep(&tiny_cfg(1)).0.iter().map(|o| o.fingerprint()).collect();
        assert_eq!(serial_fp, again, "serial sweep must be double-run deterministic");
        for threads in [3usize, 16] {
            let par: Vec<String> = run_sweep(&tiny_cfg(threads))
                .0
                .iter()
                .map(|o| o.fingerprint())
                .collect();
            assert_eq!(serial_fp, par, "sweep diverged at threads={threads}");
        }
    }
}
