//! Open-loop online serving driver: the millions-of-users mode where the
//! simulator is fed by an arrival *stream* it does not control
//! ([`crate::workload::openloop`]) through an admission layer
//! ([`crate::coordinator::admission`]) instead of replaying a pre-admitted
//! trace.
//!
//! [`ServeSim`] wraps the closed-loop [`Simulation`] core untouched: every
//! decision instant it offers due arrivals to the [`Admission`] gate
//! (token-bucket pacing per class, bounded per-class queues, SLO-feedback
//! shedding keyed on the rolling deferral-wait p95 and the arrival's
//! projected LARS slack), pushes the released requests into the
//! simulation's pending queue, and steps the core. Shed and queue-reject
//! decisions are metered per class
//! ([`crate::metrics::Metrics::n_shed`] /
//! [`n_rejected_queue_full`](crate::metrics::Metrics::n_rejected_queue_full)).
//!
//! **Equivalence contract:** under the pass-through
//! [`AdmissionConfig::default`] (unpaced, unbounded, shedding off), a
//! [`ServeSim`] run is bit-identical to [`Simulation::run`] on the same
//! trace. The one subtlety is event timing: the core's private
//! `next_event` consults `pending.front()` for the next arrival, and in
//! open-loop mode future arrivals live outside the core. [`ServeSim::run`]
//! therefore lends the core a sentinel pending entry carrying the next
//! external wake-up (next un-offered arrival or next token-bucket release)
//! for the duration of each `step`, so the core wakes at exactly the
//! instants the closed loop would — same condition (pooled routing, or a
//! barrier with no group admission point), same times. Asserted
//! bit-exactly in `tests/sim_serve.rs` and by the open-loop golden
//! snapshots in `tests/sim_golden.rs`.

use crate::config::DeploymentConfig;
use crate::coordinator::admission::{Admission, AdmissionConfig, AdmissionOutcome, ReqClass};
use crate::coordinator::{RoutingMode, SchedPolicyKind};
use crate::workload::openloop::{generate, OpenLoopConfig, Scenario};
use crate::workload::RequestSpec;

use super::{est_prefill_s, kvp_convoy_dep, SimOptions, Simulation};

/// Open-loop serving run: an arrival source, an admission gate, and the
/// closed-loop simulation core.
pub struct ServeSim {
    /// The wrapped closed-loop core; `sim.metrics` carries the shed/reject
    /// counters next to everything else.
    pub sim: Simulation,
    admission: Admission,
    /// The full offered stream, sorted by `(arrival_s, id)` like the
    /// closed-loop pending queue.
    source: Vec<RequestSpec>,
    /// First source index not yet offered to admission.
    cursor: usize,
    released_buf: Vec<RequestSpec>,
}

impl ServeSim {
    pub fn new(
        dep: DeploymentConfig,
        mut source: Vec<RequestSpec>,
        opts: SimOptions,
        admission: AdmissionConfig,
    ) -> ServeSim {
        admission.validate().expect("invalid admission config");
        source.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
        ServeSim {
            sim: Simulation::new(dep, Vec::new(), opts),
            admission: Admission::new(admission),
            source,
            cursor: 0,
            released_buf: Vec::new(),
        }
    }

    /// The admission gate (queue depths, high-water marks, config).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// Arrivals offered to admission so far.
    pub fn n_offered(&self) -> u64 {
        self.cursor as u64
    }

    /// Offer every due source arrival to the admission gate, then release
    /// whatever the class buckets allow into the core's pending queue.
    /// Shed/reject outcomes are metered here, at decision time.
    fn offer_due(&mut self) {
        let now = self.sim.now;
        while self.cursor < self.source.len() && self.source[self.cursor].arrival_s <= now {
            let spec = self.source[self.cursor];
            self.cursor += 1;
            let est = est_prefill_s(&self.sim.pm, spec.prompt_len);
            let deadline_rel = self.sim.dep.slo.ttft_deadline_for(est);
            // Query the rolling p95 only when shedding can act on it: the
            // query sorts the sample reservoir in place, and the
            // pass-through config must leave the core's metrics state
            // bit-identical to a closed-loop run.
            let p95 = if self.admission.config().shed_deferral_frac > 0.0 {
                self.sim.metrics.deferral_wait.p95()
            } else {
                f64::NAN
            };
            let doc = self.admission.config().class_of(spec.prompt_len) == ReqClass::Doc;
            match self.admission.offer(spec, est, deadline_rel, p95) {
                AdmissionOutcome::Enqueued => {}
                AdmissionOutcome::Shed => self.sim.metrics.record_shed(doc),
                AdmissionOutcome::RejectedQueueFull => self.sim.metrics.record_queue_reject(doc),
            }
        }
        self.released_buf.clear();
        self.admission.release(now, &mut self.released_buf);
        for spec in self.released_buf.drain(..) {
            self.sim.pending.push_back(spec);
        }
    }

    /// Earliest future external event: the next un-offered arrival or the
    /// next token-bucket release of a queued one.
    fn next_wake(&self) -> Option<f64> {
        let mut t: Option<f64> = None;
        if self.cursor < self.source.len() {
            t = Some(self.source[self.cursor].arrival_s);
        }
        if let Some(r) = self.admission.next_release_s(self.sim.now) {
            t = Some(t.map_or(r, |x: f64| x.min(r)));
        }
        t
    }

    /// Run to completion (source drained, queues empty, core idle) or
    /// horizon. Returns the end time. Mirrors [`Simulation::run`] exactly,
    /// with admission spliced between arrivals and the core.
    pub fn run(&mut self) -> f64 {
        loop {
            if !self.sim.opts.faults.is_empty() {
                self.sim.apply_due_faults();
            }
            self.offer_due();
            self.sim.admit_arrivals();
            if !self.sim.has_work() {
                match self.next_wake() {
                    Some(t) if t > self.sim.now => {
                        self.sim.now = t;
                        for tl in &mut self.sim.timelines {
                            tl.advance_to(t);
                        }
                        continue;
                    }
                    // A release nominally due now with nothing released
                    // cannot happen (release() just drained everything
                    // eligible); bump defensively rather than spin.
                    Some(_) => {
                        self.sim.now += 1e-6;
                        continue;
                    }
                    None => break,
                }
            }
            if self.sim.now > self.sim.opts.horizon_s {
                break;
            }
            // Lend the core the next external wake-up as a sentinel
            // pending entry so its internal `next_event` interleaves
            // arrivals/releases exactly as the closed loop interleaves
            // arrivals. `step` never pops `pending`, so the sentinel is
            // gone before anyone could admit it.
            debug_assert!(self.sim.pending.is_empty());
            let lent = match self.next_wake() {
                Some(t) => {
                    self.sim.pending.push_back(RequestSpec {
                        id: u64::MAX,
                        prompt_len: 1,
                        max_new_tokens: 0,
                        arrival_s: t,
                        ..RequestSpec::default()
                    });
                    true
                }
                None => false,
            };
            self.sim.step();
            if lent {
                self.sim.pending.pop_back();
            }
        }
        self.sim.metrics.preemptions = self.sim.scheds.iter().map(|s| s.preemptions).sum();
        self.sim.metrics.kv_overcommit_tokens = self.sim.kvp_mgr.kv_overcommit_tokens;
        self.sim.now
    }
}

/// The deployment the `serve-sim` scenarios run on: the kvp_convoy fleet
/// (Llama-3 8B tp=8 across 4 KVP groups, static 4K chunks) with per-group
/// KV capacity bounded to the document scale, so routed mode has real
/// capacity pressure — the deferral-wait signal SLO-feedback shedding
/// listens to.
pub fn serve_scenario_dep(
    kind: SchedPolicyKind,
    routing: RoutingMode,
    cfg: &OpenLoopConfig,
) -> DeploymentConfig {
    let convoy = crate::workload::KvpConvoyConfig {
        doc_prompt: cfg.doc_prompt,
        ..crate::workload::KvpConvoyConfig::default()
    };
    let mut dep = kvp_convoy_dep(kind, routing, &convoy);
    // Room for one sharded document half plus a working set of shorts per
    // group; a second concurrent document must wait for capacity.
    dep.scheduler.kvp_capacity_tokens = cfg.doc_prompt + cfg.doc_prompt / 2;
    dep
}

/// Build-and-run helper shared by the CLI, the `overload` figure, and the
/// acceptance/golden tests: one named scenario on the serve deployment
/// under the given admission gate.
pub fn run_serve_scenario(
    scenario: Scenario,
    cfg: &OpenLoopConfig,
    kind: SchedPolicyKind,
    routing: RoutingMode,
    admission: AdmissionConfig,
    seed: u64,
) -> ServeSim {
    let dep = serve_scenario_dep(kind, routing, cfg);
    let source = generate(scenario, cfg, seed);
    let mut serve = ServeSim::new(dep, source, SimOptions::default(), admission);
    serve.run();
    serve
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::BucketConfig;

    /// Small open-loop shape shared by the in-module tests.
    fn small_cfg() -> OpenLoopConfig {
        OpenLoopConfig {
            base_rate_per_s: 6.0,
            horizon_s: 12.0,
            doc_prompt: 65_536,
            doc_every: 24,
            ..OpenLoopConfig::default()
        }
    }

    #[test]
    fn pass_through_serve_matches_closed_loop_exactly() {
        let cfg = small_cfg();
        let source = generate(Scenario::Overcommit, &cfg, 42);
        let dep = serve_scenario_dep(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg);

        let mut closed = Simulation::new(dep.clone(), source.clone(), SimOptions::default());
        let end_closed = closed.run();

        let mut open = ServeSim::new(dep, source, SimOptions::default(), AdmissionConfig::default());
        let end_open = open.run();

        assert_eq!(end_closed.to_bits(), end_open.to_bits());
        let (a, b) = (closed.metrics.summary(), open.sim.metrics.summary());
        assert_eq!(a.finished, b.finished);
        assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits());
        assert_eq!(a.ttft_p95.to_bits(), b.ttft_p95.to_bits());
        assert_eq!(a.tbt_p99.to_bits(), b.tbt_p99.to_bits());
        assert_eq!(a.routing_refusals, b.routing_refusals);
        assert_eq!(a.n_deferred, b.n_deferred);
        assert_eq!(b.n_shed, 0);
        assert_eq!(b.n_rejected_queue_full, 0);
        // per-request equality, not just aggregates
        assert_eq!(closed.retired().len(), open.sim.retired().len());
        for (x, y) in closed.retired().iter().zip(open.sim.retired().iter()) {
            assert_eq!(x.id, y.id);
            assert_eq!(
                x.ttft().map(f64::to_bits),
                y.ttft().map(f64::to_bits),
                "req {}",
                x.id
            );
        }
    }

    #[test]
    fn serve_run_is_deterministic_across_runs() {
        let cfg = small_cfg();
        let adm = AdmissionConfig::protective(cfg.base_rate_per_s, cfg.doc_prompt);
        let a = run_serve_scenario(
            Scenario::Flash,
            &cfg,
            SchedPolicyKind::Lars,
            RoutingMode::Routed,
            adm.clone(),
            7,
        );
        let mut b = run_serve_scenario(
            Scenario::Flash,
            &cfg,
            SchedPolicyKind::Lars,
            RoutingMode::Routed,
            adm,
            7,
        );
        let mut a = a;
        let (sa, sb) = (a.sim.metrics.summary(), b.sim.metrics.summary());
        assert_eq!(sa.finished, sb.finished);
        assert_eq!(sa.goodput_rps.to_bits(), sb.goodput_rps.to_bits());
        assert_eq!(sa.n_shed, sb.n_shed);
        assert_eq!(sa.n_rejected_queue_full, sb.n_rejected_queue_full);
        assert_eq!(a.n_offered(), b.n_offered());
    }

    #[test]
    fn bounded_queues_never_exceed_their_limits() {
        let cfg = OpenLoopConfig {
            overcommit_mult: 3.0,
            ..small_cfg()
        };
        let adm = AdmissionConfig {
            short: BucketConfig {
                rate_per_s: cfg.base_rate_per_s,
                burst: 4.0,
                queue_limit: 10,
            },
            doc: BucketConfig {
                rate_per_s: 0.2,
                burst: 1.0,
                queue_limit: 2,
            },
            doc_threshold: cfg.doc_prompt,
            ..AdmissionConfig::default()
        };
        let serve = run_serve_scenario(
            Scenario::Overcommit,
            &cfg,
            SchedPolicyKind::Lars,
            RoutingMode::Routed,
            adm,
            42,
        );
        assert!(
            serve.admission().short_q_high_water <= 10,
            "short high water {}",
            serve.admission().short_q_high_water
        );
        assert!(
            serve.admission().doc_q_high_water <= 2,
            "doc high water {}",
            serve.admission().doc_q_high_water
        );
        // 3x overcommit against paced buckets must overflow something
        let mut serve = serve;
        let s = serve.sim.metrics.summary();
        assert!(
            s.n_rejected_queue_full > 0,
            "3x overcommit never overflowed a bounded queue"
        );
        assert_eq!(s.n_rejected_queue_full, s.n_rejected_short + s.n_rejected_doc);
    }

    #[test]
    fn injected_deferral_pressure_sheds_projected_late_arrivals() {
        // Deterministic exercise of the SLO-feedback path: pre-load the
        // rolling deferral-wait distribution far past every short
        // request's TTFT budget, then serve. Every short arrival projects
        // negative slack and is shed at the door; admitted work still
        // completes.
        let cfg = small_cfg();
        let dep = serve_scenario_dep(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg);
        let source = generate(Scenario::Overcommit, &cfg, 42);
        let n_docs = source.iter().filter(|r| cfg.is_doc(r.prompt_len)).count();
        assert!(n_docs > 0, "scenario must contain documents");
        let adm = AdmissionConfig {
            shed_deferral_frac: 0.5,
            doc_threshold: cfg.doc_prompt,
            ..AdmissionConfig::default()
        };
        let mut serve = ServeSim::new(dep, source, SimOptions::default(), adm);
        for _ in 0..50 {
            serve.sim.metrics.record_deferral_wait(1_000.0);
        }
        serve.run();
        let s = serve.sim.metrics.summary();
        assert!(s.n_shed > 0, "no arrival was shed under crushing pressure");
        assert!(s.n_shed_short > 0, "shorts project late first");
        assert_eq!(s.n_shed, s.n_shed_short + s.n_shed_doc);
        assert_eq!(s.n_rejected_queue_full, 0, "unbounded queues never reject");
    }

    #[test]
    fn flash_and_diurnal_scenarios_complete_and_meter() {
        for scenario in [Scenario::Flash, Scenario::Diurnal] {
            let cfg = OpenLoopConfig {
                horizon_s: 8.0,
                ..small_cfg()
            };
            let mut serve = run_serve_scenario(
                scenario,
                &cfg,
                SchedPolicyKind::Lars,
                RoutingMode::Routed,
                AdmissionConfig::protective(cfg.base_rate_per_s, cfg.doc_prompt),
                11,
            );
            let s = serve.sim.metrics.summary();
            let dropped = s.n_shed + s.n_rejected_queue_full;
            assert_eq!(
                serve.cursor as u64,
                s.finished + dropped + serve.sim.n_live() as u64,
                "{}: every offered arrival is finished, dropped, or live",
                scenario.name()
            );
            assert!(s.finished > 0, "{}: nothing finished", scenario.name());
        }
    }
}
