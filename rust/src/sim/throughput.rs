//! Simulator-core throughput measurement (`sim/throughput`,
//! `sim/million`): how many scheduler iterations per wall-second the core
//! sustains, and whether a million-request mixed trace completes end to
//! end with bounded memory.
//!
//! The functions here are shared by `benches/hotpath.rs` (which records
//! the results into `BENCH_sim.json`) and the `bench_smoke` integration
//! test (which runs a down-scaled version under `MEDHA_BENCH_SMOKE=1` to
//! keep the bench path compiling and its JSON valid).
//!
//! Wall-clock note: D2-allowlisted (`medha lint`) — steps/wall-second is
//! the *measurement*; simulated time advances only by the perf model.

use std::time::Instant;

use super::{SimOptions, Simulation};
use crate::config::DeploymentConfig;
use crate::util::json::Json;
use crate::workload::{self, LengthDist, RequestSpec};

/// One simulator throughput measurement.
#[derive(Debug, Clone)]
pub struct SimThroughput {
    pub name: String,
    pub requests: usize,
    pub finished: u64,
    pub iterations: u64,
    pub wall_s: f64,
    pub iters_per_s: f64,
    pub sim_span_s: f64,
    pub arena_high_water: usize,
}

impl SimThroughput {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("requests", self.requests.into()),
            ("finished", self.finished.into()),
            ("iterations", self.iterations.into()),
            ("wall_s", self.wall_s.into()),
            ("iters_per_s", self.iters_per_s.into()),
            ("sim_span_s", self.sim_span_s.into()),
            ("arena_high_water", self.arena_high_water.into()),
        ])
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<52} {:>10.0} iters/s  ({} iters, {} reqs, {:.2}s wall)",
            self.name, self.iters_per_s, self.iterations, self.requests, self.wall_s
        )
    }
}

/// Deployment used for throughput runs: static chunking (the cheap policy)
/// so the measurement isolates the simulator core, not the predictor.
pub fn throughput_dep(kvp: u32) -> DeploymentConfig {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, kvp);
    dep.scheduler.adaptive_chunking = false;
    dep.scheduler.static_chunk = 2048;
    dep
}

/// Decode-heavy steady state: `n_decoders` short requests decoding
/// `tokens_each` output tokens in lockstep. Every simulator iteration is
/// one small mixed batch, so iterations/sec measures the core's
/// per-iteration overhead (batch formation, pipeline flow, bookkeeping)
/// rather than perf-model arithmetic over huge batches.
pub fn decode_stream_workload(n_decoders: usize, tokens_each: u64) -> Vec<RequestSpec> {
    (0..n_decoders)
        .map(|i| RequestSpec {
            id: i as u64,
            prompt_len: 256,
            max_new_tokens: tokens_each,
            ..RequestSpec::default()
        })
        .collect()
}

/// Mixed production-like trace: Poisson arrivals, Zipf-skewed short
/// context lengths, plus `n_long` genuinely long (KVP-sharded) requests
/// spread across the horizon — section 3's C3 heterogeneity at trace
/// scale.
pub fn mixed_million_workload(n_requests: usize, n_long: usize, seed: u64) -> Vec<RequestSpec> {
    let n_short = n_requests.saturating_sub(n_long);
    // Arrival rate chosen so the trace spans ~500 simulated seconds
    // regardless of size; lengths stay below the default long threshold.
    let horizon_s = 500.0;
    let rate = n_short as f64 / horizon_s;
    let mut w = workload::poisson_mixed(
        rate.max(1.0),
        horizon_s,
        LengthDist::ZipfBuckets {
            buckets: vec![128, 512, 2048, 8192],
            s: 1.2,
        },
        4,
        seed,
    );
    w.truncate(n_short);
    let next_id = w.len() as u64;
    for i in 0..n_long {
        w.push(RequestSpec {
            id: next_id + i as u64,
            prompt_len: 100_000,
            max_new_tokens: 8,
            arrival_s: (i as f64 + 0.5) / n_long.max(1) as f64 * horizon_s,
            ..RequestSpec::default()
        });
    }
    w
}

/// Run `workload` through the optimized simulator in lean mode and report
/// iteration throughput.
pub fn run_sim_throughput(
    name: &str,
    dep: DeploymentConfig,
    workload: Vec<RequestSpec>,
) -> SimThroughput {
    let n = workload.len();
    let opts = SimOptions {
        retain_finished: false,
        metrics_reservoir: Some(4096),
        ..SimOptions::default()
    };
    let mut sim = Simulation::new(dep, workload, opts);
    let t0 = Instant::now();
    let span = sim.run();
    let wall_s = t0.elapsed().as_secs_f64();
    let iterations = sim.metrics.n_iters;
    SimThroughput {
        name: name.to_string(),
        requests: n,
        finished: sim.metrics.finished_requests,
        iterations,
        wall_s,
        iters_per_s: iterations as f64 / wall_s.max(1e-12),
        sim_span_s: span,
        arena_high_water: sim.arena_high_water(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_stream_reports_throughput() {
        let r = run_sim_throughput(
            "sim/throughput decode-stream (test)",
            throughput_dep(1),
            decode_stream_workload(8, 500),
        );
        assert_eq!(r.finished, 8);
        // ~one iteration per decode step across the lockstep batch
        assert!(r.iterations >= 500, "iterations={}", r.iterations);
        assert!(r.iters_per_s > 0.0);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("finished").and_then(|x| x.as_u64()), Some(8));
    }

    #[test]
    fn mixed_workload_shapes() {
        let w = mixed_million_workload(1_000, 10, 7);
        assert!(w.len() <= 1_000);
        assert_eq!(w.iter().filter(|r| r.prompt_len == 100_000).count(), 10);
        // ids unique
        let mut ids: Vec<u64> = w.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), w.len());
    }

    #[test]
    fn mixed_trace_completes_with_bounded_arena() {
        let r = run_sim_throughput(
            "sim/million mixed (down-scaled test)",
            throughput_dep(2),
            mixed_million_workload(2_000, 4, 11),
        );
        assert_eq!(r.finished as usize, r.requests);
        assert!(r.sim_span_s < 86_400.0, "hit the horizon: {}", r.sim_span_s);
        // memory tracked concurrency, not trace length
        assert!(
            r.arena_high_water < r.requests,
            "arena high-water {} vs {} requests",
            r.arena_high_water,
            r.requests
        );
    }
}
