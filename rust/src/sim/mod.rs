//! Cluster simulator: executes the coordinator's scheduling decisions
//! against the perf model's time charges, at the paper's 128-GPU scale.
//!
//! The simulated unit is one **cooperating KVP set** (Fig. 12): `kvp`
//! worker groups, each a pipeline of `spp` stages of `tp` GPUs. Short
//! requests are routed to individual groups and batched independently; a
//! long request is chunk-prefilled (adaptive sizing), its KV sharded across
//! groups with dynamic onboarding (Fig. 10), and its chunk/decode queries
//! are broadcast to all participating groups with online-softmax merge —
//! exactly the execution model of section 4.
//!
//! Timing model:
//! * every group's mixed batch flows through its stage pipeline
//!   (`PipelineTimeline`);
//! * prefill-only batches are admitted **densely** (SPP, Fig. 9b);
//! * batches containing decode tokens serialize on pipeline exit
//!   (autoregressive dependency);
//! * cooperative iterations (sharded long request) complete at the max of
//!   the participating groups' exits, plus the KVP merge charge.

use std::collections::{BTreeMap, VecDeque};

use crate::config::DeploymentConfig;
use crate::coordinator::chunking::ChunkPolicy;
use crate::coordinator::request::{Phase, Request};
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::spp::PipelineTimeline;
use crate::coordinator::{AdaptiveChunk, KvpManager, Router, StaticChunk, Topology};
use crate::kvcache::RequestId;
use crate::metrics::{IterRecord, Metrics};
use crate::perfmodel::{BatchShape, DecodeWork, PerfModel, PrefillWork};
use crate::workload::RequestSpec;

/// Simulation options beyond the deployment config.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Requests with prompts longer than this are treated as "long":
    /// chunked, KVP-sharded, driven cooperatively.
    pub long_threshold: u64,
    /// Stop after this much simulated time (safety valve).
    pub horizon_s: f64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            long_threshold: 16_384,
            horizon_s: 86_400.0,
        }
    }
}

pub struct Simulation {
    pub dep: DeploymentConfig,
    pub opts: SimOptions,
    pm: PerfModel,
    layers_per_stage: u32,
    policy: Box<dyn ChunkPolicy>,
    topo: Topology,

    requests: BTreeMap<RequestId, Request>,
    pending: VecDeque<RequestSpec>,
    /// Per-group short-request schedulers.
    scheds: Vec<Scheduler>,
    timelines: Vec<PipelineTimeline>,
    long_queue: VecDeque<RequestId>,
    active_long: Option<RequestId>,
    kvp_mgr: KvpManager,
    router: Router,
    pub metrics: Metrics,
    now: f64,
}

impl Simulation {
    pub fn new(dep: DeploymentConfig, workload: Vec<RequestSpec>, opts: SimOptions) -> Simulation {
        dep.validate().expect("invalid deployment");
        let pm = PerfModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
        let kvp_groups = dep.parallel.kvp.max(1);
        let policy: Box<dyn ChunkPolicy> = if dep.scheduler.adaptive_chunking {
            Box::new(AdaptiveChunk::new(dep.scheduler.chunk_sizes.clone()))
        } else {
            Box::new(StaticChunk(dep.scheduler.static_chunk))
        };
        let mut pending: Vec<RequestSpec> = workload;
        pending.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let layers_per_stage = dep.model.n_layers / dep.parallel.spp.max(1);
        let topo = Topology::new(dep.parallel, &dep.hardware);
        Simulation {
            pm,
            layers_per_stage,
            policy,
            topo,
            requests: BTreeMap::new(),
            pending: pending.into(),
            scheds: (0..kvp_groups)
                .map(|_| {
                    Scheduler::new(
                        Box::new(StaticChunk(dep.scheduler.static_chunk)),
                        dep.scheduler.max_batch_size,
                    )
                })
                .collect(),
            timelines: (0..kvp_groups)
                .map(|_| PipelineTimeline::new(dep.parallel.spp.max(1) as usize, 0.0))
                .collect(),
            long_queue: VecDeque::new(),
            active_long: None,
            kvp_mgr: KvpManager::new(dep.scheduler.kvp_onboard_threshold, kvp_groups),
            router: Router::new(kvp_groups),
            metrics: Metrics::new(),
            now: 0.0,
            dep,
            opts,
        }
    }

    fn admit_arrivals(&mut self) {
        while let Some(spec) = self.pending.front() {
            if spec.arrival_s > self.now {
                break;
            }
            let spec = self.pending.pop_front().unwrap();
            let r = Request::new(spec.id, spec.prompt_len, spec.max_new_tokens, spec.arrival_s);
            if spec.prompt_len > self.opts.long_threshold {
                let g = self.router.route(spec.id, spec.prompt_len);
                self.kvp_mgr.onboard_request(spec.id, g, self.now);
                self.long_queue.push_back(spec.id);
            } else {
                let g = self.router.route(spec.id, spec.prompt_len);
                self.scheds[g as usize].enqueue(spec.id);
            }
            self.requests.insert(spec.id, r);
        }
        if self.active_long.is_none() {
            self.active_long = self.long_queue.pop_front();
        }
    }

    fn has_work(&self) -> bool {
        self.active_long.is_some()
            || !self.long_queue.is_empty()
            || self.scheds.iter().any(|s| s.has_work())
    }

    /// Local KV length the group's kernels scan for a short request.
    fn short_local_kv(r: &Request) -> u64 {
        r.kv_len().max(1)
    }

    /// Run the simulation to completion (or horizon). Returns total time.
    pub fn run(&mut self) -> f64 {
        loop {
            self.admit_arrivals();
            if !self.has_work() {
                match self.pending.front() {
                    Some(spec) => {
                        self.now = spec.arrival_s;
                        for tl in &mut self.timelines {
                            tl.advance_to(self.now);
                        }
                        continue;
                    }
                    None => break,
                }
            }
            if self.now > self.opts.horizon_s {
                break;
            }
            self.step();
        }
        self.now
    }

    /// One lockstep iteration across the cooperating set.
    fn step(&mut self) {
        let n_groups = self.scheds.len();
        let slo = self.dep.slo;

        // ---- long-request work selection -------------------------------
        let long_id = self.active_long;
        let mut long_chunk: Option<u64> = None;
        let mut long_decode = false;
        if let Some(id) = long_id {
            let r = &self.requests[&id];
            match r.phase {
                Phase::Queued | Phase::Prefilling => {
                    // decode contexts seen by the chunk policy: the busiest
                    // group's decode load (binding constraint).
                    let decode_ctxs: Vec<u64> = (0..n_groups)
                        .map(|_| 0u64)
                        .collect::<Vec<_>>()
                        .iter()
                        .enumerate()
                        .flat_map(|(g, _)| self.group_decode_ctxs(g))
                        .collect();
                    let c = self.policy.next_chunk(
                        r.kv_len(),
                        r.remaining_prefill(),
                        &decode_ctxs,
                        &self.pm,
                        &slo,
                    );
                    long_chunk = Some(c.max(1).min(r.remaining_prefill()));
                }
                Phase::Decoding => long_decode = true,
                Phase::Finished => {}
            }
        }
        let long_nq = long_chunk.unwrap_or(if long_decode { 1 } else { 0 });
        let participating: Vec<(u32, u64)> = match long_id {
            Some(id) if long_nq > 0 => self.kvp_mgr.local_lengths(id),
            _ => Vec::new(),
        };

        // ---- per-group batch formation ----------------------------------
        let mut group_plans = Vec::with_capacity(n_groups);
        for g in 0..n_groups {
            let plan = self.scheds[g].next_batch(&self.requests, &self.pm, &slo, Self::short_local_kv);
            group_plans.push(plan);
        }

        // ---- build shapes and flow through pipelines ---------------------
        let mut any_decode = long_decode;
        let mut exits = vec![self.now; n_groups];
        let mut max_stage0_exit = self.now;
        let mut worked = false;
        let mut combined = BatchShape::default();
        for g in 0..n_groups {
            let mut shape = self.scheds[g].batch_shape(&group_plans[g], &self.requests, Self::short_local_kv);
            // Long-request share on this group: partial attention over the
            // local shard (queries broadcast to every participating group).
            if let Some(&(_, local)) = participating.iter().find(|&&(gg, _)| gg as usize == g) {
                if let Some(c) = long_chunk {
                    shape.prefills.push(PrefillWork {
                        chunk: c,
                        kv_len: local + c,
                    });
                } else if long_decode {
                    shape.decodes.push(DecodeWork {
                        kv_len: local.max(1),
                    });
                }
            }
            if shape.is_empty() {
                continue;
            }
            worked = true;
            any_decode |= !shape.decodes.is_empty();
            combined.prefills.extend(shape.prefills.iter().copied());
            combined.decodes.extend(shape.decodes.iter().copied());
            let st = self.pm.stage_time(&shape, self.layers_per_stage).total();
            let hop = self.pm.stage_hop_s(shape.tokens());
            let dense_ok = shape.decodes.is_empty();
            let ready = if dense_ok {
                self.timelines[g].stage0_free().max(self.now)
            } else {
                self.now
            };
            let res = self.timelines[g].flow(ready, |_| st, hop);
            max_stage0_exit = max_stage0_exit.max(res.first_stage_exit());
            exits[g] = res.exit();
        }

        if !worked {
            // nothing runnable this instant (e.g. long queue only, already
            // finished): bump time slightly to make progress.
            self.now += 1e-6;
            return;
        }

        let mut iter_end = exits.iter().cloned().fold(self.now, f64::max);
        // KVP merge charge for cooperative work.
        if participating.len() > 1 && long_nq > 0 {
            iter_end += self.pm.kvp_merge_s(long_nq);
        }

        // Next admission point: dense for pure-prefill, serialized otherwise.
        let t_next = if any_decode { iter_end } else { max_stage0_exit };
        let dur = iter_end - self.now;

        // ---- bookkeeping --------------------------------------------------
        // Short requests finish per their group plans.
        for g in 0..n_groups {
            let plan = group_plans[g].clone();
            if plan.is_empty() {
                continue;
            }
            let finished = self.scheds[g].complete_iteration(&plan, &mut self.requests, iter_end);
            for id in finished {
                let r = &self.requests[&id];
                if let Some(t) = r.ttft() {
                    self.metrics.record_ttft(t);
                }
                for &s in &r.tbt_samples {
                    self.metrics.record_tbt(s);
                }
                self.metrics.finished_requests += 1;
                self.router.release(id, r.prompt_len);
            }
        }
        // Long request progress.
        if let Some(id) = long_id {
            if let Some(c) = long_chunk {
                let r = self.requests.get_mut(&id).unwrap();
                r.complete_chunk(c, iter_end);
                self.kvp_mgr.append_tokens(id, c, iter_end);
                if r.phase == Phase::Decoding || r.phase == Phase::Finished {
                    if let Some(t) = r.ttft() {
                        self.metrics.record_ttft(t);
                    }
                }
            } else if long_decode {
                let r = self.requests.get_mut(&id).unwrap();
                r.complete_decode(iter_end);
                self.kvp_mgr.append_tokens(id, 1, iter_end);
            }
            let r = &self.requests[&id];
            if r.is_finished() {
                for &s in &r.tbt_samples {
                    self.metrics.record_tbt(s);
                }
                self.metrics.finished_requests += 1;
                self.kvp_mgr.release(id);
                self.router.release(id, r.prompt_len);
                self.active_long = None;
            }
        }

        let active_gpus = match long_id {
            Some(id) => self
                .topo
                .gpus_active(self.kvp_mgr.active_groups(id).max(1)),
            None => self.topo.parallel.workers_per_replica(),
        };
        if dur > 0.0 {
            self.metrics
                .mfu
                .add(self.pm.mfu(&combined, dur, active_gpus.max(1)));
            self.metrics
                .mbu
                .add(self.pm.mbu(&combined, dur, active_gpus.max(1)));
        }
        self.metrics.record_iter(IterRecord {
            t: iter_end,
            dur_s: dur,
            chunk: long_chunk.or_else(|| {
                group_plans
                    .iter()
                    .find_map(|p| p.prefill.map(|(_, c)| c))
            }),
            n_decodes: combined.decodes.len(),
            active_gpus,
        });
        self.now = t_next;
    }

    fn group_decode_ctxs(&self, g: usize) -> Vec<u64> {
        let slo = self.dep.slo;
        // peek: decoding requests on this group's scheduler
        let mut v = Vec::new();
        let _ = (&slo, &mut v);
        for (id, r) in &self.requests {
            if r.phase == Phase::Decoding && self.router.group_of(*id) == Some(g as u32) {
                v.push(r.kv_len().max(1));
            }
        }
        v
    }

    pub fn request(&self, id: RequestId) -> Option<&Request> {
        self.requests.get(&id)
    }

    pub fn kvp_onboard_log(&self) -> &[(f64, RequestId, u32)] {
        &self.kvp_mgr.onboard_log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::workload;

    fn dep(tp: u32, spp: u32, kvp: u32) -> DeploymentConfig {
        DeploymentConfig::llama3_8b_tp8().with_parallel(tp, spp, kvp)
    }

    #[test]
    fn single_short_request_completes() {
        let w = workload::single_long(1_000, 8); // below long threshold
        let mut sim = Simulation::new(dep(8, 1, 1), w, SimOptions::default());
        sim.run();
        let r = sim.request(0).unwrap();
        assert!(r.is_finished());
        assert!(r.ttft().unwrap() > 0.0);
        assert_eq!(sim.metrics.finished_requests, 1);
    }

    #[test]
    fn long_request_prefill_records_ttft() {
        let w = workload::single_long(1_000_000, 4);
        let mut sim = Simulation::new(dep(8, 4, 1), w, SimOptions::default());
        sim.run();
        let r = sim.request(0).unwrap();
        assert!(r.is_finished());
        let ttft = r.ttft().unwrap();
        // 1M tokens on 32 H100-class GPUs: tens of seconds
        assert!((1.0..200.0).contains(&ttft), "ttft={ttft}");
    }

    #[test]
    fn spp_reduces_ttft_vs_single_stage() {
        let run = |spp: u32| {
            let w = workload::single_long(1_000_000, 4);
            let mut sim = Simulation::new(dep(8, spp, 1), w, SimOptions::default());
            sim.run();
            sim.request(0).unwrap().ttft().unwrap()
        };
        let t1 = run(1);
        let t4 = run(4);
        let speedup = t1 / t4;
        assert!(speedup > 3.0, "speedup={speedup} (t1={t1}, t4={t4})");
    }

    #[test]
    fn kvp_onboards_groups_as_context_grows() {
        let mut d = dep(8, 1, 4);
        d.scheduler.kvp_onboard_threshold = 256_000;
        let w = workload::single_long(1_000_000, 4);
        let mut sim = Simulation::new(d, w, SimOptions::default());
        sim.run();
        // 1M / 256K -> 4 groups onboarded
        assert_eq!(sim.kvp_onboard_log().len(), 4);
        let gpus: Vec<u32> = sim.metrics.iters.iter().map(|i| i.active_gpus).collect();
        assert!(gpus.iter().any(|&g| g == 8));
        assert!(gpus.iter().any(|&g| g == 32));
        // staircase: non-decreasing while the long request runs
        let peak = gpus.iter().copied().max().unwrap();
        assert_eq!(peak, 32);
    }

    #[test]
    fn mixed_batching_keeps_decodes_flowing() {
        // Decodes batched alongside a 1M prefill must see bounded TBT —
        // the anti-HOL-blocking claim (Fig. 14b).
        let mut d = dep(8, 1, 1);
        d.scheduler.max_batch_size = 64;
        let w = workload::long_plus_decodes(500_000, 8, 1_000, 64);
        let mut sim = Simulation::new(d, w, SimOptions::default());
        sim.run();
        let mut m = sim.metrics;
        let s = m.summary();
        assert!(s.n_tbt > 0);
        // every decode token arrived within a bounded iteration (<300ms),
        // not after the full multi-second prefill
        assert!(s.tbt_max < 0.3, "tbt_max={}", s.tbt_max);
        assert_eq!(s.finished, 9);
    }

    #[test]
    fn adaptive_chunks_shrink_over_long_prefill() {
        let mut d = dep(8, 1, 1);
        d.scheduler.adaptive_chunking = true;
        let w = workload::long_plus_decodes(2_000_000, 16, 1_000, 400);
        let mut sim = Simulation::new(d, w, SimOptions::default());
        sim.run();
        let chunks: Vec<u64> = sim.metrics.iters.iter().filter_map(|i| i.chunk).collect();
        assert!(chunks.len() > 10);
        let first = chunks[0];
        let last_quartile: Vec<u64> = chunks[chunks.len() * 3 / 4..].to_vec();
        let late_max = last_quartile.iter().copied().max().unwrap();
        assert!(
            late_max <= first,
            "late chunks ({late_max}) should not exceed early ({first})"
        );
    }

    #[test]
    fn arrivals_respected() {
        let w = vec![
            RequestSpec {
                id: 0,
                prompt_len: 100,
                max_new_tokens: 4,
                arrival_s: 0.0,
            },
            RequestSpec {
                id: 1,
                prompt_len: 100,
                max_new_tokens: 4,
                arrival_s: 1_000.0,
            },
        ];
        let mut sim = Simulation::new(dep(8, 1, 1), w, SimOptions::default());
        let end = sim.run();
        assert!(end >= 1_000.0);
        let r1 = sim.request(1).unwrap();
        assert!(r1.first_token_s.unwrap() >= 1_000.0);
    }
}
