//! Cluster simulator: executes the coordinator's scheduling decisions
//! against the perf model's time charges, at the paper's 128-GPU scale.
//!
//! The simulated unit is one **cooperating KVP set** (Fig. 12): `kvp`
//! worker groups, each a pipeline of `spp` stages of `tp` GPUs. Short
//! requests are routed to individual groups and batched independently; a
//! long request is chunk-prefilled (adaptive sizing), its KV sharded across
//! groups with dynamic onboarding (Fig. 10), and its chunk/decode queries
//! are broadcast to all participating groups with online-softmax merge —
//! exactly the execution model of section 4.
//!
//! Scheduling: every request is admitted with a length-aware TTFT deadline
//! and a perf-model work estimate; the deployment's
//! [`SchedPolicyKind`](crate::coordinator::SchedPolicyKind) (FCFS / SRPT /
//! EDF / LARS, `scheduler.policy`) orders each group's ready set and the
//! long-request queue, with preemption at chunk boundaries. Per-request
//! deadline attainment and goodput land in [`Metrics`].
//!
//! Routing: placement across KVP groups follows the deployment's
//! [`RoutingMode`] (`scheduler.routing`). All three modes run through the
//! **single pool-scheduled execution path** of [`Simulation::step`]: every
//! group owns an iteration clock (`free_at`), the members of the
//! **cooperative set** iterate together (completing at the set's max exit
//! plus the KVP merge charge), and every other group serves short traffic
//! independently on its own clock (section 7). The modes differ only in
//! how they configure that one path:
//!
//! * `blind` — least-loaded placement through the same [`GroupView`] hook
//!   the routed mode uses (capacity filter waived), with **every** group a
//!   member of the cooperative set. The per-group clocks therefore stay
//!   equal and the schedule degenerates to the original lockstep iteration
//!   semantics (the pre-pool behavior, pinned by the recorded golden
//!   snapshots in `tests/sim_golden.rs`); the active long request holds
//!   the cooperative slot to completion.
//! * `round-robin` — strictly alternating placement; only the shard
//!   holders of the active long request cooperate, the rest pool-serve.
//! * `routed` — placement (the long-request *primary* included) delegated
//!   to the policy's urgency-aware [`GroupView`] hook; a preemptive policy
//!   may additionally yield the **active** sharded prefill at a chunk
//!   boundary (KV shards retained, resume bit-exact, recorded as
//!   [`PreemptionEvent`](crate::metrics::PreemptionEvent)s).
//!
//! Routed admission is **capacity-aware**: with a finite
//! `scheduler.kvp_capacity_tokens`, the routing hook refuses groups
//! without room for a request's full KV footprint; refusals are counted
//! (`Metrics::routing_refusals`) and the admission deferred until capacity
//! frees. The deferred set is ordered by the scheduling policy's own
//! priority — FIFO under FCFS, most-urgent-first under SRPT/EDF/LARS — so
//! a deadline-critical short never waits out a slack-rich one that merely
//! arrived earlier, and each deferral's wait time is recorded in
//! [`Metrics::deferral_wait`]. Every per-group signal the hook reads —
//! urgency counts, free capacity, load — is incrementally maintained O(1)
//! state, so an admission costs O(groups) even at million-request
//! backlogs.
//!
//! # Prefix-aware KV reuse (`scheduler.prefix_reuse`)
//!
//! With reuse on, every short admission consults the hash-consed prefix
//! index ([`crate::kvcache::PrefixIndex`]): a request whose
//! `(prefix_ns, sys_tokens)` identity matches a resident chain can skip
//! prefilling the resident span *if* it is placed on the chain's owner
//! group. The hit threads through every layer it touches:
//!
//! * **Estimates & deadlines** — a granted request's `est_prefill_s`
//!   covers only the remaining span
//!   ([`PerfModel::prefill_time_spp_resume`](crate::perfmodel::PerfModel::prefill_time_spp_resume)),
//!   so its TTFT deadline tightens and LARS slack stays honest.
//! * **Routing** — the placement views carry the pending request's hit on
//!   the owner group ([`GroupView::prefix_hit_tokens`]); the policy hooks
//!   subtract it from effective load and relax the capacity check by the
//!   resident span, *after* the anti-starvation urgency terms. Blind and
//!   round-robin placements ignore the hit but still grant on a
//!   coincidental landing.
//! * **Ledger** — shared blocks are charged once to the KVP ledger's
//!   `shared` column ([`KvpManager::charge_shared`]); a granted request
//!   reserves its footprint *minus* the resident span. A crash returns
//!   the column wholesale, drops the group's chains, and meters the
//!   victims' shared spans as `Metrics::reprefill_shared_tokens`; a drain
//!   drops its group's (pure-cache) chains once no request holds them.
//! * **Lifecycle** — finish releases the pinned node and indexes the
//!   finished KV (prompt + generated tokens) as the next turn's chain;
//!   refcount-0 chains past the block budget evict LRU-by-sim-time.
//!
//! With `prefix_reuse = false` (the default) the index is never
//! constructed and every path above degenerates to the pre-reuse code,
//! bit for bit — pinned by the recorded golden snapshots.
//!
//! # Elastic fleet & deterministic failure injection
//!
//! The KVP fleet is a **runtime object**, not a constructor constant:
//! every group slot carries a lifecycle state
//! ([`GroupState`](crate::coordinator::GroupState) — `Active`, `Draining`,
//! `Joining`, `Down`) and every placement decision (routing views,
//! round-robin cursors, KV shard growth, capacity reservations) consults
//! live membership instead of `0..n_groups`. A
//! [`FaultPlan`](crate::config::FaultPlan) (`SimOptions::faults`) schedules
//! crashes, drains, joins, and transient slowdowns at precise simulation
//! times; the run loop applies every event whose time has been reached
//! before admitting arrivals, so a plan replays bit-identically. An empty
//! plan leaves every code path exactly on the fault-free trajectory (the
//! recorded golden snapshots pin this).
//!
//! **Crash recovery** (`crash` events): the dead group's ledger occupancy
//! and short reservations return to the conservation invariant instantly
//! ([`KvpManager::crash_group`]); every long request holding a shard there
//! is rewound to its **last surviving chunk boundary** — the KV prefix on
//! surviving groups is retained, only the lost range re-prefills
//! ([`Request::rewind_prefill`]) — and re-queued under its post-rewind
//! priority; shorts resident on the group lose their KV wholly and
//! re-admit from scratch. The degradation bill lands in [`Metrics`]:
//! `group_crashes`, `shards_lost`, `reprefill_tokens`, and per-victim
//! `recovery_wait` percentiles. A full-restart baseline
//! (`baselines/disagg.rs`) pays the *entire* context again; the
//! `reproduce --figure faults` table compares the two.
//!
//! Timing model:
//! * every group's mixed batch flows through its stage pipeline
//!   (`PipelineTimeline`);
//! * prefill-only batches are admitted **densely** (SPP, Fig. 9b);
//! * batches containing decode tokens serialize on pipeline exit
//!   (autoregressive dependency);
//! * cooperative iterations (sharded long request) complete at the max of
//!   the participating groups' exits, plus the KVP merge charge.
//!
//! # Simulator-core architecture (arena + allocation-free iteration)
//!
//! The hot loop is built to sustain >10⁶ iterations per wall-second on
//! million-request traces (the scale at which tail percentiles stabilize):
//!
//! * **Arena request store** — requests live in a dense
//!   [`RequestArena`](crate::coordinator::RequestArena) and every
//!   coordinator structure (scheduler queues, router placement, KVP shard
//!   maps) refers to them by [`Slot`] handle: request touches are array
//!   indexing, not `BTreeMap` descents, and retired slots are recycled so
//!   memory tracks *concurrency*, not trace length.
//! * **Allocation-free iteration** — `step()` reuses per-group scratch
//!   (`BatchPlan`s, one `BatchShape`, exit/context buffers) via the
//!   scheduler's `next_batch_into`/`batch_shape_into`/
//!   `complete_iteration_into` APIs; the steady state performs no heap
//!   allocation per iteration. Decode contexts are tracked incrementally by
//!   each scheduler instead of being rebuilt from the request map.
//! * **Indexed ready sets** — preemptive selection is served by each
//!   scheduler's [`ReadySet`](crate::coordinator::ReadySet) (O(log n),
//!   bit-identical to the O(n) priority scan it replaced — asserted by a
//!   per-selection `debug_assert` and the differential harness in
//!   `tests/invariants.rs`), so deep backlogs no longer pay a linear scan
//!   per iteration; the `sched/select` bench records the win. The
//!   dedicated **long-request queue** and the **capacity-deferred
//!   admission set** are `ReadySet`-indexed too, so document-heavy
//!   workloads and deep deferral backlogs never regress to linear scans.
//! * **Event-driven time advance** — when an instant has no runnable work
//!   the clock jumps to the next event (arrival or earliest group
//!   admission point) instead of spinning in 1e-6 s bumps.
//! * **Streaming metrics** — `SimOptions::metrics_reservoir` switches
//!   [`Metrics`] to reservoir-sampled percentiles with the per-iteration
//!   trace dropped, bounding memory on multi-million-sample runs; by
//!   default metrics are exact and **bit-deterministic**: the recorded
//!   golden snapshots in `tests/sim_golden.rs` assert identical metric
//!   streams across runs for every policy × routing combination.
//!
//! # Parallel step (`scheduler.threads > 1`)
//!
//! `step()` is split into two phases. **Phase A** — batch formation,
//! long-share injection, and pipeline flow ([`group_phase_a`]) — touches
//! only *per-group* mutable state (that group's `Scheduler`,
//! `PipelineTimeline`, `BatchPlan`, and `BatchShape` scratch) plus shared
//! **immutable** reads ([`StepCtx`]: the request arena, perf model, KVP
//! ledger, shard map, slowdowns). **Phase B** — metrics recording,
//! cooperative-set accumulation, clock updates, and plan completion
//! (`merge_group_outcome`) — is serial. With `scheduler.threads > 1` the
//! phase-A calls fan out across a persistent [`ThreadPool`] (borrowed jobs
//! via `ThreadPool::scoped`, one pre-sized result slot per group) and the
//! reduction merges the slots **in group-index order**, so metric streams,
//! clocks, and the capacity ledger are byte-identical to the serial
//! schedule. The serial path (`threads = 1`, the default) keeps the
//! original interleaving — merge group *g* before forming group *g+1*'s
//! batch — so the determinism tests in `tests/sim_golden.rs` compare the
//! parallel reduction against unchanged semantics.
//!
//! Why the fan-out is safe *and* deterministic: a request belongs to
//! exactly one group's scheduler, so phase A(g) never reads state phase
//! B(g′≠g) mutates within the same instant — completions retire arena
//! slots, release reservations, and free router lanes, but none of that
//! feeds another group's batch formation until the *next* admission
//! instant (slot recycling happens only at admission-time inserts). The
//! per-group results are therefore independent of execution order, and
//! merging them in index order reproduces the serial schedule bit-exactly.
//!
//! Benches: `sim/mixed 100K-prefill + 8 decodes` plus `sim/throughput
//! decode-stream`, `sim/million mixed`, and the serial-vs-threaded
//! `sim/parallel_step` pair live in `benches/hotpath.rs`, which records
//! results (including `sim_parallel_speedup` and the concurrent
//! policy × routing × load `sweep`, see [`sweep`]) to `BENCH_sim.json`.
//!
//! # Determinism contract
//!
//! Everything above is only verifiable because the simulator is **bit
//! deterministic**: the golden snapshots (`tests/sim_golden.rs`) assume a
//! run is a pure function of (deployment, workload, seed, fault plan);
//! the thread-matrix tests assume `--threads 1/2/4` agree bit-exactly;
//! the sweep assumes outcomes are worker-count invariant; and the
//! open-loop serving tests assume Lewis–Shedler arrival draws replay
//! exactly. Four coding rules carry that weight, and they are enforced
//! *statically* by `medha lint` (see `util::lint`, run by
//! `tests/lint.rs` on every `cargo test` and by the `medha lint`
//! subcommand / CI step):
//!
//! * **D1** no `HashMap`/`HashSet` in sim / coordinator / kvcache /
//!   workload / config / metrics state — hash iteration order varies per
//!   process, so one stray iteration scrambles replay. Use `BTreeMap`,
//!   `Vec`, or the arena/`SlotVec` substrates. The prefix index is the
//!   deliberate stress case: it is *content-hashed* (chained SplitMix64
//!   over block position keys) yet stores those hashes in `BTreeMap`s and
//!   orders its LRU by a simulation-time sequence stamp — the hash values
//!   are pure functions of the workload, never of process state, so
//!   lookup, insertion, eviction, and crash-drop order replay exactly.
//! * **D2** no `Instant`/`SystemTime` outside the timing-only modules
//!   (`util/bench.rs`, [`sweep`], [`throughput`], `engine/pipeline.rs`,
//!   `util/threadpool.rs`) — wall clock measures the simulator, never
//!   feeds it.
//! * **D3** no `partial_cmp` — a NaN panics the unwrap or makes the sort
//!   order run-dependent; `total_cmp` everywhere.
//! * **D4** no truncating float→`usize` rank casts and no integer
//!   `* N / 100` percentile arithmetic in metrics paths — rounding must
//!   be explicit (`.floor()`/`.ceil()`/`.round()`).
//!
//! (Plus **U1**: `unsafe` only in `util/threadpool.rs` and
//! `runtime/mod.rs`, always under a `// SAFETY:` comment.)
//!
//! CLI: `medha lint` prints findings and exits non-zero on any violation;
//! `medha lint --json` emits them machine-readably. To extend an
//! allowlist (e.g. a new timing-only module), edit
//! `util::lint::LintConfig::repo_default` with a comment justifying the
//! exemption — the fixtures in `tests/lint.rs` keep every rule honest.

pub mod serve;
pub mod sweep;
pub mod throughput;

use std::collections::VecDeque;

use crate::config::{DeploymentConfig, FaultEvent, FaultKind, FaultPlan, SloConfig};
use crate::coordinator::chunking::ChunkPolicy;
use crate::coordinator::policy::{self, GroupView, HeadroomTuner, SchedPolicy};
use crate::coordinator::request::{Phase, Request};
use crate::coordinator::scheduler::{BatchPlan, Scheduler};
use crate::coordinator::spp::PipelineTimeline;
use crate::coordinator::{
    AdaptiveChunk, GroupState, KvpManager, ReadySet, RequestArena, Router, RoutingMode, Slot,
    StaticChunk, Topology,
};
use crate::kvcache::{GroupId, NodeRef, PrefixHit, PrefixIndex, RequestId};
use crate::metrics::{IterRecord, Metrics};
use crate::perfmodel::{BatchShape, DecodeWork, PerfModel, PrefillWork};
use crate::util::slotvec::SlotVec;
use crate::util::threadpool::ThreadPool;
use crate::workload::RequestSpec;

/// Simulation options beyond the deployment config.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Requests with prompts longer than this are treated as "long":
    /// chunked, KVP-sharded, driven cooperatively.
    pub long_threshold: u64,
    /// Stop after this much simulated time (safety valve).
    pub horizon_s: f64,
    /// Keep finished `Request` records for post-run inspection
    /// (`Simulation::request`). Turn off for million-request runs so
    /// memory tracks concurrency, not trace length.
    pub retain_finished: bool,
    /// `Some(cap)`: reservoir-sample latency metrics at `cap` and drop the
    /// per-iteration trace (see [`Metrics::streaming`]). `None`: exact.
    pub metrics_reservoir: Option<usize>,
    /// Deterministic fleet lifecycle schedule (crashes, joins, drains,
    /// slowdowns). Empty — the default — is the fault-free fleet.
    pub faults: FaultPlan,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            long_threshold: 16_384,
            horizon_s: 86_400.0,
            retain_finished: true,
            metrics_reservoir: None,
            faults: FaultPlan::default(),
        }
    }
}

/// Chunk size used for admission-time isolated-prefill estimates (the basis
/// of length-aware deadlines and scheduling-policy work estimates). A large
/// chunk keeps the estimate cheap — O(prompt/4096) perf-model queries, once
/// per request — and close to the best-case prefill rate.
const EST_CHUNK: u64 = 4096;

/// Perf-model estimate of a request's isolated prefill time on one replica
/// (dense SPP pipelining at the deployment's depth).
fn est_prefill_s(pm: &PerfModel, prompt_len: u64) -> f64 {
    pm.prefill_time_spp(prompt_len, EST_CHUNK)
}

/// Build and run the heterogeneous convoy scenario shared by
/// `figures::sched`, the `sched/policy_compare` bench, and
/// `tests/sched_policy.rs`: one Llama-3 8B tp=8 replica, static chunking,
/// documents flowing through the same per-group queue as the interactive
/// requests (`long_threshold = u64::MAX`). One definition, so the figure,
/// the bench record, and the regression thresholds always measure the same
/// scenario.
pub fn run_convoy_scenario(
    kind: crate::coordinator::SchedPolicyKind,
    cfg: &crate::workload::ConvoyConfig,
    seed: u64,
) -> Simulation {
    let mut dep = DeploymentConfig::llama3_8b_tp8();
    dep.scheduler.policy = kind;
    dep.scheduler.adaptive_chunking = false;
    let opts = SimOptions {
        long_threshold: u64::MAX,
        ..SimOptions::default()
    };
    let mut sim = Simulation::new(dep, crate::workload::convoy(cfg, seed), opts);
    sim.run();
    sim
}

/// Split finished-request TTFTs by convoy class — (interactive, documents)
/// — using the shared [`Samples`](crate::util::stats::Samples) percentile
/// rule everywhere the convoy is evaluated.
pub fn convoy_ttft_split(
    sim: &Simulation,
    cfg: &crate::workload::ConvoyConfig,
) -> (crate::util::stats::Samples, crate::util::stats::Samples) {
    let mut short = crate::util::stats::Samples::new();
    let mut long = crate::util::stats::Samples::new();
    for r in sim.retired() {
        if let Some(t) = r.ttft() {
            if cfg.is_long(r.prompt_len) {
                long.add(t);
            } else {
                short.add(t);
            }
        }
    }
    (short, long)
}

/// Build and run the KVP-routing scenario shared by the `sched` figure's
/// routing table, the `sched/kvp_routing` bench, and
/// `tests/kvp_routing.rs`: Llama-3 8B tp=8 across 4 KVP groups, static
/// chunking, an onboarding threshold that shards each document across two
/// groups, and the `kvp_convoy` trace of overlapping documents plus short
/// interactive traffic. One definition, so the figure, the bench record,
/// and the regression thresholds always measure the same scenario.
pub fn run_kvp_convoy_scenario(
    kind: crate::coordinator::SchedPolicyKind,
    routing: RoutingMode,
    cfg: &crate::workload::KvpConvoyConfig,
    seed: u64,
) -> Simulation {
    run_kvp_convoy_scenario_with_faults(kind, routing, cfg, seed, FaultPlan::default())
}

/// The kvp_convoy scenario under a deterministic [`FaultPlan`] — the
/// degradation counterpart of [`run_kvp_convoy_scenario`] (which is this
/// with an empty plan, bit-identically). Shared by the `faults` figure,
/// the fault-matrix smoke tests, and the crash-recovery acceptance tests.
pub fn run_kvp_convoy_scenario_with_faults(
    kind: crate::coordinator::SchedPolicyKind,
    routing: RoutingMode,
    cfg: &crate::workload::KvpConvoyConfig,
    seed: u64,
    faults: FaultPlan,
) -> Simulation {
    let dep = kvp_convoy_dep(kind, routing, cfg);
    let opts = SimOptions {
        faults,
        ..SimOptions::default()
    };
    let mut sim = Simulation::new(dep, crate::workload::kvp_convoy(cfg, seed), opts);
    sim.run();
    sim
}

/// The deployment every kvp_convoy evaluation runs on (the figure, the
/// bench, the sweep grid, and the golden/determinism tests — which also
/// layer `scheduler.threads` overrides onto it): Llama-3 8B tp=8 across 4
/// KVP groups, static chunking, onboarding threshold sized so each
/// document shards across two groups.
pub fn kvp_convoy_dep(
    kind: crate::coordinator::SchedPolicyKind,
    routing: RoutingMode,
    cfg: &crate::workload::KvpConvoyConfig,
) -> DeploymentConfig {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 4);
    dep.scheduler.policy = kind;
    dep.scheduler.routing = routing;
    dep.scheduler.adaptive_chunking = false;
    // Big document chunks: each sharding-group iteration is chunk-scale
    // work, which is exactly what a blindly placed short request waits out.
    dep.scheduler.static_chunk = 4096;
    // Documents shard across two of the four groups, leaving an
    // independent short-serving pool (the section 7 opportunity).
    dep.scheduler.kvp_onboard_threshold = cfg.doc_prompt.div_ceil(2).max(1);
    dep
}

/// Build and run the multi-turn prefix-reuse scenario shared by the
/// `reuse` figure, the multiturn golden scenarios, and the CI smoke step:
/// Llama-3 8B tp=8 across 4 KVP groups, static chunking, the seeded
/// [`workload::multiturn`](crate::workload::multiturn) trace (chat
/// sessions sharing a system prompt, per-turn growing history, convoy
/// shorts), with the prefix index switched by `reuse`. `reuse = false` is
/// the control arm: the same trace on the pre-reuse paths, bit for bit.
pub fn run_multiturn_scenario(
    kind: crate::coordinator::SchedPolicyKind,
    routing: RoutingMode,
    cfg: &crate::workload::MultiTurnConfig,
    seed: u64,
    reuse: bool,
) -> Simulation {
    let mut dep = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 1, 4);
    dep.scheduler.policy = kind;
    dep.scheduler.routing = routing;
    dep.scheduler.adaptive_chunking = false;
    dep.scheduler.static_chunk = 2048;
    dep.scheduler.prefix_reuse = reuse;
    let mut sim = Simulation::new(
        dep,
        crate::workload::multiturn(cfg, seed),
        SimOptions::default(),
    );
    sim.run();
    sim
}

/// Split finished-request TTFTs of a multiturn run by class —
/// (background shorts, session turns) — with the shared `Samples`
/// percentile rule. Session turns always carry the system prompt, so any
/// prompt longer than a background short is a turn.
pub fn multiturn_ttft_split(
    sim: &Simulation,
    cfg: &crate::workload::MultiTurnConfig,
) -> (crate::util::stats::Samples, crate::util::stats::Samples) {
    let mut short = crate::util::stats::Samples::new();
    let mut turns = crate::util::stats::Samples::new();
    for r in sim.retired() {
        if let Some(t) = r.ttft() {
            if r.prompt_len > cfg.short_prompt {
                turns.add(t);
            } else {
                short.add(t);
            }
        }
    }
    (short, turns)
}

/// Split finished-request TTFTs of a kvp_convoy run by class —
/// (interactive, documents) — with the shared `Samples` percentile rule.
pub fn kvp_convoy_ttft_split(
    sim: &Simulation,
    cfg: &crate::workload::KvpConvoyConfig,
) -> (crate::util::stats::Samples, crate::util::stats::Samples) {
    let mut short = crate::util::stats::Samples::new();
    let mut docs = crate::util::stats::Samples::new();
    for r in sim.retired() {
        if let Some(t) = r.ttft() {
            if cfg.is_doc(r.prompt_len) {
                docs.add(t);
            } else {
                short.add(t);
            }
        }
    }
    (short, docs)
}

pub struct Simulation {
    pub dep: DeploymentConfig,
    pub opts: SimOptions,
    pm: PerfModel,
    layers_per_stage: u32,
    policy: Box<dyn ChunkPolicy>,
    /// Ready-set ordering for the dedicated long-request queue (the
    /// per-group schedulers each hold their own instance of the same kind).
    sched_policy: Box<dyn SchedPolicy>,
    topo: Topology,

    requests: RequestArena,
    /// Finished requests, retained when `opts.retain_finished`.
    retired: Vec<Request>,
    pending: VecDeque<RequestSpec>,
    /// Routed-mode admissions refused for lack of per-group KV capacity,
    /// waiting for capacity to free. Indexed by the scheduling policy's
    /// priority: the most urgent deferred request is retried at every
    /// decision instant, and while it does not fit nothing less urgent may
    /// take the capacity that frees (the anti-starvation blocking rule,
    /// generalizing the old strict FIFO head-block — which FCFS still
    /// degenerates to). Each deferral was counted in
    /// `Metrics::routing_refusals`; placement records the wait into
    /// `Metrics::deferral_wait`.
    deferred: ReadySet,
    /// Deferral start time per deferred slot (the wait-time numerator).
    deferred_since: SlotVec<f64>,
    /// Per-group short-request schedulers.
    scheds: Vec<Scheduler>,
    timelines: Vec<PipelineTimeline>,
    /// Queued long (KVP-sharded) requests, indexed by the scheduling
    /// policy's priority (the same `ReadySet` machinery as the per-group
    /// prefill queues), so document-heavy workloads select the next
    /// cooperative request in O(log n) instead of the old O(n) scan.
    long_queue: ReadySet,
    active_long: Option<Slot>,
    kvp_mgr: KvpManager,
    router: Router,
    /// Placement mode across KVP groups (`scheduler.routing`). All modes
    /// share the single pool-scheduled [`Self::step`]; `Blind` runs every
    /// group in the cooperative set (clocks stay equal — the original
    /// lockstep schedule) while the pooled modes cooperate only the shard
    /// holders and let the rest serve shorts independently.
    routing: RoutingMode,
    /// The earliest time each group can form its next batch (its previous
    /// iteration's admission point). Under blind routing all entries stay
    /// equal — the lockstep degeneration.
    free_at: Vec<f64>,
    pub metrics: Metrics,
    now: f64,

    // ---- per-iteration scratch (reused across steps) --------------------
    group_plans: Vec<BatchPlan>,
    /// One shape scratch per group — disjoint, so phase A runs
    /// group-parallel; the serial path uses them identically.
    group_shapes: Vec<BatchShape>,
    /// Pre-sized phase-A result slots, merged in group-index order (the
    /// deterministic reduction).
    phase_outs: Vec<GroupPhaseA>,
    /// Workers for the parallel step (`scheduler.threads > 1`); `None` is
    /// the serial path.
    pool: Option<ThreadPool>,
    combined: BatchShape,
    long_ctxs: Vec<u64>,
    participating: Vec<(GroupId, u64)>,
    finished_buf: Vec<Slot>,
    /// Routed-admission scratch: per-group occupancy views.
    views: Vec<GroupView>,

    // ---- elastic-fleet state (quiescent in fault-free runs) -------------
    /// Placement mask, one flag per group slot (`true` = `Active`),
    /// refreshed after every fleet lifecycle change. All-true in a
    /// fault-free run, where it filters nothing.
    placeable: Vec<bool>,
    /// Cursor into the sorted `opts.faults.events` schedule.
    fault_cursor: usize,
    /// `Joining` groups and their activation instants (join warm-ups).
    warming: Vec<(f64, GroupId)>,
    /// Transient slowdowns in force: `(group, factor, until_s)`.
    slowdowns: Vec<(GroupId, f64, f64)>,
    /// Crash victims awaiting their first post-crash service, stamped with
    /// the crash time (the `Metrics::recovery_wait` numerator).
    recovery_since: SlotVec<f64>,
    /// Scratch for crash-time scheduler eviction.
    evict_buf: Vec<Slot>,

    // ---- prefix-aware KV reuse (None/empty when `scheduler.prefix_reuse`
    // ---- is off — every path below then degenerates to the pre-reuse one)
    /// Hash-consed, ref-counted prefix block chains indexed by content
    /// position ([`PrefixIndex`]). `None` when reuse is disabled.
    prefix: Option<PrefixIndex>,
    /// Per-slot reuse identity carried from the [`RequestSpec`]:
    /// `(prefix_ns, sys_tokens)`. Present only for short requests admitted
    /// with a nonzero namespace while reuse is on; survives crash
    /// re-admission (the re-run's KV is re-indexable) and is dropped at
    /// retirement.
    reuse_meta: SlotVec<(u64, u64)>,
    /// The chain node a granted request pinned at admission
    /// ([`PrefixIndex::acquire`]); released exactly once — at finish, or
    /// forgotten when the owning group crashes (`drop_group` invalidated
    /// the handle and the ledger column was returned wholesale).
    reuse_hold: SlotVec<NodeRef>,
    /// LARS headroom auto-tuner (`scheduler.headroom_autotune`): an EWMA
    /// of observed-vs-predicted iteration time that scales **admission
    /// time** estimates only — never the priority key of an already-queued
    /// request, preserving the ready-set's time-invariance contract.
    /// `None` (the default) leaves every estimate byte-identical.
    tuner: Option<HeadroomTuner>,
}

impl Simulation {
    pub fn new(dep: DeploymentConfig, workload: Vec<RequestSpec>, opts: SimOptions) -> Simulation {
        dep.validate().expect("invalid deployment");
        let pm = PerfModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
        let kvp_groups = dep.parallel.kvp.max(1);
        let policy: Box<dyn ChunkPolicy> = if dep.scheduler.adaptive_chunking {
            Box::new(AdaptiveChunk::new(dep.scheduler.chunk_sizes.clone()))
        } else {
            Box::new(StaticChunk(dep.scheduler.static_chunk))
        };
        let mut pending: Vec<RequestSpec> = workload;
        // (arrival, id) — not arrival alone — so same-tick arrivals admit
        // deterministically regardless of trace construction order (the
        // tie-break `workload::kvp_convoy` already sorts by).
        pending.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
        let layers_per_stage = dep.model.n_layers / dep.parallel.spp.max(1);
        let topo = Topology::new(dep.parallel, &dep.hardware);
        let mut metrics = match opts.metrics_reservoir {
            Some(cap) => Metrics::streaming(cap, 0x6d65_6468_61u64),
            None => Metrics::new(),
        };
        metrics.tbt_slo_s = dep.slo.tbt_s;
        let sched_kind = dep.scheduler.policy;
        let routing = dep.scheduler.routing;
        let sched_policy = sched_kind.build();
        let key_shape = sched_policy.key_shape();
        Simulation {
            pm,
            layers_per_stage,
            policy,
            sched_policy,
            topo,
            requests: RequestArena::new(),
            retired: Vec::new(),
            pending: pending.into(),
            deferred: ReadySet::new(key_shape),
            deferred_since: SlotVec::new(),
            scheds: (0..kvp_groups)
                .map(|_| {
                    Scheduler::with_policy(
                        Box::new(StaticChunk(dep.scheduler.static_chunk)),
                        sched_kind.build(),
                        dep.scheduler.max_batch_size,
                    )
                })
                .collect(),
            timelines: (0..kvp_groups)
                .map(|_| PipelineTimeline::new(dep.parallel.spp.max(1) as usize, 0.0))
                .collect(),
            long_queue: ReadySet::new(key_shape),
            active_long: None,
            kvp_mgr: KvpManager::with_capacity(
                dep.scheduler.kvp_onboard_threshold,
                kvp_groups,
                dep.scheduler.kvp_capacity_tokens,
            ),
            router: Router::new(kvp_groups),
            routing,
            free_at: vec![0.0; kvp_groups as usize],
            metrics,
            now: 0.0,
            group_plans: (0..kvp_groups).map(|_| BatchPlan::default()).collect(),
            group_shapes: (0..kvp_groups).map(|_| BatchShape::default()).collect(),
            phase_outs: Vec::new(),
            pool: if dep.scheduler.threads > 1 {
                Some(ThreadPool::new(dep.scheduler.threads))
            } else {
                None
            },
            combined: BatchShape::default(),
            long_ctxs: Vec::new(),
            participating: Vec::new(),
            finished_buf: Vec::new(),
            views: Vec::new(),
            placeable: vec![true; kvp_groups as usize],
            fault_cursor: 0,
            warming: Vec::new(),
            slowdowns: Vec::new(),
            recovery_since: SlotVec::new(),
            evict_buf: Vec::new(),
            prefix: if dep.scheduler.prefix_reuse {
                Some(PrefixIndex::new(
                    dep.scheduler.prefix_block_tokens,
                    dep.scheduler.prefix_cache_blocks,
                ))
            } else {
                None
            },
            reuse_meta: SlotVec::new(),
            reuse_hold: SlotVec::new(),
            tuner: if dep.scheduler.headroom_autotune {
                Some(HeadroomTuner::default())
            } else {
                None
            },
            dep,
            opts,
        }
    }

    fn admit_arrivals(&mut self) {
        // Retry capacity-deferred admissions first: capacity may have
        // freed since the last decision instant. Retries pop in the
        // scheduling policy's priority order (FIFO under FCFS), and while
        // the most urgent deferred request does not fit, nothing less
        // urgent may take the capacity that frees — the anti-starvation
        // blocking rule. O(1) when nothing is deferred.
        while let Some(slot) =
            self.deferred
                .select(self.sched_policy.as_ref(), &self.requests, self.now)
        {
            if !self.place_short_routed(slot, false, None) {
                break;
            }
            self.deferred.remove(slot);
            if let Some(since) = self.deferred_since.remove(slot as usize) {
                self.metrics.record_deferral_wait(self.now - since);
            }
        }
        while let Some(spec) = self.pending.front() {
            if spec.arrival_s > self.now {
                break;
            }
            let spec = self.pending.pop_front().unwrap();
            // Length-aware SLO state: the perf-model prefill estimate sets
            // both the scheduling policies' work term and the TTFT deadline.
            // With `headroom_autotune`, the estimate is scaled by the EWMA
            // correction learned from completed iterations.
            let est = match &self.tuner {
                Some(t) => est_prefill_s(&self.pm, spec.prompt_len) * t.factor(),
                None => est_prefill_s(&self.pm, spec.prompt_len),
            };
            let deadline = spec.arrival_s + self.dep.slo.ttft_deadline_for(est);
            let r = Request::new(spec.id, spec.prompt_len, spec.max_new_tokens, spec.arrival_s)
                .with_slo(est, deadline);
            let slot = self.requests.insert(r);
            if spec.prompt_len > self.opts.long_threshold {
                self.admit_long(slot, spec.id, spec.prompt_len);
            } else {
                // Prefix reuse is a short-path concern: consult the index
                // once per admission (namespace 0 opts out — background
                // traffic), remember the request's reuse identity for the
                // finish-time insert, and hand the hit to placement. The
                // grant itself happens only if placement lands on the
                // chain's owner group.
                let hit = match &self.prefix {
                    Some(px) => px.lookup(spec.prefix_ns, spec.sys_tokens, spec.prompt_len),
                    None => None,
                };
                if self.prefix.is_some() && spec.prefix_ns != 0 {
                    self.reuse_meta
                        .insert(slot as usize, (spec.prefix_ns, spec.sys_tokens));
                }
                self.admit_short(slot, spec.prompt_len, hit);
            }
        }
    }

    /// Admit a long (KVP-sharded) request: claim a primary group, onboard
    /// it with the KVP manager, and queue it for the cooperative slot. The
    /// primary anchors the first shard and the cooperative set; KV grows
    /// across groups via the manager regardless of where it starts.
    /// Blind and round-robin modes keep least-loaded primaries; `routed`
    /// places the primary through the same policy hook short requests use
    /// (urgency-aware, avoiding the active document's groups), with the
    /// capacity footprint clamped to what the primary will actually hold
    /// before the next group onboards.
    fn admit_long(&mut self, slot: Slot, ext_id: RequestId, prompt_len: u64) {
        let g = if self.routing == RoutingMode::Routed {
            self.fill_group_views(None);
            let need = policy::kv_need(self.requests.get(slot))
                .min(self.dep.scheduler.kvp_onboard_threshold);
            let g = match self
                .sched_policy
                .route(self.requests.get(slot), &self.views, need, self.now)
            {
                Some(g) => g,
                // The fleet is packed: counted as a refusal, placed with
                // the capacity filter waived — documents shard across
                // groups, so deferring the main workload would idle the
                // fleet it is about to fill.
                None => {
                    self.metrics.routing_refusals += 1;
                    self.route_capacity_waived(slot, need)
                }
            };
            self.router.route_to(slot, prompt_len, g);
            g
        } else {
            // Blind / round-robin primaries are least-loaded, through the
            // same GroupView hook routed mode uses (capacity waived).
            self.place_least_loaded(slot, prompt_len)
        };
        self.kvp_mgr.onboard_request(slot, ext_id, g, self.now);
        self.long_queue
            .push(slot, self.sched_policy.as_ref(), &self.requests);
    }

    /// Least-loaded placement over the [`GroupView`] snapshots with the
    /// capacity filter waived (`need = 0`): the pre-pool blind rule —
    /// min `(load, group)` — expressed through the same routing-hook state
    /// every other placement reads.
    fn place_least_loaded(&mut self, slot: Slot, prompt_len: u64) -> GroupId {
        self.fill_group_views(None);
        let g = policy::route_least_loaded(&self.views, 0).expect("deployment has a group");
        self.router.route_to(slot, prompt_len, g);
        g
    }

    /// Admit a short request to a group scheduler per the routing mode.
    /// Its full KV footprint (prompt + output) is reserved on the chosen
    /// group until retirement; under `routed` with finite capacity the
    /// placement may be refused and the admission deferred.
    fn admit_short(&mut self, slot: Slot, prompt_len: u64, hit: Option<PrefixHit>) {
        match self.routing {
            RoutingMode::Blind => {
                // The folded blind mode: least-loaded over GroupViews,
                // capacity-blind — bit-identical placement to the old
                // dedicated lockstep path. Placement ignores the hit
                // (blind), but a coincidental landing on the chain's owner
                // group still grants the reuse.
                let g = self.place_least_loaded(slot, prompt_len);
                self.maybe_grant(slot, g, hit);
                self.reserve_short(slot, g);
                self.scheds[g as usize].enqueue(slot, &self.requests);
            }
            RoutingMode::RoundRobin => {
                // Masked over live membership; with every group `Active`
                // this is exactly the unmasked cursor walk.
                let g = self
                    .router
                    .route_round_robin_masked(slot, prompt_len, &self.placeable)
                    .expect("the fleet keeps at least one active group");
                self.maybe_grant(slot, g, hit);
                self.reserve_short(slot, g);
                self.scheds[g as usize].enqueue(slot, &self.requests);
            }
            RoutingMode::Routed => {
                // Under capacity pressure a new arrival joins the deferred
                // set without attempting placement — letting it place
                // directly would take capacity the retry loop is about to
                // hand to a more urgent waiter. The set is ordered by the
                // policy's priority, so a deadline-critical arrival is
                // still retried ahead of slack-rich earlier deferrals
                // (strict FIFO under FCFS). Requests larger than a whole
                // group's capacity skip the set entirely: waiting can
                // never make them placeable, so they go straight to
                // overflow placement.
                let oversized = policy::kv_need(self.requests.get(slot))
                    > self.dep.scheduler.kvp_capacity_tokens;
                if !oversized && !self.deferred.is_empty() {
                    // The hit is dropped with the deferral: a deferred
                    // request's deadline is already fixed in the ready-set
                    // key, and the chain may be evicted before capacity
                    // frees — reuse is evaluated once, at admission.
                    self.metrics.routing_refusals += 1;
                    self.defer(slot);
                } else if !self.place_short_routed(slot, true, hit) {
                    self.defer(slot);
                }
            }
        }
    }

    /// Park a refused routed admission in the priority-ordered deferred
    /// set, stamping the deferral start for the wait-time metric.
    fn defer(&mut self, slot: Slot) {
        self.deferred
            .push(slot, self.sched_policy.as_ref(), &self.requests);
        self.deferred_since.insert(slot as usize, self.now);
    }

    fn reserve_short(&mut self, slot: Slot, g: GroupId) {
        let need = Self::reserve_need(self.requests.get(slot));
        self.kvp_mgr.reserve(g, need);
    }

    /// KV tokens a short request must reserve on its group: the full
    /// footprint minus any span already resident as a shared prefix chain
    /// (counted once, in the ledger's `shared` column). Identical to
    /// [`policy::kv_need`] when no reuse was granted. The finish-time
    /// unreserve recomputes this from the same field, so the pair always
    /// balances (a crash clears `reused_tokens` *before* re-admission
    /// re-reserves, keeping both sides on the full footprint).
    fn reserve_need(r: &Request) -> u64 {
        policy::kv_need(r).saturating_sub(r.reused_tokens)
    }

    /// Grant a prefix-cache hit if placement landed on the chain's owner
    /// group: pin the node, skip the resident span in the request's
    /// prefill accounting, and re-derive the admission SLO state from the
    /// *remaining* span (`prefill_time_spp_resume`) — tighter deadline,
    /// honest LARS slack. A miss (different group, or the chain was
    /// evicted since lookup) leaves the request byte-identical to the
    /// no-reuse path.
    fn maybe_grant(&mut self, slot: Slot, g: GroupId, hit: Option<PrefixHit>) {
        let (Some(px), Some(h)) = (self.prefix.as_mut(), hit) else {
            return;
        };
        if h.group != g || h.tokens == 0 || !px.is_live(h.node) {
            return;
        }
        px.acquire(h.node);
        self.reuse_hold.insert(slot as usize, h.node);
        let (prompt_len, arrival_s) = {
            let r = self.requests.get(slot);
            (r.prompt_len, r.arrival_s)
        };
        let base = self.pm.prefill_time_spp_resume(prompt_len, h.tokens, EST_CHUNK);
        let est = match &self.tuner {
            Some(t) => base * t.factor(),
            None => base,
        };
        let deadline = arrival_s + self.dep.slo.ttft_deadline_for(est);
        let r = self.requests.get_mut(slot);
        r.grant_reuse(h.tokens);
        r.est_prefill_s = est;
        r.deadline_s = deadline;
        self.metrics.prefix_hit_tokens += h.tokens;
    }

    /// Re-route with the capacity filter waived, for refusals that waiting
    /// can never satisfy (requests larger than a whole group's capacity,
    /// and long-request primaries on a packed fleet). The caller accounts
    /// the refusal; `fill_group_views` must have populated `views`.
    fn route_capacity_waived(&mut self, slot: Slot, need: u64) -> GroupId {
        for v in &mut self.views {
            v.kv_free = u64::MAX;
        }
        self.sched_policy
            .route(self.requests.get(slot), &self.views, need, self.now)
            .expect("capacity-waived routing always places")
    }

    /// Routed-mode placement of a short request, honoring per-group KV
    /// capacity through the policy's routing hook. Returns `false` when no
    /// group can currently fit the request — the caller defers admission
    /// until capacity frees. `count_refusal` is set on the first attempt
    /// only, so a deferred request counts once in `routing_refusals`.
    /// Requests larger than a whole group's capacity can never satisfy the
    /// check and are placed with it waived (counted, never deferred).
    fn place_short_routed(&mut self, slot: Slot, count_refusal: bool, hit: Option<PrefixHit>) -> bool {
        self.fill_group_views(hit);
        let need = policy::kv_need(self.requests.get(slot));
        let choice = self
            .sched_policy
            .route(self.requests.get(slot), &self.views, need, self.now);
        let g = match choice {
            Some(g) => g,
            None => {
                if count_refusal {
                    self.metrics.routing_refusals += 1;
                }
                if need <= self.dep.scheduler.kvp_capacity_tokens {
                    return false; // will fit once capacity frees: defer
                }
                // Larger than a whole group: waiting can never help, so
                // the request is placed with the check waived.
                self.route_capacity_waived(slot, need)
            }
        };
        let prompt_len = self.requests.get(slot).prompt_len;
        self.router.route_to(slot, prompt_len, g);
        // Grant before reserving: a granted request's reservation shrinks
        // by the resident span (the routing hook's `affinity_fits` relaxed
        // the capacity check by exactly this much on the owner group).
        self.maybe_grant(slot, g, hit);
        let need = Self::reserve_need(self.requests.get(slot));
        self.kvp_mgr.reserve(g, need);
        self.scheds[g as usize].enqueue(slot, &self.requests);
        true
    }

    /// Snapshot per-group occupancy for the policy routing hook: router
    /// load, ready-set depth, participation in the active sharded long
    /// request, the deadline-critical queue count, and free KV capacity.
    /// O(groups) per admission — every field is an O(1) read of
    /// incrementally maintained state (the schedulers' urgency counters
    /// and the KVP manager's capacity ledger), replacing the
    /// O(total queued) backlog rescan the pre-heap router performed on
    /// each admission.
    /// `hit` threads a pending admission's prefix-cache lookup into the
    /// views: the owner group's view carries the resident span
    /// (`prefix_hit_tokens`), every other view carries zero, so the
    /// policy's affinity terms see exactly one candidate chain. `None`
    /// (every non-reuse placement) leaves all views at zero — the
    /// pre-reuse snapshot, bit for bit.
    fn fill_group_views(&mut self, hit: Option<PrefixHit>) {
        self.views.clear();
        let preemptive = self.sched_policy.preemptive();
        for g in 0..self.scheds.len() {
            // Membership filter: only `Active` groups are placement
            // candidates. All-true in a fault-free fleet — the views (and
            // every placement derived from them) are then exactly the
            // fixed-fleet ones.
            if !self.placeable[g] {
                continue;
            }
            let gid = g as GroupId;
            let urgent = if preemptive {
                self.scheds[g].n_urgent(self.now)
            } else {
                0
            };
            self.views.push(GroupView {
                group: gid,
                load: self.router.load_of(gid),
                queue_len: self.scheds[g].queue_len(),
                n_decoding: self.scheds[g].n_decoding(),
                active_long: self
                    .active_long
                    .map(|slot| self.kvp_mgr.holds(slot, gid))
                    .unwrap_or(false),
                more_urgent_queued: urgent,
                kv_free: self.kvp_mgr.kv_free(gid),
                prefix_hit_tokens: match hit {
                    Some(h) if h.group == gid => h.tokens,
                    _ => 0,
                },
            });
        }
    }

    fn has_work(&self) -> bool {
        self.active_long.is_some()
            || !self.long_queue.is_empty()
            || !self.deferred.is_empty()
            || self.scheds.iter().any(|s| s.has_work())
    }

    /// Local KV length the group's kernels scan for a short request.
    fn short_local_kv(r: &Request) -> u64 {
        r.kv_len().max(1)
    }

    /// Retire a finished request: recycle its arena slot, optionally
    /// keeping the record for post-run inspection.
    fn retire(&mut self, slot: Slot) {
        self.reuse_meta.remove(slot as usize);
        debug_assert!(
            !self.reuse_hold.contains(slot as usize),
            "retired request still pins a prefix node"
        );
        let r = self.requests.remove(slot);
        if self.opts.retain_finished {
            self.retired.push(r);
        }
    }

    /// The next decision instant: the earliest group admission point or
    /// pending arrival after `now`. Replaces the degenerate 1e-6 s
    /// busy-wait bumps of the pre-arena simulator; the tiny bump survives
    /// only as a last-resort guarantee of progress.
    ///
    /// The pooled modes interleave per-group clocks with arrivals (a new
    /// request may be routable to an idle pool group mid-iteration). The
    /// blind barrier instead admits strictly at iteration boundaries — the
    /// lockstep contract the retired core enforced structurally (its clock
    /// jumped straight to the iteration end), and what keeps blind
    /// admission timing, long-request activation instants, and the
    /// onboarding log bit-exact with the pre-refactor path. Arrivals are
    /// consulted under the barrier only when no group has a pending
    /// admission point (the fleet is idle).
    fn next_event(&self) -> f64 {
        let mut t = f64::INFINITY;
        for &f in &self.free_at {
            if f > self.now {
                t = t.min(f);
            }
        }
        if self.routing.pooled() || !t.is_finite() {
            if let Some(spec) = self.pending.front() {
                t = t.min(spec.arrival_s);
            }
        }
        // Scheduled faults and pending join activations are decision
        // instants too (both vectors stay empty in a fault-free run).
        if self.fault_cursor < self.opts.faults.events.len() {
            let ft = self.opts.faults.events[self.fault_cursor].t_s;
            if ft > self.now {
                t = t.min(ft);
            }
        }
        for &(wt, _) in &self.warming {
            if wt > self.now {
                t = t.min(wt);
            }
        }
        if t.is_finite() && t > self.now {
            t
        } else {
            self.now + 1e-6
        }
    }

    /// Run the simulation to completion (or horizon). Returns total time.
    pub fn run(&mut self) -> f64 {
        loop {
            if !self.opts.faults.is_empty() {
                // Fleet lifecycle first: membership changes apply before
                // the admissions and batches of the same instant.
                self.apply_due_faults();
            }
            self.admit_arrivals();
            if !self.has_work() {
                match self.pending.front() {
                    Some(spec) => {
                        self.now = spec.arrival_s;
                        for tl in &mut self.timelines {
                            tl.advance_to(self.now);
                        }
                        continue;
                    }
                    None => break,
                }
            }
            if self.now > self.opts.horizon_s {
                break;
            }
            self.step();
        }
        self.metrics.preemptions = self.scheds.iter().map(|s| s.preemptions).sum();
        self.metrics.kv_overcommit_tokens = self.kvp_mgr.kv_overcommit_tokens;
        self.now
    }

    /// One pool-scheduled decision instant — the single execution path
    /// every routing mode runs through.
    ///
    /// The **cooperative set** iterates together (each member's own mixed
    /// batch, the shard holders additionally carrying the sharded chunk's
    /// partial attention) and completes at the set's max exit plus the KVP
    /// merge charge. Every other group is an **independent short-request
    /// pool** (paper section 7): it forms, executes, and completes its own
    /// mixed batches on its own clock, so a short request routed to an
    /// idle group never waits out a document chunk on a sharding group.
    ///
    /// Membership is the routing mode's one degree of freedom: the pooled
    /// modes (`round-robin`, `routed`) cooperate exactly the shard holders
    /// of the active long request, while `blind` makes **every** group a
    /// member — the per-group clocks then stay equal and the schedule
    /// degenerates to the original lockstep iteration semantics (one
    /// combined iteration record per instant, a single global re-admission
    /// point).
    fn step(&mut self) {
        let n_groups = self.scheds.len();
        let slo = self.dep.slo;
        // Blind barrier: every group is a cooperative-set member.
        let barrier = !self.routing.pooled();
        self.reselect_active_long();

        // Shard holders of the active long request.
        self.participating.clear();
        if let Some(slot) = self.active_long {
            if let Some(m) = self.kvp_mgr.shard_map(slot) {
                for &(g, _, n) in &m.shards {
                    self.participating.push((g, n));
                }
            }
        }
        // The cooperative set runs only when every member is idle (a chunk
        // boundary). Under the barrier that is all groups; otherwise the
        // shard holders (no holders → no cooperative iteration).
        let coop_ready = if barrier {
            self.free_at.iter().all(|&f| f <= self.now)
        } else {
            !self.participating.is_empty()
                && self
                    .participating
                    .iter()
                    .all(|&(g, _)| self.free_at[g as usize] <= self.now)
        };

        // ---- long-request work selection (whole coop set must be idle) --
        let long_slot = self.active_long;
        let mut long_chunk: Option<u64> = None;
        let mut long_decode = false;
        if coop_ready {
            if let Some(slot) = long_slot {
                let r = self.requests.get(slot);
                match r.phase {
                    Phase::Queued | Phase::Prefilling => {
                        // Decode contexts seen by the chunk policy: the
                        // resident decode load across the groups, gathered
                        // from the schedulers' incrementally-tracked context
                        // lists (no per-request scan, no allocation).
                        let (kv_done, remaining, dl) = (
                            r.kv_len(),
                            r.remaining_prefill(),
                            r.deadline_remaining_s(self.now),
                        );
                        self.long_ctxs.clear();
                        for sched in &self.scheds {
                            self.long_ctxs.extend_from_slice(sched.decode_ctxs());
                        }
                        let c = self
                            .policy
                            .next_chunk(kv_done, remaining, &self.long_ctxs, dl, &self.pm, &slo);
                        long_chunk = Some(c.max(1).min(remaining));
                    }
                    Phase::Decoding => long_decode = true,
                    Phase::Finished => {}
                }
            }
        }
        let long_nq = long_chunk.unwrap_or(if long_decode { 1 } else { 0 });

        // ---- batch formation + flow (phase A, then the ordered merge) ---
        let mut coop = CoopAcc {
            ran: false,
            exit: self.now,
            first: self.now,
            any_decode: long_decode,
            decodes: 0,
            chunk: None,
        };
        // Scratch moves out of `self` so phase A can borrow per-group
        // `&mut` slices alongside the shared immutable `StepCtx` reads.
        let mut combined = std::mem::take(&mut self.combined);
        combined.clear(); // accumulates the coop set's shapes
        let mut shapes = std::mem::take(&mut self.group_shapes);
        shapes.resize_with(n_groups, BatchShape::default); // fleet growth
        let mut outs = std::mem::take(&mut self.phase_outs);
        outs.clear();
        outs.resize(n_groups, GroupPhaseA::default());

        if let Some(pool) = self.pool.take() {
            // Parallel phase A: one borrowed job per group, results into
            // pre-sized slots. Work-order free; merge order is not.
            {
                let ctx = StepCtx {
                    requests: &self.requests,
                    pm: &self.pm,
                    kvp: &self.kvp_mgr,
                    slo,
                    now: self.now,
                    layers_per_stage: self.layers_per_stage,
                    barrier,
                    coop_ready,
                    long_nq,
                    long_chunk,
                    long_decode,
                    participating: &self.participating,
                    slowdowns: &self.slowdowns,
                    pool_gpus: self.topo.parallel.workers_per_replica(),
                };
                let free_at = &self.free_at;
                let per_group = self
                    .scheds
                    .iter_mut()
                    .zip(self.timelines.iter_mut())
                    .zip(self.group_plans.iter_mut())
                    .zip(shapes.iter_mut().zip(outs.iter_mut()))
                    .enumerate();
                pool.scoped(|scope| {
                    for (g, (((sched, timeline), plan), (shape, out))) in per_group {
                        let ctx = &ctx;
                        let free_at_g = free_at[g];
                        scope.spawn(move || {
                            *out = group_phase_a(ctx, g, free_at_g, sched, timeline, plan, shape);
                        });
                    }
                });
            }
            self.pool = Some(pool);
            // Deterministic reduction: merge in group-index order, so
            // metric streams, clocks, and completions are byte-identical
            // to the serial schedule below.
            for g in 0..n_groups {
                let out = outs[g];
                self.merge_group_outcome(g, &out, &shapes[g], &mut coop, &mut combined);
            }
        } else {
            // Serial schedule (the default): each group's outcome merges
            // before the next group forms its batch — the original
            // interleaving, which the parallel reduction must reproduce
            // bit-exactly (asserted by the thread-matrix golden tests).
            for g in 0..n_groups {
                let out = {
                    let ctx = StepCtx {
                        requests: &self.requests,
                        pm: &self.pm,
                        kvp: &self.kvp_mgr,
                        slo,
                        now: self.now,
                        layers_per_stage: self.layers_per_stage,
                        barrier,
                        coop_ready,
                        long_nq,
                        long_chunk,
                        long_decode,
                        participating: &self.participating,
                        slowdowns: &self.slowdowns,
                        pool_gpus: self.topo.parallel.workers_per_replica(),
                    };
                    group_phase_a(
                        &ctx,
                        g,
                        self.free_at[g],
                        &mut self.scheds[g],
                        &mut self.timelines[g],
                        &mut self.group_plans[g],
                        &mut shapes[g],
                    )
                };
                outs[g] = out;
                self.merge_group_outcome(g, &out, &shapes[g], &mut coop, &mut combined);
            }
        }

        // ---- cooperative completion -------------------------------------
        if coop.ran {
            if self.participating.len() > 1 && long_nq > 0 {
                coop.exit += self.pm.kvp_merge_s(long_nq);
            }
            let coop_exit = coop.exit;
            let coop_chunk = coop.chunk;
            let coop_decodes = coop.decodes;
            let dur = coop_exit - self.now;
            // Dense SPP admission survives for pure-prefill coop batches:
            // the set re-admits at its max stage-0 exit, not full drain.
            let free = if coop.any_decode { coop_exit } else { coop.first };
            if barrier {
                // Lockstep accounting convention, kept bit-exact with the
                // pre-pool blind core: complete first, account after — the
                // combined record's `active_gpus` reflects the *post-growth*
                // shard count (the Fig. 19 staircase rule).
                for g in 0..n_groups {
                    self.free_at[g] = free;
                    self.complete_group_plan(g, coop_exit);
                }
                if let Some(slot) = long_slot {
                    self.complete_long_progress(slot, long_chunk, long_decode, coop_exit);
                }
                let gpus = match long_slot {
                    Some(slot) => self
                        .topo
                        .gpus_active(self.kvp_mgr.active_groups(slot).max(1)),
                    None => self.topo.parallel.workers_per_replica(),
                };
                if dur > 0.0 {
                    self.metrics
                        .mfu
                        .add(self.pm.mfu(&combined, dur, gpus.max(1)));
                    self.metrics
                        .mbu
                        .add(self.pm.mbu(&combined, dur, gpus.max(1)));
                }
                self.metrics.record_iter(IterRecord {
                    t: coop_exit,
                    dur_s: dur,
                    chunk: coop_chunk,
                    n_decodes: coop_decodes,
                    active_gpus: gpus,
                });
            } else {
                // Pooled accounting convention: the coop record reflects
                // the shard holders that actually iterated (pre-growth).
                let gpus = self.topo.gpus_active(self.participating.len().max(1) as u32);
                if dur > 0.0 {
                    self.metrics
                        .mfu
                        .add(self.pm.mfu(&combined, dur, gpus.max(1)));
                    self.metrics
                        .mbu
                        .add(self.pm.mbu(&combined, dur, gpus.max(1)));
                }
                self.metrics.record_iter(IterRecord {
                    t: coop_exit,
                    dur_s: dur,
                    chunk: coop_chunk,
                    n_decodes: coop_decodes,
                    active_gpus: gpus,
                });
                for i in 0..self.participating.len() {
                    let g = self.participating[i].0 as usize;
                    self.free_at[g] = free;
                }
                for i in 0..self.participating.len() {
                    let g = self.participating[i].0 as usize;
                    self.complete_group_plan(g, coop_exit);
                }
                if let Some(slot) = long_slot {
                    self.complete_long_progress(slot, long_chunk, long_decode, coop_exit);
                }
            }
        }

        // Hand the scratch back for the next step.
        self.combined = combined;
        self.group_shapes = shapes;
        self.phase_outs = outs;

        // Whether or not anything ran, the next decision instant is the
        // earliest group admission point or arrival.
        self.now = self.next_event();
    }

    /// Phase B of one group's decision instant: the order-dependent half —
    /// metric recording, cooperative-set accumulation, pool-group clock
    /// updates, and plan completion. Always called in group-index order;
    /// together with phase A's independence that is what makes the
    /// parallel reduction byte-identical to the serial schedule.
    fn merge_group_outcome(
        &mut self,
        g: usize,
        out: &GroupPhaseA,
        shape: &BatchShape,
        coop: &mut CoopAcc,
        combined: &mut BatchShape,
    ) {
        if !out.ran {
            return;
        }
        // Headroom auto-tuning: feed the EWMA the model-predicted duration
        // (the observed one with transient slowdowns divided back out)
        // against the observed one. Gated on the config flag — `tuner` is
        // `None` by default and this is a no-op.
        if let Some(t) = self.tuner.as_mut() {
            let dur = out.exit - self.now;
            let f = slow_factor_of(&self.slowdowns, self.now, g);
            t.observe(dur / f, dur);
        }
        self.metrics
            .record_group_iter(g, out.exit - self.now, out.prefill_toks, out.n_decodes as u64);
        if out.member {
            coop.ran = true;
            coop.exit = coop.exit.max(out.exit);
            coop.first = coop.first.max(out.first);
            coop.any_decode |= out.has_decode;
            coop.decodes += out.n_decodes;
            if coop.chunk.is_none() {
                // The combined record reports the sharded chunk; under
                // the barrier it falls back to the first member's own
                // prefill chunk (the lockstep record's rule).
                coop.chunk = out.long_chunk.or(if out.barrier {
                    self.group_plans[g].prefill.map(|(_, c)| c)
                } else {
                    None
                });
            }
            combined.extend_from(shape);
        } else {
            // Independent pool iteration: this group's requests
            // complete at its own exit, on its own clock.
            let dur = out.exit - self.now;
            let gpus = self.topo.parallel.workers_per_replica();
            if dur > 0.0 {
                // Utilization precomputed in phase A from this group's own
                // shape — pure values, added here in deterministic order.
                self.metrics.mfu.add(out.mfu);
                self.metrics.mbu.add(out.mbu);
            }
            self.metrics.record_iter(IterRecord {
                t: out.exit,
                dur_s: dur,
                chunk: self.group_plans[g].prefill.map(|(_, c)| c),
                n_decodes: out.n_decodes,
                active_gpus: gpus,
            });
            self.free_at[g] = if out.has_decode { out.exit } else { out.first };
            self.complete_group_plan(g, out.exit);
        }
    }

    /// Apply one group's completed plan at time `t`: request transitions
    /// via the group scheduler, finished-request metrics, router release,
    /// arena retirement. Cooperative-set members complete together at the
    /// set's exit; independent pool groups each complete at their own.
    fn complete_group_plan(&mut self, g: usize, t: f64) {
        if self.group_plans[g].is_empty() {
            return;
        }
        self.scheds[g].complete_iteration_into(
            &self.group_plans[g],
            &mut self.requests,
            t,
            Self::short_local_kv,
            &mut self.finished_buf,
        );
        for i in 0..self.finished_buf.len() {
            let slot = self.finished_buf[i];
            let (prompt_len, kv_need) = {
                let r = self.requests.get(slot);
                self.metrics.record_finished_request(r);
                (r.prompt_len, Self::reserve_need(r))
            };
            // Release the KV reservation held since admission (group read
            // before the router forgets the placement), then settle the
            // prefix index: unpin the chain node this request held and
            // index its finished KV for the next turn.
            if let Some(g) = self.router.group_of(slot) {
                self.kvp_mgr.unreserve(g, kv_need);
                self.finish_prefix(slot, g);
            }
            self.router.release(slot, prompt_len);
            self.note_recovery(slot, t);
            self.retire(slot);
        }
    }

    /// Finish-time prefix-index settlement for a short request on group
    /// `g`: release the node pinned at admission (exactly-once pairing
    /// with [`Self::maybe_grant`]), then — if the request carries a reuse
    /// namespace — index its full KV (prompt + generated tokens, the next
    /// turn's shared history) as a chain owned by `g`. Newly indexed
    /// blocks are charged to the ledger's `shared` column once, and the
    /// LRU evicts refcount-0 chains past the block budget, crediting the
    /// ledger back per group.
    fn finish_prefix(&mut self, slot: Slot, g: GroupId) {
        let Some(px) = self.prefix.as_mut() else {
            return;
        };
        if let Some(node) = self.reuse_hold.remove(slot as usize) {
            px.release(node);
        }
        let Some(&(ns, sys_tokens)) = self.reuse_meta.get(slot as usize) else {
            return;
        };
        let kv = self.requests.get(slot).kv_len();
        let out = px.insert(ns, sys_tokens, kv, g);
        if out.new_blocks > 0 {
            self.metrics.blocks_shared += out.new_blocks;
            self.kvp_mgr.charge_shared(g, out.new_blocks * px.block_tokens());
        }
        for (eg, blocks) in px.evict_over_capacity() {
            self.kvp_mgr.release_shared(eg, blocks * px.block_tokens());
        }
    }

    /// Advance the active long request by one cooperative iteration's
    /// outcome at time `t` (chunk completed, or one decode token), growing
    /// its KV shards and retiring it when it finishes.
    fn complete_long_progress(
        &mut self,
        slot: Slot,
        long_chunk: Option<u64>,
        long_decode: bool,
        t: f64,
    ) {
        if let Some(c) = long_chunk {
            // TTFT is recorded once, by `record_finished_request` — the
            // same rule short requests follow. (The pre-PR-3 cores also
            // recorded it at decode entry, double-counting every finished
            // long request's TTFT in the percentile stream.)
            self.requests.get_mut(slot).complete_chunk(c, t);
            self.kvp_mgr.append_tokens(slot, c, t);
            self.note_recovery(slot, t);
        } else if long_decode {
            self.requests.get_mut(slot).complete_decode(t);
            self.kvp_mgr.append_tokens(slot, 1, t);
            self.note_recovery(slot, t);
        }
        let finished = {
            let r = self.requests.get(slot);
            if r.is_finished() {
                self.metrics.record_finished_request(r);
                Some(r.prompt_len)
            } else {
                None
            }
        };
        if let Some(prompt_len) = finished {
            self.kvp_mgr.release(slot);
            self.router.release(slot, prompt_len);
            self.active_long = None;
            self.retire(slot);
        }
    }

    /// Ownership of the cooperative long-request slot, called at the top
    /// of every step. Activates the most urgent queued long request when
    /// the slot is empty — served by the indexed [`ReadySet`] in O(log n)
    /// under the canonical `(priority, enqueue-order)` rule, bit-identical
    /// to the O(n) scan it replaced (re-asserted by a `debug_assert` on
    /// every selection). Under a **pooled** routing mode with a preemptive
    /// policy, at a chunk boundary (every shard-holding group idle), the
    /// **actively prefilling** request additionally yields to a strictly
    /// more urgent challenger; the yielded request keeps all of its
    /// per-group KV shards ([`KvpManager::yield_active`]) and its queue
    /// eligibility — resuming is just winning the slot back, from the
    /// exact boundary. Under blind routing the active request holds the
    /// slot to completion (the original lockstep contract).
    fn reselect_active_long(&mut self) {
        let active = match self.active_long {
            None => {
                let best = match self.select_queued_long() {
                    Some(s) => s,
                    None => return,
                };
                self.long_queue.remove(best);
                self.kvp_mgr.resume(best, self.now);
                self.active_long = Some(best);
                return;
            }
            Some(a) => a,
        };
        if !self.routing.pooled()
            || !self.sched_policy.preemptive()
            || self.long_queue.is_empty()
        {
            return;
        }
        // Preemption is legal only at a chunk boundary: every group holding
        // one of the active request's shards must be idle.
        let at_boundary = match self.kvp_mgr.shard_map(active) {
            Some(m) => m
                .shards
                .iter()
                .all(|&(g, _, _)| self.free_at[g as usize] <= self.now),
            None => true,
        };
        if !at_boundary {
            return;
        }
        match self.requests.get(active).phase {
            // Prefill preemption only: a decoding request holds the slot
            // to completion (its chunked work is already done).
            Phase::Decoding | Phase::Finished => {}
            Phase::Queued => {
                // Never ran a chunk yet: swapping it out is a queued
                // re-ordering, not an active yield — no event recorded.
                if self.challenger_beats(active).is_some() {
                    self.long_queue
                        .push(active, self.sched_policy.as_ref(), &self.requests);
                    self.active_long = None;
                    self.reselect_active_long();
                }
            }
            Phase::Prefilling => {
                if let Some(challenger) = self.challenger_beats(active) {
                    self.long_queue.remove(challenger);
                    self.kvp_mgr.yield_active(active, self.now);
                    self.metrics
                        .record_active_preemption(self.now, self.requests.get(active).id);
                    self.long_queue
                        .push(active, self.sched_policy.as_ref(), &self.requests);
                    self.kvp_mgr.resume(challenger, self.now);
                    self.active_long = Some(challenger);
                }
            }
        }
    }

    /// Most urgent queued long request per the indexed ready set, with the
    /// standing differential proof against the O(n) scan.
    fn select_queued_long(&self) -> Option<Slot> {
        let best = self
            .long_queue
            .select(self.sched_policy.as_ref(), &self.requests, self.now);
        debug_assert_eq!(
            best,
            self.long_queue
                .select_via_scan(self.sched_policy.as_ref(), &self.requests, self.now),
            "{}: long-queue index diverged from the scan at now={}",
            self.sched_policy.name(),
            self.now
        );
        best
    }

    /// The queued long request that would preempt `active`: the most
    /// urgent queued one, if **strictly** more urgent (a tie never evicts
    /// the request already holding KV shards on its groups).
    fn challenger_beats(&self, active: Slot) -> Option<Slot> {
        let best = self.select_queued_long()?;
        let p_best = self
            .sched_policy
            .priority(self.requests.get(best), self.now);
        let p_active = self
            .sched_policy
            .priority(self.requests.get(active), self.now);
        if p_best < p_active {
            Some(best)
        } else {
            None
        }
    }

    // ---- elastic fleet & failure injection ------------------------------

    /// Apply every scheduled fault whose time has been reached, merged in
    /// time order with pending join activations, then expire finished
    /// slowdowns and complete idle drains. Only entered when the run has a
    /// fault plan — a fault-free run never touches any of this state.
    fn apply_due_faults(&mut self) {
        loop {
            let ev_t = self
                .opts
                .faults
                .events
                .get(self.fault_cursor)
                .map_or(f64::INFINITY, |e| e.t_s);
            let warm = self
                .warming
                .iter()
                .enumerate()
                .min_by(|a, b| (a.1).0.total_cmp(&(b.1).0))
                .map(|(i, &(t, _))| (i, t));
            let warm_t = warm.map_or(f64::INFINITY, |&(_, t)| t);
            if ev_t <= self.now && ev_t <= warm_t {
                let e = self.opts.faults.events[self.fault_cursor].clone();
                self.fault_cursor += 1;
                self.apply_fault(&e);
            } else if warm_t <= self.now {
                let (i, _) = warm.unwrap();
                let (_, g) = self.warming.remove(i);
                self.kvp_mgr.activate(g);
                self.refresh_membership();
            } else {
                break;
            }
        }
        if !self.slowdowns.is_empty() {
            let now = self.now;
            self.slowdowns.retain(|&(_, _, until_s)| until_s > now);
        }
        // Opportunistic drain completion: a `Draining` group with nothing
        // resident (no KV, no reservations, no queued work) leaves the
        // fleet. Resident prefix chains are pure cache: once the group has
        // no queued work and no occupancy (hence no chain holders — pins
        // are owner-local and released at finish), they are dropped and
        // their ledger charge credited back so the drain can complete.
        for g in 0..self.scheds.len() {
            let gid = g as GroupId;
            if self.kvp_mgr.state(gid) != GroupState::Draining || self.scheds[g].has_work() {
                continue;
            }
            if self.kvp_mgr.occupancy(gid) == 0
                && self.kvp_mgr.reserved_on(gid) == 0
                && self.kvp_mgr.shared_on(gid) > 0
            {
                if let Some(px) = self.prefix.as_mut() {
                    let blocks = px.drop_group(gid);
                    let bt = px.block_tokens();
                    self.kvp_mgr.release_shared(gid, blocks * bt);
                }
            }
            if self.kvp_mgr.drain_idle(gid) {
                self.kvp_mgr.finish_drain(gid);
                self.refresh_membership();
            }
        }
    }

    fn apply_fault(&mut self, e: &FaultEvent) {
        match e.kind {
            FaultKind::Crash => {
                self.apply_crash(e.group.expect("validated: crash names a group"));
            }
            FaultKind::Drain => {
                self.kvp_mgr
                    .begin_drain(e.group.expect("validated: drain names a group"));
                self.refresh_membership();
            }
            FaultKind::Join { warmup_s } => {
                let g = self.fleet_join(e.group);
                if warmup_s > 0.0 {
                    self.warming.push((self.now + warmup_s, g));
                } else {
                    self.kvp_mgr.activate(g);
                }
                self.refresh_membership();
            }
            FaultKind::Slowdown { factor, until_s } => {
                self.slowdowns.push((
                    e.group.expect("validated: slowdown names a group"),
                    factor,
                    until_s,
                ));
            }
        }
    }

    /// Crash group `g` at the current instant. The KVP manager zeroes the
    /// group's ledger (occupancy **and** short reservations — the crash
    /// path cannot leak a reservation by construction) and reports every
    /// shard-losing request. Long victims rewind to their last surviving
    /// chunk boundary — surviving KV is retained, only the lost range
    /// re-prefills — and re-queue under their post-rewind priority; shorts
    /// resident on the group lose their KV wholly and re-admit from
    /// scratch. Iterations already completed for this instant stand: a
    /// crash lands at the first decision instant at or after its scheduled
    /// time.
    fn apply_crash(&mut self, g: GroupId) {
        assert!(
            self.kvp_mgr.is_live(g),
            "fault plan crashes group {g} which is already down"
        );
        let actives_left = self.kvp_mgr.n_active() - (self.kvp_mgr.is_placeable(g) as u32);
        assert!(
            actives_left >= 1,
            "crash of group {g} would leave no active group"
        );
        let rep = self.kvp_mgr.crash_group(g, self.now);
        self.metrics.group_crashes += 1;
        self.metrics.shards_lost += rep.shards_lost;
        self.refresh_membership();
        // The group's prefix chains died with its KV pool: drop them from
        // the index (handles invalidated — holders are exactly the shorts
        // evicted below, whose pins are forgotten, not released). The
        // ledger's `shared` column was already returned wholesale by
        // `crash_group` (`rep.shared_dropped`).
        if let Some(px) = self.prefix.as_mut() {
            px.drop_group(g);
        }

        // Long victims: rewind to the shard boundary the surviving prefix
        // ends at; chunk completion is what grew the shards, so that is a
        // completed-chunk boundary — re-prefill never redoes retained work.
        for &(slot, _before, surviving) in &rep.victims {
            let lost = self.requests.get_mut(slot).rewind_prefill(surviving);
            self.metrics.reprefill_tokens += lost;
            self.recovery_since.insert(slot as usize, self.now);
            if surviving == 0 {
                // Every shard died: forget the empty map and re-onboard on
                // a live group. The drop log pairs this fresh onboarding
                // with the loss, keeping the exactly-once audit clean.
                let (ext_id, prompt_len) = {
                    let r = self.requests.get(slot);
                    (r.id, r.prompt_len)
                };
                self.kvp_mgr.release(slot);
                self.router.release(slot, prompt_len);
                let home = self.place_least_loaded(slot, prompt_len);
                self.kvp_mgr.onboard_request(slot, ext_id, home, self.now);
            }
            // Re-file under the post-rewind priority (work remaining
            // grew); an active victim returns to the queue and the next
            // step re-decides who holds the cooperative slot.
            if self.active_long == Some(slot) {
                self.active_long = None;
            } else {
                self.long_queue.remove(slot);
            }
            self.long_queue
                .push(slot, self.sched_policy.as_ref(), &self.requests);
        }

        // Short victims: a short's KV lives wholly on its group, so its
        // resident progress is gone — rewind to zero and re-admit through
        // the normal (live-membership) admission path, which re-reserves
        // on the new group. The dead group's reservations were already
        // returned wholesale by `crash_group`.
        let mut evicted = std::mem::take(&mut self.evict_buf);
        self.scheds[g as usize].evict_all(&mut evicted);
        for i in 0..evicted.len() {
            let slot = evicted[i];
            // A granted victim's shared span died with the group's chains:
            // the span re-enters the request's own prefill work
            // (`clear_reuse` before the rewind, so the full footprint
            // re-reserves) and is metered separately — it was never
            // prefilled by this request, so it is new work forced by the
            // crash, not re-prefill of its own progress.
            let shared = {
                let r = self.requests.get_mut(slot);
                let shared = r.clear_reuse();
                let lost = r.rewind_prefill(0);
                self.metrics.reprefill_tokens += lost.saturating_sub(shared);
                self.metrics.reprefill_shared_tokens += shared;
                shared
            };
            if shared > 0 {
                self.reuse_hold.remove(slot as usize);
            }
            self.recovery_since.insert(slot as usize, self.now);
            let prompt_len = self.requests.get(slot).prompt_len;
            self.router.release(slot, prompt_len);
            // Re-admit without a reuse grant: the only chain this request
            // could hit died with its group (chains are single-group and
            // grants owner-local), and a fresh deadline would loosen the
            // admission-time SLO the victim already carries.
            self.admit_short(slot, prompt_len, None);
        }
        evicted.clear();
        self.evict_buf = evicted;
    }

    /// A group joins the fleet: revive the named `Down` slot, or grow by a
    /// brand-new group (fresh scheduler, timeline, clock, router lane,
    /// mask slot). The group is `Joining` — excluded from placement —
    /// until activated.
    fn fleet_join(&mut self, want: Option<GroupId>) -> GroupId {
        let prev = self.scheds.len();
        let g = self.kvp_mgr.announce_join(want);
        let spp = self.dep.parallel.spp.max(1) as usize;
        if (g as usize) < prev {
            // Revived slot: every structure is still sized; reset the
            // clock so the rejoined group starts from now, not from
            // whatever instant it died at.
            self.timelines[g as usize] = PipelineTimeline::new(spp, self.now);
            self.free_at[g as usize] = self.now;
        } else {
            let kind = self.dep.scheduler.policy;
            self.scheds.push(Scheduler::with_policy(
                Box::new(StaticChunk(self.dep.scheduler.static_chunk)),
                kind.build(),
                self.dep.scheduler.max_batch_size,
            ));
            self.timelines.push(PipelineTimeline::new(spp, self.now));
            self.free_at.push(self.now);
            self.group_plans.push(BatchPlan::default());
            self.router.grow_to(g + 1);
            self.placeable.push(false);
        }
        g
    }

    /// Rebuild the placement mask from the manager's group states.
    /// All-true when every group is `Active` (the fault-free fleet).
    fn refresh_membership(&mut self) {
        self.placeable.resize(self.scheds.len(), false);
        for g in 0..self.scheds.len() {
            self.placeable[g] = self.kvp_mgr.is_placeable(g as GroupId);
        }
    }

    /// Record a crash victim's recovery wait at its first post-crash
    /// service the simulator can observe per-request: a long request's
    /// next completed chunk or decode of re-prefill progress (at its
    /// completion instant `t`), a short request's completion. No-op (one
    /// `SlotVec` probe) for non-victims.
    fn note_recovery(&mut self, slot: Slot, t: f64) {
        if let Some(since) = self.recovery_since.remove(slot as usize) {
            self.metrics.record_recovery_wait(t - since);
        }
    }

    /// Lifecycle state of group `g` (post-run inspection).
    pub fn group_state(&self, g: GroupId) -> GroupState {
        self.kvp_mgr.state(g)
    }

    /// Number of `Active` groups right now.
    pub fn n_active_groups(&self) -> u32 {
        self.kvp_mgr.n_active()
    }

    /// See [`KvpManager::ledger_is_conserved`] — the capacity-conservation
    /// invariant, exposed for the test harness.
    /// Post-run inspection: the prefix index's internal invariants
    /// (refcount/tree/LRU consistency — [`PrefixIndex::check_invariants`]).
    /// Vacuously `true` when reuse is disabled.
    pub fn prefix_index_is_consistent(&self) -> bool {
        self.prefix
            .as_ref()
            .map_or(true, |px| px.check_invariants().is_ok())
    }

    /// Post-run inspection: shared-chain tokens currently charged to group
    /// `g` in the KVP ledger's `shared` column.
    pub fn kvp_shared_on(&self, g: GroupId) -> u64 {
        self.kvp_mgr.shared_on(g)
    }

    pub fn kvp_ledger_is_conserved(&self) -> bool {
        self.kvp_mgr.ledger_is_conserved()
    }

    /// Crash-time shard-drop audit trail: `(t, request, group)` per shard
    /// lost, the counterpart of [`Self::kvp_onboard_log`].
    pub fn kvp_drop_log(&self) -> &[(f64, RequestId, u32)] {
        &self.kvp_mgr.drop_log
    }

    /// Look up a request by its external id — live or (when
    /// `opts.retain_finished`) retired. Linear scan; post-run inspection
    /// only, never on the hot path.
    pub fn request(&self, id: RequestId) -> Option<&Request> {
        self.requests
            .iter()
            .map(|(_, r)| r)
            .chain(self.retired.iter())
            .find(|r| r.id == id)
    }

    pub fn kvp_onboard_log(&self) -> &[(f64, RequestId, u32)] {
        &self.kvp_mgr.onboard_log
    }

    /// See [`KvpManager::onboard_log_is_duplicate_free`] — the
    /// never-re-onboard invariant, exposed for the test harness.
    pub fn kvp_onboard_log_is_duplicate_free(&self) -> bool {
        self.kvp_mgr.onboard_log_is_duplicate_free()
    }

    /// Finished requests retained for post-run inspection
    /// (`opts.retain_finished`); empty in lean mode. Drives per-class
    /// latency splits (e.g. short-interactive vs long-document TTFT in the
    /// policy-comparison figure).
    pub fn retired(&self) -> &[Request] {
        &self.retired
    }

    /// High-water mark of concurrent requests (arena slots ever allocated)
    /// — the number that bounds simulator memory, independent of trace
    /// length.
    pub fn arena_high_water(&self) -> usize {
        self.requests.capacity()
    }

    /// Requests still live in the arena (0 after a fully drained run —
    /// every slot recycled; the invariant harness checks this).
    pub fn n_live(&self) -> usize {
        self.requests.len()
    }
}

/// Immutable per-instant inputs shared by every group's phase A. Nothing
/// here is mutated while phase A runs — the request arena, perf model,
/// and KVP ledger change only in phase B and between steps — which is the
/// whole safety argument for fanning the per-group calls out across
/// threads (see the module docs).
struct StepCtx<'a> {
    requests: &'a RequestArena,
    pm: &'a PerfModel,
    kvp: &'a KvpManager,
    slo: SloConfig,
    now: f64,
    layers_per_stage: u32,
    barrier: bool,
    coop_ready: bool,
    long_nq: u64,
    long_chunk: Option<u64>,
    long_decode: bool,
    participating: &'a [(GroupId, u64)],
    slowdowns: &'a [(GroupId, f64, f64)],
    /// `workers_per_replica()` — the pool-group iteration's GPU count.
    pool_gpus: u32,
}

/// One group's phase-A outcome, written into a pre-sized slot and merged
/// in group-index order. Pure data: everything order-dependent (metrics,
/// clocks, completions) happens at merge time, in `merge_group_outcome`.
#[derive(Debug, Clone, Copy, Default)]
struct GroupPhaseA {
    /// This group formed a non-empty batch this instant.
    ran: bool,
    /// Member of the cooperative set (barrier mode or shard holder).
    member: bool,
    has_decode: bool,
    /// Copies of the instant-wide inputs the merge's coop-chunk rule
    /// needs (uniform across groups; carried here so the slot is
    /// self-contained).
    barrier: bool,
    long_chunk: Option<u64>,
    /// Pipeline stage-0 re-admission point and batch exit.
    first: f64,
    exit: f64,
    prefill_toks: u64,
    n_decodes: usize,
    /// Pool-group utilization samples, precomputed from this group's own
    /// shape (zero and unused for cooperative members).
    mfu: f64,
    mbu: f64,
}

/// Cooperative-set accumulator threaded through the group merge.
struct CoopAcc {
    ran: bool,
    exit: f64,
    first: f64,
    any_decode: bool,
    decodes: usize,
    chunk: Option<u64>,
}

/// Iteration-time multiplier for group `g` under the transient slowdowns
/// in force — exactly 1.0 (not approximately) when none target it, so
/// undisturbed groups keep bit-exact timing.
fn slow_factor_of(slowdowns: &[(GroupId, f64, f64)], now: f64, g: usize) -> f64 {
    let mut f = 1.0;
    for &(sg, factor, until_s) in slowdowns {
        if sg as usize == g && now < until_s {
            f = f.max(factor);
        }
    }
    f
}

/// Phase A of one group's decision instant: batch formation, long-share
/// injection, and pipeline flow. Mutates only the group's own scheduler,
/// timeline, plan, and shape scratch (disjoint across groups) plus the
/// shared immutable [`StepCtx`] reads, so the per-group calls are
/// independent — the parallel step runs them on threadpool workers and
/// the serial step inline, with identical results either way.
fn group_phase_a(
    ctx: &StepCtx<'_>,
    g: usize,
    free_at_g: f64,
    sched: &mut Scheduler,
    timeline: &mut PipelineTimeline,
    plan: &mut BatchPlan,
    shape: &mut BatchShape,
) -> GroupPhaseA {
    let mut out = GroupPhaseA {
        barrier: ctx.barrier,
        long_chunk: ctx.long_chunk,
        ..GroupPhaseA::default()
    };
    plan.clear();
    shape.clear();
    if !ctx.kvp.is_live(g as GroupId) {
        // A crashed slot: holds nothing, forms nothing, until (and
        // unless) a join revives it. Always live fault-free.
        return out;
    }
    let holder = ctx.participating.iter().any(|&(gg, _)| gg as usize == g);
    let member = ctx.barrier || holder;
    out.member = member;
    let run_now = if member {
        // Pooled holders additionally wait for actual long work —
        // unreachable in practice (an active request always has a
        // chunk or a decode pending), kept as a guard.
        ctx.coop_ready && (ctx.barrier || ctx.long_nq > 0)
    } else {
        free_at_g <= ctx.now
    };
    if !run_now {
        return out;
    }
    sched.next_batch_into(ctx.requests, ctx.pm, &ctx.slo, ctx.now, plan);
    sched.batch_shape_into(plan, ctx.requests, Simulation::short_local_kv, shape);
    if holder {
        // Long-request share on this group: partial attention over
        // the local shard (queries broadcast to every holder).
        let local = ctx
            .participating
            .iter()
            .find(|&&(gg, _)| gg as usize == g)
            .expect("holder has a shard")
            .1;
        if let Some(c) = ctx.long_chunk {
            shape.prefills.push(PrefillWork {
                chunk: c,
                kv_len: local + c,
            });
        } else if ctx.long_decode {
            shape.decodes.push(DecodeWork {
                kv_len: local.max(1),
            });
        }
    }
    if shape.is_empty() {
        return out;
    }
    out.ran = true;
    out.has_decode = !shape.decodes.is_empty();
    // `slow_factor_of` is exactly 1.0 without a slowdown in force —
    // the multiply is then bit-exact with the undisturbed time.
    let st = ctx.pm.stage_time(shape, ctx.layers_per_stage).total()
        * slow_factor_of(ctx.slowdowns, ctx.now, g);
    let hop = ctx.pm.stage_hop_s(shape.tokens());
    let ready = if out.has_decode {
        ctx.now
    } else {
        timeline.stage0_free().max(ctx.now)
    };
    let (first, exit) = timeline.flow_compact(ready, |_| st, hop);
    out.first = first;
    out.exit = exit;
    out.prefill_toks = shape.prefills.iter().map(|p| p.chunk).sum();
    out.n_decodes = shape.decodes.len();
    if !member {
        // Pool-group utilization is a pure function of this group's shape
        // and duration: computed here so the merge stays bookkeeping.
        let dur = exit - ctx.now;
        if dur > 0.0 {
            let gpus = ctx.pool_gpus.max(1);
            out.mfu = ctx.pm.mfu(shape, dur, gpus);
            out.mbu = ctx.pm.mbu(shape, dur, gpus);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::workload;

    fn dep(tp: u32, spp: u32, kvp: u32) -> DeploymentConfig {
        DeploymentConfig::llama3_8b_tp8().with_parallel(tp, spp, kvp)
    }

    #[test]
    fn single_short_request_completes() {
        let w = workload::single_long(1_000, 8); // below long threshold
        let mut sim = Simulation::new(dep(8, 1, 1), w, SimOptions::default());
        sim.run();
        let r = sim.request(0).unwrap();
        assert!(r.is_finished());
        assert!(r.ttft().unwrap() > 0.0);
        assert_eq!(sim.metrics.finished_requests, 1);
    }

    #[test]
    fn long_request_prefill_records_ttft() {
        let w = workload::single_long(1_000_000, 4);
        let mut sim = Simulation::new(dep(8, 4, 1), w, SimOptions::default());
        sim.run();
        let r = sim.request(0).unwrap();
        assert!(r.is_finished());
        let ttft = r.ttft().unwrap();
        // 1M tokens on 32 H100-class GPUs: tens of seconds
        assert!((1.0..200.0).contains(&ttft), "ttft={ttft}");
    }

    #[test]
    fn spp_reduces_ttft_vs_single_stage() {
        let run = |spp: u32| {
            let w = workload::single_long(1_000_000, 4);
            let mut sim = Simulation::new(dep(8, spp, 1), w, SimOptions::default());
            sim.run();
            sim.request(0).unwrap().ttft().unwrap()
        };
        let t1 = run(1);
        let t4 = run(4);
        let speedup = t1 / t4;
        assert!(speedup > 3.0, "speedup={speedup} (t1={t1}, t4={t4})");
    }

    #[test]
    fn kvp_onboards_groups_as_context_grows() {
        let mut d = dep(8, 1, 4);
        d.scheduler.kvp_onboard_threshold = 256_000;
        let w = workload::single_long(1_000_000, 4);
        let mut sim = Simulation::new(d, w, SimOptions::default());
        sim.run();
        // 1M / 256K -> 4 groups onboarded
        assert_eq!(sim.kvp_onboard_log().len(), 4);
        let gpus: Vec<u32> = sim.metrics.iters.iter().map(|i| i.active_gpus).collect();
        assert!(gpus.iter().any(|&g| g == 8));
        assert!(gpus.iter().any(|&g| g == 32));
        // staircase: non-decreasing while the long request runs
        let peak = gpus.iter().copied().max().unwrap();
        assert_eq!(peak, 32);
    }

    #[test]
    fn mixed_batching_keeps_decodes_flowing() {
        // Decodes batched alongside a 1M prefill must see bounded TBT —
        // the anti-HOL-blocking claim (Fig. 14b).
        let mut d = dep(8, 1, 1);
        d.scheduler.max_batch_size = 64;
        let w = workload::long_plus_decodes(500_000, 8, 1_000, 64);
        let mut sim = Simulation::new(d, w, SimOptions::default());
        sim.run();
        let mut m = sim.metrics;
        let s = m.summary();
        assert!(s.n_tbt > 0);
        // every decode token arrived within a bounded iteration (<300ms),
        // not after the full multi-second prefill
        assert!(s.tbt_max < 0.3, "tbt_max={}", s.tbt_max);
        assert_eq!(s.finished, 9);
    }

    #[test]
    fn adaptive_chunks_shrink_over_long_prefill() {
        let mut d = dep(8, 1, 1);
        d.scheduler.adaptive_chunking = true;
        let w = workload::long_plus_decodes(2_000_000, 16, 1_000, 400);
        let mut sim = Simulation::new(d, w, SimOptions::default());
        sim.run();
        let chunks: Vec<u64> = sim.metrics.iters.iter().filter_map(|i| i.chunk).collect();
        assert!(chunks.len() > 10);
        let first = chunks[0];
        let last_quartile: Vec<u64> = chunks[chunks.len() * 3 / 4..].to_vec();
        let late_max = last_quartile.iter().copied().max().unwrap();
        assert!(
            late_max <= first,
            "late chunks ({late_max}) should not exceed early ({first})"
        );
    }

    #[test]
    fn arrivals_respected() {
        let w = vec![
            RequestSpec {
                id: 0,
                prompt_len: 100,
                max_new_tokens: 4,
                ..RequestSpec::default()
            },
            RequestSpec {
                id: 1,
                prompt_len: 100,
                max_new_tokens: 4,
                arrival_s: 1_000.0,
                ..RequestSpec::default()
            },
        ];
        let mut sim = Simulation::new(dep(8, 1, 1), w, SimOptions::default());
        let end = sim.run();
        assert!(end >= 1_000.0);
        let r1 = sim.request(1).unwrap();
        assert!(r1.first_token_s.unwrap() >= 1_000.0);
    }

    #[test]
    fn slots_recycle_under_churn() {
        // 200 sequential short requests: concurrency stays tiny, so the
        // arena's high-water mark must too.
        let w: Vec<RequestSpec> = (0..200)
            .map(|i| RequestSpec {
                id: i,
                prompt_len: 64,
                max_new_tokens: 2,
                arrival_s: i as f64 * 10.0, // far apart: never concurrent
                ..RequestSpec::default()
            })
            .collect();
        let opts = SimOptions {
            retain_finished: false,
            ..SimOptions::default()
        };
        let mut sim = Simulation::new(dep(8, 1, 1), w, opts);
        sim.run();
        assert_eq!(sim.metrics.finished_requests, 200);
        assert!(sim.requests.is_empty());
        assert!(
            sim.requests.capacity() <= 4,
            "arena grew to {} slots for sequential traffic",
            sim.requests.capacity()
        );
    }

    #[test]
    fn streaming_metrics_match_exact_counters() {
        let w = workload::long_plus_decodes(100_000, 8, 1_000, 64);
        let run = |opts: SimOptions| {
            let mut d = dep(8, 1, 1);
            d.scheduler.adaptive_chunking = false;
            d.scheduler.static_chunk = 2048;
            let mut sim = Simulation::new(d, w.clone(), opts);
            sim.run();
            sim.metrics
        };
        let exact = run(SimOptions::default());
        let lean = run(SimOptions {
            retain_finished: false,
            metrics_reservoir: Some(64),
            ..SimOptions::default()
        });
        // counters are exact in both modes
        assert_eq!(exact.finished_requests, lean.finished_requests);
        assert_eq!(exact.n_iters, lean.n_iters);
        assert_eq!(exact.decode_tokens, lean.decode_tokens);
        assert_eq!(exact.prefill_tokens, lean.prefill_tokens);
        assert_eq!(exact.tbt.count(), lean.tbt.count());
        assert!((exact.span_s() - lean.span_s()).abs() < 1e-12);
        // the lean run dropped the trace and capped the reservoirs
        assert!(lean.iters.is_empty() && !exact.iters.is_empty());
        assert!(lean.tbt.len() <= 64);
    }

    #[test]
    fn lars_policy_runs_and_records_attainment() {
        use crate::coordinator::SchedPolicyKind;
        let mut d = dep(8, 1, 1);
        d.scheduler.policy = SchedPolicyKind::Lars;
        d.scheduler.adaptive_chunking = false;
        d.scheduler.static_chunk = 2048;
        // a document prefill plus short interactive arrivals, all through
        // the group scheduler (no dedicated long path)
        let mut w = vec![RequestSpec {
            id: 0,
            prompt_len: 200_000,
            max_new_tokens: 4,
            ..RequestSpec::default()
        }];
        for i in 1..6u64 {
            w.push(RequestSpec {
                id: i,
                prompt_len: 512,
                max_new_tokens: 8,
                arrival_s: i as f64 * 0.5,
                ..RequestSpec::default()
            });
        }
        let opts = SimOptions {
            long_threshold: u64::MAX,
            ..SimOptions::default()
        };
        let mut sim = Simulation::new(d, w, opts);
        sim.run();
        let s = sim.metrics.summary();
        assert_eq!(s.finished, 6);
        // attainment was judged for every finished request
        assert_eq!(sim.metrics.ttft_deadline_met + sim.metrics.ttft_deadline_missed, 6);
        assert!(!s.ttft_attainment.is_nan());
        // shorts preempted the document at least once
        assert!(s.preemptions >= 1, "preemptions={}", s.preemptions);
        // every short got its first token long before the document finished
        let doc = sim.request(0).unwrap();
        for i in 1..6u64 {
            let short = sim.request(i).unwrap();
            assert!(
                short.first_token_s.unwrap() < doc.finished_s.unwrap(),
                "short {i} waited for the document"
            );
        }
    }

    #[test]
    fn pooled_round_robin_drains_kvp_convoy() {
        use crate::coordinator::SchedPolicyKind;
        let cfg = workload::KvpConvoyConfig {
            rate_per_s: 4.0,
            horizon_s: 10.0,
            doc_prompt: 64_000,
            n_docs: 2,
            doc_start_s: 1.0,
            doc_stagger_s: 3.0,
            ..workload::KvpConvoyConfig::default()
        };
        let n = workload::kvp_convoy(&cfg, 7).len() as u64;
        for kind in SchedPolicyKind::ALL {
            for routing in [RoutingMode::RoundRobin, RoutingMode::Routed] {
                let sim = run_kvp_convoy_scenario(kind, routing, &cfg, 7);
                assert_eq!(
                    sim.metrics.finished_requests,
                    n,
                    "{}/{} left requests behind",
                    kind.name(),
                    routing.name()
                );
            }
        }
    }

    #[test]
    fn pooled_srpt_yields_active_doc_and_resumes_exactly() {
        use crate::coordinator::SchedPolicyKind;
        let mut d = dep(8, 1, 4);
        d.scheduler.policy = SchedPolicyKind::Srpt;
        d.scheduler.routing = RoutingMode::Routed;
        d.scheduler.adaptive_chunking = false;
        d.scheduler.static_chunk = 2048;
        d.scheduler.kvp_onboard_threshold = 64_000;
        let w = vec![
            RequestSpec { id: 0, prompt_len: 200_000, max_new_tokens: 4, ..RequestSpec::default() },
            RequestSpec { id: 1, prompt_len: 32_000, max_new_tokens: 4, arrival_s: 1.0, ..RequestSpec::default() },
        ];
        let mut sim = Simulation::new(d, w, SimOptions::default());
        sim.run();
        assert_eq!(sim.metrics.finished_requests, 2);
        // the shorter document preempted the active one at a chunk boundary
        assert!(sim.metrics.active_preemptions >= 1);
        let ev = sim.metrics.preemption_events[0];
        assert_eq!(ev.request, 0);
        assert_eq!(ev.kind, crate::metrics::PreemptionKind::ActiveYield);
        let a = sim.request(0).unwrap();
        let b = sim.request(1).unwrap();
        assert!(b.finished_s.unwrap() < a.finished_s.unwrap(), "SRPT runs the short doc first");
        // resume is exact: every prompt token prefilled once, KV grown once
        assert_eq!(a.prefilled, 200_000);
        assert_eq!(sim.metrics.prefill_tokens, 232_000);
        // a retained shard is never re-onboarded across the yield
        assert!(sim.kvp_onboard_log_is_duplicate_free(), "shard re-onboarded after yield");
    }

    #[test]
    fn blind_routing_field_keeps_lockstep_counters() {
        // a routed-capable build must leave the default blind path
        // untouched: same scenario as `mixed_batching_keeps_decodes_flowing`
        // but asserting the new counters stay zero under FCFS + blind
        let mut d = dep(8, 1, 1);
        d.scheduler.max_batch_size = 64;
        let w = workload::long_plus_decodes(500_000, 8, 1_000, 64);
        let mut sim = Simulation::new(d, w, SimOptions::default());
        sim.run();
        assert_eq!(sim.metrics.active_preemptions, 0);
        assert!(sim.metrics.preemption_events.is_empty());
        // per-group utilization recorded even in lockstep mode
        assert_eq!(sim.metrics.group_busy_s.len(), 1);
        assert!(sim.metrics.group_busy_s[0] > 0.0);
        assert!(sim.metrics.group_utilization()[0] > 0.5);
    }

    #[test]
    fn admission_assigns_length_aware_deadlines() {
        let w = vec![
            RequestSpec { id: 0, prompt_len: 100, max_new_tokens: 2, ..RequestSpec::default() },
            RequestSpec { id: 1, prompt_len: 1_000_000, max_new_tokens: 2, ..RequestSpec::default() },
        ];
        let mut sim = Simulation::new(dep(8, 1, 1), w, SimOptions::default());
        sim.run();
        let short = sim.request(0).unwrap();
        let long = sim.request(1).unwrap();
        assert!(short.deadline_s.is_finite() && long.deadline_s.is_finite());
        // short request: floored budget; long request: proportional budget
        assert_eq!(short.ttft_budget_s(), sim.dep.slo.ttft_floor_s);
        assert!(long.ttft_budget_s() > short.ttft_budget_s());
        assert!(long.est_prefill_s > short.est_prefill_s);
    }

    fn one_fault(t_s: f64, group: Option<u32>, kind: FaultKind) -> FaultPlan {
        FaultPlan {
            events: vec![FaultEvent { t_s, group, kind }],
        }
    }

    #[test]
    fn crash_rewinds_long_prefill_to_surviving_boundary() {
        let mk = || {
            let mut d = dep(8, 1, 4);
            d.scheduler.routing = RoutingMode::RoundRobin;
            d.scheduler.adaptive_chunking = false;
            d.scheduler.static_chunk = 4096;
            d.scheduler.kvp_onboard_threshold = 128_000;
            d
        };
        let w = workload::single_long(400_000, 4);
        // Probe run: when does the second group onboard, and when does the
        // run end? The crash is scheduled a quarter of the way between —
        // mid-prefill, with at least one shard on a surviving group.
        let mut probe = Simulation::new(mk(), w.clone(), SimOptions::default());
        let end = probe.run();
        let log = probe.kvp_onboard_log();
        assert!(log.len() >= 2, "document never sharded: {log:?}");
        let (t1, _, victim_group) = log[1];
        let crash_t = t1 + (end - t1) * 0.25;

        let opts = SimOptions {
            faults: one_fault(crash_t, Some(victim_group), FaultKind::Crash),
            ..SimOptions::default()
        };
        let mut sim = Simulation::new(mk(), w, opts);
        sim.run();
        // Degradation is accounted, and the request still completes fully.
        assert_eq!(sim.metrics.finished_requests, 1);
        let r = sim.request(0).unwrap();
        assert!(r.is_finished());
        assert_eq!(r.prefilled, 400_000);
        assert_eq!(sim.metrics.group_crashes, 1);
        assert!(sim.metrics.shards_lost >= 1);
        // The rewind is partial: the lost range re-prefills, the surviving
        // prefix (the first group's shard) is never redone.
        assert!(
            sim.metrics.reprefill_tokens > 0 && sim.metrics.reprefill_tokens < 400_000,
            "reprefill_tokens={}",
            sim.metrics.reprefill_tokens
        );
        assert_eq!(
            sim.metrics.prefill_tokens,
            400_000 + sim.metrics.reprefill_tokens,
            "prefill executed must be prompt plus exactly the lost range"
        );
        // Exactly-once audit holds across the loss: drops pair with
        // re-onboardings, the ledger stays conserved, the group is down.
        assert!(!sim.kvp_drop_log().is_empty());
        assert!(sim.kvp_onboard_log_is_duplicate_free());
        assert!(sim.kvp_ledger_is_conserved());
        assert_eq!(sim.group_state(victim_group), GroupState::Down);
        assert_eq!(sim.n_active_groups(), 3);
        // The victim's recovery wait was sampled once.
        assert_eq!(sim.metrics.summary().n_recovered, 1);
    }

    #[test]
    fn join_grows_the_fleet_and_serves_new_work() {
        let mut d = dep(8, 1, 2);
        d.scheduler.routing = RoutingMode::RoundRobin;
        let w: Vec<RequestSpec> = (0..12)
            .map(|i| RequestSpec {
                id: i,
                prompt_len: 2_000,
                max_new_tokens: 2,
                arrival_s: 2.0 + i as f64 * 0.5,
                ..RequestSpec::default()
            })
            .collect();
        let opts = SimOptions {
            faults: one_fault(1.0, None, FaultKind::Join { warmup_s: 0.5 }),
            ..SimOptions::default()
        };
        let mut sim = Simulation::new(d, w, opts);
        sim.run();
        assert_eq!(sim.metrics.finished_requests, 12);
        assert_eq!(sim.n_active_groups(), 3, "the joined group is active");
        assert_eq!(sim.group_state(2), GroupState::Active);
        // Round-robin rotated real work onto the new group.
        assert_eq!(sim.metrics.group_busy_s.len(), 3);
        assert!(sim.metrics.group_busy_s[2] > 0.0, "joined group never served");
        assert!(sim.kvp_ledger_is_conserved());
    }

    #[test]
    fn drain_retires_a_group_without_dropping_work() {
        let mut d = dep(8, 1, 2);
        d.scheduler.routing = RoutingMode::RoundRobin;
        let w: Vec<RequestSpec> = (0..10)
            .map(|i| RequestSpec {
                id: i,
                prompt_len: 2_000,
                max_new_tokens: 2,
                arrival_s: i as f64 * 0.5,
                ..RequestSpec::default()
            })
            .collect();
        let opts = SimOptions {
            faults: one_fault(1.0, Some(1), FaultKind::Drain),
            ..SimOptions::default()
        };
        let mut sim = Simulation::new(d, w, opts);
        sim.run();
        // Graceful: every request finishes, nothing is lost or redone.
        assert_eq!(sim.metrics.finished_requests, 10);
        assert_eq!(sim.metrics.group_crashes, 0);
        assert_eq!(sim.metrics.shards_lost, 0);
        assert_eq!(sim.metrics.reprefill_tokens, 0);
        // The drained group finished its resident work and left the fleet.
        assert_eq!(sim.group_state(1), GroupState::Down);
        assert_eq!(sim.n_active_groups(), 1);
        assert!(sim.kvp_ledger_is_conserved());
    }

    #[test]
    fn slowdown_stretches_only_the_target_group() {
        let run = |faults: FaultPlan| {
            let opts = SimOptions {
                faults,
                ..SimOptions::default()
            };
            let w = workload::single_long(4_000, 8); // short: below threshold
            let mut sim = Simulation::new(dep(8, 1, 1), w, opts);
            sim.run();
            sim.request(0).unwrap().finished_s.unwrap()
        };
        let base = run(FaultPlan::default());
        let slowed = run(one_fault(
            0.0,
            Some(0),
            FaultKind::Slowdown {
                factor: 3.0,
                until_s: 1e9,
            },
        ));
        assert!(
            slowed > base * 1.5,
            "slowdown did not stretch the run: base={base} slowed={slowed}"
        );
    }

    #[test]
    fn crash_then_rejoin_restores_the_fleet() {
        let mut d = dep(8, 1, 2);
        d.scheduler.routing = RoutingMode::RoundRobin;
        let w: Vec<RequestSpec> = (0..10)
            .map(|i| RequestSpec {
                id: i,
                prompt_len: 2_000,
                max_new_tokens: 2,
                arrival_s: i as f64 * 0.4,
                ..RequestSpec::default()
            })
            .collect();
        let opts = SimOptions {
            faults: FaultPlan {
                events: vec![
                    FaultEvent {
                        t_s: 1.0,
                        group: Some(1),
                        kind: FaultKind::Crash,
                    },
                    FaultEvent {
                        t_s: 2.0,
                        group: Some(1),
                        kind: FaultKind::Join { warmup_s: 0.0 },
                    },
                ],
            },
            ..SimOptions::default()
        };
        let mut sim = Simulation::new(d, w, opts);
        sim.run();
        assert_eq!(sim.metrics.finished_requests, 10, "no request left behind");
        assert_eq!(sim.metrics.group_crashes, 1);
        assert_eq!(sim.group_state(1), GroupState::Active, "slot revived");
        assert_eq!(sim.n_active_groups(), 2);
        assert!(sim.kvp_ledger_is_conserved());
        assert!(sim.kvp_onboard_log_is_duplicate_free());
    }

    #[test]
    fn parallel_step_summary_matches_serial_in_module() {
        // The in-crate sanity check for scheduler.threads > 1 (the full
        // bit-exact matrix lives in tests/sim_golden.rs): same mixed
        // trace, pooled 4-group round-robin, serial vs threaded summary.
        let run = |threads: usize| {
            let mut d = dep(8, 1, 4);
            d.scheduler.routing = RoutingMode::RoundRobin;
            d.scheduler.adaptive_chunking = false;
            d.scheduler.static_chunk = 2048;
            d.scheduler.threads = threads;
            let w = workload::poisson_mixed(
                8.0,
                10.0,
                workload::LengthDist::ZipfBuckets { buckets: vec![128, 1_024, 4_096], s: 1.2 },
                8,
                7,
            );
            let mut sim = Simulation::new(d, w, SimOptions::default());
            let end = sim.run();
            let s = sim.metrics.summary();
            (
                end.to_bits(),
                s.finished,
                sim.metrics.n_iters,
                s.ttft_p95.to_bits(),
                s.goodput_rps.to_bits(),
            )
        };
        let serial = run(1);
        assert!(serial.1 > 10, "degenerate trace: {} finished", serial.1);
        assert_eq!(serial, run(2), "threads=2 diverged");
        assert_eq!(serial, run(4), "threads=4 diverged");
    }

    #[test]
    fn idle_gaps_jump_to_next_event() {
        // two requests 1000s apart: the run must not spin through the gap
        // (bounded iteration count implies the event jump worked)
        let w = vec![
            RequestSpec { id: 0, prompt_len: 100, max_new_tokens: 2, ..RequestSpec::default() },
            RequestSpec { id: 1, prompt_len: 100, max_new_tokens: 2, arrival_s: 1_000.0, ..RequestSpec::default() },
        ];
        let mut sim = Simulation::new(dep(8, 1, 1), w, SimOptions::default());
        let end = sim.run();
        assert!(end >= 1_000.0);
        assert!(
            sim.metrics.n_iters < 100,
            "spun {} iterations across an idle gap",
            sim.metrics.n_iters
        );
    }

    // ---- prefix-aware KV reuse ------------------------------------------

    /// Two turns of one session on a blind 1-group fleet: the second turn
    /// is granted the first turn's full-block chain, its estimate covers
    /// only the remaining span, and the chain blocks land in the ledger's
    /// shared column exactly once.
    #[test]
    fn reuse_grant_skips_resident_span_and_tightens_estimate() {
        let turn = |id: u64, prompt: u64, at: f64| RequestSpec {
            id,
            prompt_len: prompt,
            max_new_tokens: 4,
            arrival_s: at,
            prefix_ns: 1,
            sys_tokens: 0,
        };
        let w = vec![turn(0, 4_096, 0.0), turn(1, 4_352, 50.0)];
        let mut d = dep(8, 1, 1);
        d.scheduler.prefix_reuse = true;
        let mut sim = Simulation::new(d, w, SimOptions::default());
        sim.run();
        assert_eq!(sim.metrics.finished_requests, 2);
        let r0 = sim.request(0).unwrap();
        let r1 = sim.request(1).unwrap();
        assert_eq!(r0.reused_tokens, 0, "nothing resident at the first turn");
        // Turn 0 retires 4096 + 4 KV tokens: 16 full 256-token blocks.
        assert_eq!(r1.reused_tokens, 4_096, "turn 1 reuses the indexed chain");
        assert!(
            r1.est_prefill_s < r0.est_prefill_s,
            "hit-aware estimate must cover only the remaining span: {} vs {}",
            r1.est_prefill_s,
            r0.est_prefill_s
        );
        assert_eq!(sim.metrics.prefix_hit_tokens, 4_096);
        assert!(sim.metrics.blocks_shared >= 16);
        assert!(sim.prefix_index_is_consistent());
        assert!(sim.kvp_ledger_is_conserved());
        assert!(sim.kvp_shared_on(0) > 0, "retired chains stay indexed");
    }

    /// Crash of the chain-owning group mid-flight: the granted victim's
    /// shared span re-enters its own prefill work, is metered once as
    /// `reprefill_shared_tokens`, and the dead group's shared-ledger
    /// column returns to zero. The re-admitted request completes on the
    /// survivor without a second grant.
    #[test]
    fn reuse_crash_reprefills_shared_span_exactly_once() {
        let turn = |id: u64, prompt: u64, at: f64| RequestSpec {
            id,
            prompt_len: prompt,
            max_new_tokens: 4,
            arrival_s: at,
            prefix_ns: 1,
            sys_tokens: 0,
        };
        // Turn 0 on the (tied, lowest-id) group 0 indexes 32 blocks =
        // 8192 tokens; turn 1 arrives long after it finished, ties to
        // group 0 again, and is granted the full chain. The crash lands
        // at the first decision instant after turn 1 starts executing.
        let w = vec![turn(0, 8_192, 0.0), turn(1, 15_000, 100.0)];
        let mut d = dep(8, 1, 2);
        d.scheduler.prefix_reuse = true;
        let opts = SimOptions {
            faults: one_fault(100.001, Some(0), FaultKind::Crash),
            ..SimOptions::default()
        };
        let mut sim = Simulation::new(d, w, opts);
        sim.run();
        assert_eq!(sim.metrics.group_crashes, 1);
        assert_eq!(sim.metrics.finished_requests, 2, "no request left behind");
        assert_eq!(
            sim.metrics.prefix_hit_tokens, 8_192,
            "one grant, before the crash; the re-admission finds no chain"
        );
        assert_eq!(
            sim.metrics.reprefill_shared_tokens, 8_192,
            "the shared span is metered exactly once"
        );
        assert_eq!(sim.kvp_shared_on(0), 0, "crashed group's column returned");
        assert!(sim.kvp_shared_on(1) > 0, "survivor indexed the re-run's KV");
        assert!(sim.prefix_index_is_consistent());
        assert!(sim.kvp_ledger_is_conserved());
    }

    /// A draining group's resident chains are pure cache: once its work
    /// completes they are dropped, the shared column returns to zero, and
    /// the drain finishes.
    #[test]
    fn drain_completes_after_dropping_cached_chains() {
        let w = vec![
            RequestSpec {
                id: 0,
                prompt_len: 4_096,
                max_new_tokens: 4,
                prefix_ns: 1,
                ..RequestSpec::default()
            },
            // A later namespace-free short keeps the run alive past the
            // drain instant (and lands on the surviving group).
            RequestSpec { id: 1, prompt_len: 512, max_new_tokens: 4, arrival_s: 5.0, ..RequestSpec::default() },
        ];
        let mut d = dep(8, 1, 2);
        d.scheduler.prefix_reuse = true;
        let opts = SimOptions {
            faults: one_fault(2.0, Some(0), FaultKind::Drain),
            ..SimOptions::default()
        };
        let mut sim = Simulation::new(d, w, opts);
        sim.run();
        assert_eq!(sim.metrics.finished_requests, 2);
        assert_eq!(sim.n_active_groups(), 1, "the drained group left the fleet");
        assert_eq!(sim.kvp_shared_on(0), 0, "cached chains dropped at drain");
        assert!(sim.prefix_index_is_consistent());
        assert!(sim.kvp_ledger_is_conserved());
    }

    /// The reuse acceptance criteria on the shared multiturn scenario
    /// (LARS + routed affinity): nonzero hit rate, strictly fewer prefill
    /// tokens executed than the no-reuse control, and background-short
    /// p99 TTFT no worse.
    #[test]
    fn multiturn_reuse_saves_prefill_without_hurting_shorts() {
        use crate::coordinator::SchedPolicyKind;
        let cfg = workload::MultiTurnConfig::default();
        let mut on =
            run_multiturn_scenario(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg, 7, true);
        let mut off =
            run_multiturn_scenario(SchedPolicyKind::Lars, RoutingMode::Routed, &cfg, 7, false);
        assert_eq!(
            on.metrics.finished_requests, off.metrics.finished_requests,
            "reuse must not change which requests finish"
        );
        assert!(on.metrics.prefix_hit_tokens > 0, "sessions must hit the index");
        assert_eq!(off.metrics.prefix_hit_tokens, 0);
        let s_on = on.metrics.summary();
        let s_off = off.metrics.summary();
        assert!(s_on.prefix_hit_rate > 0.0);
        assert!(
            on.metrics.prefill_tokens < off.metrics.prefill_tokens,
            "granted spans must not be prefilled again: {} vs {}",
            on.metrics.prefill_tokens,
            off.metrics.prefill_tokens
        );
        let (mut short_on, _) = multiturn_ttft_split(&on, &cfg);
        let (mut short_off, _) = multiturn_ttft_split(&off, &cfg);
        assert!(short_on.count() > 0 && short_off.count() > 0);
        assert!(
            short_on.p99() <= short_off.p99() + 1e-6,
            "reuse+affinity must not degrade short p99 TTFT: {} vs {}",
            short_on.p99(),
            short_off.p99()
        );
        assert!(on.prefix_index_is_consistent());
        assert!(on.kvp_ledger_is_conserved());
    }

    /// With reuse disabled, the multiturn trace's namespace fields are
    /// inert: the run is bit-identical to the same trace with them
    /// stripped (the differential guard behind "reuse off ≡ pre-reuse").
    #[test]
    fn multiturn_reuse_disabled_ignores_namespace_fields() {
        use crate::coordinator::SchedPolicyKind;
        let cfg = workload::MultiTurnConfig::default();
        let run = |strip: bool| {
            let mut w = workload::multiturn(&cfg, 11);
            if strip {
                for spec in &mut w {
                    spec.prefix_ns = 0;
                    spec.sys_tokens = 0;
                }
            }
            let mut d = dep(8, 1, 4);
            d.scheduler.policy = SchedPolicyKind::Lars;
            d.scheduler.routing = RoutingMode::Routed;
            d.scheduler.adaptive_chunking = false;
            d.scheduler.static_chunk = 2048;
            let mut sim = Simulation::new(d, w, SimOptions::default());
            let end = sim.run();
            let s = sim.metrics.summary();
            (
                end.to_bits(),
                s.finished,
                sim.metrics.n_iters,
                sim.metrics.prefill_tokens,
                s.ttft_p95.to_bits(),
                s.goodput_rps.to_bits(),
            )
        };
        assert_eq!(run(false), run(true), "namespace fields leaked into a reuse-off run");
    }

    /// `scheduler.headroom_autotune`: under a persistent slowdown the EWMA
    /// correction scales later admissions' estimates up; with the flag off
    /// (or no slowdown) estimates are untouched.
    #[test]
    fn headroom_autotune_scales_admission_estimates() {
        let w = || {
            vec![
                RequestSpec { id: 0, prompt_len: 8_000, max_new_tokens: 8, ..RequestSpec::default() },
                RequestSpec { id: 1, prompt_len: 8_000, max_new_tokens: 8, arrival_s: 200.0, ..RequestSpec::default() },
            ]
        };
        let slow = || {
            one_fault(0.0, Some(0), FaultKind::Slowdown { factor: 4.0, until_s: 1e12 })
        };
        let run = |autotune: bool| {
            let mut d = dep(8, 1, 1);
            d.scheduler.headroom_autotune = autotune;
            let opts = SimOptions { faults: slow(), ..SimOptions::default() };
            let mut sim = Simulation::new(d, w(), opts);
            sim.run();
            let e0 = sim.request(0).unwrap().est_prefill_s;
            let e1 = sim.request(1).unwrap().est_prefill_s;
            (e0, e1)
        };
        let (base0, base1) = run(false);
        assert_eq!(base0, base1, "identical requests, identical estimates");
        let (tuned0, tuned1) = run(true);
        assert_eq!(
            tuned0, base0,
            "the first admission precedes any observation: factor is 1.0"
        );
        assert!(
            tuned1 > base1 * 1.5,
            "the EWMA must have absorbed the 4x slowdown: {} vs {}",
            tuned1,
            base1
        );
    }
}
