//! Streaming statistics: mean/variance (Welford), exact percentiles over
//! retained samples, and fixed-bucket histograms. Used by the metrics layer
//! (TTFT/TBT percentiles) and the bench harness.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile sample collector with O(1) amortized ingestion: samples are
/// appended unsorted and sorting is deferred to the first percentile query
/// after a batch of inserts.
///
/// By default every sample is retained (exact percentiles). For
/// multi-million-sample runs, [`Samples::reservoir`] caps memory with
/// uniform reservoir sampling (Vitter's Algorithm R): percentiles become
/// estimates over a fixed-size uniform subsample, while `count()` still
/// reports the true number ingested.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
    /// Max retained samples (`None` = retain everything, exact).
    cap: Option<usize>,
    /// Total samples ever ingested (>= xs.len() when capped).
    seen: u64,
    /// xorshift64* state for reservoir replacement decisions.
    rng: u64,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reservoir-sampled collector retaining at most `cap` samples.
    pub fn reservoir(cap: usize, seed: u64) -> Self {
        Samples {
            xs: Vec::with_capacity(cap.max(1).min(1 << 20)),
            sorted: false,
            cap: Some(cap.max(1)),
            seen: 0,
            rng: seed | 1, // xorshift state must be nonzero
        }
    }

    fn next_u64(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.rng;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    pub fn add(&mut self, x: f64) {
        self.seen += 1;
        match self.cap {
            Some(cap) if self.xs.len() >= cap => {
                // Algorithm R: keep each of the `seen` samples with equal
                // probability cap/seen.
                let j = self.next_u64() % self.seen;
                if (j as usize) < cap {
                    self.xs[j as usize] = x;
                    self.sorted = false;
                }
            }
            _ => {
                self.xs.push(x);
                self.sorted = false;
            }
        }
    }

    pub fn extend(&mut self, other: &Samples) {
        for &x in &other.xs {
            self.add(x);
        }
        // Samples `other` ingested but did not retain (its own reservoir
        // dropped them) still count toward the total seen here.
        self.seen += other.seen.saturating_sub(other.xs.len() as u64);
    }

    /// Retained samples (== `count()` unless reservoir-capped).
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// Total samples ever ingested.
    pub fn count(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // total_cmp, not partial_cmp().unwrap(): a NaN sample (e.g. a
            // latency computed from an uninitialized timestamp) must sort
            // to the end, not panic the whole metrics query.
            self.xs.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Percentile p in [0, 100], linear interpolation between ranks.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let rank = p / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    /// Percentile p in [0, 100] by the nearest-rank definition (the value at
    /// rank `ceil(p/100 * n)`, 1-based) — no interpolation, always an actual
    /// sample. Prefer this over ad-hoc `(len as f64 * p) as usize` indexing,
    /// which truncates toward zero and is biased low.
    pub fn percentile_nearest_rank(&mut self, p: f64) -> f64 {
        self.ensure_sorted();
        nearest_rank_sorted(&self.xs, p)
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        *self.xs.last().unwrap_or(&f64::NAN)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        *self.xs.first().unwrap_or(&f64::NAN)
    }
}

/// Nearest-rank percentile over an arbitrary (unsorted) slice: sorts a copy
/// NaN-safely (`total_cmp`) and returns the sample at rank
/// `ceil(p/100 * n)` (1-based). `NaN` when empty.
///
/// This is the one shared definition for call sites that hold a plain
/// `Vec<f64>` rather than a [`Samples`] collector (e.g. per-request TBT
/// vectors in the serving report).
pub fn percentile_nearest_rank(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    nearest_rank_sorted(&sorted, p)
}

/// Nearest-rank lookup over an already-sorted slice.
fn nearest_rank_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.max(1) - 1]
}

/// Streaming quantile estimator (Jain & Chlamtac's P² algorithm): tracks a
/// single quantile `p` with five markers in O(1) memory and O(1) per
/// sample — the no-retention alternative to [`Samples::reservoir`] when
/// only one or two percentiles of a multi-million-sample stream matter.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    p: f64,
    /// Marker heights (estimated quantile values).
    q: [f64; 5],
    /// Marker positions (1-based ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired position increments per observation.
    dn: [f64; 5],
    count: u64,
}

impl P2Quantile {
    pub fn new(p: f64) -> P2Quantile {
        assert!((0.0..=1.0).contains(&p), "quantile must be in [0,1]");
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if self.count <= 5 {
            let k = (self.count - 1) as usize;
            self.q[k] = x;
            if self.count == 5 {
                // NaN-safe: see Samples::ensure_sorted.
                self.q.sort_by(|a, b| a.total_cmp(b));
            }
            return;
        }
        // locate the cell containing x, clamping the extreme markers
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= self.q[k + 1] {
                k += 1;
            }
            k
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // adjust interior markers toward their desired positions
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                // parabolic (P²) prediction, falling back to linear
                let qp = self.q[i]
                    + d / (self.n[i + 1] - self.n[i - 1])
                        * ((self.n[i] - self.n[i - 1] + d) * (self.q[i + 1] - self.q[i])
                            / (self.n[i + 1] - self.n[i])
                            + (self.n[i + 1] - self.n[i] - d) * (self.q[i] - self.q[i - 1])
                                / (self.n[i] - self.n[i - 1]));
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    let j = if d > 0.0 { i + 1 } else { i - 1 };
                    self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
                };
                self.n[i] += d;
            }
        }
    }

    /// Current estimate of the tracked quantile.
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.count < 5 {
            // exact over the few samples seen so far
            let mut xs: Vec<f64> = self.q[..self.count as usize].to_vec();
            xs.sort_by(|a, b| a.total_cmp(b));
            let rank = self.p * (xs.len() - 1) as f64;
            return xs[rank.round() as usize];
        }
        self.q[2]
    }
}

/// Fixed-bucket histogram over [lo, hi) with `n` buckets plus under/overflow.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Self {
            lo,
            hi,
            buckets: vec![0; n],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            // floor is the intended bucketing (x >= lo, so the operand is
            // non-negative and floor == trunc); spelling it out keeps the
            // rounding mode explicit per the determinism contract (D4).
            let i = ((x - self.lo) / (self.hi - self.lo) * n as f64).floor() as usize;
            self.buckets[i.min(n - 1)] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }
}

/// Pretty-print a duration given in seconds with adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if !secs.is_finite() {
        format!("{secs}")
    } else if secs >= 60.0 {
        format!("{:.1}min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.2}us", secs * 1e6)
    } else {
        format!("{:.0}ns", secs * 1e9)
    }
}

/// Pretty-print a token count (1.0M, 256K, ...).
pub fn fmt_tokens(n: u64) -> String {
    if n >= 1_000_000 && n % 100_000 == 0 {
        format!("{}M", n as f64 / 1e6)
    } else if n >= 1_000 && n % 1_000 == 0 {
        format!("{}K", n / 1_000)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::new();
        for i in 0..=100 {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(95.0), 95.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Samples::new();
        s.add(0.0);
        s.add(10.0);
        assert!((s.percentile(50.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_matches_definition() {
        // 1..=20: p95 is the 19th order statistic (ceil(0.95*20) = 19).
        let xs: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&xs, 95.0), 19.0);
        assert_eq!(percentile_nearest_rank(&xs, 100.0), 20.0);
        assert_eq!(percentile_nearest_rank(&xs, 0.0), 1.0);
        assert_eq!(percentile_nearest_rank(&xs, 50.0), 10.0);
        // single sample: every percentile is that sample
        assert_eq!(percentile_nearest_rank(&[7.0], 95.0), 7.0);
        assert!(percentile_nearest_rank(&[], 95.0).is_nan());
        // input order must not matter
        let mut rev = xs.clone();
        rev.reverse();
        assert_eq!(percentile_nearest_rank(&rev, 95.0), 19.0);
    }

    #[test]
    fn nearest_rank_samples_method_agrees_with_free_fn() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        let mut s = Samples::new();
        for &x in &xs {
            s.add(x);
        }
        for p in [0.0, 1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile_nearest_rank(p), percentile_nearest_rank(&xs, p));
        }
    }

    #[test]
    fn nearest_rank_is_nan_safe() {
        // A NaN sample sorts to the end (total_cmp): low/mid percentiles
        // stay meaningful instead of panicking or poisoning everything.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile_nearest_rank(&xs, 50.0), 2.0);
        assert!(percentile_nearest_rank(&xs, 100.0).is_nan());
    }

    #[test]
    fn reservoir_caps_memory_but_counts_all() {
        let mut s = Samples::reservoir(100, 42);
        for i in 0..10_000 {
            s.add(i as f64);
        }
        assert_eq!(s.len(), 100);
        assert_eq!(s.count(), 10_000);
        // a uniform subsample of 0..10000: the median estimate must land
        // in the central half
        let med = s.median();
        assert!((2_000.0..8_000.0).contains(&med), "median={med}");
    }

    #[test]
    fn extend_from_reservoir_keeps_total_count() {
        let mut src = Samples::reservoir(50, 9);
        for i in 0..5_000 {
            src.add(i as f64);
        }
        let mut dst = Samples::new();
        dst.add(1.0);
        dst.extend(&src);
        assert_eq!(dst.len(), 51); // 1 + the 50 retained
        assert_eq!(dst.count(), 5_001); // but every ingested sample counted
    }

    #[test]
    fn exact_mode_count_equals_len() {
        let mut s = Samples::new();
        for i in 0..1000 {
            s.add(i as f64);
        }
        assert_eq!(s.len(), 1000);
        assert_eq!(s.count(), 1000);
        assert_eq!(s.percentile(100.0), 999.0);
    }

    #[test]
    fn p2_tracks_median_and_p99() {
        // deterministic pseudo-random stream, uniform in [0, 1)
        let mut state = 88172645463325252u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let mut med = P2Quantile::new(0.5);
        let mut p99 = P2Quantile::new(0.99);
        let mut exact = Samples::new();
        for _ in 0..50_000 {
            let x = next();
            med.add(x);
            p99.add(x);
            exact.add(x);
        }
        assert!((med.value() - exact.median()).abs() < 0.02, "{}", med.value());
        assert!((p99.value() - exact.p99()).abs() < 0.02, "{}", p99.value());
        assert_eq!(med.count(), 50_000);
    }

    #[test]
    fn p2_small_streams_are_exact_enough() {
        let mut q = P2Quantile::new(0.5);
        assert!(q.value().is_nan());
        for x in [5.0, 1.0, 3.0] {
            q.add(x);
        }
        assert_eq!(q.value(), 3.0);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(100.0);
        assert_eq!(h.count(), 12);
        assert!(h.bucket_counts().iter().all(|&c| c == 1));
        assert_eq!(h.overflow(), 1);
    }

    #[test]
    fn nan_sample_does_not_panic_percentiles() {
        // Regression: the sorts used partial_cmp().unwrap(), so a single
        // NaN latency sample panicked every subsequent percentile query.
        let mut s = Samples::new();
        for i in 0..10 {
            s.add(i as f64);
        }
        s.add(f64::NAN);
        // total_cmp sorts the NaN to the end: low/mid percentiles stay
        // meaningful, the max degrades to NaN instead of panicking
        assert_eq!(s.percentile(0.0), 0.0);
        assert!((s.median() - 5.0).abs() <= 1.0);
        assert!(s.max().is_nan());
        assert_eq!(s.len(), 11);
    }

    #[test]
    fn nan_sample_does_not_panic_p2_estimator() {
        // The P² marker sorts had the same NaN-unsafe comparator, both in
        // the first-five fill and the small-stream exact path.
        let mut q = P2Quantile::new(0.5);
        q.add(1.0);
        q.add(f64::NAN);
        q.add(3.0);
        let _ = q.value(); // small-stream sort path
        for i in 0..20 {
            q.add(i as f64); // five-marker fill sort path + steady state
        }
        assert_eq!(q.count(), 23);
        let _ = q.value(); // must not panic; value may be NaN-tainted
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(90.0), "1.5min");
        assert_eq!(fmt_duration(1.5), "1.50s");
        assert_eq!(fmt_duration(0.0301), "30.10ms");
        assert_eq!(fmt_duration(2e-5), "20.00us");
    }

    #[test]
    fn token_formatting() {
        assert_eq!(fmt_tokens(10_000_000), "10M");
        assert_eq!(fmt_tokens(512_000), "512K");
        assert_eq!(fmt_tokens(37), "37");
    }
}
