//! Streaming statistics: mean/variance (Welford), exact percentiles over
//! retained samples, and fixed-bucket histograms. Used by the metrics layer
//! (TTFT/TBT percentiles) and the bench harness.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact-percentile sample collector. Stores all samples; the workloads in
/// this repo produce at most a few million latency points, which is fine.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile p in [0, 100], linear interpolation between ranks.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let rank = p / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        *self.xs.last().unwrap_or(&f64::NAN)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        *self.xs.first().unwrap_or(&f64::NAN)
    }
}

/// Fixed-bucket histogram over [lo, hi) with `n` buckets plus under/overflow.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Self {
            lo,
            hi,
            buckets: vec![0; n],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let i = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[i.min(n - 1)] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }
}

/// Pretty-print a duration given in seconds with adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if !secs.is_finite() {
        format!("{secs}")
    } else if secs >= 60.0 {
        format!("{:.1}min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.2}us", secs * 1e6)
    } else {
        format!("{:.0}ns", secs * 1e9)
    }
}

/// Pretty-print a token count (1.0M, 256K, ...).
pub fn fmt_tokens(n: u64) -> String {
    if n >= 1_000_000 && n % 100_000 == 0 {
        format!("{}M", n as f64 / 1e6)
    } else if n >= 1_000 && n % 1_000 == 0 {
        format!("{}K", n / 1_000)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::new();
        for i in 0..=100 {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(95.0), 95.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Samples::new();
        s.add(0.0);
        s.add(10.0);
        assert!((s.percentile(50.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(100.0);
        assert_eq!(h.count(), 12);
        assert!(h.bucket_counts().iter().all(|&c| c == 1));
        assert_eq!(h.overflow(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(90.0), "1.5min");
        assert_eq!(fmt_duration(1.5), "1.50s");
        assert_eq!(fmt_duration(0.0301), "30.10ms");
        assert_eq!(fmt_duration(2e-5), "20.00us");
    }

    #[test]
    fn token_formatting() {
        assert_eq!(fmt_tokens(10_000_000), "10M");
        assert_eq!(fmt_tokens(512_000), "512K");
        assert_eq!(fmt_tokens(37), "37");
    }
}
