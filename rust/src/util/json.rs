//! Minimal JSON parser/serializer (the offline registry has no serde).
//!
//! Supports everything this repo needs: the artifact manifest written by
//! `python/compile/aot.py`, config files under `configs/`, and result dumps
//! from the reproduction harness. Full JSON spec minus exotic escapes
//! (\u surrogate pairs are handled; bignum precision beyond f64 is not).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&s).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["weights", "tensors"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers that produce useful error messages.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not an array"))
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            self.pos -= 1; // compensate the += 1 below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hx = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hx, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---- serialization --------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-3}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
        // surrogate pair: U+1F600
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn manifest_like_document() {
        let src = r#"{
            "entries": {"embed_c16": {"file": "embed_c16.hlo.txt",
                        "inputs": [{"shape": [16], "dtype": "i32"}]}},
            "weights": {"file": "weights.bin",
                        "tensors": [{"name": "embed", "shape": [256, 512],
                                     "offset": 0, "size": 524288}]}
        }"#;
        let j = Json::parse(src).unwrap();
        let e = j.at(&["entries", "embed_c16"]).unwrap();
        assert_eq!(e.req_str("file").unwrap(), "embed_c16.hlo.txt");
        let t = &j.at(&["weights", "tensors"]).unwrap().as_arr().unwrap()[0];
        assert_eq!(t.req_u64("size").unwrap(), 524288);
    }
}
