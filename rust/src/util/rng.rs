//! Deterministic PRNG + distribution samplers.
//!
//! The offline registry has no `rand`/`rand_distr`, so this module provides
//! the pieces the workload generators and property tests need: SplitMix64
//! (seeding), xoshiro256++ (bulk generation), and Exponential / Poisson /
//! Zipf / LogUniform samplers. All generators are fully deterministic from
//! their seed, which the reproduction harness relies on.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the main PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for per-worker/per-test rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) (Lemire's method, bias-free for our sizes).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Rejection sampling on the top bits; n << 2^64 so loops are rare.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Inter-arrival times.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Poisson with mean `lambda` (Knuth for small, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction.
            let x = lambda + lambda.sqrt() * self.gaussian();
            x.max(0.0).round() as u64
        }
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf-like rank sampler over [0, n): P(i) proportional to 1/(i+1)^s.
    /// Used for mixed-context-length workloads (few huge, many small).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        // Inverse-CDF on the generalized harmonic numbers; n is small
        // (context-length buckets), so a linear scan is fine.
        let h: f64 = (1..=n).map(|i| 1.0 / (i as f64).powf(s)).sum();
        let mut u = self.f64() * h;
        for i in 1..=n {
            u -= 1.0 / (i as f64).powf(s);
            if u <= 0.0 {
                return i - 1;
            }
        }
        n - 1
    }

    /// Log-uniform integer in [lo, hi]: uniform over orders of magnitude.
    /// Matches "context lengths range from 10s to millions of tokens".
    pub fn log_uniform(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo >= 1 && hi >= lo);
        let x = self.range_f64((lo as f64).ln(), (hi as f64 + 1.0).ln());
        (x.exp() as u64).clamp(lo, hi)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(13);
        for lambda in [3.0, 80.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(19);
        let mut counts = [0u32; 8];
        for _ in 0..10_000 {
            counts[r.zipf(8, 1.2) as usize] += 1;
        }
        assert!(counts[0] > counts[7] * 4, "{counts:?}");
    }

    #[test]
    fn log_uniform_bounds() {
        let mut r = Rng::new(23);
        for _ in 0..5_000 {
            let x = r.log_uniform(16, 1 << 20);
            assert!((16..=(1 << 20)).contains(&x));
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(29);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
