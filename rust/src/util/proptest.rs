//! Seeded randomized property-testing harness (proptest is unavailable
//! offline). Runs a property over many generated cases; on failure, reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```ignore
//! check("kv pages never leak", 500, |rng| {
//!     let n = rng.range_u64(1, 100);
//!     ...assertions...
//! });
//! ```
//!
//! Set `MEDHA_PROPTEST_SEED` to replay a single failing case, and
//! `MEDHA_PROPTEST_CASES` to scale case counts up/down globally.

use super::rng::Rng;

/// Run `prop` over `cases` generated cases. Panics (with the seed) on the
/// first failing case.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    if let Ok(seed_s) = std::env::var("MEDHA_PROPTEST_SEED") {
        let seed: u64 = seed_s.parse().expect("MEDHA_PROPTEST_SEED must be u64");
        eprintln!("[proptest] replaying {name} with seed {seed}");
        let mut rng = Rng::new(seed);
        prop(&mut rng);
        return;
    }
    let scale: f64 = std::env::var("MEDHA_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cases = ((cases as f64 * scale) as u64).max(1);
    let base = fnv1a(name.as_bytes());
    for i in 0..cases {
        let seed = base ^ (i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(payload) = result {
            eprintln!(
                "[proptest] property '{name}' FAILED on case {i}/{cases}.\n\
                 [proptest] replay with: MEDHA_PROPTEST_SEED={seed}"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always true", 50, |_| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_panics_with_seed() {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check("fails eventually", 100, |rng| {
                assert!(rng.f64() < 0.9, "drew a big number");
            });
        }));
        assert!(r.is_err());
    }

    #[test]
    fn deterministic_given_name() {
        let mut a = Vec::new();
        check("det", 5, |rng| a.push(rng.next_u64()));
        let mut b = Vec::new();
        check("det", 5, |rng| b.push(rng.next_u64()));
        assert_eq!(a, b);
    }
}
