//! Fixed-size worker thread pool over std channels (tokio is unavailable
//! offline). This is the substrate for **both layers of simulator
//! parallelism**: the parallel phase-A of `Simulation::step` runs per-group
//! batch formation as *borrowed* jobs through [`ThreadPool::scoped`], and
//! the sweep driver (`sim::sweep`) runs whole independent simulations as
//! `'static` jobs through [`ThreadPool::map`] / [`ThreadPool::map_chunks`].
//!
//! Design: workers share one injector queue (`Mutex<VecDeque>` + condvar);
//! jobs are boxed `FnOnce`. Three submission shapes:
//!
//! * [`submit`](ThreadPool::submit) — one `'static` job, joined through a
//!   [`JobHandle`] whose [`try_join`](JobHandle::try_join) distinguishes a
//!   job that **panicked** from one that was **dropped un-run** (a worker
//!   died before reaching it and the pool shut down — the shutdown race);
//! * [`map`](ThreadPool::map) / [`map_chunks`](ThreadPool::map_chunks) —
//!   order-preserving parallel map; the chunked variant pays one job +
//!   channel per *chunk* instead of per element, for hot paths where the
//!   per-item work is small;
//! * [`scoped`](ThreadPool::scoped) — jobs that borrow from the caller's
//!   stack (`'scope` instead of `'static`). The scope blocks until every
//!   spawned job has finished before returning (and on unwind, via `Drop`),
//!   which is what makes the lifetime erasure sound; a panicking scoped job
//!   is caught on the worker (the worker survives, the barrier always
//!   resolves) and re-raised on the scope owner.
//!
//! Everything is deterministic from the caller's perspective as long as the
//! jobs themselves are: results land where the caller put their slots, in
//! submission order, regardless of which worker ran what when.

// One of the two modules declared unsafe-capable by the determinism
// contract (`medha lint`, rule U1): the scoped-job lifetime erasure below
// needs `transmute`, and every unsafe block here carries a SAFETY note.
// The crate root denies unsafe_code everywhere else.
#![allow(unsafe_code)]

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Queue>,
    cv: Condvar,
}

struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Why a [`JobHandle`] could not produce a value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinError {
    /// The job ran and panicked on its worker.
    Panicked,
    /// The job was dropped without ever running: its worker died (an
    /// earlier job panicked) and the pool shut down with the job still
    /// queued. Distinct from [`JoinError::Panicked`] — the job's own code
    /// was never at fault.
    Dropped,
}

impl std::fmt::Display for JoinError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JoinError::Panicked => write!(f, "worker job panicked"),
            JoinError::Dropped => write!(f, "worker job dropped un-run at pool shutdown"),
        }
    }
}

/// A handle resolving to the job's return value.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<Result<T, JoinError>>,
}

impl<T> JobHandle<T> {
    /// Block until the job resolves: its value, or why there isn't one
    /// ([`JoinError::Panicked`] vs [`JoinError::Dropped`]).
    pub fn try_join(self) -> Result<T, JoinError> {
        match self.rx.recv() {
            Ok(out) => out,
            // The sender vanished without a verdict (only possible if the
            // outcome send itself failed); the job cannot have completed.
            Err(mpsc::RecvError) => Err(JoinError::Dropped),
        }
    }

    /// Block until the job finishes, panicking with the specific failure
    /// (`{}` of [`JoinError`]) when it didn't.
    pub fn join(self) -> T {
        match self.try_join() {
            Ok(v) => v,
            Err(e) => panic!("{e}"),
        }
    }
}

/// Sends the job's outcome exactly once: `Ok` on completion, or — from
/// `Drop` — `Panicked` while unwinding and `Dropped` when the un-run job
/// box is discarded at shutdown.
struct Outcome<T> {
    tx: Option<mpsc::Sender<Result<T, JoinError>>>,
}

impl<T> Outcome<T> {
    fn complete(mut self, value: T) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Ok(value)); // receiver may have been dropped; fine
        }
    }
}

impl<T> Drop for Outcome<T> {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let e = if thread::panicking() {
                JoinError::Panicked
            } else {
                JoinError::Dropped
            };
            let _ = tx.send(Err(e));
        }
    }
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("medha-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    fn push_job(&self, job: Job) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            assert!(!q.shutdown, "submit after shutdown");
            q.jobs.push_back(job);
        }
        self.shared.cv.notify_one();
    }

    /// Submit a job; returns a handle to its result.
    pub fn submit<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let job: Job = Box::new(move || {
            let outcome = Outcome { tx: Some(tx) };
            let value = f();
            outcome.complete(value);
        });
        self.push_job(job);
        JobHandle { rx }
    }

    /// Map `f` over `items` in parallel, preserving order. One job +
    /// result channel per item: right when each item is substantial work
    /// (a whole simulation); for many small items use
    /// [`map_chunks`](Self::map_chunks).
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + Clone + 'static,
    {
        self.map_chunks(items, 1, f)
    }

    /// Order-preserving parallel map in contiguous chunks of up to
    /// `chunk` items: one boxed job + channel pair per chunk rather than
    /// per element, so a million tiny items cost thousands of
    /// allocations, not millions. `chunk = 1` degenerates to [`map`]
    /// exactly; larger chunks trade scheduling granularity for overhead.
    ///
    /// [`map`]: Self::map
    pub fn map_chunks<T, U, F>(&self, items: Vec<T>, chunk: usize, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + Clone + 'static,
    {
        assert!(chunk > 0, "chunk size must be positive");
        let n = items.len();
        let mut handles = Vec::with_capacity(n.div_ceil(chunk.max(1)));
        let mut it = items.into_iter();
        loop {
            let batch: Vec<T> = it.by_ref().take(chunk).collect();
            if batch.is_empty() {
                break;
            }
            let f = f.clone();
            handles.push(self.submit(move || batch.into_iter().map(&f).collect::<Vec<U>>()));
        }
        let mut out = Vec::with_capacity(n);
        for h in handles {
            out.extend(h.join());
        }
        out
    }

    /// Run jobs that **borrow** from the caller's stack: `body` spawns
    /// work through [`Scope::spawn`]; `scoped` returns only after every
    /// spawned job has finished (a completion barrier on the persistent
    /// pool — no per-call thread spawning), so jobs may safely capture
    /// `&`/`&mut` references with lifetime `'scope`. If any job panicked,
    /// the panic is re-raised here after the barrier resolves.
    ///
    /// This is what the parallel `Simulation::step` runs per-group phase-A
    /// work on: each job takes disjoint `&mut` borrows of per-group state
    /// plus shared `&` reads, and the barrier restores exclusive access
    /// before the serial merge.
    // `'scope` is early-bound (the rayon `scope` shape, not std's
    // higher-ranked one): the caller's borrowed data picks it at the call
    // site, so spawned jobs may capture non-'static references.
    pub fn scoped<'pool, 'scope, R, F>(&'pool self, body: F) -> R
    where
        F: FnOnce(&Scope<'scope, 'pool>) -> R,
    {
        let scope = Scope {
            pool: self,
            sync: Arc::new(ScopeSync {
                pending: Mutex::new(0),
                done: Condvar::new(),
                panicked: AtomicBool::new(false),
            }),
            _invariant: PhantomData,
        };
        let out = body(&scope);
        scope.wait_all();
        if scope.sync.panicked.load(Ordering::SeqCst) {
            panic!("scoped worker job panicked");
        }
        out
    }
}

struct ScopeSync {
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

/// Spawn surface of [`ThreadPool::scoped`]. Invariant over `'scope` so a
/// longer-lived scope cannot be smuggled through a subtyping coercion.
pub struct Scope<'scope, 'pool> {
    pool: &'pool ThreadPool,
    sync: Arc<ScopeSync>,
    _invariant: PhantomData<&'scope mut &'scope ()>,
}

impl<'scope> Scope<'scope, '_> {
    /// Spawn a job that may borrow data outliving the scope. The job's
    /// panic (if any) is caught on the worker — the worker survives and
    /// the scope's barrier always resolves — and re-raised by
    /// [`ThreadPool::scoped`] once every sibling has finished.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        *self.sync.pending.lock().unwrap() += 1;
        let sync = Arc::clone(&self.sync);
        let wrapped = move || {
            if catch_unwind(AssertUnwindSafe(f)).is_err() {
                sync.panicked.store(true, Ordering::SeqCst);
            }
            let mut n = sync.pending.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                sync.done.notify_all();
            }
        };
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(wrapped);
        // SAFETY: the queue requires 'static jobs, but `wait_all` — called
        // by `ThreadPool::scoped` before returning AND by `Scope::drop`
        // (covering unwinds out of `body`) — blocks until this job has
        // run to completion, so its `'scope` borrows are live for the
        // job's whole execution. The lifetime is erased, never exceeded.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job)
        };
        self.pool.push_job(job);
    }

    fn wait_all(&self) {
        let mut n = self.sync.pending.lock().unwrap();
        while *n > 0 {
            n = self.sync.done.wait(n).unwrap();
        }
    }
}

impl Drop for Scope<'_, '_> {
    fn drop(&mut self) {
        // The soundness backstop: even if `body` unwinds before the
        // explicit barrier, no borrowed job survives the scope.
        self.wait_all();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        job();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_and_returns_values() {
        let pool = ThreadPool::new(4);
        let h = pool.submit(|| 2 + 2);
        assert_eq!(h.join(), 4);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..64).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn map_chunks_matches_map_for_every_chunking() {
        let pool = ThreadPool::new(3);
        let expect: Vec<u64> = (0..100).map(|x| x * 3 + 1).collect();
        for chunk in [1usize, 2, 7, 33, 100, 1000] {
            let out = pool.map_chunks((0..100).collect::<Vec<u64>>(), chunk, |x| x * 3 + 1);
            assert_eq!(out, expect, "chunk={chunk}");
        }
        // empty input: no jobs, empty output
        let out: Vec<u64> = pool.map_chunks(Vec::<u64>::new(), 8, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn all_jobs_complete_on_drop() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            let handles: Vec<_> = (0..100)
                .map(|_| {
                    let c = Arc::clone(&counter);
                    pool.submit(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    })
                })
                .collect();
            for h in handles {
                h.join();
            }
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallelism_actually_happens() {
        use std::time::{Duration, Instant};
        let pool = ThreadPool::new(4);
        let t0 = Instant::now();
        let hs: Vec<_> = (0..4)
            .map(|_| pool.submit(|| thread::sleep(Duration::from_millis(50))))
            .collect();
        for h in hs {
            h.join();
        }
        // 4 sleeps of 50ms on 4 threads should take ~50ms, not 200ms.
        assert!(t0.elapsed() < Duration::from_millis(150));
    }

    #[test]
    fn panicked_job_reports_panicked() {
        let pool = ThreadPool::new(1);
        let h = pool.submit(|| -> u32 { panic!("boom") });
        assert_eq!(h.try_join(), Err(JoinError::Panicked));
    }

    /// The shutdown race the seed mis-reported: a job queued behind a
    /// panicking one on a single-worker pool is dropped un-run when the
    /// dead worker's pool shuts down — it must join as `Dropped`, not be
    /// blamed with "worker job panicked".
    #[test]
    fn shutdown_race_reports_dropped_not_panicked() {
        let pool = ThreadPool::new(1);
        let ran = Arc::new(AtomicUsize::new(0));
        let h_panic = pool.submit(|| -> u32 { panic!("boom") });
        let ran2 = Arc::clone(&ran);
        let h_dropped = pool.submit(move || {
            ran2.fetch_add(1, Ordering::SeqCst);
            7u32
        });
        // The panic verdict arrives while the worker unwinds.
        assert_eq!(h_panic.try_join(), Err(JoinError::Panicked));
        // Shutting the pool down joins the dead worker and drops the
        // still-queued job, which resolves its handle as Dropped.
        drop(pool);
        assert_eq!(h_dropped.try_join(), Err(JoinError::Dropped));
        assert_eq!(ran.load(Ordering::SeqCst), 0, "dropped job must never have run");
    }

    #[test]
    fn join_error_messages_are_distinct() {
        assert_eq!(JoinError::Panicked.to_string(), "worker job panicked");
        assert_ne!(JoinError::Panicked.to_string(), JoinError::Dropped.to_string());
    }

    #[test]
    fn scoped_jobs_borrow_and_barrier() {
        let pool = ThreadPool::new(4);
        let mut slots = vec![0u64; 64];
        let base = 10u64; // borrowed immutably by every job
        pool.scoped(|scope| {
            for (i, slot) in slots.iter_mut().enumerate() {
                let base = &base;
                scope.spawn(move || {
                    *slot = *base + i as u64;
                });
            }
        });
        // the barrier has resolved: every borrowed write landed
        for (i, &v) in slots.iter().enumerate() {
            assert_eq!(v, 10 + i as u64);
        }
    }

    #[test]
    fn scoped_with_no_spawns_is_a_noop() {
        let pool = ThreadPool::new(2);
        let out = pool.scoped(|_scope| 42);
        assert_eq!(out, 42);
    }

    #[test]
    fn scoped_reraises_job_panic_after_barrier() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scoped(|scope| {
                scope.spawn(|| panic!("scoped boom"));
                let d = &d;
                scope.spawn(move || {
                    d.fetch_add(1, Ordering::SeqCst);
                });
            });
        }));
        assert!(result.is_err(), "scope must re-raise the job panic");
        // the sibling still ran to completion (the worker survived)
        assert_eq!(done.load(Ordering::SeqCst), 1);
        // ...and the pool is still usable afterwards
        assert_eq!(pool.submit(|| 5).join(), 5);
    }

    #[test]
    fn scoped_more_jobs_than_workers() {
        let pool = ThreadPool::new(2);
        let mut out = vec![0u32; 200];
        pool.scoped(|scope| {
            for (i, slot) in out.iter_mut().enumerate() {
                scope.spawn(move || *slot = i as u32 + 1);
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u32 + 1));
    }
}
