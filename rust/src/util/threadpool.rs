//! Fixed-size worker thread pool over std channels (tokio is unavailable
//! offline; the engine's stage workers and KVP shard workers run on this).
//!
//! Design: each worker owns a receiver on a shared injector queue
//! (Mutex<VecDeque>) with a condvar; jobs are boxed `FnOnce`. `scope`-like
//! joining is provided by `JobHandle` futures backed by channels.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Queue>,
    cv: Condvar,
}

struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// A handle resolving to the job's return value.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block until the job finishes. Panics if the job panicked.
    pub fn join(self) -> T {
        self.rx.recv().expect("worker job panicked")
    }
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("medha-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; returns a handle to its result.
    pub fn submit<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let job: Job = Box::new(move || {
            let out = f();
            let _ = tx.send(out); // receiver may have been dropped; fine
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            assert!(!q.shutdown, "submit after shutdown");
            q.jobs.push_back(job);
        }
        self.shared.cv.notify_one();
        JobHandle { rx }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + Clone + 'static,
    {
        let handles: Vec<JobHandle<U>> = items
            .into_iter()
            .map(|it| {
                let f = f.clone();
                self.submit(move || f(it))
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        job();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_and_returns_values() {
        let pool = ThreadPool::new(4);
        let h = pool.submit(|| 2 + 2);
        assert_eq!(h.join(), 4);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..64).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn all_jobs_complete_on_drop() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            let handles: Vec<_> = (0..100)
                .map(|_| {
                    let c = Arc::clone(&counter);
                    pool.submit(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    })
                })
                .collect();
            for h in handles {
                h.join();
            }
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallelism_actually_happens() {
        use std::time::{Duration, Instant};
        let pool = ThreadPool::new(4);
        let t0 = Instant::now();
        let hs: Vec<_> = (0..4)
            .map(|_| pool.submit(|| thread::sleep(Duration::from_millis(50))))
            .collect();
        for h in hs {
            h.join();
        }
        // 4 sleeps of 50ms on 4 threads should take ~50ms, not 200ms.
        assert!(t0.elapsed() < Duration::from_millis(150));
    }
}
