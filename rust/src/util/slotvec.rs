//! Dense slot-indexed map: a `Vec<Option<T>>` keyed by a small integer id.
//!
//! The simulator's per-request bookkeeping (router placement, KVP shard
//! maps, KV block tables) is keyed by dense slot ids handed out by the
//! request arena, so a flat vector beats a `BTreeMap`: O(1) access with no
//! pointer chasing, and iteration is a linear scan.

/// A map from small integer keys to `T`, backed by a flat vector.
#[derive(Debug, Clone)]
pub struct SlotVec<T> {
    slots: Vec<Option<T>>,
    live: usize,
}

impl<T> Default for SlotVec<T> {
    fn default() -> Self {
        SlotVec {
            slots: Vec::new(),
            live: 0,
        }
    }
}

impl<T> SlotVec<T> {
    pub fn new() -> SlotVec<T> {
        SlotVec::default()
    }

    pub fn with_capacity(n: usize) -> SlotVec<T> {
        SlotVec {
            slots: Vec::with_capacity(n),
            live: 0,
        }
    }

    fn grow_to(&mut self, idx: usize) {
        if idx >= self.slots.len() {
            self.slots.resize_with(idx + 1, || None);
        }
    }

    /// Insert `v` at `idx`, returning the previous occupant if any.
    pub fn insert(&mut self, idx: usize, v: T) -> Option<T> {
        self.grow_to(idx);
        let old = self.slots[idx].replace(v);
        if old.is_none() {
            self.live += 1;
        }
        old
    }

    pub fn get(&self, idx: usize) -> Option<&T> {
        self.slots.get(idx).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, idx: usize) -> Option<&mut T> {
        self.slots.get_mut(idx).and_then(|s| s.as_mut())
    }

    /// Get the value at `idx`, inserting `T::default()` first if vacant.
    pub fn get_or_insert_default(&mut self, idx: usize) -> &mut T
    where
        T: Default,
    {
        self.grow_to(idx);
        if self.slots[idx].is_none() {
            self.slots[idx] = Some(T::default());
            self.live += 1;
        }
        self.slots[idx].as_mut().unwrap()
    }

    pub fn remove(&mut self, idx: usize) -> Option<T> {
        let v = self.slots.get_mut(idx).and_then(|s| s.take());
        if v.is_some() {
            self.live -= 1;
        }
        v
    }

    pub fn contains(&self, idx: usize) -> bool {
        self.get(idx).is_some()
    }

    /// Number of occupied slots.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Iterate occupied slots in key order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &T)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|v| (i, v)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove() {
        let mut m: SlotVec<u64> = SlotVec::new();
        assert!(m.is_empty());
        assert_eq!(m.insert(3, 30), None);
        assert_eq!(m.insert(0, 1), None);
        assert_eq!(m.len(), 2);
        assert_eq!(m.get(3), Some(&30));
        assert_eq!(m.get(1), None);
        assert_eq!(m.insert(3, 31), Some(30));
        assert_eq!(m.len(), 2);
        assert_eq!(m.remove(3), Some(31));
        assert_eq!(m.remove(3), None);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn iter_in_key_order() {
        let mut m: SlotVec<&str> = SlotVec::new();
        m.insert(5, "e");
        m.insert(1, "a");
        m.insert(3, "c");
        m.remove(3);
        let got: Vec<(usize, &&str)> = m.iter().collect();
        assert_eq!(got, vec![(1, &"a"), (5, &"e")]);
    }

    #[test]
    fn get_or_insert_default_counts_once() {
        let mut m: SlotVec<u64> = SlotVec::new();
        *m.get_or_insert_default(7) += 1;
        *m.get_or_insert_default(7) += 1;
        assert_eq!(m.len(), 1);
        assert_eq!(m.get(7), Some(&2));
    }
}
