//! Measurement harness for `cargo bench` (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean/p50/p95 reporting, a
//! registry so bench binaries can expose `--filter` selection like
//! criterion, and machine-readable JSON output (`BENCH_sim.json`).
//!
//! Smoke mode (`MEDHA_BENCH_SMOKE=1`) caps the per-bench budget and
//! iteration count so an integration test can exercise every bench in
//! milliseconds — keeping the bench binaries compiling and their JSON
//! output valid under plain `cargo test`.
//!
//! Wall-clock note: this module is on the determinism-contract allowlist
//! for rule D2 (`medha lint`) — it *measures* real time around runs; no
//! reading ever feeds back into simulated state.

use std::time::Instant;

use super::json::Json;
use super::stats::{fmt_duration, Samples};

/// Env var that switches the harness into smoke mode.
pub const SMOKE_ENV: &str = "MEDHA_BENCH_SMOKE";

/// Hard cap on timed iterations per bench (overrides calibration); set via
/// `MEDHA_BENCH_MAX_ITERS`, implied small in smoke mode.
pub const MAX_ITERS_ENV: &str = "MEDHA_BENCH_MAX_ITERS";

fn smoke_enabled() -> bool {
    std::env::var(SMOKE_ENV).map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

fn env_max_iters() -> Option<u64> {
    std::env::var(MAX_ITERS_ENV).ok().and_then(|v| v.parse().ok())
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<52} {:>10} {:>10} {:>10} {:>8} iters",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.p95_s),
            self.iters
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", self.iters.into()),
            ("mean_s", self.mean_s.into()),
            ("p50_s", self.p50_s.into()),
            ("p95_s", self.p95_s.into()),
            ("min_s", self.min_s.into()),
        ])
    }
}

/// Time `f` with warmup; each sample is one call. Target ~`budget_s`
/// seconds, hard-capped at `max_iters` timed calls when given.
pub fn bench_with_limit<F: FnMut()>(
    name: &str,
    budget_s: f64,
    max_iters: Option<u64>,
    mut f: F,
) -> BenchResult {
    // Warmup + calibration: run until 10% of budget or 3 iterations —
    // shrunk to the iteration cap when one is set, so a hard cap of 1
    // really means ~2 total calls.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    let warm_cap = max_iters.map(|m| m.clamp(1, 3)).unwrap_or(1000);
    while warm_start.elapsed().as_secs_f64() < budget_s * 0.1 || warm_iters < warm_cap.min(3) {
        f();
        warm_iters += 1;
        if warm_iters >= warm_cap {
            break;
        }
    }
    let per_call = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
    let mut target_iters = ((budget_s * 0.9) / per_call.max(1e-9)).clamp(5.0, 100_000.0) as u64;
    if let Some(m) = max_iters {
        target_iters = target_iters.min(m.max(1));
    }

    let mut samples = Samples::new();
    for _ in 0..target_iters {
        let t0 = Instant::now();
        f();
        samples.add(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean_s: samples.mean(),
        p50_s: samples.median(),
        p95_s: samples.p95(),
        min_s: samples.min(),
    }
}

/// Time `f` with warmup; each sample is one call. Target ~`budget_s` seconds.
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, f: F) -> BenchResult {
    bench_with_limit(name, budget_s, env_max_iters(), f)
}

/// A named group of benches, with criterion-style filtering.
pub struct BenchSuite {
    filter: Option<String>,
    pub results: Vec<BenchResult>,
    budget_s: f64,
    smoke: bool,
    max_iters: Option<u64>,
}

impl BenchSuite {
    /// Reads `--filter <substr>` / positional filter and `--budget <secs>`
    /// from argv (cargo bench passes `--bench`; it is ignored), plus the
    /// `MEDHA_BENCH_SMOKE` / `MEDHA_BENCH_MAX_ITERS` env caps.
    pub fn from_env() -> BenchSuite {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut filter = None;
        let mut budget_s = 1.0;
        let mut i = 0;
        while i < argv.len() {
            match argv[i].as_str() {
                "--filter" if i + 1 < argv.len() => {
                    filter = Some(argv[i + 1].clone());
                    i += 1;
                }
                "--budget" if i + 1 < argv.len() => {
                    budget_s = argv[i + 1].parse().unwrap_or(1.0);
                    i += 1;
                }
                "--bench" => {}
                s if !s.starts_with('-') => filter = Some(s.to_string()),
                _ => {}
            }
            i += 1;
        }
        BenchSuite::with_budget(budget_s, filter)
    }

    /// Direct constructor (tests / embedding); still honors the env caps.
    pub fn with_budget(budget_s: f64, filter: Option<String>) -> BenchSuite {
        let smoke = smoke_enabled();
        let mut max_iters = env_max_iters();
        let mut budget_s = budget_s;
        if smoke {
            budget_s = budget_s.min(0.02);
            max_iters = Some(max_iters.unwrap_or(2).min(2));
        }
        BenchSuite {
            filter,
            results: Vec::new(),
            budget_s,
            smoke,
            max_iters,
        }
    }

    /// True when `MEDHA_BENCH_SMOKE` is set: benches should shrink their
    /// workloads (fewer requests, shorter traces) as well.
    pub fn is_smoke(&self) -> bool {
        self.smoke
    }

    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            None => true,
            Some(f) => name.contains(f.as_str()),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        let r = bench_with_limit(name, self.budget_s, self.max_iters, f);
        println!("{}", r.report_line());
        self.results.push(r);
    }

    /// Time exactly one call of `f` — for multi-second end-to-end runs
    /// (e.g. a million-request simulation) where repetition is wasteful.
    pub fn bench_once<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        let r = BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_s: dt,
            p50_s: dt,
            p95_s: dt,
            min_s: dt,
        };
        println!("{}", r.report_line());
        self.results.push(r);
    }

    /// Run a harness section that prints its own table (figure reproduction);
    /// still honors the filter.
    pub fn section<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        println!("\n=== {name} ===");
        f();
    }

    pub fn header(&self) {
        println!(
            "{:<52} {:>10} {:>10} {:>10} {:>8}",
            "benchmark", "mean", "p50", "p95", "samples"
        );
        println!("{}", "-".repeat(98));
    }

    /// All results as a JSON document, with `extra` top-level fields
    /// appended (e.g. simulator throughput reports).
    pub fn to_json(&self, extra: Vec<(&str, Json)>) -> Json {
        let mut fields = vec![
            ("smoke", Json::from(self.smoke)),
            (
                "results",
                Json::arr(self.results.iter().map(|r| r.to_json())),
            ),
        ];
        fields.extend(extra);
        Json::obj(fields)
    }

    /// Write the JSON document to `path`.
    pub fn write_json(
        &self,
        path: &std::path::Path,
        extra: Vec<(&str, Json)>,
    ) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json(extra)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_with_limit("noop-ish", 0.05, None, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s >= 0.0 && r.mean_s < 0.05);
        assert!(r.p95_s >= r.p50_s * 0.5);
    }

    #[test]
    fn report_line_contains_name() {
        let r = bench_with_limit("xyz", 0.02, None, || {});
        assert!(r.report_line().contains("xyz"));
    }

    #[test]
    fn iteration_cap_is_respected() {
        let mut calls = 0u64;
        let r = bench_with_limit("capped", 0.05, Some(4), || {
            calls += 1;
        });
        assert_eq!(r.iters, 4);
        // warmup (<= 3) + timed (4)
        assert!(calls <= 7, "calls={calls}");
    }

    #[test]
    fn suite_json_round_trips() {
        let mut suite = BenchSuite::with_budget(0.01, None);
        suite.bench("a/b", || {
            std::hint::black_box(1 + 1);
        });
        let j = suite.to_json(vec![("extra", Json::from(7u64))]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("extra").and_then(|x| x.as_u64()), Some(7));
        let rs = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].get("name").and_then(|x| x.as_str()), Some("a/b"));
    }
}
