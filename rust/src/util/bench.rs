//! Measurement harness for `cargo bench` (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean/p50/p95 reporting, and a
//! registry so bench binaries can expose `--filter` selection like criterion.

use std::time::Instant;

use super::stats::{fmt_duration, Samples};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<52} {:>10} {:>10} {:>10} {:>8} iters",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.p95_s),
            self.iters
        )
    }
}

/// Time `f` with warmup; each sample is one call. Target ~`budget_s` seconds.
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    // Warmup + calibration: run until 10% of budget or 3 iterations.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed().as_secs_f64() < budget_s * 0.1 || warm_iters < 3 {
        f();
        warm_iters += 1;
        if warm_iters >= 1000 {
            break;
        }
    }
    let per_call = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
    let target_iters = ((budget_s * 0.9) / per_call.max(1e-9)).clamp(5.0, 100_000.0) as u64;

    let mut samples = Samples::new();
    for _ in 0..target_iters {
        let t0 = Instant::now();
        f();
        samples.add(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean_s: samples.mean(),
        p50_s: samples.median(),
        p95_s: samples.p95(),
        min_s: samples.min(),
    }
}

/// A named group of benches, with criterion-style filtering.
pub struct BenchSuite {
    filter: Option<String>,
    pub results: Vec<BenchResult>,
    budget_s: f64,
}

impl BenchSuite {
    /// Reads `--filter <substr>` / positional filter and `--budget <secs>`
    /// from argv (cargo bench passes `--bench`; it is ignored).
    pub fn from_env() -> BenchSuite {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut filter = None;
        let mut budget_s = 1.0;
        let mut i = 0;
        while i < argv.len() {
            match argv[i].as_str() {
                "--filter" if i + 1 < argv.len() => {
                    filter = Some(argv[i + 1].clone());
                    i += 1;
                }
                "--budget" if i + 1 < argv.len() => {
                    budget_s = argv[i + 1].parse().unwrap_or(1.0);
                    i += 1;
                }
                "--bench" => {}
                s if !s.starts_with('-') => filter = Some(s.to_string()),
                _ => {}
            }
            i += 1;
        }
        BenchSuite {
            filter,
            results: Vec::new(),
            budget_s,
        }
    }

    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            None => true,
            Some(f) => name.contains(f.as_str()),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        let r = bench(name, self.budget_s, f);
        println!("{}", r.report_line());
        self.results.push(r);
    }

    /// Run a harness section that prints its own table (figure reproduction);
    /// still honors the filter.
    pub fn section<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        println!("\n=== {name} ===");
        f();
    }

    pub fn header(&self) {
        println!(
            "{:<52} {:>10} {:>10} {:>10} {:>8}",
            "benchmark", "mean", "p50", "p95", "samples"
        );
        println!("{}", "-".repeat(98));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 0.05, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.mean_s >= 0.0 && r.mean_s < 0.05);
        assert!(r.p95_s >= r.p50_s * 0.5);
    }

    #[test]
    fn report_line_contains_name() {
        let r = bench("xyz", 0.02, || {});
        assert!(r.report_line().contains("xyz"));
    }
}
