//! `medha lint` — the repo-native determinism-contract checker.
//!
//! Every guarantee this reproduction makes — golden-snapshot bit-identity,
//! thread-matrix parity, worker-count-invariant sweeps, Lewis–Shedler exact
//! replay — rests on a determinism contract that the compiler does not
//! enforce: no iteration-order-nondeterministic containers in simulator
//! state, no wall-clock reads feeding simulated time, no NaN-unsafe float
//! ordering, no silently truncating percentile indexes, and no `unsafe`
//! outside the two modules that declare (and justify) it. This module
//! enforces that contract statically with a dependency-free line/token
//! scanner: comment- and string-literal-aware stripping, per-rule scopes
//! and allowlists, machine-readable findings.
//!
//! # Rules
//!
//! * **D1 `hash-collections`** — no `HashMap`/`HashSet` in simulator,
//!   coordinator, kvcache, workload, config, or metrics state: their
//!   iteration order varies across runs (`RandomState`), which breaks
//!   bit-exact replay the moment anyone iterates. Use `BTreeMap`, `Vec`,
//!   or the arena/`SlotVec` substrates.
//! * **D2 `wall-clock`** — no `Instant`/`SystemTime`/`std::time` outside
//!   the timing-only modules (bench harness, sweep/throughput wall-clock
//!   reporting, the real-model pipeline, the thread pool): wall time must
//!   measure the simulator, never feed it.
//! * **D3 `float-ord`** — no `partial_cmp` on floats: a single NaN makes
//!   `partial_cmp(..).unwrap()` panic mid-sort and `sort_by` with a
//!   partial comparator is order-nondeterministic. Use `total_cmp` (the
//!   rule that would have caught the PR 4 stats bug and the
//!   `config/faults.rs` comparator this lint landed alongside fixing).
//! * **D4 `trunc-index`** — no truncating float→`usize` casts and no
//!   integer `* N / 100` rank arithmetic in percentile/metrics paths (the
//!   PR 8 p95 bug class: `len * 95 / 100` under-reads small samples).
//!   Make the rounding mode explicit (`.floor()`/`.ceil()`/`.round()`) or
//!   use the shared `percentile_nearest_rank` helpers.
//! * **U1 `unsafe-hygiene`** — `unsafe` (and `allow(unsafe_code)`) may
//!   appear only in the declared modules (`util/threadpool.rs`,
//!   `runtime/mod.rs`), and every `unsafe` there must be immediately
//!   preceded by a `// SAFETY:` comment stating the invariant. Everywhere
//!   else the crate root's `#![deny(unsafe_code)]` holds.
//!
//! The scanner is lexical by design: it sees one line at a time after
//! comments and string/char literals are blanked, so it cannot be fooled
//! by banned tokens inside strings or docs, but it also cannot do type
//! inference — the rules are calibrated (scopes + allowlists) so the
//! committed tree is clean and each rule still fires on its bug class.
//! `rust/tests/lint.rs` runs [`check_tree`] over `rust/src` on every
//! `cargo test`, and the `medha lint` subcommand exposes the same pass
//! (exit status 1 on findings, `--json` for machine-readable output).
//!
//! Extending the contract: add the module to the matching [`RuleScope`]
//! allowlist in [`LintConfig::repo_default`] *with a comment saying why
//! the exemption is sound*, or add a new rule + fixture pair. Never
//! silence a finding by weakening the stripper.

use std::fmt;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// The determinism-contract rules, in documentation order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// D1: iteration-order-nondeterministic hash containers in state.
    HashCollections,
    /// D2: wall-clock reads outside the timing-only modules.
    WallClock,
    /// D3: NaN-unsafe `partial_cmp` float ordering.
    FloatOrd,
    /// D4: truncating index arithmetic in percentile/metrics paths.
    TruncIndex,
    /// U1: `unsafe` outside declared modules or without a SAFETY comment.
    UnsafeHygiene,
}

impl Rule {
    pub const ALL: [Rule; 5] = [
        Rule::HashCollections,
        Rule::WallClock,
        Rule::FloatOrd,
        Rule::TruncIndex,
        Rule::UnsafeHygiene,
    ];

    /// Short stable identifier used in findings and CI logs.
    pub fn id(self) -> &'static str {
        match self {
            Rule::HashCollections => "D1",
            Rule::WallClock => "D2",
            Rule::FloatOrd => "D3",
            Rule::TruncIndex => "D4",
            Rule::UnsafeHygiene => "U1",
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Rule::HashCollections => "hash-collections",
            Rule::WallClock => "wall-clock",
            Rule::FloatOrd => "float-ord",
            Rule::TruncIndex => "trunc-index",
            Rule::UnsafeHygiene => "unsafe-hygiene",
        }
    }
}

const MSG_HASH: &str =
    "nondeterministic hash container in replayable state; use BTreeMap/Vec/SlotVec";
const MSG_CLOCK: &str =
    "wall-clock read outside the timing-only modules; real time must never reach sim state";
const MSG_FLOAT_ORD: &str =
    "NaN-unsafe float ordering panics or scrambles the sort on non-finite values; use total_cmp";
const MSG_UNSAFE_MODULE: &str =
    "`unsafe` outside the declared modules; the crate root denies unsafe_code everywhere else";
const MSG_UNSAFE_SAFETY: &str =
    "`unsafe` without an immediately preceding `// SAFETY:` comment stating the invariant";

/// One contract violation: where, which rule, and what to do instead.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Path relative to the scanned root, forward slashes.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
    /// The offending source line, trimmed.
    pub snippet: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{} {}] {}\n    | {}",
            self.path,
            self.line,
            self.rule.id(),
            self.rule.name(),
            self.message,
            self.snippet
        )
    }
}

impl Finding {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::str(&self.path)),
            ("line", Json::num(self.line as f64)),
            ("rule", Json::str(self.rule.id())),
            ("name", Json::str(self.rule.name())),
            ("message", Json::str(&self.message)),
            ("snippet", Json::str(&self.snippet)),
        ])
    }
}

/// Where a rule applies. Paths are root-relative with forward slashes and
/// match by prefix, so `"sim/"` covers the whole directory and
/// `"util/stats.rs"` one file.
#[derive(Debug, Clone, Default)]
pub struct RuleScope {
    /// Prefixes the rule applies to; empty means the whole tree.
    pub include: Vec<String>,
    /// Prefixes exempt from the rule (the per-module allowlist).
    pub allow: Vec<String>,
}

impl RuleScope {
    fn tree_wide(allow: &[&str]) -> RuleScope {
        RuleScope {
            include: Vec::new(),
            allow: allow.iter().map(|s| s.to_string()).collect(),
        }
    }

    fn only(include: &[&str]) -> RuleScope {
        RuleScope {
            include: include.iter().map(|s| s.to_string()).collect(),
            allow: Vec::new(),
        }
    }

    pub fn applies(&self, path: &str) -> bool {
        let included =
            self.include.is_empty() || self.include.iter().any(|p| path.starts_with(p.as_str()));
        included && !self.allow.iter().any(|p| path.starts_with(p.as_str()))
    }
}

/// Per-rule scopes and allowlists. [`LintConfig::repo_default`] encodes
/// this repository's determinism contract; tests construct narrower
/// configs to exercise individual rules.
#[derive(Debug, Clone)]
pub struct LintConfig {
    pub hash_collections: RuleScope,
    pub wall_clock: RuleScope,
    pub float_ord: RuleScope,
    pub trunc_index: RuleScope,
    /// The only modules in which `unsafe` (and `allow(unsafe_code)`) may
    /// appear — each occurrence still requires a `// SAFETY:` comment.
    pub unsafe_modules: Vec<String>,
}

impl LintConfig {
    /// The contract the committed tree is held to (see module docs).
    pub fn repo_default() -> LintConfig {
        LintConfig {
            // Everything that carries replayable simulator state. util/ is
            // out of scope: the substrates there (json, args, slotvec) hold
            // host-side config or are deterministic by construction.
            hash_collections: RuleScope::only(&[
                "sim/",
                "coordinator/",
                "kvcache/",
                "workload/",
                "config/",
                "metrics/",
            ]),
            // Wall clock is measurement-only; these modules measure.
            wall_clock: RuleScope::tree_wide(&[
                "util/bench.rs",      // the bench harness times real work
                "sim/sweep.rs",       // reports sweep wall-clock, never sim time
                "sim/throughput.rs",  // reports steps/sec wall-clock
                "engine/pipeline.rs", // serves the real model: TTFT/TBT are real
                "util/threadpool.rs", // test-only timing of the shutdown wait
            ]),
            float_ord: RuleScope::tree_wide(&[]),
            // Percentile/metrics paths, where a truncated rank silently
            // biases a reported tail (the PR 8 p95 class) — plus the
            // prefix index, where a truncated block count would silently
            // shrink or inflate a reuse grant.
            trunc_index: RuleScope::only(&[
                "util/stats.rs",
                "metrics/",
                "sim/",
                "figures/",
                "kvcache/",
            ]),
            unsafe_modules: vec![
                "util/threadpool.rs".to_string(), // lifetime-erased scoped jobs
                "runtime/mod.rs".to_string(),     // reserved for PJRT FFI views
            ],
        }
    }
}

/// Lint every `.rs` file under `root` (recursively, in sorted path order)
/// against the repo-default contract. Returns all findings; an empty vec
/// is a clean tree.
pub fn check_tree(root: impl AsRef<Path>) -> anyhow::Result<Vec<Finding>> {
    check_tree_with(root.as_ref(), &LintConfig::repo_default())
}

/// [`check_tree`] with an explicit configuration.
pub fn check_tree_with(root: &Path, cfg: &LintConfig) -> anyhow::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let rel: String = f
            .strip_prefix(root)
            .unwrap_or(f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", f.display()))?;
        out.extend(check_source(&rel, &src, cfg));
    }
    Ok(out)
}

/// Number of `.rs` files [`check_tree`] would scan under `root`.
pub fn count_rs_files(root: impl AsRef<Path>) -> anyhow::Result<usize> {
    let mut files = Vec::new();
    collect_rs_files(root.as_ref(), &mut files)?;
    Ok(files.len())
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading dir {}: {e}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn finding(path: &str, line: usize, rule: Rule, message: impl Into<String>, snip: &str) -> Finding {
    Finding {
        path: path.to_string(),
        line,
        rule,
        message: message.into(),
        snippet: snip.trim().to_string(),
    }
}

/// Lint one file's source. `path` is the root-relative forward-slash path
/// the scopes match against; fixtures pass synthetic paths to place a
/// string inside or outside a rule's scope.
pub fn check_source(path: &str, source: &str, cfg: &LintConfig) -> Vec<Finding> {
    let views = strip_lines(source);
    let raw: Vec<&str> = source.lines().collect();
    let mut out = Vec::new();

    for (i, view) in views.iter().enumerate() {
        let code = view.code.as_str();
        let line = i + 1;
        let snip = raw.get(i).copied().unwrap_or("");

        if cfg.hash_collections.applies(path)
            && (find_word(code, "HashMap") || find_word(code, "HashSet"))
        {
            out.push(finding(path, line, Rule::HashCollections, MSG_HASH, snip));
        }

        if cfg.wall_clock.applies(path)
            && (find_word(code, "Instant")
                || find_word(code, "SystemTime")
                || code.contains("std::time"))
        {
            out.push(finding(path, line, Rule::WallClock, MSG_CLOCK, snip));
        }

        if cfg.float_ord.applies(path) && find_word(code, "partial_cmp") {
            out.push(finding(path, line, Rule::FloatOrd, MSG_FLOAT_ORD, snip));
        }

        if cfg.trunc_index.applies(path) {
            if let Some(msg) = trunc_index_violation(code) {
                out.push(finding(path, line, Rule::TruncIndex, msg, snip));
            }
        }

        if find_word(code, "unsafe") || code.contains("allow(unsafe_code)") {
            let declared = cfg.unsafe_modules.iter().any(|m| path.starts_with(m.as_str()));
            if !declared {
                out.push(finding(path, line, Rule::UnsafeHygiene, MSG_UNSAFE_MODULE, snip));
            } else if find_word(code, "unsafe") && !has_safety_comment(&views, i) {
                out.push(finding(path, line, Rule::UnsafeHygiene, MSG_UNSAFE_SAFETY, snip));
            }
        }
    }
    out
}

// ---- source stripping ------------------------------------------------------

/// One source line split into its code text (string/char literal contents
/// blanked, comments removed) and its comment text (for SAFETY lookup).
#[derive(Debug, Clone, Default)]
struct LineView {
    code: String,
    comment: String,
}

/// Split source into per-line code/comment views. Handles line comments,
/// nested block comments, string literals (plain, raw `r#".."#`, byte),
/// char/byte-char literals with escapes, and lifetimes (`'a` is code, not
/// an unterminated char). Literal *contents* never reach the code view,
/// so banned tokens inside strings or docs cannot fire a rule.
fn strip_lines(source: &str) -> Vec<LineView> {
    let cs: Vec<char> = source.chars().collect();
    let n = cs.len();
    let mut out = Vec::new();
    let mut line = LineView::default();
    let mut i = 0;
    // Block-comment nesting depth (Rust block comments nest); 0 = code.
    let mut block_depth = 0usize;
    let mut in_line_comment = false;

    while i < n {
        let c = cs[i];
        if c == '\n' {
            in_line_comment = false;
            out.push(std::mem::take(&mut line));
            i += 1;
            continue;
        }
        if in_line_comment {
            line.comment.push(c);
            i += 1;
            continue;
        }
        if block_depth > 0 {
            if c == '*' && cs.get(i + 1) == Some(&'/') {
                block_depth -= 1;
                i += 2;
            } else if c == '/' && cs.get(i + 1) == Some(&'*') {
                block_depth += 1;
                i += 2;
            } else {
                line.comment.push(c);
                i += 1;
            }
            continue;
        }
        // code state
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            in_line_comment = true;
            i += 2;
            continue;
        }
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            block_depth += 1;
            i += 2;
            continue;
        }
        if c == '"' {
            i = skip_string(&cs, i);
            line.code.push_str("\"\"");
            continue;
        }
        // raw / byte strings: r".."  r#".."#  b".."  br#".."#
        if (c == 'r' || c == 'b') && !prev_is_ident(&cs, i) {
            if let Some(end) = raw_or_byte_string_end(&cs, i) {
                i = end;
                line.code.push_str("\"\"");
                continue;
            }
        }
        if c == '\'' {
            // char literal vs lifetime/label
            if cs.get(i + 1) == Some(&'\\') {
                // escaped char: jump past the escape head, then scan to
                // the closing quote
                let mut j = i + 3;
                while j < n && cs[j] != '\'' {
                    j += 1;
                }
                line.code.push_str("' '");
                i = (j + 1).min(n);
                continue;
            }
            if cs.get(i + 2) == Some(&'\'') {
                line.code.push_str("' '");
                i += 3;
                continue;
            }
            // lifetime or loop label: plain code
            line.code.push(c);
            i += 1;
            continue;
        }
        line.code.push(c);
        i += 1;
    }
    out.push(line);
    out
}

fn prev_is_ident(cs: &[char], i: usize) -> bool {
    i > 0 && (cs[i - 1].is_alphanumeric() || cs[i - 1] == '_' || cs[i - 1] == '"')
}

/// Past-the-end index of a plain string literal starting at `i` (a `"`).
fn skip_string(cs: &[char], i: usize) -> usize {
    let n = cs.len();
    let mut j = i + 1;
    while j < n {
        match cs[j] {
            '\\' => j += 2,
            '"' => return j + 1,
            _ => j += 1,
        }
    }
    n
}

/// If a raw or byte string starts at `i` (`r`/`b`/`br` prefix), return its
/// past-the-end index.
fn raw_or_byte_string_end(cs: &[char], i: usize) -> Option<usize> {
    let n = cs.len();
    let mut j = i;
    if cs.get(j) == Some(&'b') {
        j += 1;
        if cs.get(j) == Some(&'\'') {
            // byte char b'x' — the char-literal path handles it next round
            return None;
        }
    }
    let raw = cs.get(j) == Some(&'r');
    if raw {
        j += 1;
    }
    let mut hashes = 0usize;
    while cs.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if cs.get(j) != Some(&'"') || (!raw && hashes > 0) {
        return None;
    }
    if !raw {
        // plain byte string b"..": same escape rules as a normal string
        return Some(skip_string(cs, j));
    }
    j += 1;
    while j < n {
        if cs[j] == '"' {
            let mut k = 0usize;
            while k < hashes && cs.get(j + 1 + k) == Some(&'#') {
                k += 1;
            }
            if k == hashes {
                return Some(j + 1 + hashes);
            }
        }
        j += 1;
    }
    Some(n)
}

// ---- token matching --------------------------------------------------------

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Whole-word occurrence of `word` in `code` (identifier boundaries on
/// both sides, so a ban on one word never matches inside another).
fn find_word(code: &str, word: &str) -> bool {
    let cs: Vec<char> = code.chars().collect();
    let ws: Vec<char> = word.chars().collect();
    if ws.is_empty() || cs.len() < ws.len() {
        return false;
    }
    cs.windows(ws.len()).enumerate().any(|(start, w)| {
        w == ws.as_slice()
            && (start == 0 || !is_ident_char(cs[start - 1]))
            && cs.get(start + ws.len()).is_none_or(|c| !is_ident_char(*c))
    })
}

// ---- D4: truncating index arithmetic ---------------------------------------

/// Returns a message if the code line contains truncating rank arithmetic:
/// either a float→usize cast whose operand is float-valued but carries no
/// explicit rounding call, or the integer `* N / 100` percentile idiom.
fn trunc_index_violation(code: &str) -> Option<String> {
    if let Some(operand) = float_cast_operand(code) {
        return Some(format!(
            "float expression `{}` cast straight to usize truncates toward zero; \
             make rounding explicit (.floor()/.ceil()/.round()) or use the \
             percentile helpers",
            operand.trim()
        ));
    }
    if int_percent_arithmetic(code) {
        return Some(
            "integer `* N / 100` rank arithmetic truncates and under-reads small \
             samples; use the shared percentile helpers"
                .to_string(),
        );
    }
    None
}

/// Find an `as usize` cast whose operand looks float-valued and has no
/// explicit rounding-mode call.
fn float_cast_operand(code: &str) -> Option<String> {
    let mut search = 0usize;
    while let Some(rel) = code[search..].find("as usize") {
        let idx = search + rel;
        search = idx + "as usize".len();
        let before_ok = code[..idx].chars().next_back().is_none_or(|c| !is_ident_char(c));
        let after_ok = code[search..].chars().next().is_none_or(|c| !is_ident_char(c));
        if !before_ok || !after_ok {
            continue;
        }
        let operand = cast_operand(&code[..idx]);
        let sanctioned = [".floor()", ".ceil()", ".round()", ".trunc()"]
            .iter()
            .any(|m| operand.ends_with(m));
        if !sanctioned && is_float_marked(&operand) {
            return Some(operand);
        }
    }
    None
}

/// The lexical cast operand preceding an `as`: trailing paren groups and
/// the identifier/method chains between them, walked right to left. An
/// approximation — it sees one line — but exact for the idioms in tree.
fn cast_operand(prefix: &str) -> String {
    let cs: Vec<char> = prefix.trim_end().chars().collect();
    let mut i = cs.len();
    loop {
        let round_start = i;
        if i > 0 && cs[i - 1] == ')' {
            let mut depth = 0usize;
            while i > 0 {
                i -= 1;
                match cs[i] {
                    ')' => depth += 1,
                    '(' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
            }
        }
        let chain_end = i;
        while i > 0 && (is_ident_char(cs[i - 1]) || cs[i - 1] == '.' || cs[i - 1] == ':') {
            i -= 1;
        }
        // keep absorbing `(..).method` chains; otherwise we are done
        let chain_starts_with_dot = i < chain_end && cs[i] == '.';
        if i == round_start || !(chain_starts_with_dot && i > 0 && cs[i - 1] == ')') {
            break;
        }
    }
    cs[i..].iter().collect()
}

/// Does the operand evaluate to a float, lexically: an `as f64` cast, an
/// `f64::` path, or a float literal (`1.5`, `1e6`; hex excluded).
fn is_float_marked(operand: &str) -> bool {
    if operand.contains("as f64") || operand.contains("f64::") {
        return true;
    }
    if operand.contains("0x") || operand.contains("0X") {
        return false;
    }
    let cs: Vec<char> = operand.chars().collect();
    cs.windows(3).any(|w| {
        let float_dot = w[0].is_ascii_digit() && w[1] == '.' && w[2].is_ascii_digit();
        let float_exp = w[0].is_ascii_digit()
            && (w[1] == 'e' || w[1] == 'E')
            && (w[2].is_ascii_digit() || w[2] == '+' || w[2] == '-');
        float_dot || float_exp
    })
}

/// Token sequence `* <int> / 100` (the truncating percentile idiom).
fn int_percent_arithmetic(code: &str) -> bool {
    let toks = tokens(code);
    toks.windows(4).any(|w| {
        w[0] == "*"
            && !w[1].is_empty()
            && w[1].chars().all(|c| c.is_ascii_digit() || c == '_')
            && w[1].chars().any(|c| c.is_ascii_digit())
            && w[2] == "/"
            && w[3] == "100"
    })
}

/// Split a code line into identifier/number words and single-char
/// punctuation tokens (whitespace dropped). `100.0` stays one token, so
/// it can never be mistaken for the integer `100`.
fn tokens(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let cs: Vec<char> = code.chars().collect();
    let mut i = 0;
    while i < cs.len() {
        let c = cs[i];
        if c.is_whitespace() {
            i += 1;
        } else if is_ident_char(c) {
            let start = i;
            while i < cs.len() && (is_ident_char(cs[i]) || cs[i] == '.') {
                i += 1;
            }
            out.push(cs[start..i].iter().collect());
        } else {
            out.push(c.to_string());
            i += 1;
        }
    }
    out
}

// ---- U1: SAFETY comment adjacency ------------------------------------------

/// Is the `unsafe` on line `i` covered by a `// SAFETY:` comment — on the
/// same line or in the contiguous comment block immediately above it?
/// A blank line or an intervening code line breaks adjacency.
fn has_safety_comment(views: &[LineView], i: usize) -> bool {
    if views[i].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let v = &views[j];
        if !v.code.trim().is_empty() {
            return false;
        }
        if v.comment.contains("SAFETY:") {
            return true;
        }
        if v.comment.trim().is_empty() {
            // blank line: the comment block no longer immediately precedes
            return false;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LintConfig {
        LintConfig::repo_default()
    }

    fn check(path: &str, src: &str) -> Vec<Finding> {
        check_source(path, src, &cfg())
    }

    fn rules_of(findings: &[Finding]) -> Vec<Rule> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ---- stripper --------------------------------------------------------

    #[test]
    fn stripper_blanks_comments_and_strings() {
        let src =
            "let x = 1; // HashMap here\nlet s = \"Instant::now\";\n/* SystemTime */ let y;\n";
        let v = strip_lines(src);
        assert!(!v[0].code.contains("HashMap"));
        assert!(v[0].comment.contains("HashMap"));
        assert!(!v[1].code.contains("Instant"));
        assert!(v[2].comment.contains("SystemTime"));
        assert!(v[2].code.contains("let y;"));
    }

    #[test]
    fn stripper_handles_raw_strings_chars_and_lifetimes() {
        let src = "let r = r#\"partial_cmp \"quoted\" inside\"#;\nlet c = '\\'';\n\
                   fn f<'a>(x: &'a u8) -> &'a u8 { x }\nlet b = b'{';\nlet bs = b\"unsafe\";\n";
        let v = strip_lines(src);
        assert!(!v[0].code.contains("partial_cmp"), "raw string: {}", v[0].code);
        assert!(v[0].code.contains("let r ="));
        assert!(v[1].code.contains("let c ="));
        assert!(v[2].code.contains("fn f<'a>"), "lifetime survives: {}", v[2].code);
        assert!(v[3].code.contains("let b ="));
        assert!(!v[4].code.contains("unsafe"), "byte string: {}", v[4].code);
    }

    #[test]
    fn stripper_handles_nested_block_comments() {
        let src = "/* outer /* inner unsafe */ still comment */ let z = 3;\n";
        let v = strip_lines(src);
        assert!(!v[0].code.contains("unsafe"));
        assert!(v[0].code.contains("let z = 3;"));
        assert!(v[0].comment.contains("inner unsafe"));
    }

    #[test]
    fn word_matching_respects_identifier_boundaries() {
        assert!(find_word("use std::collections::HashMap;", "HashMap"));
        assert!(!find_word("struct HashMapLike;", "HashMap"));
        assert!(!find_word("#[deny(unsafe_code)]", "unsafe"));
        assert!(find_word("unsafe { x() }", "unsafe"));
    }

    // ---- D1 --------------------------------------------------------------

    #[test]
    fn d1_fires_on_hash_containers_in_state_modules() {
        let f = check("sim/mod.rs", "use std::collections::HashMap;\n");
        assert_eq!(rules_of(&f), vec![Rule::HashCollections]);
        let f = check("coordinator/kvp.rs", "let s: HashSet<u32> = HashSet::new();\n");
        assert_eq!(rules_of(&f), vec![Rule::HashCollections]);
    }

    #[test]
    fn d1_silent_outside_state_scope_and_in_comments() {
        assert!(check("util/json.rs", "use std::collections::HashMap;\n").is_empty());
        assert!(check("sim/mod.rs", "// a HashMap would break replay\n").is_empty());
        assert!(check("sim/mod.rs", "let s = \"HashMap\";\n").is_empty());
    }

    // ---- D2 --------------------------------------------------------------

    #[test]
    fn d2_fires_on_wall_clock_in_sim_code() {
        let f = check("sim/mod.rs", "let t0 = Instant::now();\n");
        assert_eq!(rules_of(&f), vec![Rule::WallClock]);
        let f = check("coordinator/scheduler.rs", "use std::time::SystemTime;\n");
        assert_eq!(rules_of(&f), vec![Rule::WallClock]);
    }

    #[test]
    fn d2_allowlists_the_timing_modules() {
        assert!(check("util/bench.rs", "let t0 = Instant::now();\n").is_empty());
        assert!(check("sim/sweep.rs", "use std::time::Instant;\n").is_empty());
        assert!(check("sim/throughput.rs", "let t0 = Instant::now();\n").is_empty());
        assert!(check("engine/pipeline.rs", "let now = Instant::now();\n").is_empty());
        assert!(check("util/threadpool.rs", "use std::time::{Duration, Instant};\n").is_empty());
    }

    // ---- D3 --------------------------------------------------------------

    #[test]
    fn d3_fires_on_partial_cmp_anywhere() {
        let src = "xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        for path in ["config/faults.rs", "util/stats.rs", "sim/mod.rs"] {
            let f = check(path, src);
            assert_eq!(rules_of(&f), vec![Rule::FloatOrd], "{path}");
        }
        // the exact shape that sat at config/faults.rs:76
        let f = check(
            "config/faults.rs",
            ".sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).expect(\"non-finite\"));\n",
        );
        assert_eq!(rules_of(&f), vec![Rule::FloatOrd]);
    }

    #[test]
    fn d3_silent_on_total_cmp_and_comments() {
        assert!(check("util/stats.rs", "xs.sort_by(|a, b| a.total_cmp(b));\n").is_empty());
        assert!(check("util/stats.rs", "// partial_cmp would panic on NaN\n").is_empty());
    }

    // ---- D4 --------------------------------------------------------------

    #[test]
    fn d4_fires_on_truncating_float_casts() {
        let f = check("util/stats.rs", "let i = (xs.len() as f64 * 0.95) as usize;\n");
        assert_eq!(rules_of(&f), vec![Rule::TruncIndex]);
        let f = check("metrics/mod.rs", "let k = (rank * 1.5) as usize;\n");
        assert_eq!(rules_of(&f), vec![Rule::TruncIndex]);
    }

    #[test]
    fn d4_fires_on_integer_percent_arithmetic() {
        let f = check("util/stats.rs", "let i = xs.len() * 95 / 100;\n");
        assert_eq!(rules_of(&f), vec![Rule::TruncIndex]);
    }

    #[test]
    fn d4_sanctions_explicit_rounding_and_integer_casts() {
        let ok = [
            "let i = (p / 100.0 * n as f64).ceil() as usize;",
            "let lo = rank.floor() as usize;",
            "let hi = rank.ceil() as usize;",
            "let k = xs[rank.round() as usize];",
            "let g = group_id as usize;",
            "let t = PipelineTimeline::new(spp.max(1) as usize, 0.0);",
            "let c = (self.count - 1) as usize;",
        ];
        for src in ok {
            assert!(check("util/stats.rs", src).is_empty(), "false positive: {src}");
        }
    }

    #[test]
    fn d4_scoped_to_percentile_paths() {
        // the same truncating cast is fine in, say, the RNG (bit mixing)
        assert!(check("util/rng.rs", "let i = (x as f64 * 0.5) as usize;\n").is_empty());
        // ...but not in the prefix index, where it would shrink a grant
        let f = check("kvcache/prefix.rs", "let b = (tokens as f64 / bt) as usize;\n");
        assert_eq!(rules_of(&f), vec![Rule::TruncIndex]);
    }

    // ---- U1 --------------------------------------------------------------

    #[test]
    fn u1_fires_outside_declared_modules() {
        let f = check("sim/mod.rs", "let p = unsafe { &*ptr };\n");
        assert_eq!(rules_of(&f), vec![Rule::UnsafeHygiene]);
        let f = check("kvcache/mod.rs", "#![allow(unsafe_code)]\n");
        assert_eq!(rules_of(&f), vec![Rule::UnsafeHygiene]);
    }

    #[test]
    fn u1_requires_safety_comment_in_declared_modules() {
        let f = check("util/threadpool.rs", "let p = unsafe { &*ptr };\n");
        assert_eq!(rules_of(&f), vec![Rule::UnsafeHygiene]);
        assert!(f[0].message.contains("SAFETY"));
        let ok = "// SAFETY: ptr is valid for the whole scope, see wait_all.\n\
                  let p = unsafe { &*ptr };\n";
        assert!(check("util/threadpool.rs", ok).is_empty());
        // multi-line comment block directly above still counts
        let ok2 = "// SAFETY: the queue requires 'static jobs, but the barrier\n\
                   // blocks until this job completes.\nlet job = unsafe { erase(job) };\n";
        assert!(check("runtime/mod.rs", ok2).is_empty());
    }

    #[test]
    fn u1_blank_line_breaks_safety_adjacency() {
        let src = "// SAFETY: stale justification\n\nlet p = unsafe { &*ptr };\n";
        let f = check("util/threadpool.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnsafeHygiene]);
    }

    #[test]
    fn u1_ignores_the_deny_attribute_and_strings() {
        assert!(check("lib.rs", "#![deny(unsafe_code)]\n").is_empty());
        assert!(check("sim/mod.rs", "let s = \"unsafe\";\n").is_empty());
        assert!(check("sim/mod.rs", "// unsafe is banned here\n").is_empty());
    }

    // ---- findings plumbing -----------------------------------------------

    #[test]
    fn findings_render_and_serialize() {
        let f = check("sim/mod.rs", "fn f() {}\nlet t = Instant::now();\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 2);
        let disp = f[0].to_string();
        assert!(disp.contains("sim/mod.rs:2"), "{disp}");
        assert!(disp.contains("D2"), "{disp}");
        let j = f[0].to_json();
        assert_eq!(j.get("rule").and_then(|x| x.as_str()), Some("D2"));
        assert_eq!(j.get("line").and_then(|x| x.as_u64()), Some(2));
    }

    #[test]
    fn custom_scope_allowlists_are_honored() {
        let mut c = cfg();
        c.wall_clock.allow.push("sim/replay_clock.rs".to_string());
        let f = check_source("sim/replay_clock.rs", "let t = Instant::now();\n", &c);
        assert!(f.is_empty());
    }

    #[test]
    fn rule_ids_and_names_are_stable() {
        let ids: Vec<&str> = Rule::ALL.iter().map(|r| r.id()).collect();
        assert_eq!(ids, vec!["D1", "D2", "D3", "D4", "U1"]);
        for r in Rule::ALL {
            assert!(!r.name().is_empty());
        }
    }
}
