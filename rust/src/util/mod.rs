//! From-scratch substrates: PRNG, statistics, JSON, CLI args, bench harness,
//! thread pool, and a property-testing helper. The offline crate registry
//! only carries the `xla` closure, so these replace rand / serde_json / clap
//! / criterion / tokio / proptest respectively (see DESIGN.md §3).

pub mod args;
pub mod bench;
pub mod json;
pub mod lint;
pub mod proptest;
pub mod rng;
pub mod slotvec;
pub mod stats;
pub mod threadpool;
