//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports: subcommands, `--flag`, `--key value`, `--key=value`, positional
//! arguments, typed accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `flag_names` lists boolean options (no value).
    pub fn parse(argv: &[String], flag_names: &[&str], with_subcommand: bool) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        if with_subcommand && i < argv.len() && !argv[i].starts_with('-') {
            out.subcommand = Some(argv[i].clone());
            i += 1;
        }
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    // trailing valueless option: treat as a flag
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env(flag_names: &[&str], with_subcommand: bool) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, flag_names, with_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|s| parse_tokens(s).unwrap_or_else(|| panic!("--{name}: bad integer '{s}'")))
            .unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.u64_or(name, default as u64) as usize
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name}: bad float '{s}'")))
            .unwrap_or(default)
    }

    /// Comma-separated u64 list, with K/M suffix support ("32,1K,2M").
    pub fn u64_list(&self, name: &str, default: &[u64]) -> Vec<u64> {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .map(|t| parse_tokens(t.trim()).unwrap_or_else(|| panic!("--{name}: bad entry '{t}'")))
                .collect(),
        }
    }
}

/// Parse "128", "4K", "2M" and "1.5M" style token counts.
pub fn parse_tokens(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(num) = s.strip_suffix(['M', 'm']) {
        return Some((num.parse::<f64>().ok()? * 1e6) as u64);
    }
    if let Some(num) = s.strip_suffix(['K', 'k']) {
        return Some((num.parse::<f64>().ok()? * 1e3) as u64);
    }
    s.parse::<u64>().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            &sv(&["simulate", "--ctx", "1M", "--verbose", "--out=results", "trace.json"]),
            &["verbose"],
            true,
        );
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.u64_or("ctx", 0), 1_000_000);
        assert!(a.flag("verbose"));
        assert_eq!(a.str_or("out", ""), "results");
        assert_eq!(a.positional, vec!["trace.json"]);
    }

    #[test]
    fn token_suffixes() {
        assert_eq!(parse_tokens("128"), Some(128));
        assert_eq!(parse_tokens("4K"), Some(4_000));
        assert_eq!(parse_tokens("2M"), Some(2_000_000));
        assert_eq!(parse_tokens("1.5M"), Some(1_500_000));
        assert_eq!(parse_tokens("x"), None);
    }

    #[test]
    fn lists() {
        let a = Args::parse(&sv(&["--chunks", "32,128,4K"]), &[], false);
        assert_eq!(a.u64_list("chunks", &[]), vec![32, 128, 4000]);
        assert_eq!(a.u64_list("absent", &[7]), vec![7]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]), &[], false);
        assert_eq!(a.u64_or("n", 9), 9);
        assert_eq!(a.f64_or("x", 1.5), 1.5);
        assert!(!a.flag("v"));
    }
}
