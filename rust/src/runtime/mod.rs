//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` + manifest)
//! and executes them on the CPU PJRT client. This is the only place the
//! `xla` crate is touched; Python never runs at serve time.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md for why not serialized protos).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parsed artifact manifest (written by python/compile/aot.py).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub spec: TinySpec,
    pub chunk_buckets: Vec<u64>,
    pub stage_buckets: Vec<u32>,
    pub kvp_shard_caps: Vec<u64>,
    pub kvp_merge_counts: Vec<u32>,
    pub layer_weight_names: Vec<String>,
    pub entries: BTreeMap<String, Entry>,
    pub weights: Vec<TensorInfo>,
    pub weights_file: String,
    pub golden: Option<Golden>,
}

/// The tiny served model's architecture (mirror of python ModelSpec).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TinySpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub hq: usize,
    pub hkv: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub n_params: u64,
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub file: String,
    /// (shape, dtype) per positional input.
    pub inputs: Vec<(Vec<usize>, String)>,
}

#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct Golden {
    pub prompt: Vec<i32>,
    pub chunk_size: u64,
    pub generated: Vec<i32>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let spec = j.req("spec")?;
        let spec = TinySpec {
            vocab: spec.req_u64("vocab")? as usize,
            d_model: spec.req_u64("d_model")? as usize,
            n_layers: spec.req_u64("n_layers")? as usize,
            hq: spec.req_u64("hq")? as usize,
            hkv: spec.req_u64("hkv")? as usize,
            d_head: spec.req_u64("d_head")? as usize,
            d_ff: spec.req_u64("d_ff")? as usize,
            max_seq: spec.req_u64("max_seq")? as usize,
            n_params: spec.req_u64("n_params")?,
        };
        let list_u64 = |key: &str| -> Result<Vec<u64>> {
            Ok(j.req_arr(key)?
                .iter()
                .filter_map(|x| x.as_u64())
                .collect())
        };
        let mut entries = BTreeMap::new();
        for (name, e) in j.req("entries")?.as_obj().ok_or_else(|| anyhow!("entries"))? {
            let inputs = e
                .req_arr("inputs")?
                .iter()
                .map(|i| {
                    let shape = i
                        .req_arr("shape")
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect();
                    (shape, i.req_str("dtype").unwrap_or("f32").to_string())
                })
                .collect();
            entries.insert(
                name.clone(),
                Entry {
                    file: e.req_str("file")?.to_string(),
                    inputs,
                },
            );
        }
        let w = j.req("weights")?;
        let weights = w
            .req_arr("tensors")?
            .iter()
            .map(|t| {
                Ok(TensorInfo {
                    name: t.req_str("name")?.to_string(),
                    shape: t
                        .req_arr("shape")?
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect(),
                    offset: t.req_u64("offset")? as usize,
                    size: t.req_u64("size")? as usize,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let golden = match j.get("golden") {
            Some(Json::Null) | None => None,
            Some(g) => Some(Golden {
                prompt: g
                    .req_arr("prompt")?
                    .iter()
                    .filter_map(|x| x.as_i64().map(|v| v as i32))
                    .collect(),
                chunk_size: g.req_u64("chunk_size")?,
                generated: g
                    .req_arr("generated")?
                    .iter()
                    .filter_map(|x| x.as_i64().map(|v| v as i32))
                    .collect(),
            }),
        };
        Ok(Manifest {
            spec,
            chunk_buckets: list_u64("chunk_buckets")?,
            stage_buckets: list_u64("stage_buckets")?.iter().map(|&x| x as u32).collect(),
            kvp_shard_caps: list_u64("kvp_shard_caps")?,
            kvp_merge_counts: list_u64("kvp_merge_counts")?
                .iter()
                .map(|&x| x as u32)
                .collect(),
            layer_weight_names: j
                .req_arr("layer_weight_names")?
                .iter()
                .filter_map(|x| x.as_str().map(|s| s.to_string()))
                .collect(),
            entries,
            weights,
            weights_file: w.req_str("file")?.to_string(),
            golden,
        })
    }
}

/// Host-side tensor (f32) read from weights.bin.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Load all weights from the binary blob.
pub fn load_weights(dir: &Path, m: &Manifest) -> Result<BTreeMap<String, HostTensor>> {
    let blob = std::fs::read(dir.join(&m.weights_file))
        .with_context(|| format!("reading {}", m.weights_file))?;
    let mut out = BTreeMap::new();
    for t in &m.weights {
        let bytes = &blob
            .get(t.offset..t.offset + t.size)
            .ok_or_else(|| anyhow!("weight {} out of range", t.name))?;
        let mut data = vec![0f32; t.size / 4];
        for (i, ch) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        out.insert(
            t.name.clone(),
            HostTensor {
                name: t.name.clone(),
                shape: t.shape.clone(),
                data,
            },
        );
    }
    Ok(out)
}

/// The executable store: lazily compiles artifacts on the CPU PJRT client
/// and caches them. Thread-safe; executions can run concurrently from the
/// engine's stage workers.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an entry's executable.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let entry = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("no artifact entry '{name}'"))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute an entry with literal inputs; returns the untupled outputs.
    pub fn call(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = args.iter().collect();
        self.call_refs(name, &refs)
    }

    /// Execute with borrowed inputs — avoids deep-copying large literals
    /// (e.g. resident weights) into the argument list (§Perf L3 iteration 3:
    /// `Literal::clone` is a full C++ copy, ~72 MB per stage call).
    pub fn call_refs(&self, name: &str, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let entry = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("no artifact entry '{name}'"))?;
        if args.len() != entry.inputs.len() {
            bail!(
                "entry '{name}' expects {} inputs, got {}",
                entry.inputs.len(),
                args.len()
            );
        }
        let exe = self.executable(name)?;
        let out = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    pub fn entry_names(&self) -> Vec<String> {
        self.manifest.entries.keys().cloned().collect()
    }
}

// ---- literal helpers -------------------------------------------------------

/// Encode a 4-byte-element slice as little-endian bytes for PJRT's
/// untyped-literal constructor. This replaces the previous
/// `slice::from_raw_parts` reinterpretation: literal creation copies the
/// buffer internally and only runs on the load path, so the safe copy
/// costs nothing measurable — and unlike the cast, it is byte-order
/// explicit (PJRT literals are little-endian on every supported host).
fn le_bytes_4<T: Copy>(data: &[T], enc: impl Fn(T) -> [u8; 4]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for &v in data {
        bytes.extend_from_slice(&enc(v));
    }
    bytes
}

/// f32 literal of the given shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape {:?} wants {n} elements, got {}", shape, data.len());
    }
    let bytes = le_bytes_4(data, f32::to_le_bytes);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, &bytes)
        .map_err(|e| anyhow!("literal: {e:?}"))
}

/// i32 literal.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape {:?} wants {n} elements, got {}", shape, data.len());
    }
    let bytes = le_bytes_4(data, i32::to_le_bytes);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, &bytes)
        .map_err(|e| anyhow!("literal: {e:?}"))
}

/// Zero-filled f32 literal.
pub fn lit_zeros_f32(shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    lit_f32(shape, &vec![0f32; n])
}

pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.spec.vocab, 256);
        assert!(m.entries.contains_key("embed_c16"));
        assert!(m.golden.is_some());
        assert_eq!(m.layer_weight_names.len(), 9);
    }

    #[test]
    fn weights_load_and_match_param_count() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let w = load_weights(&artifacts_dir(), &m).unwrap();
        let total: u64 = w.values().map(|t| t.data.len() as u64).sum();
        assert_eq!(total, m.spec.n_params);
        assert!(w.contains_key("embed"));
        assert!(w.contains_key("layers.7.w_down"));
    }

    #[test]
    fn literal_roundtrip() {
        let l = lit_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert!(lit_f32(&[2, 2], &[1.0]).is_err());
    }

    #[test]
    fn le_bytes_4_matches_native_encoding() {
        // the safe copy must produce exactly the bytes the old raw-parts
        // reinterpretation handed PJRT (little-endian hosts)
        assert_eq!(le_bytes_4(&[1.0f32], f32::to_le_bytes), 1.0f32.to_le_bytes());
        assert_eq!(
            le_bytes_4(&[-7i32, 300], i32::to_le_bytes),
            [(-7i32).to_le_bytes(), 300i32.to_le_bytes()].concat()
        );
        assert!(le_bytes_4(&[] as &[f32], f32::to_le_bytes).is_empty());
    }
}
