//! Medha CLI — leader entrypoint.
//!
//! Subcommands:
//!   serve       serve the tiny real model on CPU PJRT (SPP pipeline)
//!   simulate    run the cluster simulator on a workload
//!   serve-sim   open-loop online serving: arrival stream + admission gate
//!   sweep       run the policy x routing x load grid concurrently
//!   reproduce   regenerate a paper table/figure (--figure fig15 | all)
//!   inspect     list AOT artifacts and the manifest summary
//!   table1      print the capability matrix
//!   lint        run the determinism-contract checker over rust/src

use medha::config::DeploymentConfig;
use medha::coordinator::{RoutingMode, SchedPolicyKind};
use medha::engine::pipeline::{serve, ServeRequest};
use medha::engine::{detokenize, tokenize};
use medha::sim::{SimOptions, Simulation};
use medha::util::args::Args;
use medha::util::stats::{fmt_duration, fmt_tokens};
use medha::workload::{self, ConvoyConfig, LengthDist};

const USAGE: &str = "\
medha — long-context LLM serving (Mnemosyne/Medha reproduction)

USAGE:
  medha serve     [--artifacts DIR] [--stages N] [--chunk-cap C] [--prompt TEXT] [--requests N] [--new-tokens N]
  medha simulate  [--model llama3-8b|llama3-70b] [--tp N] [--spp N] [--kvp N]
                  [--policy fcfs|srpt|edf|lars] [--routing blind|round-robin|routed]
                  [--kvp-capacity TOKENS] [--workload mixed|convoy|kvp-convoy|multiturn]
                  [--ctx TOKENS] [--requests N] [--rate R] [--horizon S] [--seed S]
                  [--threads N]          parallel per-group stepping (bit-identical to serial)
                  [--faults PLAN.json]   deterministic group crash/join/drain/slowdown schedule
                  [--no-reuse]           multiturn only: disable the prefix index (control arm)
  medha serve-sim [--scenario flash|diurnal|overcommit] [--policy fcfs|srpt|edf|lars]
                  [--routing blind|round-robin|routed] [--rate R] [--horizon S]
                  [--mult M] [--seed S] [--admission pass|PLAN.json] [--smoke]
                  open-loop online serving: the scenario offers an arrival
                  stream the fleet does not control; a per-class token-bucket
                  admission gate paces, queues, or sheds (default: protective
                  gate scaled to the base rate; 'pass' = unpaced pass-through,
                  bit-identical to the closed-loop simulate path)
  medha sweep     [--threads N] [--seed S] [--loads 0.5,1,2] [--kvp-capacity TOKENS] [--smoke]
                  run the full policy x routing x load grid concurrently (one sim
                  per worker, per-cell seeds from (seed, cell)) and print the
                  Pareto-frontier table: goodput vs short p99 TTFT vs deferrals
  medha reproduce --figure <fig1|table1|fig5a|...|sweep|all>
  medha inspect   [--artifacts DIR]
  medha table1
  medha lint      [--root DIR] [--json]
                  statically check the determinism contract (D1 hash
                  containers, D2 wall clock, D3 partial_cmp, D4 truncating
                  rank casts, U1 unsafe/SAFETY hygiene) over the source
                  tree; exits 1 and prints findings on any violation
";

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(
        &["verbose", "adaptive", "no-adaptive", "smoke", "json", "no-reuse"],
        true,
    );
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve-sim") => cmd_serve_sim(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("reproduce") => {
            let fig = args.str_or("figure", "all");
            medha::figures::run(fig)
        }
        Some("inspect") => cmd_inspect(&args),
        Some("table1") => medha::figures::run("table1"),
        Some("lint") => cmd_lint(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let stages = args.usize_or("stages", 2);
    let chunk_cap = args.u64_or("chunk-cap", 64);
    let n_requests = args.usize_or("requests", 3);
    let new_tokens = args.usize_or("new-tokens", 16);
    let prompt = args.str_or(
        "prompt",
        "Long context inference needs chunked prefills, sequence pipeline \
         parallelism and KV cache parallelism to serve every request well.",
    );
    println!("loading artifacts from {dir}; {stages}-stage SPP pipeline, chunk cap {chunk_cap}");
    let mut reqs = vec![ServeRequest {
        prompt: tokenize(prompt),
        max_new_tokens: new_tokens,
    }];
    for i in 1..n_requests {
        reqs.push(ServeRequest {
            prompt: tokenize(&format!("short request number {i} says hello")),
            max_new_tokens: new_tokens,
        });
    }
    let report = serve(dir, stages, chunk_cap, &reqs)?;
    println!(
        "\nserved {} requests in {} — {:.1} decode tok/s, {:.1} total tok/s",
        report.requests.len(),
        fmt_duration(report.wall_s),
        report.decode_tps(),
        report.total_tps()
    );
    for (i, r) in report.requests.iter().enumerate() {
        let mean_tbt = if r.tbt_s.is_empty() {
            f64::NAN
        } else {
            r.tbt_s.iter().sum::<f64>() / r.tbt_s.len() as f64
        };
        println!(
            "  req{i}: prompt={} ttft={} mean_tbt={} out={:?}",
            r.prompt_len,
            fmt_duration(r.ttft_s),
            fmt_duration(mean_tbt),
            detokenize(&r.generated)
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let model = args.str_or("model", "llama3-8b");
    let mut dep = match model {
        "llama3-70b" => DeploymentConfig::llama3_70b_tp8(),
        _ => DeploymentConfig::llama3_8b_tp8(),
    }
    .with_parallel(
        args.u64_or("tp", 8) as u32,
        args.u64_or("spp", 4) as u32,
        args.u64_or("kvp", 1) as u32,
    );
    if args.flag("no-adaptive") {
        dep.scheduler.adaptive_chunking = false;
    }
    if let Some(p) = args.get("policy") {
        dep.scheduler.policy = SchedPolicyKind::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown --policy '{p}' (fcfs|srpt|edf|lars)"))?;
    }
    if let Some(rm) = args.get("routing") {
        dep.scheduler.routing = RoutingMode::parse(rm)
            .ok_or_else(|| anyhow::anyhow!("unknown --routing '{rm}' (blind|round-robin|routed)"))?;
    }
    // Finite per-group KV capacity: routed placement refuses groups
    // without room and defers the admission (counted in the summary).
    if let Some(cap) = args.get("kvp-capacity") {
        dep.scheduler.kvp_capacity_tokens = cap
            .parse()
            .map_err(|_| anyhow::anyhow!("--kvp-capacity must be a token count"))?;
    }
    // Parallel per-group stepping; results are bit-identical to --threads 1
    // (the determinism tests assert it), only wall-clock changes.
    dep.scheduler.threads = args.usize_or("threads", 1);
    dep.validate()?;
    let ctx = args.u64_or("ctx", 1_000_000);
    let n = args.usize_or("requests", 8);
    let rate = args.f64_or("rate", 0.0);
    let mut opts = SimOptions::default();
    // Deterministic fleet fault schedule (see config::FaultPlan for the
    // JSON schema): crashes, drains, joins, slowdowns at precise times.
    if let Some(path) = args.get("faults") {
        opts.faults = medha::config::FaultPlan::load(std::path::Path::new(path))?;
        println!("fault plan: {} events from {path}", opts.faults.events.len());
    }
    let w = match args.str_or("workload", "mixed") {
        "convoy" => {
            let cfg = ConvoyConfig {
                rate_per_s: if rate > 0.0 { rate } else { 2.0 },
                horizon_s: args.f64_or("horizon", 60.0),
                long_prompt: ctx,
                ..ConvoyConfig::default()
            };
            // the convoy scenario: documents share the interactive queue
            opts.long_threshold = u64::MAX;
            workload::convoy(&cfg, args.u64_or("seed", 0))
        }
        "kvp-convoy" => {
            // overlapping KVP-sharded documents + interactive traffic; the
            // documents take the long-request path, so pair this with
            // --kvp > 1 and --routing routed to see the serving pool
            let cfg = medha::workload::KvpConvoyConfig {
                rate_per_s: if rate > 0.0 { rate } else { 8.0 },
                horizon_s: args.f64_or("horizon", 40.0),
                ..medha::workload::KvpConvoyConfig::default()
            };
            workload::kvp_convoy(&cfg, args.u64_or("seed", 0))
        }
        "mixed" if rate > 0.0 => workload::poisson_mixed(
            rate,
            args.f64_or("horizon", 300.0),
            LengthDist::ZipfBuckets {
                buckets: vec![1_000, 16_000, 128_000, ctx],
                s: 1.1,
            },
            256,
            args.u64_or("seed", 0),
        ),
        "mixed" => workload::long_plus_decodes(ctx, n, 1_000, 512),
        "multiturn" => {
            // Seeded multi-turn chat sessions (shared system prompt,
            // per-turn growing history) plus background shorts — the
            // prefix-reuse workload. The index is on unless --no-reuse
            // selects the control arm; pair with --routing routed for
            // cache-affinity placement.
            let cfg = medha::workload::MultiTurnConfig {
                horizon_s: args.f64_or("horizon", 30.0),
                ..medha::workload::MultiTurnConfig::default()
            };
            dep.scheduler.prefix_reuse = !args.flag("no-reuse");
            workload::multiturn(&cfg, args.u64_or("seed", 0))
        }
        other => {
            anyhow::bail!("unknown --workload '{other}' (mixed|convoy|kvp-convoy|multiturn)")
        }
    };
    println!(
        "simulating {} requests on {} x{} ({}, policy {}, routing {})",
        w.len(),
        dep.model.name,
        dep.total_gpus(),
        dep.parallel.label(),
        dep.scheduler.policy.name(),
        dep.scheduler.routing.name()
    );
    let mut sim = Simulation::new(dep, w, opts);
    let end = sim.run();
    let s = sim.metrics.summary();
    println!("simulated span: {}", fmt_duration(end));
    println!(
        "finished: {}   TTFT p50/p95: {} / {}",
        s.finished,
        fmt_duration(s.ttft_p50),
        fmt_duration(s.ttft_p95)
    );
    println!(
        "TBT p50/p95/p99/max: {} / {} / {} / {}",
        fmt_duration(s.tbt_p50),
        fmt_duration(s.tbt_p95),
        fmt_duration(s.tbt_p99),
        fmt_duration(s.tbt_max)
    );
    println!(
        "decode throughput: {:.1} tok/s   mean MFU: {:.0}%   mean MBU: {:.0}%",
        s.decode_tps,
        s.mfu_mean * 100.0,
        s.mbu_mean * 100.0
    );
    println!(
        "SLO: TTFT deadline attainment {:.0}%   TBT attainment {:.0}%   \
         goodput {:.2} req/s   preemptions {} queued / {} active yields",
        s.ttft_attainment * 100.0,
        s.tbt_attainment * 100.0,
        s.goodput_rps,
        s.preemptions,
        s.active_preemptions
    );
    if s.routing_refusals > 0 {
        println!(
            "capacity: {} admissions refused for KV room ({} deferred, \
             wait p95 {} — retries in policy-priority order)",
            s.routing_refusals,
            s.n_deferred,
            fmt_duration(s.deferral_wait_p95)
        );
    }
    if s.group_crashes > 0 {
        println!(
            "degradation: {} crashes, {} shards lost, {} tokens re-prefilled \
             ({} victims, recovery wait p50/p95 {} / {})",
            s.group_crashes,
            s.shards_lost,
            fmt_tokens(s.reprefill_tokens),
            s.n_recovered,
            fmt_duration(s.recovery_wait_p50),
            fmt_duration(s.recovery_wait_p95)
        );
    }
    if s.kv_overcommit_tokens > 0 {
        println!(
            "kv over-commit: {} tokens absorbed past the ledger (fleet full)",
            fmt_tokens(s.kv_overcommit_tokens)
        );
    }
    if s.prefix_hit_tokens > 0 {
        println!(
            "prefix reuse: {} prompt tokens served from cache (hit rate {:.0}%), \
             {} blocks shared, {} shared tokens re-prefilled after crashes",
            fmt_tokens(s.prefix_hit_tokens),
            s.prefix_hit_rate * 100.0,
            s.blocks_shared,
            fmt_tokens(s.reprefill_shared_tokens)
        );
    }
    Ok(())
}

/// `medha serve-sim`: open-loop online serving. An arrival generator
/// (`workload::openloop`) offers a stream the fleet does not control; the
/// admission gate (`coordinator::admission`) paces it through per-class
/// token buckets with bounded queues and SLO-feedback shedding, and the
/// pool-scheduled core serves what gets through. Prints the simulate
/// summary plus the admission ledger (shed / queue-rejected per class).
fn cmd_serve_sim(args: &Args) -> anyhow::Result<()> {
    use medha::coordinator::AdmissionConfig;
    use medha::sim::serve::{serve_scenario_dep, ServeSim};
    use medha::workload::openloop::{generate, OpenLoopConfig, Scenario};

    let scen_name = args.str_or("scenario", "overcommit");
    let scenario = Scenario::parse(scen_name).ok_or_else(|| {
        anyhow::anyhow!("unknown --scenario '{scen_name}' (flash|diurnal|overcommit)")
    })?;
    let smoke = args.flag("smoke") || std::env::var("MEDHA_BENCH_SMOKE").is_ok();
    let mut cfg = if smoke {
        OpenLoopConfig::smoke()
    } else {
        OpenLoopConfig::default()
    };
    cfg.base_rate_per_s = args.f64_or("rate", cfg.base_rate_per_s);
    cfg.horizon_s = args.f64_or("horizon", cfg.horizon_s);
    cfg.overcommit_mult = args.f64_or("mult", cfg.overcommit_mult);
    let policy = match args.get("policy") {
        Some(p) => SchedPolicyKind::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown --policy '{p}' (fcfs|srpt|edf|lars)"))?,
        None => SchedPolicyKind::Lars,
    };
    let routing = match args.get("routing") {
        Some(rm) => RoutingMode::parse(rm)
            .ok_or_else(|| anyhow::anyhow!("unknown --routing '{rm}' (blind|round-robin|routed)"))?,
        None => RoutingMode::Routed,
    };
    // Admission gate: protective by default (buckets scaled to the base
    // rate, shedding armed), 'pass' for the unpaced pass-through that is
    // bit-identical to the closed loop, or a JSON plan for custom buckets.
    let admission = match args.get("admission") {
        None => AdmissionConfig::protective(cfg.base_rate_per_s, cfg.doc_prompt),
        Some("pass") => AdmissionConfig::default(),
        Some(path) => {
            let j = medha::util::json::Json::parse_file(std::path::Path::new(path))?;
            AdmissionConfig::from_json(&j)?
        }
    };
    let seed = args.u64_or("seed", 0);
    let source = generate(scenario, &cfg, seed);
    let dep = serve_scenario_dep(policy, routing, &cfg);
    println!(
        "serve-sim '{}': {} offered arrivals over {} ({:.1} req/s base) on {} x{} \
         ({}, policy {}, routing {})",
        scenario.name(),
        source.len(),
        fmt_duration(cfg.horizon_s),
        cfg.base_rate_per_s,
        dep.model.name,
        dep.total_gpus(),
        dep.parallel.label(),
        dep.scheduler.policy.name(),
        dep.scheduler.routing.name()
    );
    let mut serve = ServeSim::new(dep, source, SimOptions::default(), admission);
    let end = serve.run();
    let offered = serve.n_offered();
    let (short_hw, doc_hw) = (
        serve.admission().short_q_high_water,
        serve.admission().doc_q_high_water,
    );
    let s = serve.sim.metrics.summary();
    println!("served span: {}", fmt_duration(end));
    println!(
        "offered {}   admitted {}   finished {}",
        offered,
        offered - s.n_shed - s.n_rejected_queue_full,
        s.finished
    );
    println!(
        "admission: {} shed ({} short / {} doc)   {} queue-rejected ({} short / {} doc)   \
         queue high-water {} short / {} doc",
        s.n_shed,
        s.n_shed_short,
        s.n_shed_doc,
        s.n_rejected_queue_full,
        s.n_rejected_short,
        s.n_rejected_doc,
        short_hw,
        doc_hw
    );
    println!(
        "TTFT p50/p95: {} / {}   TBT p95/p99: {} / {}",
        fmt_duration(s.ttft_p50),
        fmt_duration(s.ttft_p95),
        fmt_duration(s.tbt_p95),
        fmt_duration(s.tbt_p99)
    );
    println!(
        "SLO: TTFT attainment {:.0}%   TBT attainment {:.0}%   goodput {:.2} req/s   \
         preemptions {} queued / {} active yields",
        s.ttft_attainment * 100.0,
        s.tbt_attainment * 100.0,
        s.goodput_rps,
        s.preemptions,
        s.active_preemptions
    );
    if s.routing_refusals > 0 {
        println!(
            "capacity: {} admissions refused for KV room ({} deferred, wait p95 {})",
            s.routing_refusals,
            s.n_deferred,
            fmt_duration(s.deferral_wait_p95)
        );
    }
    if s.kv_overcommit_tokens > 0 {
        println!(
            "kv over-commit: {} tokens absorbed past the ledger (fleet full)",
            fmt_tokens(s.kv_overcommit_tokens)
        );
    }
    Ok(())
}

/// `medha sweep`: the concurrent policy × routing × load grid with the
/// Pareto-frontier table. Results are independent of --threads (cells get
/// deterministic per-cell seeds and land in canonical order); the flag
/// only divides wall-clock.
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    use medha::sim::sweep::{print_table, run_sweep, SweepConfig};
    let smoke = args.flag("smoke") || std::env::var("MEDHA_BENCH_SMOKE").is_ok();
    let mut cfg = if smoke {
        SweepConfig::smoke()
    } else {
        SweepConfig::default()
    };
    cfg.base_seed = args.u64_or("seed", cfg.base_seed);
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8);
    cfg.threads = args.usize_or("threads", default_threads);
    anyhow::ensure!(cfg.threads > 0, "--threads must be positive (1 = serial)");
    if let Some(loads) = args.get("loads") {
        cfg.load_levels = loads
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--loads: '{t}' is not a number"))
            })
            .collect::<anyhow::Result<Vec<f64>>>()?;
        anyhow::ensure!(
            !cfg.load_levels.is_empty(),
            "--loads must name at least one load multiplier"
        );
    }
    if let Some(cap) = args.get("kvp-capacity") {
        cfg.kvp_capacity_tokens = cap
            .parse()
            .map_err(|_| anyhow::anyhow!("--kvp-capacity must be a token count"))?;
    }
    let (outcomes, wall_s) = run_sweep(&cfg);
    print_table(&outcomes, wall_s, cfg.threads);
    Ok(())
}

/// `medha lint`: the determinism-contract checker (see `util::lint`).
/// Scans the source tree with the repo-default rule set and exits
/// non-zero on any finding, so CI and pre-commit hooks can gate on it;
/// `--json` emits the findings as a machine-readable array instead.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    use medha::util::json::Json;
    use medha::util::lint::{check_tree, count_rs_files};

    // Default to the in-repo tree: relative to the current directory when
    // run from a checkout, falling back to the crate manifest dir so
    // `cargo run -- lint` works from anywhere.
    let root = match args.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => {
            let local = std::path::PathBuf::from("rust/src");
            if local.is_dir() {
                local
            } else {
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src")
            }
        }
    };
    anyhow::ensure!(root.is_dir(), "lint root {} is not a directory", root.display());
    let findings = check_tree(&root)?;
    let n_files = count_rs_files(&root)?;
    if args.flag("json") {
        let arr = Json::arr(findings.iter().map(|f| f.to_json()));
        println!("{arr}");
    } else {
        for f in &findings {
            println!("{f}");
        }
        println!(
            "medha lint: {} finding(s) across {} files under {}",
            findings.len(),
            n_files,
            root.display()
        );
    }
    if !findings.is_empty() {
        anyhow::bail!("determinism contract violated: {} finding(s)", findings.len());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let rt = medha::runtime::Runtime::load(dir)?;
    let m = &rt.manifest;
    println!(
        "model: {} params, {} layers, hq={} hkv={} d_model={} max_seq={}",
        fmt_tokens(m.spec.n_params),
        m.spec.n_layers,
        m.spec.hq,
        m.spec.hkv,
        m.spec.d_model,
        m.spec.max_seq
    );
    println!("chunk buckets: {:?}", m.chunk_buckets);
    println!("stage buckets (layers/stage): {:?}", m.stage_buckets);
    println!(
        "kvp shard caps: {:?}; merge counts: {:?}",
        m.kvp_shard_caps, m.kvp_merge_counts
    );
    println!("platform: {}", rt.platform());
    println!("{} entries:", m.entries.len());
    for (name, e) in &m.entries {
        println!("  {:<24} {} inputs  ({})", name, e.inputs.len(), e.file);
    }
    Ok(())
}
