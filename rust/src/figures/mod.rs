//! Reproduction harness: one function per table/figure in the paper's
//! evaluation, printing the same rows/series the paper reports (DESIGN.md §5
//! maps each to its modules). Invoked via `medha reproduce --figure <id>`
//! and wrapped by the `paper_figures` bench target.

use crate::baselines::{ring_prefill_time, striped_prefill_time, RingConfig, VllmModel};
use crate::config::{DeploymentConfig, SloConfig};
use crate::perfmodel::{gpus_required, resource_limits, BatchShape, PerfModel, PrefillWork};
use crate::sim::{SimOptions, Simulation};
use crate::util::stats::{fmt_duration, fmt_tokens};
use crate::workload;

pub const ALL_FIGURES: &[&str] = &[
    "fig1", "table1", "fig5a", "fig5b", "fig6", "fig7", "fig8", "fig13a", "fig13b", "fig14a",
    "fig14b", "fig15", "fig16", "fig17", "fig18", "fig19", "fig20", "fig21", "fig22", "sec62",
    // ablations of DESIGN.md §6 (not paper figures, but design-choice evidence)
    "fig9", "disagg", "kvpthresh",
    // scheduling-policy comparison on the heterogeneous convoy trace (sec. 5)
    "sched",
    // robustness: 1-of-N KVP group crash, boundary re-prefill recovery
    "faults",
    // prefix-aware KV reuse on the multi-turn chat trace: hit rate,
    // prefill tokens saved, short p99 TTFT (affinity vs blind vs off)
    "reuse",
    // concurrent policy x routing x load sweep with the Pareto frontier
    "sweep",
    // open-loop overload: goodput vs offered load under admission control
    "overload",
];

pub fn run(figure: &str) -> anyhow::Result<()> {
    match figure {
        "fig1" => fig1(),
        "table1" => table1(),
        "fig5a" => fig5a(),
        "fig5b" => fig5b(),
        "fig6" => fig6(),
        "fig7" => fig7(),
        "fig8" => fig8(),
        "fig13a" => fig13a(),
        "fig13b" => fig13b(),
        "fig14a" => fig14a(),
        "fig14b" => fig14b(),
        "fig15" => fig15(),
        "fig16" => fig16(),
        "fig17" => fig17(),
        "fig18" => fig18(),
        "fig19" => fig19(),
        "fig20" => fig20(),
        "fig21" => fig21(),
        "fig22" => fig22(),
        "sec62" => sec62(),
        "fig9" => fig9(),
        "disagg" => disagg(),
        "kvpthresh" => kvpthresh(),
        "sched" => sched(),
        "faults" => faults(),
        "reuse" => reuse(),
        "sweep" => sweep(),
        "overload" => overload(),
        "all" => {
            for f in ALL_FIGURES {
                run(f)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown figure '{other}' (try one of {ALL_FIGURES:?})"),
    }
}

fn pm_for(dep: &DeploymentConfig) -> PerfModel {
    PerfModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel)
}

fn dep8b(tp: u32, spp: u32, kvp: u32) -> DeploymentConfig {
    DeploymentConfig::llama3_8b_tp8().with_parallel(tp, spp, kvp)
}

fn dep70b(tp: u32, spp: u32, kvp: u32) -> DeploymentConfig {
    DeploymentConfig::llama3_70b_tp8().with_parallel(tp, spp, kvp)
}

// ---------------------------------------------------------------------------

/// Fig. 1 — headline: 1M/5M/10M on the full 128-GPU 3D deployment (70B).
pub fn fig1() -> anyhow::Result<()> {
    println!("\n== Fig. 1: Medha headline performance (Llama-3 70B, 128 H100, 3D parallel) ==");
    println!(
        "{:<12} {:>10} {:>14} {:>16}",
        "context", "gpus", "prefill (TTFT)", "decode (tok/s)"
    );
    for &(ctx, spp, kvp) in &[(1_000_000u64, 8u32, 2u32), (5_000_000, 8, 2), (10_000_000, 8, 2)] {
        let dep = dep70b(8, spp, kvp);
        let pm = pm_for(&dep);
        // Eq. 10: KVP groups cooperate on chunk attention during prefill.
        let ttft = pm.prefill_time_3d(ctx, 4096);
        let tbt = pm.decode_tbt(ctx);
        println!(
            "{:<12} {:>10} {:>14} {:>16.1}",
            fmt_tokens(ctx),
            dep.total_gpus(),
            fmt_duration(ttft),
            1.0 / tbt
        );
    }
    println!("paper: 1M ~74s prefill / 64 tok/s; 5M ~3.5min / 56; 10M ~10.6min / 40");
    println!("(absolute decode rate is bf16-KV; the paper's testbed is consistent with");
    println!(" fp8 KV — dtype_bytes=1 doubles the modeled decode rate. Shapes match.)");
    Ok(())
}

/// Table 1 — capability matrix.
pub fn table1() -> anyhow::Result<()> {
    println!("\n== Table 1: Parallelization strategies for long-context inference ==");
    print!("{}", crate::baselines::table1::render_matrix());
    Ok(())
}

/// Fig. 5a — max supported tokens per resource type (8xH100, 8B).
pub fn fig5a() -> anyhow::Result<()> {
    println!("\n== Fig. 5a: max tokens per resource, Llama-3 8B on 8xH100 (30s TTFT / 20ms TBT) ==");
    let slo = SloConfig {
        ttft_s: 30.0,
        tbt_s: 0.020,
        ..SloConfig::default()
    };
    let dep = dep8b(8, 1, 1);
    let r = resource_limits(&dep.model, &dep.hardware, 8, &slo);
    println!("compute-bound max tokens:   {:>12}", fmt_tokens(r.compute_tokens));
    println!("bandwidth-bound max tokens: {:>12}", fmt_tokens(r.bandwidth_tokens));
    println!("capacity-bound max tokens:  {:>12}", fmt_tokens(r.capacity_tokens));
    println!("paper: compute binds first (~768K); capacity scales furthest");
    Ok(())
}

/// Fig. 5b — GPUs needed per resource type vs context length.
pub fn fig5b() -> anyhow::Result<()> {
    println!("\n== Fig. 5b: GPUs required vs context (Llama-3 8B, 30s TTFT / 20ms TBT) ==");
    let slo = SloConfig {
        ttft_s: 30.0,
        tbt_s: 0.020,
        ..SloConfig::default()
    };
    let dep = dep8b(8, 1, 1);
    println!(
        "{:<10} {:>9} {:>10} {:>10} {:>8}",
        "context", "compute", "bandwidth", "capacity", "max"
    );
    for &ctx in &[128_000u64, 256_000, 512_000, 1_000_000, 2_000_000, 4_000_000] {
        let g = gpus_required(&dep.model, &dep.hardware, ctx, &slo);
        println!(
            "{:<10} {:>9} {:>10} {:>10} {:>8}",
            fmt_tokens(ctx),
            g.compute,
            g.bandwidth,
            g.capacity,
            g.max()
        );
    }
    println!("paper: ~20 GPUs @1M, ~80 @2M (quadratic in context)");
    Ok(())
}

/// Fig. 6 — chunked-prefill read amplification (Eq. 6).
pub fn fig6() -> anyhow::Result<()> {
    println!("\n== Fig. 6: KV read amplification of chunked prefill (Llama-3 8B, 1M tokens) ==");
    let m = dep8b(8, 1, 1).model;
    let n = 1_000_000u64;
    let contiguous = crate::perfmodel::counts::attn_read_bytes(&m, n) * m.n_layers as f64;
    println!(
        "{:<12} {:>16} {:>14}",
        "chunk", "total KV reads", "amplification"
    );
    for &c in &[4096u64, 1024, 256, 64, 32] {
        let r = crate::perfmodel::counts::chunked_prefill_total_reads(&m, n, c);
        println!(
            "{:<12} {:>13.1} TB {:>13.0}x",
            c,
            r / 1e12,
            r / contiguous
        );
    }
    println!("reads grow O(n^2/c) — yet Fig. 7 shows compute still dominates.");
    Ok(())
}

/// Fig. 7 — attention prefill time vs chunk size (1M ctx, 70B, 8xH100).
pub fn fig7() -> anyhow::Result<()> {
    println!("\n== Fig. 7: attention time for 1M-token prefill vs chunk size (Llama-3 70B, tp=8) ==");
    let dep = dep70b(8, 1, 1);
    let pm = pm_for(&dep);
    let n = 1_000_000u64;
    let attn_time = |c: u64| -> f64 {
        // attention term only, summed over all chunks
        let mut t = 0.0;
        let mut done = 0u64;
        while done < n {
            let chunk = c.min(n - done);
            let it = pm.stage_time(&BatchShape::prefill_only(chunk, done + chunk), dep.model.n_layers);
            t += it.attn_s;
            done += chunk;
        }
        t
    };
    let base = attn_time(2048);
    println!("{:<10} {:>14} {:>12}", "chunk", "attn time", "vs c=2048");
    for &c in &[32u64, 64, 128, 256, 512, 1024, 2048, 4096] {
        let t = attn_time(c);
        println!(
            "{:<10} {:>14} {:>11.1}%",
            c,
            fmt_duration(t),
            (t / base - 1.0) * 100.0
        );
    }
    println!("paper: chunk 32 adds only ~11% attention overhead vs 2048");
    Ok(())
}

/// Fig. 8 — static vs adaptive chunking Pareto (prefill vs decode latency).
pub fn fig8() -> anyhow::Result<()> {
    println!("\n== Fig. 8: prefill/decode latency trade-off, static chunks vs adaptive (8B, tp=8) ==");
    let ctx = 1_000_000u64;
    let run = |adaptive: bool, static_chunk: u64| -> (f64, f64) {
        let mut dep = dep8b(8, 1, 1);
        dep.scheduler.adaptive_chunking = adaptive;
        dep.scheduler.static_chunk = static_chunk;
        let w = workload::long_plus_decodes(ctx, 8, 1_000, 2_000);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        sim.run();
        let ttft = sim.request(0).unwrap().ttft().unwrap();
        let p95 = sim.metrics.tbt.p95();
        (ttft, p95)
    };
    println!("{:<16} {:>12} {:>16}", "policy", "TTFT", "P95 decode TBT");
    for &c in &[32u64, 128, 512, 2048, 4096] {
        let (ttft, p95) = run(false, c);
        println!(
            "{:<16} {:>12} {:>16}",
            format!("static c={c}"),
            fmt_duration(ttft),
            fmt_duration(p95)
        );
    }
    let (ttft, p95) = run(true, 0);
    println!(
        "{:<16} {:>12} {:>16}",
        "adaptive",
        fmt_duration(ttft),
        fmt_duration(p95)
    );
    println!("adaptive should sit on/below the static Pareto frontier");
    Ok(())
}

/// Fig. 13a — vLLM vs Medha-1D prefill latency across chunk sizes (1M, 8B).
pub fn fig13a() -> anyhow::Result<()> {
    println!("\n== Fig. 13a: prefill latency vs chunk size, vLLM-like vs Medha 1D TP (1M, 8B) ==");
    let dep = dep8b(8, 1, 1);
    let pm = pm_for(&dep);
    let vllm = VllmModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
    println!(
        "{:<10} {:>12} {:>12} {:>8}",
        "chunk", "vLLM-like", "Medha", "ratio"
    );
    for &c in &[128u64, 256, 512, 1024, 2048, 4096] {
        let tv = vllm.prefill_time_chunked(1_000_000, c);
        let tm = pm.prefill_time_monolithic(1_000_000, c);
        println!(
            "{:<10} {:>12} {:>12} {:>7.1}x",
            c,
            fmt_duration(tv),
            fmt_duration(tm),
            tv / tm
        );
    }
    println!("paper: ~6x gap at small chunks from CPU-path optimizations");
    Ok(())
}

/// Fig. 13b — decode latency vs context, vLLM vs Medha (8B, tp=8).
pub fn fig13b() -> anyhow::Result<()> {
    println!("\n== Fig. 13b: decode latency (TBT) vs context, vLLM-like vs Medha 1D TP (8B) ==");
    let dep = dep8b(8, 1, 1);
    let pm = pm_for(&dep);
    let vllm = VllmModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
    println!(
        "{:<10} {:>12} {:>12} {:>8}",
        "context", "vLLM-like", "Medha", "ratio"
    );
    for &ctx in &[100_000u64, 500_000, 1_000_000, 2_000_000] {
        let tv = vllm.decode_tbt(ctx);
        let tm = pm.decode_tbt(ctx);
        println!(
            "{:<10} {:>12} {:>12} {:>7.1}x",
            fmt_tokens(ctx),
            fmt_duration(tv),
            fmt_duration(tm),
            tv / tm
        );
    }
    println!("paper: up to ~3.8-4x lower decode latency for Medha");
    Ok(())
}

/// Fig. 14a — striped attention vs Medha 2D (SPP+TP) prefill, 1M tokens.
pub fn fig14a() -> anyhow::Result<()> {
    println!("\n== Fig. 14a: 1M-token prefill, Striped Attention vs Medha 2D SPP+TP (8B) ==");
    println!(
        "{:<9} {:>7} {:>12} {:>12} {:>12} {:>10}",
        "servers", "gpus", "ring", "striped", "medha-2d", "speedup"
    );
    for &servers in &[1u32, 2, 4, 8, 16] {
        let dep = dep8b(8, servers, 1);
        let pm = pm_for(&dep);
        let cfg = RingConfig { p: servers, tp: 8 };
        let t_ring = ring_prefill_time(&dep.model, &dep.hardware, &cfg, 1_000_000);
        let t_striped = striped_prefill_time(&dep.model, &dep.hardware, &cfg, 1_000_000);
        let t_medha = pm.prefill_time_spp(1_000_000, 4096);
        println!(
            "{:<9} {:>7} {:>12} {:>12} {:>12} {:>9.0}%",
            servers,
            servers * 8,
            fmt_duration(t_ring),
            fmt_duration(t_striped),
            fmt_duration(t_medha),
            (t_striped / t_medha - 1.0) * 100.0
        );
    }
    println!("paper: Medha 64% faster than striped at 16 servers");
    Ok(())
}

/// Fig. 14b — preemption granularity.
pub fn fig14b() -> anyhow::Result<()> {
    println!("\n== Fig. 14b: preemption granularity (head-of-line delay for a new arrival) ==");
    let dep = dep8b(8, 16, 1);
    let pm = pm_for(&dep);
    let cfg = RingConfig { p: 16, tp: 8 };
    let striped = striped_prefill_time(&dep.model, &dep.hardware, &cfg, 1_000_000);
    // Medha: a new arrival waits for the current chunk to clear ONE pipeline
    // stage (dense SPP admits at stage-0 granularity).
    let worst_iter = pm
        .stage_time(
            &BatchShape::prefill_only(4096, 1_000_000),
            dep.model.n_layers / dep.parallel.spp,
        )
        .total();
    println!("striped attention (monolithic prefill): {:>12}", fmt_duration(striped));
    println!("medha (chunked, largest chunk 4096):    {:>12}", fmt_duration(worst_iter));
    println!(
        "ratio: {:.0}x finer-grained (paper: 120s vs 62ms)",
        striped / worst_iter
    );
    Ok(())
}

/// Fig. 15 — SPP scaling heatmap: TTFT vs (context x spp), 8B & 70B.
pub fn fig15() -> anyhow::Result<()> {
    println!("\n== Fig. 15: Medha 2D (SPP+TP) prefill scaling; 'x' = out of memory ==");
    for (name, dep_fn) in [
        ("Llama-3 8B", dep8b as fn(u32, u32, u32) -> DeploymentConfig),
        ("Llama-3 70B", dep70b as fn(u32, u32, u32) -> DeploymentConfig),
    ] {
        println!("\n{name} (tp=8):");
        print!("{:<10}", "context");
        for &spp in &[1u32, 2, 4, 8, 16] {
            print!("{:>12}", format!("spp={spp}"));
        }
        println!();
        for &ctx in &[1_000_000u64, 2_000_000, 5_000_000, 10_000_000] {
            print!("{:<10}", fmt_tokens(ctx));
            for &spp in &[1u32, 2, 4, 8, 16] {
                let dep = dep_fn(8, spp, 1);
                let pm = pm_for(&dep);
                if !pm.fits_memory(ctx) {
                    print!("{:>12}", "x");
                } else {
                    print!("{:>12}", fmt_duration(pm.prefill_time_spp(ctx, 4096)));
                }
            }
            println!();
        }
        // scaling efficiency 1 -> 16 stages at 2M (where both fit)
        let pm1 = pm_for(&dep_fn(8, 1, 1));
        let pm16 = pm_for(&dep_fn(8, 16, 1));
        let ctx = 2_000_000u64;
        if pm1.fits_memory(ctx) && pm16.fits_memory(ctx) {
            let eff = pm1.prefill_time_spp(ctx, 4096) / (16.0 * pm16.prefill_time_spp(ctx, 4096));
            println!("scaling efficiency 1->16 stages @2M: {:.0}% (paper: >80%)", eff * 100.0);
        }
    }
    Ok(())
}

/// Fig. 16 — TBT vs SPP degree (2M ctx).
pub fn fig16() -> anyhow::Result<()> {
    println!("\n== Fig. 16: decode TBT vs SPP degree, 2M context (SPP+TP) ==");
    println!("{:<14} {:>10} {:>10} {:>10} {:>10}", "model", "spp=2", "spp=4", "spp=8", "spp=16");
    for (name, dep_fn) in [
        ("Llama-3 8B", dep8b as fn(u32, u32, u32) -> DeploymentConfig),
        ("Llama-3 70B", dep70b as fn(u32, u32, u32) -> DeploymentConfig),
    ] {
        print!("{:<14}", name);
        for &spp in &[2u32, 4, 8, 16] {
            let pm = pm_for(&dep_fn(8, spp, 1));
            if pm.fits_memory(2_000_000) {
                print!("{:>10}", fmt_duration(pm.decode_tbt(2_000_000)));
            } else {
                print!("{:>10}", "x");
            }
        }
        println!();
    }
    println!("paper: TBT only marginally affected by pipeline depth");
    Ok(())
}

/// Fig. 17 — TBT vs KVP degree (4M & 10M).
pub fn fig17() -> anyhow::Result<()> {
    println!("\n== Fig. 17: decode TBT vs KVP degree (3D parallel, decode-only batches) ==");
    println!(
        "{:<14} {:<9} {:>10} {:>10} {:>10} {:>12}",
        "model", "context", "kvp=1", "kvp=2", "kvp=4", "1->4 gain"
    );
    for (name, dep_fn, spp) in [
        ("Llama-3 8B", dep8b as fn(u32, u32, u32) -> DeploymentConfig, 4u32),
        ("Llama-3 70B", dep70b as fn(u32, u32, u32) -> DeploymentConfig, 8u32),
    ] {
        for &ctx in &[4_000_000u64, 10_000_000] {
            print!("{:<14} {:<9}", name, fmt_tokens(ctx));
            let mut t1 = f64::NAN;
            let mut t4 = f64::NAN;
            for &kvp in &[1u32, 2, 4] {
                let pm = pm_for(&dep_fn(8, spp, kvp));
                let t = pm.decode_tbt(ctx);
                if kvp == 1 {
                    t1 = t;
                }
                if kvp == 4 {
                    t4 = t;
                }
                print!("{:>10}", fmt_duration(t));
            }
            println!("{:>11.1}x", t1 / t4);
        }
    }
    println!("paper: 1.7x @4M -> 2.5x @10M for 8B (Amdahl-limited, grows with ctx)");
    Ok(())
}

/// Fig. 18 — TTFT vs P95 TBT trade-off cloud (mixed batching).
pub fn fig18() -> anyhow::Result<()> {
    println!("\n== Fig. 18: TTFT vs P95 TBT trade-off (8B, tp=4, spp=4; chunk x kvp x ctx) ==");
    println!(
        "{:<8} {:<6} {:<7} {:>12} {:>14}",
        "ctx", "kvp", "chunk", "TTFT", "P95 TBT"
    );
    for &ctx in &[1_000_000u64, 2_000_000, 4_000_000] {
        for &kvp in &[1u32, 2, 4] {
            for &chunk in &[32u64, 64, 128, 256] {
                let mut dep = dep8b(4, 4, kvp);
                dep.scheduler.adaptive_chunking = false;
                dep.scheduler.static_chunk = chunk;
                dep.scheduler.kvp_onboard_threshold = (ctx / kvp as u64).max(1);
                let w = workload::long_plus_decodes(ctx, 4, 1_000, 600);
                let mut sim = Simulation::new(dep, w, SimOptions::default());
                sim.run();
                let ttft = sim.request(0).unwrap().ttft().unwrap();
                let p95 = sim.metrics.tbt.p95();
                println!(
                    "{:<8} {:<6} {:<7} {:>12} {:>14}",
                    fmt_tokens(ctx),
                    kvp,
                    chunk,
                    fmt_duration(ttft),
                    fmt_duration(p95)
                );
            }
        }
    }
    println!("bigger chunks: lower TTFT / higher TBT; higher kvp helps both at long ctx");
    Ok(())
}

/// Fig. 19 — GPUs over time: dynamic KVP onboarding during a 2M prefill.
pub fn fig19() -> anyhow::Result<()> {
    println!("\n== Fig. 19: dynamic KVP growth, 2M-token request (8B; tp=8, spp=4... kvp<=4) ==");
    let mut dep = dep8b(8, 4, 4);
    dep.scheduler.kvp_onboard_threshold = 512_000;
    let w = workload::single_long(2_000_000, 16);
    let mut sim = Simulation::new(dep, w, SimOptions::default());
    sim.run();
    println!("{:>10} {:>8} {:>14}", "time", "gpus", "iter time");
    let iters = &sim.metrics.iters;
    let step = (iters.len() / 12).max(1);
    for rec in iters.iter().step_by(step) {
        println!(
            "{:>10} {:>8} {:>14}",
            fmt_duration(rec.t),
            rec.active_gpus,
            fmt_duration(rec.dur_s)
        );
    }
    println!(
        "onboard events: {:?}",
        sim.kvp_onboard_log()
            .iter()
            .map(|(t, _, g)| format!("g{g}@{}", fmt_duration(*t)))
            .collect::<Vec<_>>()
    );
    println!("paper: staircase 32 -> 128 GPUs with near-constant iteration time");
    Ok(())
}

/// Fig. 20 — MFU of 2D SPP+TP prefill.
pub fn fig20() -> anyhow::Result<()> {
    println!("\n== Fig. 20: Model FLOPs Utilization, Medha 2D SPP+TP prefill ==");
    println!("{:<10} {:>9} {:>9} {:>9} {:>9}", "context", "spp=1", "spp=2", "spp=4", "spp=8");
    for &ctx in &[1_000_000u64, 2_000_000, 4_000_000] {
        print!("{:<10}", fmt_tokens(ctx));
        for &spp in &[1u32, 2, 4, 8] {
            let dep = dep8b(8, spp, 1);
            let pm = pm_for(&dep);
            if !pm.fits_memory(ctx) {
                print!("{:>9}", "x");
                continue;
            }
            let t = pm.prefill_time_spp(ctx, 4096);
            let flops = crate::perfmodel::counts::prefill_total_flops(&dep.model, ctx);
            let mfu = flops / (t * dep.hardware.peak_flops * dep.total_gpus() as f64);
            print!("{:>8.0}%", mfu * 100.0);
        }
        println!();
    }
    println!("paper: 50-60%+ MFU, decreasing with parallelism degree");
    Ok(())
}

/// Fig. 21 — MBU of 2D KVP+TP decode.
pub fn fig21() -> anyhow::Result<()> {
    println!("\n== Fig. 21: Model Bandwidth Utilization, Medha 2D KVP+TP decode ==");
    println!("{:<10} {:>9} {:>9} {:>9}", "context", "kvp=1", "kvp=2", "kvp=4");
    for &ctx in &[1_000_000u64, 2_000_000, 4_000_000, 10_000_000] {
        print!("{:<10}", fmt_tokens(ctx));
        for &kvp in &[1u32, 2, 4] {
            let dep = dep8b(8, 1, kvp);
            let pm = pm_for(&dep);
            let t = pm.decode_tbt(ctx);
            let m = &dep.model;
            let bytes = (crate::perfmodel::counts::attn_read_bytes(m, ctx)
                + crate::perfmodel::counts::weight_bytes_per_layer(m) * kvp as f64)
                * m.n_layers as f64;
            let mbu = bytes / (t * dep.hardware.hbm_bw * dep.total_gpus() as f64);
            print!("{:>8.0}%", mbu * 100.0);
        }
        println!();
    }
    println!("paper: up to ~92% MBU at kvp=1, decreasing with parallelism");
    Ok(())
}

/// Fig. 22 — P95 mixed-batch execution time vs (batched decodes x chunk).
pub fn fig22() -> anyhow::Result<()> {
    println!("\n== Fig. 22: mixed-batch execution time, 1M prefill + N decodes of 1K (8B, tp=8) ==");
    let dep = dep8b(8, 1, 1);
    let pm = pm_for(&dep);
    print!("{:<8}", "chunk");
    for &n in &[0usize, 8, 32, 64, 128] {
        print!("{:>12}", format!("{n} decodes"));
    }
    println!();
    for &c in &[512u64, 1024, 2048, 4096] {
        print!("{:<8}", c);
        let alone = pm
            .iteration_time(&BatchShape {
                prefills: vec![PrefillWork { chunk: c, kv_len: 1_000_000 }],
                decodes: vec![],
            })
            .total();
        for &n in &[0usize, 8, 32, 64, 128] {
            let b = BatchShape {
                prefills: vec![PrefillWork { chunk: c, kv_len: 1_000_000 }],
                decodes: (0..n)
                    .map(|_| crate::perfmodel::DecodeWork { kv_len: 1_000 })
                    .collect(),
            };
            let t = pm.iteration_time(&b).total();
            print!("{:>11}{}", fmt_duration(t), if t / alone < 1.05 { " " } else { "*" });
        }
        println!();
    }
    println!("(* = >5% over running the chunk alone; paper: <=5% up to 128 decodes)");
    Ok(())
}

/// Section 6.2 text claim: chunk 32 vs 4096 end-to-end prefill ratio ~1.75x.
pub fn sec62() -> anyhow::Result<()> {
    println!("\n== sec 6.2: end-to-end prefill, chunk 32 vs 4096 (8B, 1M tokens, tp=8) ==");
    let pm = pm_for(&dep8b(8, 1, 1));
    let t32 = pm.prefill_time_monolithic(1_000_000, 32);
    let t4096 = pm.prefill_time_monolithic(1_000_000, 4096);
    println!(
        "chunk 32: {}   chunk 4096: {}   ratio: {:.2}x (paper: 1.75x)",
        fmt_duration(t32),
        fmt_duration(t4096),
        t32 / t4096
    );
    Ok(())
}

/// Fig. 9 ablation: dense SPP schedule vs conventional micro-batch PP.
pub fn fig9() -> anyhow::Result<()> {
    println!("\n== Fig. 9 (ablation): dense SPP vs conventional PP prefill schedule ==");
    use crate::coordinator::{conventional_pp_prefill_schedule, spp_prefill_schedule};
    let dep = dep8b(8, 8, 1);
    let pm = pm_for(&dep);
    let layers_per_stage = dep.model.n_layers / dep.parallel.spp;
    for &ctx in &[250_000u64, 1_000_000, 4_000_000] {
        let chunk = 4096u64;
        let n_chunks = ctx.div_ceil(chunk) as usize;
        let stage_t = |i: usize| {
            pm.stage_time(
                &BatchShape::prefill_only(chunk, (i as u64 + 1) * chunk),
                layers_per_stage,
            )
            .total()
        };
        let hop = pm.stage_hop_s(chunk);
        let (dense, _) = spp_prefill_schedule(n_chunks, 8, stage_t, hop);
        let (conv, _) = conventional_pp_prefill_schedule(n_chunks, 8, stage_t, hop);
        println!(
            "ctx {:<6} dense {:>10}  conventional {:>10}  speedup {:.1}x",
            fmt_tokens(ctx),
            fmt_duration(dense),
            fmt_duration(conv),
            conv / dense
        );
    }
    println!("(dense admission is the SPP insight — near p_spp x for many chunks)");
    Ok(())
}

/// Section 2.4 / 7 ablation: colocated Medha vs prefill-decode disaggregation.
pub fn disagg() -> anyhow::Result<()> {
    println!("\n== Disaggregation (ablation): colocated Medha vs prefill/decode split (8B) ==");
    use crate::baselines::DisaggModel;
    let dep = dep8b(8, 8, 1);
    let pm = pm_for(&dep);
    let dm = DisaggModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
    println!(
        "{:<10} {:>12} {:>14} {:>14} {:>14}",
        "context", "medha TTFT", "disagg prefill", "KV transfer", "disagg TTFT"
    );
    for &ctx in &[128_000u64, 1_000_000, 4_000_000] {
        let l = dm.latency(ctx, 4096);
        println!(
            "{:<10} {:>12} {:>14} {:>14} {:>14}",
            fmt_tokens(ctx),
            fmt_duration(pm.prefill_time_spp(ctx, 4096)),
            fmt_duration(l.prefill_s),
            fmt_duration(l.transfer_s),
            fmt_duration(l.ttft_s())
        );
    }
    println!("online: the KV handoff penalizes long contexts (paper section 2.4);");
    println!("offline context-building amortizes it (paper section 7).");
    Ok(())
}

/// KVP onboarding-threshold ablation (DESIGN.md §6).
pub fn kvpthresh() -> anyhow::Result<()> {
    println!("\n== KVP onboarding threshold (ablation): 2M request, 8B, tp=8 spp=4 kvp=4 ==");
    println!(
        "{:<12} {:>8} {:>12} {:>14} {:>12}",
        "threshold", "groups", "TTFT", "P95 iter time", "decode TBT"
    );
    for &thr in &[250_000u64, 500_000, 1_000_000, 2_000_000] {
        let mut dep = dep8b(8, 4, 4);
        dep.scheduler.kvp_onboard_threshold = thr;
        let w = workload::single_long(2_000_000, 64);
        let mut sim = Simulation::new(dep, w, SimOptions::default());
        sim.run();
        let groups = sim.kvp_onboard_log().len();
        let ttft = sim.request(0).unwrap().ttft().unwrap();
        let mut durs = crate::util::stats::Samples::new();
        for r in &sim.metrics.iters {
            durs.add(r.dur_s);
        }
        let tbt = sim.request(0).unwrap().tbt_samples.iter().copied().sum::<f64>()
            / sim.request(0).unwrap().tbt_samples.len().max(1) as f64;
        println!(
            "{:<12} {:>8} {:>12} {:>14} {:>12}",
            fmt_tokens(thr),
            groups,
            fmt_duration(ttft),
            fmt_duration(durs.p95()),
            fmt_duration(tbt)
        );
    }
    println!("smaller thresholds onboard more groups sooner: lower decode TBT,");
    println!("more GPUs consumed earlier (the Fig. 19 trade-off).");
    Ok(())
}

/// Scheduling-policy comparison (section 5): FCFS / SRPT / EDF / LARS on
/// the heterogeneous convoy trace, interactive and document requests
/// sharing one replica's queue.
pub fn sched() -> anyhow::Result<()> {
    use crate::coordinator::SchedPolicyKind;

    println!("\n== sched: policy comparison on the convoy trace (8B, tp=8, one replica) ==");
    let cfg = workload::ConvoyConfig::default();
    let w = workload::convoy(&cfg, 42);
    let n_long = w.iter().filter(|r| cfg.is_long(r.prompt_len)).count();
    println!(
        "{} requests over {:.0}s: {} interactive ({} tok) + {} documents ({})",
        w.len(),
        cfg.horizon_s,
        w.len() - n_long,
        cfg.short_prompt,
        n_long,
        fmt_tokens(cfg.long_prompt)
    );
    println!(
        "{:<6} {:>12} {:>12} {:>12} {:>8} {:>9} {:>10} {:>9}",
        "policy", "short p50", "short p99", "doc max", "attain", "goodput", "preempts", "TTFT p95"
    );
    for kind in SchedPolicyKind::ALL {
        let mut sim = crate::sim::run_convoy_scenario(kind, &cfg, 42);
        let (mut short, mut docs) = crate::sim::convoy_ttft_split(&sim, &cfg);
        let doc_max = docs.max();
        let s = sim.metrics.summary();
        println!(
            "{:<6} {:>12} {:>12} {:>12} {:>7.0}% {:>7.2}/s {:>10} {:>9}",
            kind.name(),
            fmt_duration(short.median()),
            fmt_duration(short.p99()),
            fmt_duration(doc_max),
            s.ttft_attainment * 100.0,
            s.goodput_rps,
            s.preemptions,
            fmt_duration(s.ttft_p95)
        );
    }
    println!("LARS: bounded short-request tails (no convoy) without starving documents;");
    println!("SRPT starves documents under load, EDF re-creates the convoy once one is late.");

    // ---- policy-aware KVP routing vs blind round-robin (section 7) -------
    use crate::coordinator::RoutingMode;
    println!("\n== sched/kvp: routing on the kvp_convoy trace (8B, tp=8, 4 KVP groups) ==");
    let kcfg = workload::KvpConvoyConfig::default();
    let kw = workload::kvp_convoy(&kcfg, 42);
    let n_docs = kw.iter().filter(|r| kcfg.is_doc(r.prompt_len)).count();
    println!(
        "{} requests: {} interactive ({} tok) + {} overlapping documents ({}, sharded 2-way)",
        kw.len(),
        kw.len() - n_docs,
        kcfg.short_prompt,
        n_docs,
        fmt_tokens(kcfg.doc_prompt)
    );
    println!(
        "{:<6} {:<12} {:>11} {:>11} {:>11} {:>8} {:>7} {:>16}",
        "policy", "routing", "short p50", "short p99", "doc max", "attain", "yields", "group util"
    );
    let mut rr_p99 = f64::NAN;
    let mut routed_p99 = f64::NAN;
    for (kind, routing) in [
        (crate::coordinator::SchedPolicyKind::Fcfs, RoutingMode::Blind),
        (crate::coordinator::SchedPolicyKind::Lars, RoutingMode::RoundRobin),
        (crate::coordinator::SchedPolicyKind::Lars, RoutingMode::Routed),
    ] {
        let mut sim = crate::sim::run_kvp_convoy_scenario(kind, routing, &kcfg, 42);
        let (mut short, mut docs) = crate::sim::kvp_convoy_ttft_split(&sim, &kcfg);
        let p99 = short.p99();
        if kind == crate::coordinator::SchedPolicyKind::Lars {
            match routing {
                RoutingMode::RoundRobin => rr_p99 = p99,
                RoutingMode::Routed => routed_p99 = p99,
                RoutingMode::Blind => {}
            }
        }
        let util = sim.metrics.group_utilization();
        let util_str = util
            .iter()
            .map(|u| format!("{:.0}%", u * 100.0))
            .collect::<Vec<_>>()
            .join("/");
        let s = sim.metrics.summary();
        println!(
            "{:<6} {:<12} {:>11} {:>11} {:>11} {:>7.0}% {:>7} {:>16}",
            kind.name(),
            routing.name(),
            fmt_duration(short.median()),
            fmt_duration(p99),
            fmt_duration(docs.max()),
            s.ttft_attainment * 100.0,
            s.active_preemptions,
            util_str
        );
    }
    if rr_p99.is_finite() && routed_p99 > 0.0 {
        println!(
            "routed LARS vs blind round-robin, short p99 TTFT: {:.1}x better",
            rr_p99 / routed_p99
        );
    }
    println!("routed: shorts steered off the sharding groups (idle groups = serving pool);");
    println!("active documents yield at chunk boundaries to fresher urgent documents.");
    Ok(())
}

/// Robustness harness (not a paper figure): recovery cost when 1 of 4 KVP
/// groups crashes mid-run under the kvp_convoy trace. Medha's chunk-boundary
/// re-prefill (surviving shards keep their KV; only the lost ranges are
/// recomputed) is compared for LARS vs FCFS, and against a disaggregated
/// restart where the whole context is re-prefilled and the KV cache
/// re-shipped across pools (`baselines/disagg.rs`).
/// Prefix-aware KV reuse on the multi-turn chat trace: hash-consed
/// ref-counted block chains serve each turn's shared history from cache.
/// Three arms on the identical trace: reuse with cache-affinity routing
/// (placement steered to the chain's owner group), reuse under blind
/// placement (grants only on coincidental landings), and the no-reuse
/// control. The table reports what the tentpole claims: hit rate, prefill
/// tokens actually executed, and the background shorts' p99 TTFT.
pub fn reuse() -> anyhow::Result<()> {
    use crate::coordinator::{RoutingMode, SchedPolicyKind};

    println!("\n== reuse: multi-turn sessions + convoy shorts (8B, tp=8, 4 KVP groups, LARS) ==");
    let cfg = workload::MultiTurnConfig::default();
    println!(
        "{} sessions x {} turns over a {} system prompt, {} background shorts/s",
        cfg.n_sessions, cfg.turns, fmt_tokens(cfg.sys_prompt), cfg.shorts_rate_per_s
    );
    println!(
        "{:<16} {:<9} {:>6} {:>10} {:>8} {:>12} {:>8} {:>11} {:>11}",
        "arm", "routing", "done", "hit toks", "hit %", "prefill toks", "blocks", "short p99", "turn p95"
    );
    for (label, routing, on) in [
        ("reuse+affinity", RoutingMode::Routed, true),
        ("reuse+blind", RoutingMode::Blind, true),
        ("no-reuse", RoutingMode::Routed, false),
    ] {
        let mut sim =
            crate::sim::run_multiturn_scenario(SchedPolicyKind::Lars, routing, &cfg, 42, on);
        let s = sim.metrics.summary();
        let (mut short, mut turns) = crate::sim::multiturn_ttft_split(&sim, &cfg);
        println!(
            "{:<16} {:<9} {:>6} {:>10} {:>7.0}% {:>12} {:>8} {:>11} {:>11}",
            label,
            sim.dep.scheduler.routing.name(),
            s.finished,
            fmt_tokens(s.prefix_hit_tokens),
            s.prefix_hit_rate * 100.0,
            fmt_tokens(sim.metrics.prefill_tokens),
            s.blocks_shared,
            fmt_duration(short.p99()),
            fmt_duration(turns.p95())
        );
    }
    println!(
        "(affinity must win on hit rate; reuse must not cost the shorts their p99 — \
         the `multiturn_reuse_saves_prefill_without_hurting_shorts` test asserts it)"
    );
    Ok(())
}

pub fn faults() -> anyhow::Result<()> {
    use crate::baselines::DisaggModel;
    use crate::config::{FaultEvent, FaultKind, FaultPlan};
    use crate::coordinator::{RoutingMode, SchedPolicyKind};

    println!("\n== faults: 1-of-4 KVP group crash under the convoy trace (8B, tp=8) ==");
    let kcfg = workload::KvpConvoyConfig::default();

    // Probe run (fault-free) to find a moment when document shards are
    // resident: crash just after a mid-run KVP onboard event, targeting the
    // group that onboarded — deterministic, but robust to perf-model drift.
    let probe = crate::sim::run_kvp_convoy_scenario_with_faults(
        SchedPolicyKind::Lars,
        RoutingMode::Routed,
        &kcfg,
        42,
        FaultPlan::default(),
    );
    let log = probe.kvp_onboard_log();
    anyhow::ensure!(!log.is_empty(), "probe run never sharded a document");
    let (t_mid, _, victim) = log[log.len() / 2];
    let crash_t = t_mid + 0.5;
    println!(
        "crash: group {victim} of 4 at t={} ({} docs of {} sharded across the fleet; \
         lost shards resume from the last surviving chunk boundary)",
        fmt_duration(crash_t),
        kcfg.n_docs,
        fmt_tokens(kcfg.doc_prompt)
    );
    println!(
        "{:<6} {:<12} {:<6} {:>6} {:>9} {:>7} {:>11} {:>10} {:>10}",
        "policy", "routing", "fault", "done", "goodput", "shards", "re-prefill", "rec p50", "rec p95"
    );
    for (kind, routing) in [
        (SchedPolicyKind::Fcfs, RoutingMode::RoundRobin),
        (SchedPolicyKind::Lars, RoutingMode::Routed),
    ] {
        for crashed in [false, true] {
            let plan = if crashed {
                FaultPlan {
                    events: vec![FaultEvent {
                        t_s: crash_t,
                        group: Some(victim),
                        kind: FaultKind::Crash,
                    }],
                }
            } else {
                FaultPlan::default()
            };
            let mut sim =
                crate::sim::run_kvp_convoy_scenario_with_faults(kind, routing, &kcfg, 42, plan);
            let s = sim.metrics.summary();
            println!(
                "{:<6} {:<12} {:<6} {:>6} {:>8.2}/s {:>7} {:>11} {:>10} {:>10}",
                kind.name(),
                routing.name(),
                if crashed { "crash" } else { "none" },
                s.finished,
                s.goodput_rps,
                s.shards_lost,
                fmt_tokens(s.reprefill_tokens),
                fmt_duration(s.recovery_wait_p50),
                fmt_duration(s.recovery_wait_p95)
            );
        }
    }

    // Analytic recovery cost for ONE document losing its back-half shard:
    // Medha recomputes only the lost range (the surviving prefix KV is
    // reused, so the cost is full(n) - full(n/2)); a disaggregated restart
    // re-prefills the whole context AND re-ships the KV cache.
    let dep = dep8b(8, 1, 4);
    let pm = pm_for(&dep);
    let dm = DisaggModel::new(dep.model.clone(), dep.hardware.clone(), dep.parallel);
    let n = kcfg.doc_prompt;
    let medha_s = pm.prefill_time_spp(n, 4096) - pm.prefill_time_spp(n / 2, 4096);
    let l = dm.latency(n, 4096);
    println!(
        "per-document recovery, {} context, back-half shard lost:",
        fmt_tokens(n)
    );
    println!(
        "  medha boundary re-prefill: {} ({} recomputed)",
        fmt_duration(medha_s),
        fmt_tokens(n / 2)
    );
    println!(
        "  disagg full restart:       {} ({} re-prefill + {} KV re-transfer) — {:.1}x worse",
        fmt_duration(l.prefill_s + l.transfer_s),
        fmt_duration(l.prefill_s),
        fmt_duration(l.transfer_s),
        (l.prefill_s + l.transfer_s) / medha_s
    );
    println!("every request completes; degradation shows up as re-prefill work and");
    println!("recovery wait, not dropped requests (no request left behind).");
    Ok(())
}

/// Concurrent evaluation sweep (not a paper figure): the full policy ×
/// routing × load grid on the kvp_convoy trace, one independent sim per
/// threadpool worker, reduced to the goodput vs short-p99-TTFT vs
/// deferrals Pareto frontier (see `sim::sweep`). Honors
/// `MEDHA_BENCH_SMOKE` with the down-scaled grid.
pub fn sweep() -> anyhow::Result<()> {
    use crate::sim::sweep::{print_table, run_sweep, SweepConfig};

    println!("\n== sweep: policy x routing x load Pareto frontier (8B, tp=8, 4 KVP groups) ==");
    let mut cfg = if std::env::var("MEDHA_BENCH_SMOKE").is_ok() {
        SweepConfig::smoke()
    } else {
        SweepConfig::default()
    };
    // Worker count never changes results (per-cell seeds, canonical-order
    // reduction) — only how fast the table arrives.
    cfg.threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8);
    let (outcomes, wall_s) = run_sweep(&cfg);
    print_table(&outcomes, wall_s, cfg.threads);
    Ok(())
}

/// Overload harness (online serving mode): goodput vs offered load on the
/// sustained-overcommit open-loop scenario, sweeping the arrival rate from
/// half to triple the base rate. Two stacks: LARS/routed behind the
/// protective admission gate (token buckets paced to the base rate, bounded
/// queues, SLO-feedback shedding) vs FCFS/blind with the gate wide open.
/// The gated stack's goodput plateaus near the paced rate — excess load is
/// shed at the door — while the ungated stack's queues grow without bound
/// and deadline attainment collapses. Honors `MEDHA_BENCH_SMOKE`.
pub fn overload() -> anyhow::Result<()> {
    use crate::coordinator::{AdmissionConfig, RoutingMode, SchedPolicyKind};
    use crate::sim::serve::run_serve_scenario;
    use crate::workload::openloop::{OpenLoopConfig, Scenario};

    println!("\n== overload: goodput vs offered load, open-loop overcommit (8B, tp=8, 4 KVP groups) ==");
    let base = if std::env::var("MEDHA_BENCH_SMOKE").is_ok() {
        OpenLoopConfig::smoke()
    } else {
        OpenLoopConfig::default()
    };
    println!(
        "base rate {:.1} req/s over {}; gated stack pacing: token buckets at the base rate",
        base.base_rate_per_s,
        fmt_duration(base.horizon_s)
    );
    println!(
        "{:<20} {:>6} {:>9} {:>9} {:>8} {:>14} {:>14}",
        "stack", "load", "offered", "goodput", "attain", "shed (s/d)", "rejected (s/d)"
    );
    for (label, kind, routing, gated) in [
        ("lars/routed gated", SchedPolicyKind::Lars, RoutingMode::Routed, true),
        ("fcfs/blind ungated", SchedPolicyKind::Fcfs, RoutingMode::Blind, false),
    ] {
        for &mult in &[0.5f64, 1.0, 1.5, 2.0, 3.0] {
            let cfg = OpenLoopConfig {
                overcommit_mult: mult,
                ..base.clone()
            };
            let adm = if gated {
                AdmissionConfig::protective(base.base_rate_per_s, base.doc_prompt)
            } else {
                AdmissionConfig::default()
            };
            let mut serve =
                run_serve_scenario(Scenario::Overcommit, &cfg, kind, routing, adm, 42);
            let offered = serve.n_offered();
            let s = serve.sim.metrics.summary();
            println!(
                "{:<20} {:>5.1}x {:>9} {:>7.2}/s {:>7.0}% {:>14} {:>14}",
                label,
                mult,
                offered,
                s.goodput_rps,
                s.ttft_attainment * 100.0,
                format!("{} ({}/{})", s.n_shed, s.n_shed_short, s.n_shed_doc),
                format!(
                    "{} ({}/{})",
                    s.n_rejected_queue_full, s.n_rejected_short, s.n_rejected_doc
                )
            );
        }
    }
    println!("gated: goodput plateaus at the paced rate as offered load grows — excess is");
    println!("shed/rejected at the door, so admitted requests keep their SLOs (graceful");
    println!("degradation); ungated: the backlog grows and attainment collapses instead.");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_run() {
        // Smoke: every harness executes without error (output to stdout).
        // The slow sim-backed ones are exercised in tests/sim_figures.rs.
        for f in ["table1", "fig5a", "fig5b", "fig6", "fig7", "fig13a", "fig13b", "fig14a",
                  "fig14b", "fig15", "fig16", "fig17", "fig20", "fig21", "fig22", "sec62", "fig1",
                  "fig9", "disagg"] {
            run(f).unwrap_or_else(|e| panic!("{f}: {e}"));
        }
    }

    #[test]
    fn unknown_figure_errors() {
        assert!(run("fig99").is_err());
    }
}
