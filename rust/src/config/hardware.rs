//! Hardware configs: per-GPU roofline numbers and interconnect topology.
//! The H100/DGX presets carry the constants the perf model calibrates
//! against (paper section 6.1 testbed).

use crate::util::json::Json;

/// One accelerator's roofline.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    pub name: String,
    /// Dense matmul peak at serving precision (fp16/bf16), FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_capacity: u64,
    /// GPUs that share the fast intra-node domain (NVLink).
    pub gpus_per_node: u32,
    pub intra_node: InterconnectConfig,
    pub inter_node: InterconnectConfig,
    /// Fixed CPU/framework overhead per batch iteration, seconds. The paper's
    /// platform optimizations (section 5: ZeroMQ, GPU-side page tables, CUDA
    /// graphs) exist precisely to shrink this; baselines model vLLM's larger
    /// value (Fig. 13).
    pub cpu_overhead_s: f64,
    /// Fixed per-attention-kernel cost per layer (launch + tile/wave
    /// quantization). This is what makes tiny prefill chunks cost ~11%
    /// extra attention time over a long prefill (Fig. 7).
    pub attn_fixed_s: f64,
    /// Achievable fraction of peak for large dense GEMMs (efficiency cap).
    pub gemm_efficiency: f64,
    /// Achievable fraction of peak HBM bandwidth for streaming reads.
    pub mem_efficiency: f64,
}

/// A link between workers.
#[derive(Debug, Clone, PartialEq)]
pub struct InterconnectConfig {
    /// Per-GPU-pair bandwidth, bytes/s (unidirectional effective).
    pub bandwidth: f64,
    /// Per-message latency, seconds.
    pub latency_s: f64,
}

impl HardwareConfig {
    /// NVIDIA H100 SXM in a DGX-H100 node, InfiniBand across nodes
    /// (paper section 6.1: NVLink 4.0 900 GB/s bidir, IB 50 GB/s per pair).
    pub fn dgx_h100() -> HardwareConfig {
        HardwareConfig {
            name: "dgx-h100".into(),
            peak_flops: 989e12,   // H100 SXM bf16 dense
            hbm_bw: 3.35e12,      // 3.35 TB/s
            hbm_capacity: 80 * (1u64 << 30),
            gpus_per_node: 8,
            intra_node: InterconnectConfig {
                bandwidth: 450e9, // NVLink4: 900 GB/s bidirectional -> 450 each way
                latency_s: 3e-6,
            },
            inter_node: InterconnectConfig {
                bandwidth: 50e9, // paper: 50 GBps per GPU pair
                latency_s: 10e-6,
            },
            // Medha's optimized per-iteration overhead (section 5: ZeroMQ,
            // GPU-side page tables, CUDA graphs). The vLLM-like baseline
            // (rust/src/baselines) uses ~4 ms, matching Fig. 13's gap.
            cpu_overhead_s: 0.3e-3,
            attn_fixed_s: 10e-6,
            gemm_efficiency: 0.75,
            mem_efficiency: 0.92,
        }
    }

    /// The local CPU device the real engine runs on (used only for sanity
    /// scaling of e2e expectations; measured, not modeled).
    pub fn cpu_dev() -> HardwareConfig {
        HardwareConfig {
            name: "cpu".into(),
            peak_flops: 2e11,
            hbm_bw: 3e10,
            hbm_capacity: 16 * (1u64 << 30),
            gpus_per_node: 1,
            intra_node: InterconnectConfig {
                bandwidth: 1e10,
                latency_s: 1e-6,
            },
            inter_node: InterconnectConfig {
                bandwidth: 1e9,
                latency_s: 50e-6,
            },
            cpu_overhead_s: 1e-4,
            attn_fixed_s: 1e-6,
            gemm_efficiency: 0.5,
            mem_efficiency: 0.5,
        }
    }

    pub fn preset(name: &str) -> anyhow::Result<HardwareConfig> {
        match name {
            "dgx-h100" | "h100" => Ok(HardwareConfig::dgx_h100()),
            "cpu" => Ok(HardwareConfig::cpu_dev()),
            other => anyhow::bail!("unknown hardware preset '{other}'"),
        }
    }

    pub fn from_json(j: &Json) -> anyhow::Result<HardwareConfig> {
        if let Some(p) = j.get("preset").and_then(|x| x.as_str()) {
            let mut h = HardwareConfig::preset(p)?;
            if let Some(x) = j.get("cpu_overhead_s").and_then(|x| x.as_f64()) {
                h.cpu_overhead_s = x;
            }
            return Ok(h);
        }
        let link = |v: &Json| -> anyhow::Result<InterconnectConfig> {
            Ok(InterconnectConfig {
                bandwidth: v.req_f64("bandwidth")?,
                latency_s: v.req_f64("latency_s")?,
            })
        };
        Ok(HardwareConfig {
            name: j.req_str("name")?.to_string(),
            peak_flops: j.req_f64("peak_flops")?,
            hbm_bw: j.req_f64("hbm_bw")?,
            hbm_capacity: j.req_u64("hbm_capacity")?,
            gpus_per_node: j.req_u64("gpus_per_node")? as u32,
            intra_node: link(j.req("intra_node")?)?,
            inter_node: link(j.req("inter_node")?)?,
            cpu_overhead_s: j.req_f64("cpu_overhead_s")?,
            attn_fixed_s: j.get("attn_fixed_s").and_then(|x| x.as_f64()).unwrap_or(10e-6),
            gemm_efficiency: j.get("gemm_efficiency").and_then(|x| x.as_f64()).unwrap_or(0.75),
            mem_efficiency: j.get("mem_efficiency").and_then(|x| x.as_f64()).unwrap_or(0.9),
        })
    }

    /// Effective sustained matmul throughput.
    pub fn sustained_flops(&self) -> f64 {
        self.peak_flops * self.gemm_efficiency
    }

    /// Effective sustained memory bandwidth.
    pub fn sustained_bw(&self) -> f64 {
        self.hbm_bw * self.mem_efficiency
    }

    /// Link between two workers given their node placement.
    pub fn link(&self, same_node: bool) -> &InterconnectConfig {
        if same_node {
            &self.intra_node
        } else {
            &self.inter_node
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_constants_sane() {
        let h = HardwareConfig::dgx_h100();
        assert!(h.peak_flops > 9e14);
        assert!(h.hbm_bw > 3e12);
        assert_eq!(h.gpus_per_node, 8);
        assert!(h.intra_node.bandwidth > h.inter_node.bandwidth);
    }

    #[test]
    fn roofline_ridge_point() {
        // H100 ridge point (FLOPs/byte) should be in the hundreds — this is
        // why prefill chunks of ~tens of tokens already saturate compute
        // with GQA (paper section 4.1).
        let h = HardwareConfig::dgx_h100();
        let ridge = h.sustained_flops() / h.sustained_bw();
        assert!((100.0..400.0).contains(&ridge), "{ridge}");
    }

    #[test]
    fn preset_round_trip_json() {
        let j = Json::parse(r#"{"preset": "dgx-h100", "cpu_overhead_s": 0.002}"#).unwrap();
        let h = HardwareConfig::from_json(&j).unwrap();
        assert_eq!(h.name, "dgx-h100");
        assert!((h.cpu_overhead_s - 0.002).abs() < 1e-12);
    }
}
