//! Deterministic fault-injection plans for the elastic KVP fleet.
//!
//! A [`FaultPlan`] schedules group lifecycle events at precise simulation
//! times; the simulator applies every event whose time has been reached
//! before admitting arrivals, so a plan replays bit-identically run after
//! run. An empty plan is the fault-free fleet and changes nothing.
//!
//! # JSON schema (`simulate --faults plan.json`)
//!
//! ```json
//! {
//!   "events": [
//!     {"t_s": 12.0, "kind": "crash",    "group": 1},
//!     {"t_s": 20.0, "kind": "join",     "group": 1, "warmup_s": 2.0},
//!     {"t_s":  8.0, "kind": "drain",    "group": 2},
//!     {"t_s":  5.0, "kind": "slowdown", "group": 0, "factor": 1.5,
//!      "until_s": 9.0}
//!   ]
//! }
//! ```
//!
//! Per event: `t_s` (required) is the simulation time in seconds; `kind`
//! (required) is one of `crash` / `join` / `drain` / `slowdown`; `group`
//! names the target group id — required for everything except `join`,
//! where omitting it (or naming a slot past the fleet end) grows the fleet
//! by a new group instead of reviving a crashed slot. `join` accepts an
//! optional `warmup_s` (default 0): the group is `Joining` — announced but
//! excluded from placement — for that long before activating. `slowdown`
//! requires `factor >= 1` (iteration-time multiplier) and `until_s > t_s`.
//!
//! Events are kept sorted by time (stable for equal times, preserving file
//! order), so application order is deterministic by construction.

use crate::util::json::Json;

/// Typed validation failure for fault plans. Non-finite times get their
/// own variant because they used to be a *panic* (a NaN `t_s` blew up the
/// old `partial_cmp` comparator inside `sort`, before validation could
/// reject it); now sorting is total and the parse path returns this.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum FaultPlanError {
    #[error("fault event {index}: time {t_s} must be a finite non-negative number of seconds")]
    BadTime { index: usize, t_s: f64 },
}

/// What happens to the target group at the event time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Instant loss: ledger occupancy and every resident shard drop; see
    /// `KvpManager::crash_group` for the recovery contract.
    Crash,
    /// Recovery / scale-up: revive a `Down` slot or grow the fleet. The
    /// group warms up (`Joining`, unplaceable) for `warmup_s` first.
    Join { warmup_s: f64 },
    /// Graceful scale-down: no new placements; resident work finishes.
    Drain,
    /// Transient degradation: the group's iteration times are multiplied
    /// by `factor` until `until_s`.
    Slowdown { factor: f64, until_s: f64 },
}

/// One scheduled lifecycle event.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Simulation time the event fires (seconds).
    pub t_s: f64,
    /// Target group id. `None` only for `Join`: grow the fleet by a slot.
    pub group: Option<u32>,
    pub kind: FaultKind,
}

/// A deterministic schedule of fleet lifecycle events, sorted by time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Normalize: stable-sort events by time (file order breaks ties).
    /// The comparator is total (`total_cmp`), so a malformed plan with a
    /// NaN time sorts deterministically instead of panicking here —
    /// [`FaultPlan::validate`] then rejects it with [`FaultPlanError`].
    pub fn sort(&mut self) {
        self.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
    }

    pub fn from_json(j: &Json) -> anyhow::Result<FaultPlan> {
        let mut events = Vec::new();
        if let Some(arr) = j.get("events").and_then(|x| x.as_arr()) {
            for e in arr {
                events.push(FaultEvent::from_json(e)?);
            }
        }
        let mut plan = FaultPlan { events };
        plan.sort();
        plan.validate()?;
        Ok(plan)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<FaultPlan> {
        FaultPlan::from_json(&Json::parse_file(path)?)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "events",
            Json::arr(self.events.iter().map(FaultEvent::to_json)),
        )])
    }

    /// Structural checks that don't need fleet context: finite
    /// non-negative times, sane slowdown windows and factors. Whether a
    /// crash targets a live group is a runtime property the simulator
    /// asserts when the event fires.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            if !e.t_s.is_finite() || e.t_s < 0.0 {
                return Err(FaultPlanError::BadTime { index: i, t_s: e.t_s }.into());
            }
            match e.kind {
                FaultKind::Crash | FaultKind::Drain => {
                    if e.group.is_none() {
                        anyhow::bail!("fault event {i}: crash/drain needs a group");
                    }
                }
                FaultKind::Join { warmup_s } => {
                    if !warmup_s.is_finite() || warmup_s < 0.0 {
                        anyhow::bail!("fault event {i}: bad warmup_s {warmup_s}");
                    }
                }
                FaultKind::Slowdown { factor, until_s } => {
                    if e.group.is_none() {
                        anyhow::bail!("fault event {i}: slowdown needs a group");
                    }
                    if !(factor >= 1.0) || !factor.is_finite() {
                        anyhow::bail!("fault event {i}: slowdown factor {factor} < 1");
                    }
                    if !(until_s > e.t_s) {
                        anyhow::bail!("fault event {i}: until_s {until_s} <= t_s {}", e.t_s);
                    }
                }
            }
        }
        debug_assert!(
            self.events.windows(2).all(|w| w[0].t_s <= w[1].t_s),
            "fault plan not sorted"
        );
        Ok(())
    }
}

impl FaultEvent {
    pub fn from_json(j: &Json) -> anyhow::Result<FaultEvent> {
        let t_s = j.req_f64("t_s")?;
        let group = j.get("group").and_then(|x| x.as_u64()).map(|g| g as u32);
        let kind = match j.req_str("kind")? {
            "crash" => FaultKind::Crash,
            "join" => FaultKind::Join {
                warmup_s: j.get("warmup_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            },
            "drain" => FaultKind::Drain,
            "slowdown" => FaultKind::Slowdown {
                factor: j.req_f64("factor")?,
                until_s: j.req_f64("until_s")?,
            },
            other => anyhow::bail!("unknown fault kind {other:?}"),
        };
        Ok(FaultEvent { t_s, group, kind })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("t_s", Json::num(self.t_s))];
        let kind = match self.kind {
            FaultKind::Crash => "crash",
            FaultKind::Join { .. } => "join",
            FaultKind::Drain => "drain",
            FaultKind::Slowdown { .. } => "slowdown",
        };
        pairs.push(("kind", Json::str(kind)));
        if let Some(g) = self.group {
            pairs.push(("group", Json::num(g as f64)));
        }
        match self.kind {
            FaultKind::Join { warmup_s } if warmup_s > 0.0 => {
                pairs.push(("warmup_s", Json::num(warmup_s)));
            }
            FaultKind::Slowdown { factor, until_s } => {
                pairs.push(("factor", Json::num(factor)));
                pairs.push(("until_s", Json::num(until_s)));
            }
            _ => {}
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sorts_and_roundtrips() {
        let j = Json::parse(
            r#"{"events": [
                {"t_s": 20.0, "kind": "join", "group": 1, "warmup_s": 2.0},
                {"t_s": 12.0, "kind": "crash", "group": 1},
                {"t_s": 5.0, "kind": "slowdown", "group": 0, "factor": 1.5,
                 "until_s": 9.0},
                {"t_s": 8.0, "kind": "drain", "group": 2},
                {"t_s": 30.0, "kind": "join"}
            ]}"#,
        )
        .unwrap();
        let plan = FaultPlan::from_json(&j).unwrap();
        let times: Vec<f64> = plan.events.iter().map(|e| e.t_s).collect();
        assert_eq!(times, vec![5.0, 8.0, 12.0, 20.0, 30.0]);
        assert_eq!(plan.events[2].kind, FaultKind::Crash);
        assert_eq!(plan.events[4].group, None, "groupless join grows fleet");
        assert_eq!(
            plan.events[3].kind,
            FaultKind::Join { warmup_s: 2.0 }
        );
        // JSON round-trip preserves the plan exactly
        let back = FaultPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn empty_and_default_plans_are_fault_free() {
        let plan = FaultPlan::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(plan.is_empty());
        assert_eq!(plan, FaultPlan::default());
        plan.validate().unwrap();
    }

    #[test]
    fn nan_and_infinite_times_are_rejected_not_panicked() {
        // NaN is not expressible in JSON, so build the plan directly to
        // prove the sort itself is total: the old comparator panicked
        // right here, before validation ever saw the event.
        let mut plan = FaultPlan {
            events: vec![
                FaultEvent { t_s: f64::NAN, group: Some(0), kind: FaultKind::Crash },
                FaultEvent { t_s: 1.0, group: Some(1), kind: FaultKind::Crash },
            ],
        };
        plan.sort();
        let err = plan.validate().unwrap_err();
        let typed = err.downcast_ref::<FaultPlanError>().expect("typed FaultPlanError");
        assert!(matches!(typed, FaultPlanError::BadTime { .. }), "{typed}");

        // JSON reaches infinity by overflow (1e999 parses to +inf), so the
        // whole parse path must reject it with the typed error, not panic.
        let j = Json::parse(r#"{"events": [{"t_s": 1e999, "kind": "crash", "group": 0}]}"#)
            .unwrap();
        let err = FaultPlan::from_json(&j).unwrap_err();
        assert!(err.downcast_ref::<FaultPlanError>().is_some(), "{err}");
    }

    #[test]
    fn validation_rejects_malformed_events() {
        for bad in [
            r#"{"events": [{"t_s": -1.0, "kind": "crash", "group": 0}]}"#,
            r#"{"events": [{"t_s": 1.0, "kind": "crash"}]}"#,
            r#"{"events": [{"t_s": 1.0, "kind": "melt", "group": 0}]}"#,
            r#"{"events": [{"t_s": 1.0, "kind": "slowdown", "group": 0,
                "factor": 0.5, "until_s": 2.0}]}"#,
            r#"{"events": [{"t_s": 1.0, "kind": "slowdown", "group": 0,
                "factor": 2.0, "until_s": 0.5}]}"#,
            r#"{"events": [{"t_s": 1.0, "kind": "join", "warmup_s": -3.0}]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(FaultPlan::from_json(&j).is_err(), "accepted: {bad}");
        }
    }
}
