//! Model architecture configs. Presets cover the paper's evaluation models
//! (Llama-3 8B / 70B) plus the tiny model actually served end-to-end on the
//! CPU PJRT runtime (matching python/compile/model.py's ModelSpec).

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: u32,
    /// Query heads per layer.
    pub hq: u32,
    /// KV heads per layer (GQA). Paper: both Llama-3 models have 8.
    pub hkv: u32,
    /// Attention head dimension.
    pub d_head: u32,
    pub d_model: u32,
    /// MLP hidden dimension (SwiGLU: 3 matmuls of d_model x d_ff).
    pub d_ff: u32,
    pub vocab: u32,
    /// Bytes per parameter / KV element (2 = fp16/bf16, 4 = fp32).
    pub dtype_bytes: u32,
}

impl ModelConfig {
    pub fn llama3_8b() -> ModelConfig {
        ModelConfig {
            name: "llama3-8b".into(),
            n_layers: 32,
            hq: 32,
            hkv: 8,
            d_head: 128,
            d_model: 4096,
            d_ff: 14336,
            vocab: 128_256,
            dtype_bytes: 2,
        }
    }

    pub fn llama3_70b() -> ModelConfig {
        ModelConfig {
            name: "llama3-70b".into(),
            n_layers: 80,
            hq: 64,
            hkv: 8,
            d_head: 128,
            d_model: 8192,
            d_ff: 28672,
            vocab: 128_256,
            dtype_bytes: 2,
        }
    }

    /// The model actually served by the CPU engine (python ModelSpec mirror).
    pub fn tiny_23m() -> ModelConfig {
        ModelConfig {
            name: "tiny-23m".into(),
            n_layers: 8,
            hq: 8,
            hkv: 2,
            d_head: 64,
            d_model: 512,
            d_ff: 1408,
            vocab: 256,
            dtype_bytes: 4,
        }
    }

    pub fn preset(name: &str) -> anyhow::Result<ModelConfig> {
        match name {
            "llama3-8b" => Ok(ModelConfig::llama3_8b()),
            "llama3-70b" => Ok(ModelConfig::llama3_70b()),
            "tiny-23m" | "tiny" => Ok(ModelConfig::tiny_23m()),
            other => anyhow::bail!("unknown model preset '{other}'"),
        }
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        if let Some(p) = j.get("preset").and_then(|x| x.as_str()) {
            let mut m = ModelConfig::preset(p)?;
            // allow field overrides on top of a preset
            if let Some(x) = j.get("dtype_bytes").and_then(|x| x.as_u64()) {
                m.dtype_bytes = x as u32;
            }
            return Ok(m);
        }
        Ok(ModelConfig {
            name: j.req_str("name")?.to_string(),
            n_layers: j.req_u64("n_layers")? as u32,
            hq: j.req_u64("hq")? as u32,
            hkv: j.req_u64("hkv")? as u32,
            d_head: j.req_u64("d_head")? as u32,
            d_model: j.req_u64("d_model")? as u32,
            d_ff: j.req_u64("d_ff")? as u32,
            vocab: j.req_u64("vocab")? as u32,
            dtype_bytes: j.get("dtype_bytes").and_then(|x| x.as_u64()).unwrap_or(2) as u32,
        })
    }

    /// GQA group size hq/hkv — the arithmetic-intensity multiplier in Eq. 7.
    pub fn gqa_group(&self) -> u32 {
        self.hq / self.hkv
    }

    /// Total parameter count (tied embeddings, SwiGLU MLP, no biases).
    pub fn n_params(&self) -> u64 {
        let dm = self.d_model as u64;
        let dh = self.d_head as u64;
        let attn = dm * (self.hq as u64) * dh // wq
            + 2 * dm * (self.hkv as u64) * dh // wk, wv
            + (self.hq as u64) * dh * dm; // wo
        let mlp = 3 * dm * self.d_ff as u64;
        let norms = 2 * dm;
        (self.n_layers as u64) * (attn + mlp + norms) + (self.vocab as u64) * dm + dm
    }

    /// Weight bytes (for memory-feasibility checks, Fig. 15 red crosses).
    pub fn param_bytes(&self) -> u64 {
        self.n_params() * self.dtype_bytes as u64
    }

    /// KV cache bytes for `n` tokens: Eq. 2, M_kv(n) = 2 * l * n * hkv * d
    /// elements (K and V), times bytes per element.
    pub fn kv_bytes(&self, n: u64) -> u64 {
        2 * self.n_layers as u64
            * n
            * self.hkv as u64
            * self.d_head as u64
            * self.dtype_bytes as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama3_8b_param_count_plausible() {
        let p = ModelConfig::llama3_8b().n_params();
        assert!((7e9..9e9).contains(&(p as f64)), "{p}");
    }

    #[test]
    fn llama3_70b_param_count_plausible() {
        let p = ModelConfig::llama3_70b().n_params();
        assert!((6.5e10..7.5e10).contains(&(p as f64)), "{p}");
    }

    #[test]
    fn kv_bytes_matches_paper_example() {
        // Paper section 2.1: Llama-3 70B @ 1M tokens needs ~320 GB KV cache.
        let m = ModelConfig::llama3_70b();
        let gb = m.kv_bytes(1_000_000) as f64 / 1e9;
        assert!((300.0..340.0).contains(&gb), "{gb} GB");
    }

    #[test]
    fn gqa_group_llama() {
        assert_eq!(ModelConfig::llama3_8b().gqa_group(), 4);
        assert_eq!(ModelConfig::llama3_70b().gqa_group(), 8);
        assert_eq!(ModelConfig::tiny_23m().gqa_group(), 4);
    }

    #[test]
    fn preset_lookup() {
        assert!(ModelConfig::preset("llama3-8b").is_ok());
        assert!(ModelConfig::preset("gpt-oops").is_err());
    }
}
