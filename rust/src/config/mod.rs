//! Configuration system: model architectures, hardware, parallelism layouts,
//! SLOs, and scheduler policy. Presets mirror the paper's evaluation setup;
//! everything is also loadable from JSON files (see `configs/`).

mod faults;
mod hardware;
mod model;
mod parallel;

pub use faults::{FaultEvent, FaultKind, FaultPlan};
pub use hardware::{HardwareConfig, InterconnectConfig};
pub use model::ModelConfig;
pub use parallel::{ParallelismConfig, PlacementError};

use crate::coordinator::policy::SchedPolicyKind;
use crate::coordinator::router::RoutingMode;
use crate::util::json::Json;

/// Latency service-level objectives (paper: 30s TTFT babbling point /
/// production-grade 20-30ms TBT), plus the length-aware TTFT deadlines
/// heterogeneous scheduling needs: one absolute target cannot serve both a
/// 500-token chat turn and a 1M-token document, so per-request deadlines
/// scale with the request's estimated isolated prefill time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    pub ttft_s: f64,
    pub tbt_s: f64,
    /// Length-aware deadline scale: a request's TTFT budget is
    /// `max(ttft_floor_s, ttft_scale × estimated isolated prefill time)`.
    /// When the proportional term wins, every fresh request starts at the
    /// same LARS relative slack (`ttft_scale − 1`, shifted down by the
    /// scheduler's headroom — see `coordinator::policy::Lars`).
    pub ttft_scale: f64,
    /// Floor on the TTFT budget, deliberately breaking proportionality for
    /// tiny requests: their fresh slack is much larger than `ttft_scale−1`
    /// but erodes fast, giving them a humane interactive deadline instead
    /// of a microsecond one.
    pub ttft_floor_s: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        // Paper section 3.1 / section 6: 30s TTFT, 20-30ms TBT.
        SloConfig {
            ttft_s: 30.0,
            tbt_s: 0.030,
            ttft_scale: 5.0,
            ttft_floor_s: 2.0,
        }
    }
}

impl SloConfig {
    /// Length-aware TTFT budget (seconds after arrival) for a request whose
    /// isolated prefill is estimated at `est_prefill_s`.
    pub fn ttft_deadline_for(&self, est_prefill_s: f64) -> f64 {
        (self.ttft_scale * est_prefill_s).max(self.ttft_floor_s)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SloConfig> {
        let d = SloConfig::default();
        Ok(SloConfig {
            ttft_s: j.req_f64("ttft_s")?,
            tbt_s: j.req_f64("tbt_s")?,
            ttft_scale: j.get("ttft_scale").and_then(|x| x.as_f64()).unwrap_or(d.ttft_scale),
            ttft_floor_s: j
                .get("ttft_floor_s")
                .and_then(|x| x.as_f64())
                .unwrap_or(d.ttft_floor_s),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ttft_s", self.ttft_s.into()),
            ("tbt_s", self.tbt_s.into()),
            ("ttft_scale", self.ttft_scale.into()),
            ("ttft_floor_s", self.ttft_floor_s.into()),
        ])
    }
}

/// Scheduler policy knobs (section 4.2 adaptive chunking + section 7).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Chunk sizes the scheduler may pick from (must be sorted ascending).
    pub chunk_sizes: Vec<u64>,
    /// If true, shrink chunk size adaptively to keep batch time under
    /// `slo.tbt_s`; if false, always use `static_chunk`.
    pub adaptive_chunking: bool,
    pub static_chunk: u64,
    /// Max decode requests batched per iteration.
    pub max_batch_size: usize,
    /// KVP dynamic-growth threshold: max KV tokens per KVP worker group
    /// before onboarding the next one (section 4.4).
    pub kvp_onboard_threshold: u64,
    /// Per-group KV-token capacity (long-request shards + short-request
    /// reservations). Under routed placement the policy's routing hook
    /// refuses groups without room and admission defers until capacity
    /// frees, counted in `Metrics::routing_refusals` (deferral retries are
    /// ordered by the scheduling policy's priority). KVP growth also skips
    /// groups without room. `u64::MAX` (the default) disables capacity
    /// accounting — the pre-capacity behavior the golden snapshots pin.
    pub kvp_capacity_tokens: u64,
    /// Preemptive scheduling policy ordering each replica's ready set
    /// (section 5): fcfs | srpt | edf | lars. FCFS preserves the original
    /// strict-FIFO behavior.
    pub policy: SchedPolicyKind,
    /// Placement of requests across KVP groups (section 7): blind |
    /// round-robin | routed. `blind` is least-loaded with every group in
    /// the cooperative set (lockstep-equivalent clocks); the pooled modes
    /// let non-sharded groups serve short traffic independently and enable
    /// active-long-request preemption under preemptive policies.
    pub routing: RoutingMode,
    /// Worker threads for the simulator's parallel step (`simulate
    /// --threads N`): per-group batch formation and pipeline timing run
    /// group-parallel on a threadpool, with results merged in group-index
    /// order so every metric and clock is bit-identical to the serial
    /// schedule. `1` (the default) keeps the single-threaded path; must be
    /// positive.
    pub threads: usize,
    /// Prefix-aware KV reuse (`kvcache::PrefixIndex`): admission matches a
    /// request's stream against hash-consed prefix block chains, skips the
    /// matched prefill span, and routing scores cache affinity. `false`
    /// (the default) keeps the strictly per-request KV behavior every
    /// pre-reuse golden snapshot pins, bit for bit.
    pub prefix_reuse: bool,
    /// Block granularity of the prefix index: reuse is granted in whole
    /// blocks of this many tokens. Must be positive.
    pub prefix_block_tokens: u64,
    /// Global budget on indexed prefix blocks; rc-0 chains age out LRU
    /// (by sim-sequence) past it. `u64::MAX` = unbounded.
    pub prefix_cache_blocks: u64,
    /// LARS headroom auto-tuning: maintain an EWMA of observed-vs-predicted
    /// iteration time (slowdown faults are the real divergence source in
    /// the simulator) and scale admission-time prefill estimates by it, so
    /// deadlines and slack absorb systematic model error. Off by default —
    /// estimates, deadlines, and every golden snapshot stay untouched.
    pub headroom_autotune: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            chunk_sizes: vec![32, 64, 128, 256, 512, 1024, 2048, 4096],
            adaptive_chunking: true,
            static_chunk: 2048,
            max_batch_size: 128,
            kvp_onboard_threshold: 512 * 1024,
            kvp_capacity_tokens: u64::MAX,
            policy: SchedPolicyKind::Fcfs,
            routing: RoutingMode::Blind,
            threads: 1,
            prefix_reuse: false,
            prefix_block_tokens: 256,
            prefix_cache_blocks: u64::MAX,
            headroom_autotune: false,
        }
    }
}

impl SchedulerConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<SchedulerConfig> {
        let d = SchedulerConfig::default();
        Ok(SchedulerConfig {
            chunk_sizes: match j.get("chunk_sizes") {
                Some(a) => a
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("chunk_sizes must be an array"))?
                    .iter()
                    .filter_map(|x| x.as_u64())
                    .collect(),
                None => d.chunk_sizes,
            },
            adaptive_chunking: j
                .get("adaptive_chunking")
                .and_then(|x| x.as_bool())
                .unwrap_or(d.adaptive_chunking),
            static_chunk: j.get("static_chunk").and_then(|x| x.as_u64()).unwrap_or(d.static_chunk),
            max_batch_size: j
                .get("max_batch_size")
                .and_then(|x| x.as_usize())
                .unwrap_or(d.max_batch_size),
            kvp_onboard_threshold: j
                .get("kvp_onboard_threshold")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.kvp_onboard_threshold),
            kvp_capacity_tokens: j
                .get("kvp_capacity_tokens")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.kvp_capacity_tokens),
            policy: match j.get("policy").and_then(|x| x.as_str()) {
                Some(s) => SchedPolicyKind::parse(s).ok_or_else(|| {
                    anyhow::anyhow!("unknown scheduler policy '{s}' (expected fcfs|srpt|edf|lars)")
                })?,
                None => d.policy,
            },
            routing: match j.get("routing").and_then(|x| x.as_str()) {
                Some(s) => RoutingMode::parse(s).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown routing mode '{s}' (expected blind|round-robin|routed)"
                    )
                })?,
                None => d.routing,
            },
            threads: j.get("threads").and_then(|x| x.as_usize()).unwrap_or(d.threads),
            prefix_reuse: j
                .get("prefix_reuse")
                .and_then(|x| x.as_bool())
                .unwrap_or(d.prefix_reuse),
            prefix_block_tokens: j
                .get("prefix_block_tokens")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.prefix_block_tokens),
            prefix_cache_blocks: j
                .get("prefix_cache_blocks")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.prefix_cache_blocks),
            headroom_autotune: j
                .get("headroom_autotune")
                .and_then(|x| x.as_bool())
                .unwrap_or(d.headroom_autotune),
        })
    }
}

/// Everything a deployment needs: what model, on what hardware, in which
/// parallel layout, under which SLOs and scheduler policy.
#[derive(Debug, Clone)]
pub struct DeploymentConfig {
    pub model: ModelConfig,
    pub hardware: HardwareConfig,
    pub parallel: ParallelismConfig,
    pub slo: SloConfig,
    pub scheduler: SchedulerConfig,
}

impl DeploymentConfig {
    /// The paper's workhorse setup: Llama-3 8B, tp=8 on one DGX-H100.
    pub fn llama3_8b_tp8() -> DeploymentConfig {
        DeploymentConfig {
            model: ModelConfig::llama3_8b(),
            hardware: HardwareConfig::dgx_h100(),
            parallel: ParallelismConfig::new(8, 1, 1),
            slo: SloConfig::default(),
            scheduler: SchedulerConfig::default(),
        }
    }

    pub fn llama3_70b_tp8() -> DeploymentConfig {
        DeploymentConfig {
            model: ModelConfig::llama3_70b(),
            hardware: HardwareConfig::dgx_h100(),
            parallel: ParallelismConfig::new(8, 1, 1),
            slo: SloConfig::default(),
            scheduler: SchedulerConfig::default(),
        }
    }

    pub fn with_parallel(mut self, tp: u32, spp: u32, kvp: u32) -> Self {
        self.parallel = ParallelismConfig::new(tp, spp, kvp);
        self
    }

    pub fn total_gpus(&self) -> u32 {
        self.parallel.total_workers()
    }

    pub fn from_json(j: &Json) -> anyhow::Result<DeploymentConfig> {
        Ok(DeploymentConfig {
            model: ModelConfig::from_json(j.req("model")?)?,
            hardware: match j.get("hardware") {
                Some(h) => HardwareConfig::from_json(h)?,
                None => HardwareConfig::dgx_h100(),
            },
            parallel: match j.get("parallel") {
                Some(p) => ParallelismConfig::from_json(p)?,
                None => ParallelismConfig::new(8, 1, 1),
            },
            slo: match j.get("slo") {
                Some(s) => SloConfig::from_json(s)?,
                None => SloConfig::default(),
            },
            scheduler: match j.get("scheduler") {
                Some(s) => SchedulerConfig::from_json(s)?,
                None => SchedulerConfig::default(),
            },
        })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<DeploymentConfig> {
        DeploymentConfig::from_json(&Json::parse_file(path)?)
    }

    /// Validate the layout against the model and hardware (e.g. TP cannot
    /// exceed KV heads or the NVLink domain).
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.scheduler.kvp_capacity_tokens == 0 {
            anyhow::bail!("kvp_capacity_tokens must be positive (use u64::MAX for unlimited)");
        }
        if self.scheduler.threads == 0 {
            anyhow::bail!("scheduler threads must be positive (1 = serial)");
        }
        if self.scheduler.prefix_block_tokens == 0 {
            anyhow::bail!("prefix_block_tokens must be positive");
        }
        if self.scheduler.prefix_cache_blocks == 0 {
            anyhow::bail!("prefix_cache_blocks must be positive (use u64::MAX for unbounded)");
        }
        self.parallel
            .validate(&self.model, &self.hardware)
            .map_err(|e| anyhow::anyhow!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        DeploymentConfig::llama3_8b_tp8().validate().unwrap();
        DeploymentConfig::llama3_70b_tp8()
            .with_parallel(8, 4, 4)
            .validate()
            .unwrap();
    }

    #[test]
    fn total_gpu_math() {
        let d = DeploymentConfig::llama3_8b_tp8().with_parallel(8, 4, 4);
        assert_eq!(d.total_gpus(), 128);
    }

    #[test]
    fn json_roundtrip_minimal() {
        let j = Json::parse(
            r#"{"model": {"preset": "llama3-8b"},
                "parallel": {"tp": 8, "spp": 2, "kvp": 1},
                "slo": {"ttft_s": 30.0, "tbt_s": 0.02}}"#,
        )
        .unwrap();
        let d = DeploymentConfig::from_json(&j).unwrap();
        assert_eq!(d.model.n_layers, 32);
        assert_eq!(d.parallel.spp, 2);
        assert!((d.slo.tbt_s - 0.02).abs() < 1e-12);
        d.validate().unwrap();
    }

    #[test]
    fn scheduler_defaults() {
        let s = SchedulerConfig::default();
        assert!(s.adaptive_chunking);
        assert!(s.chunk_sizes.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(s.policy, SchedPolicyKind::Fcfs);
    }

    #[test]
    fn scheduler_policy_from_json() {
        let j = Json::parse(r#"{"policy": "lars", "static_chunk": 1024}"#).unwrap();
        let s = SchedulerConfig::from_json(&j).unwrap();
        assert_eq!(s.policy, SchedPolicyKind::Lars);
        assert_eq!(s.static_chunk, 1024);
        // routing defaults to the lockstep-equivalent blind mode
        assert_eq!(s.routing, RoutingMode::Blind);
        let bad = Json::parse(r#"{"policy": "wfq"}"#).unwrap();
        assert!(SchedulerConfig::from_json(&bad).is_err());
    }

    #[test]
    fn scheduler_kvp_capacity_from_json() {
        // default: capacity accounting off
        assert_eq!(SchedulerConfig::default().kvp_capacity_tokens, u64::MAX);
        let j = Json::parse(r#"{"kvp_capacity_tokens": 262144}"#).unwrap();
        assert_eq!(
            SchedulerConfig::from_json(&j).unwrap().kvp_capacity_tokens,
            262_144
        );
        // a zero capacity is a config error, not a downstream assert panic
        let mut dep = DeploymentConfig::llama3_8b_tp8();
        dep.scheduler.kvp_capacity_tokens = 0;
        assert!(dep.validate().is_err());
    }

    #[test]
    fn scheduler_routing_from_json() {
        let j = Json::parse(r#"{"routing": "routed"}"#).unwrap();
        assert_eq!(
            SchedulerConfig::from_json(&j).unwrap().routing,
            RoutingMode::Routed
        );
        let j = Json::parse(r#"{"routing": "round-robin"}"#).unwrap();
        assert_eq!(
            SchedulerConfig::from_json(&j).unwrap().routing,
            RoutingMode::RoundRobin
        );
        let bad = Json::parse(r#"{"routing": "hash"}"#).unwrap();
        assert!(SchedulerConfig::from_json(&bad).is_err());
    }

    #[test]
    fn scheduler_threads_from_json() {
        // default is the serial path
        assert_eq!(SchedulerConfig::default().threads, 1);
        let j = Json::parse(r#"{"threads": 4}"#).unwrap();
        assert_eq!(SchedulerConfig::from_json(&j).unwrap().threads, 4);
        // zero threads is a config error, not a pool-construction panic
        let mut dep = DeploymentConfig::llama3_8b_tp8();
        dep.scheduler.threads = 0;
        assert!(dep.validate().is_err());
    }

    #[test]
    fn scheduler_prefix_reuse_from_json() {
        // defaults: reuse and autotune off, sane block size
        let d = SchedulerConfig::default();
        assert!(!d.prefix_reuse);
        assert!(!d.headroom_autotune);
        assert_eq!(d.prefix_block_tokens, 256);
        assert_eq!(d.prefix_cache_blocks, u64::MAX);
        let j = Json::parse(
            r#"{"prefix_reuse": true, "prefix_block_tokens": 128,
                "prefix_cache_blocks": 4096, "headroom_autotune": true}"#,
        )
        .unwrap();
        let s = SchedulerConfig::from_json(&j).unwrap();
        assert!(s.prefix_reuse);
        assert!(s.headroom_autotune);
        assert_eq!(s.prefix_block_tokens, 128);
        assert_eq!(s.prefix_cache_blocks, 4096);
        // degenerate knobs are config errors, not downstream panics
        let mut dep = DeploymentConfig::llama3_8b_tp8();
        dep.scheduler.prefix_block_tokens = 0;
        assert!(dep.validate().is_err());
        let mut dep = DeploymentConfig::llama3_8b_tp8();
        dep.scheduler.prefix_cache_blocks = 0;
        assert!(dep.validate().is_err());
    }

    #[test]
    fn length_aware_deadlines() {
        let slo = SloConfig::default();
        // tiny request: floored interactive budget
        assert_eq!(slo.ttft_deadline_for(0.05), slo.ttft_floor_s);
        // document request: proportional budget
        assert!((slo.ttft_deadline_for(60.0) - 300.0).abs() < 1e-9);
        // json roundtrip keeps the new knobs optional
        let j = Json::parse(r#"{"ttft_s": 30.0, "tbt_s": 0.02}"#).unwrap();
        let parsed = SloConfig::from_json(&j).unwrap();
        assert_eq!(parsed.ttft_scale, slo.ttft_scale);
        let j2 = Json::parse(&parsed.to_json().to_string()).unwrap();
        assert_eq!(SloConfig::from_json(&j2).unwrap(), parsed);
    }
}
