//! Parallelism layout: the paper's 3D strategy (TP x SPP x KVP), Fig. 12.
//!
//! * TP shards attention heads + linear layers within the NVLink domain;
//! * SPP (sequence pipeline parallelism) splits layers into pipeline stages
//!   and densely pipelines *prefill chunks* across them;
//! * KVP replicates the model and shards the KV cache along the sequence
//!   dimension across replica groups.
//!
//! A KVP group contains spp stages x tp workers; total = tp * spp * kvp.

use super::{HardwareConfig, ModelConfig};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelismConfig {
    pub tp: u32,
    pub spp: u32,
    pub kvp: u32,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum PlacementError {
    #[error("tp={tp} exceeds KV heads ({hkv}) — TP shards the head dimension")]
    TpExceedsKvHeads { tp: u32, hkv: u32 },
    #[error("tp={tp} exceeds the NVLink domain ({gpus_per_node} GPUs/node)")]
    TpExceedsNode { tp: u32, gpus_per_node: u32 },
    #[error("spp={spp} does not divide n_layers={layers}")]
    SppLayerMismatch { spp: u32, layers: u32 },
    #[error("degree must be >= 1")]
    ZeroDegree,
}

impl ParallelismConfig {
    pub fn new(tp: u32, spp: u32, kvp: u32) -> ParallelismConfig {
        ParallelismConfig { tp, spp, kvp }
    }

    pub fn total_workers(&self) -> u32 {
        self.tp * self.spp * self.kvp
    }

    /// Workers in one KVP replica group (one full model replica).
    pub fn workers_per_replica(&self) -> u32 {
        self.tp * self.spp
    }

    pub fn layers_per_stage(&self, model: &ModelConfig) -> u32 {
        model.n_layers / self.spp
    }

    pub fn validate(
        &self,
        model: &ModelConfig,
        hw: &HardwareConfig,
    ) -> Result<(), PlacementError> {
        if self.tp == 0 || self.spp == 0 || self.kvp == 0 {
            return Err(PlacementError::ZeroDegree);
        }
        if self.tp > model.hkv {
            return Err(PlacementError::TpExceedsKvHeads {
                tp: self.tp,
                hkv: model.hkv,
            });
        }
        if self.tp > hw.gpus_per_node {
            return Err(PlacementError::TpExceedsNode {
                tp: self.tp,
                gpus_per_node: hw.gpus_per_node,
            });
        }
        if model.n_layers % self.spp != 0 {
            return Err(PlacementError::SppLayerMismatch {
                spp: self.spp,
                layers: model.n_layers,
            });
        }
        Ok(())
    }

    /// Whether two pipeline-adjacent stages sit on the same node (TP groups
    /// are node-aligned; stage boundaries cross nodes when tp == node size).
    pub fn stage_hop_same_node(&self, hw: &HardwareConfig) -> bool {
        self.tp < hw.gpus_per_node
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ParallelismConfig> {
        Ok(ParallelismConfig {
            tp: j.req_u64("tp")? as u32,
            spp: j.get("spp").or_else(|| j.get("pp")).and_then(|x| x.as_u64()).unwrap_or(1) as u32,
            kvp: j.get("kvp").and_then(|x| x.as_u64()).unwrap_or(1) as u32,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tp", (self.tp as u64).into()),
            ("spp", (self.spp as u64).into()),
            ("kvp", (self.kvp as u64).into()),
        ])
    }

    pub fn label(&self) -> String {
        format!("tp{}-spp{}-kvp{}", self.tp, self.spp, self.kvp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_rules() {
        let m = ModelConfig::llama3_8b(); // hkv = 8, 32 layers
        let h = HardwareConfig::dgx_h100();
        assert!(ParallelismConfig::new(8, 4, 2).validate(&m, &h).is_ok());
        assert_eq!(
            ParallelismConfig::new(16, 1, 1).validate(&m, &h),
            Err(PlacementError::TpExceedsKvHeads { tp: 16, hkv: 8 })
        );
        assert_eq!(
            ParallelismConfig::new(8, 5, 1).validate(&m, &h),
            Err(PlacementError::SppLayerMismatch { spp: 5, layers: 32 })
        );
        assert_eq!(
            ParallelismConfig::new(0, 1, 1).validate(&m, &h),
            Err(PlacementError::ZeroDegree)
        );
    }

    #[test]
    fn worker_counts() {
        let p = ParallelismConfig::new(8, 4, 4);
        assert_eq!(p.total_workers(), 128); // the paper's max scale
        assert_eq!(p.workers_per_replica(), 32);
    }

    #[test]
    fn layers_per_stage() {
        let m = ModelConfig::llama3_70b();
        assert_eq!(ParallelismConfig::new(8, 8, 1).layers_per_stage(&m), 10);
    }
}
