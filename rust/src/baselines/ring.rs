//! Ring & Striped attention prefill-time models (sections 2.3, 3.2).
//!
//! Both shard the *sequence* across `p` worker groups (each group = one TP
//! domain, typically a server). Computation proceeds in `p` rounds; each
//! round a group computes attention of its query shard against the KV shard
//! it currently holds, then forwards the KV shard around the ring. Per-round
//! time is max(compute, transfer) — when shards get small the transfer
//! dominates and scaling collapses (the paper's C3).
//!
//! * **Ring** assigns contiguous query blocks. With causal masking the
//!   worker holding the last block does ~p/(p+1)... ~2x the average work in
//!   the worst round, and rounds are synchronized, so the critical path sees
//!   the *unbalanced* maximum each round.
//! * **Striped** assigns round-robin token strips, making every round's
//!   per-worker work essentially uniform (the ~1.5x fix).

use crate::config::{HardwareConfig, ModelConfig};
use crate::perfmodel::counts;

#[derive(Debug, Clone)]
pub struct RingConfig {
    /// Sequence-parallel degree (worker groups in the ring).
    pub p: u32,
    /// TP degree inside each group (shares one server).
    pub tp: u32,
}

/// Per-round KV-shard transfer time (inter-node link; KV for n/p tokens,
/// one layer — transfers overlap per layer with compute of the same layer).
fn round_transfer_s(m: &ModelConfig, hw: &HardwareConfig, shard_tokens: u64) -> f64 {
    let bytes = counts::attn_read_bytes(m, shard_tokens);
    bytes / hw.inter_node.bandwidth + hw.inter_node.latency_s
}

/// Striped attention prefill latency for `n` tokens.
pub fn striped_prefill_time(m: &ModelConfig, hw: &HardwareConfig, cfg: &RingConfig, n: u64) -> f64 {
    sequence_parallel_prefill(m, hw, cfg, n, 1.0)
}

/// Ring attention prefill latency: same structure with the causal-imbalance
/// penalty on the compute term (paper: striped is ~1.5x faster).
pub fn ring_prefill_time(m: &ModelConfig, hw: &HardwareConfig, cfg: &RingConfig, n: u64) -> f64 {
    sequence_parallel_prefill(m, hw, cfg, n, ring_imbalance(cfg.p))
}

/// With contiguous causal blocks, round r's busiest worker computes a full
/// (unmasked) block-pair while the average worker computes half — the
/// synchronized rounds run at the max. Imbalance -> 2 - 1/p.
fn ring_imbalance(p: u32) -> f64 {
    2.0 - 1.0 / p as f64
}

fn sequence_parallel_prefill(
    m: &ModelConfig,
    hw: &HardwareConfig,
    cfg: &RingConfig,
    n: u64,
    imbalance: f64,
) -> f64 {
    let p = cfg.p.max(1) as u64;
    let shard = n.div_ceil(p);
    let group_flops = hw.sustained_flops() * cfg.tp as f64;

    // Causal attention FLOPs for the whole prefill, one layer:
    let total_attn = 2.0 * (n as f64) * (n as f64) * m.d_head as f64 * m.hq as f64;
    // Ideal per-round, per-worker compute (p rounds, p workers):
    let per_round_ideal = total_attn / (p * p) as f64;
    let round_compute = per_round_ideal * imbalance / group_flops;
    let round_comm = round_transfer_s(m, hw, shard);
    // p synchronized rounds per layer. At inference the causal mask leaves
    // bubbles that defeat the training-style compute/comm overlap (the
    // paper's C3: "KV cache block transfers become the bottleneck"), so the
    // transfer is largely exposed on the critical path.
    let attn_time = p as f64 * (round_compute + round_comm) * m.n_layers as f64;

    // Linear layers are data-parallel over the sequence shards (each worker
    // runs its n/p tokens through the full stack).
    let lin_flops = counts::linear_flops(m, shard) * m.n_layers as f64;
    let lin_bytes = counts::weight_bytes_per_layer(m) * m.n_layers as f64;
    let lin_time = (lin_flops / group_flops)
        .max(lin_bytes / (hw.sustained_bw() * cfg.tp as f64));

    attn_time + lin_time + hw.cpu_overhead_s
}

/// Preemption granularity: ring/striped run the prefill as one monolithic
/// collective — a competing request waits for the *whole* thing (Fig. 14b).
pub fn preemption_granularity_s(prefill_time: f64) -> f64 {
    prefill_time
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};

    fn setup() -> (ModelConfig, HardwareConfig) {
        (ModelConfig::llama3_8b(), HardwareConfig::dgx_h100())
    }

    #[test]
    fn striped_beats_ring() {
        let (m, hw) = setup();
        let cfg = RingConfig { p: 8, tp: 8 };
        let ring = ring_prefill_time(&m, &hw, &cfg, 1_000_000);
        let striped = striped_prefill_time(&m, &hw, &cfg, 1_000_000);
        let speedup = ring / striped;
        // paper cites ~1.5x
        assert!((1.2..2.1).contains(&speedup), "speedup={speedup}");
    }

    #[test]
    fn scaling_degrades_when_shards_get_small() {
        // C3: fixed 64K context; at high p the per-round transfer dominates
        // and efficiency collapses.
        let (m, hw) = setup();
        let t1 = striped_prefill_time(&m, &hw, &RingConfig { p: 1, tp: 8 }, 65_536);
        let t16 = striped_prefill_time(&m, &hw, &RingConfig { p: 16, tp: 8 }, 65_536);
        let eff = t1 / (16.0 * t16);
        assert!(eff < 0.8, "efficiency should degrade, got {eff}");
    }

    #[test]
    fn large_context_scales_well() {
        let (m, hw) = setup();
        let t1 = striped_prefill_time(&m, &hw, &RingConfig { p: 1, tp: 8 }, 4_000_000);
        let t8 = striped_prefill_time(&m, &hw, &RingConfig { p: 8, tp: 8 }, 4_000_000);
        let eff = t1 / (8.0 * t8);
        assert!(eff > 0.7, "eff={eff}");
    }

    #[test]
    fn preemption_is_the_whole_prefill() {
        let (m, hw) = setup();
        let cfg = RingConfig { p: 16, tp: 8 };
        let t = striped_prefill_time(&m, &hw, &cfg, 1_000_000);
        // Fig. 14b's shape: striped attention's HOL delay is the whole
        // prefill (seconds-to-minutes), orders of magnitude above Medha's
        // per-chunk granularity (~tens of ms).
        let g = preemption_granularity_s(t);
        assert!(g > 1.0, "granularity={g}s");
        let medha_chunk_granularity = 0.060;
        assert!(g / medha_chunk_granularity > 20.0);
    }
}
