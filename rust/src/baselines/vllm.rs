//! vLLM-like baseline engine model (Fig. 13).
//!
//! Same GPU roofline as Medha, but with the serving-stack behaviors the
//! paper's section 5 optimizations remove:
//!
//! * a centralized scheduler that re-ships sequence state and page tables
//!   to every worker each iteration (cost grows with context length);
//! * Ray/GIL-era per-iteration overhead (~4 ms vs Medha's ~0.3 ms);
//! * attention kernels that parallelize only across query tokens, so small
//!   chunks underutilize the GPU (pre-FlashInfer), modeled as a floor on
//!   effective chunk parallelism.

use crate::config::{HardwareConfig, ModelConfig, ParallelismConfig};
use crate::perfmodel::{BatchShape, PerfModel};

#[derive(Debug, Clone)]
pub struct VllmModel {
    pm: PerfModel,
    /// Fixed per-iteration scheduler overhead (Ray RPC, GIL, pickling).
    pub base_overhead_s: f64,
    /// Per-context-token page-table/sequence-state shipping cost.
    pub per_token_overhead_s: f64,
    /// Chunks below this size run at proportionally lower attention
    /// efficiency (query-only kernel parallelization).
    pub kernel_min_chunk: u64,
}

impl VllmModel {
    pub fn new(model: ModelConfig, hw: HardwareConfig, parallel: ParallelismConfig) -> VllmModel {
        let mut hw = hw;
        hw.cpu_overhead_s = 0.0; // overheads applied explicitly below
        VllmModel {
            pm: PerfModel::new(model, hw, parallel),
            base_overhead_s: 4.0e-3,
            per_token_overhead_s: 2.0e-8,
            kernel_min_chunk: 512,
        }
    }

    /// Context-dependent per-iteration overhead (the Fig. 13b growth).
    pub fn iteration_overhead_s(&self, total_ctx: u64) -> f64 {
        self.base_overhead_s + self.per_token_overhead_s * total_ctx as f64
    }

    /// One decode iteration's latency at context `ctx`.
    pub fn decode_tbt(&self, ctx: u64) -> f64 {
        let it = self.pm.iteration_time(&BatchShape::decode_only(&[ctx]));
        it.total() + self.iteration_overhead_s(ctx)
    }

    /// Chunked prefill latency with chunk size `c` — pays the full
    /// per-iteration overhead n/c times and loses kernel efficiency on
    /// small chunks.
    pub fn prefill_time_chunked(&self, n: u64, c: u64) -> f64 {
        let mut t = 0.0;
        let mut done = 0u64;
        while done < n {
            let chunk = c.min(n - done);
            let it = self
                .pm
                .iteration_time(&BatchShape::prefill_only(chunk, done + chunk));
            // query-only parallelization: attention efficiency scales with
            // chunk/kernel_min_chunk below the floor
            let eff = (chunk as f64 / self.kernel_min_chunk as f64).min(1.0);
            let attn = it.attn_s / eff.max(1e-3);
            t += attn + it.linear_s + it.tp_comm_s + self.iteration_overhead_s(done + chunk);
            done += chunk;
        }
        t
    }

    /// Monolithic (default vLLM) prefill: one giant iteration — this is the
    /// head-of-line blocker of Fig. 4 (top).
    pub fn prefill_time_monolithic(&self, n: u64) -> f64 {
        let it = self.pm.iteration_time(&BatchShape::prefill_only(n, n));
        it.total() + self.iteration_overhead_s(n)
    }

    pub fn perf_model(&self) -> &PerfModel {
        &self.pm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;

    fn vllm() -> VllmModel {
        let d = DeploymentConfig::llama3_8b_tp8();
        VllmModel::new(d.model, d.hardware, d.parallel)
    }

    fn medha_pm() -> PerfModel {
        let d = DeploymentConfig::llama3_8b_tp8();
        PerfModel::new(d.model, d.hardware, d.parallel)
    }

    #[test]
    fn fig13b_decode_gap_grows_with_context() {
        let v = vllm();
        let m = medha_pm();
        let gap_short = v.decode_tbt(10_000)
            / m.iteration_time(&BatchShape::decode_only(&[10_000])).total();
        let gap_long = v.decode_tbt(2_000_000)
            / m.iteration_time(&BatchShape::decode_only(&[2_000_000])).total();
        assert!(gap_long > gap_short, "short={gap_short} long={gap_long}");
        // paper: ~3.8-4x at long context
        assert!((2.0..8.0).contains(&gap_long), "gap_long={gap_long}");
    }

    #[test]
    fn fig13a_small_chunk_prefill_gap() {
        // With chunk 128 over 1M tokens, vLLM's per-iteration overheads and
        // query-only kernels cost ~6x vs Medha.
        let v = vllm();
        let m = medha_pm();
        let t_v = v.prefill_time_chunked(1_000_000, 128);
        let t_m = m.prefill_time_monolithic(1_000_000, 128);
        let ratio = t_v / t_m;
        assert!((3.0..12.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn monolithic_prefill_blocks_for_long() {
        let v = vllm();
        let t = v.prefill_time_monolithic(1_000_000);
        assert!(t > 10.0, "1M monolithic prefill should take >10s, got {t}");
    }

    #[test]
    fn large_chunks_approach_medha() {
        // At chunk 4096 the kernel floor is irrelevant and overhead
        // amortizes: within ~2x of Medha.
        let v = vllm();
        let m = medha_pm();
        let ratio = v.prefill_time_chunked(1_000_000, 4096)
            / m.prefill_time_monolithic(1_000_000, 4096);
        assert!(ratio < 2.0, "ratio={ratio}");
    }
}
