//! Baseline systems the paper compares against, reimplemented as scheduling
//! / parallelism policies over the same roofline substrate (DESIGN.md §3):
//!
//! * **Ring / Striped attention** (Liu et al. / Brandon et al.): sequence-
//!   parallel prefill across servers with cyclic KV transfers — fast
//!   prefill, but monolithic (no preemption, no batching) and no decode
//!   story (Table 1, Figs. 14a/14b).
//! * **vLLM-like engine**: continuous batching without Medha's platform
//!   optimizations — centralized scheduler overhead and CPU-side page-table
//!   copies that grow with context length (Fig. 13).
//! * **Conventional pipeline parallelism** is in
//!   `coordinator::spp::conventional_pp_prefill_schedule` (Fig. 9a).

pub mod disagg;
pub mod ring;
pub mod table1;
pub mod vllm;

pub use disagg::{DisaggLatency, DisaggModel};
pub use ring::{ring_prefill_time, striped_prefill_time, RingConfig};
pub use table1::{capability_matrix, Capability};
pub use vllm::VllmModel;
