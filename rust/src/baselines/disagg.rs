//! Prefill-decode disaggregation baseline (section 2.4, DistServe/Splitwise
//! style; revisited in section 7 "online vs offline inference").
//!
//! Dedicated prefill workers run homogeneous prefill batches; the finished
//! KV cache is then shipped to decode workers. For long contexts the paper
//! argues this is unattractive **online** because the transfer volume is the
//! whole KV cache (hundreds of GB at 1M+ tokens) and the cache occupies both
//! pools during the handoff — but attractive **offline** (context building),
//! which this model also quantifies.

use crate::config::{HardwareConfig, ModelConfig, ParallelismConfig};
use crate::perfmodel::{BatchShape, PerfModel};

#[derive(Debug, Clone)]
pub struct DisaggModel {
    pm: PerfModel,
    /// Effective KV transfer bandwidth between the pools (bytes/s). IB per
    /// GPU pair times the TP degree (parallel planes).
    pub transfer_bw: f64,
}

/// Latency breakdown of a disaggregated request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DisaggLatency {
    pub prefill_s: f64,
    pub transfer_s: f64,
    pub decode_tbt_s: f64,
}

impl DisaggLatency {
    /// TTFT as the user sees it: prefill + cache handoff.
    pub fn ttft_s(&self) -> f64 {
        self.prefill_s + self.transfer_s
    }
}

impl DisaggModel {
    pub fn new(model: ModelConfig, hw: HardwareConfig, parallel: ParallelismConfig) -> DisaggModel {
        let transfer_bw = hw.inter_node.bandwidth * parallel.tp as f64;
        DisaggModel {
            pm: PerfModel::new(model, hw, parallel),
            transfer_bw,
        }
    }

    /// KV bytes that must cross pools for an `n`-token context.
    pub fn kv_transfer_bytes(&self, n: u64) -> f64 {
        self.pm.model.kv_bytes(n) as f64
    }

    pub fn latency(&self, n: u64, chunk: u64) -> DisaggLatency {
        DisaggLatency {
            prefill_s: self.pm.prefill_time_spp(n, chunk),
            transfer_s: self.kv_transfer_bytes(n) / self.transfer_bw,
            decode_tbt_s: self
                .pm
                .iteration_time(&BatchShape::decode_only(&[n]))
                .total(),
        }
    }

    /// Peak memory pressure during handoff: the cache lives in BOTH pools.
    pub fn handoff_bytes(&self, n: u64) -> f64 {
        2.0 * self.kv_transfer_bytes(n)
    }

    pub fn perf_model(&self) -> &PerfModel {
        &self.pm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;

    fn disagg(spp: u32) -> DisaggModel {
        let d = DeploymentConfig::llama3_8b_tp8().with_parallel(8, spp, 1);
        DisaggModel::new(d.model, d.hardware, d.parallel)
    }

    #[test]
    fn transfer_stalls_decode_at_long_context() {
        // Section 2.4: the handoff moves the *whole* KV cache — at long
        // context that is a stall worth hundreds of decode iterations
        // (prefill itself is quadratic, so the linear transfer never beats
        // it; the cost is felt against decode-side interactivity and
        // memory, not prefill time).
        let m = disagg(16);
        let l = m.latency(4_000_000, 4096);
        assert!(
            l.transfer_s > 50.0 * l.decode_tbt_s,
            "transfer {} vs tbt {}",
            l.transfer_s,
            l.decode_tbt_s
        );
    }

    #[test]
    fn transfer_is_small_for_short_context() {
        // Short contexts: the handoff is a few ms — why disaggregation IS
        // attractive at ordinary lengths (Splitwise/DistServe).
        let m = disagg(1);
        let l = m.latency(8_000, 2048);
        assert!(l.transfer_s < 0.010, "{}", l.transfer_s);
        assert!(l.transfer_s < l.prefill_s);
    }

    #[test]
    fn handoff_doubles_memory() {
        let m = disagg(1);
        let n = 1_000_000;
        assert_eq!(m.handoff_bytes(n), 2.0 * m.kv_transfer_bytes(n));
        // 8B @1M: ~131 GB KV -> handoff pressure ~262 GB
        let gb = m.handoff_bytes(n) / 1e9;
        assert!((100.0..400.0).contains(&gb), "{gb} GB");
    }

    #[test]
    fn medha_colocated_ttft_beats_disagg_online() {
        // Same GPUs: Medha serves TTFT without the transfer term.
        let m = disagg(8);
        let l = m.latency(2_000_000, 4096);
        let medha_ttft = m.perf_model().prefill_time_spp(2_000_000, 4096);
        assert!(l.ttft_s() > medha_ttft);
    }
}
