//! Table 1: qualitative capability matrix of parallelization strategies.
//! Generated programmatically from each strategy's properties so the bench
//! harness can print the paper's table.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scalability {
    Up,
    Down,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Capability {
    pub name: &'static str,
    /// Can combine with chunked prefills for fine-grained preemption.
    pub preemptable: bool,
    pub faster_prefills: bool,
    pub faster_decodes: bool,
    pub scalability: Scalability,
}

/// The six rows of Table 1.
pub fn capability_matrix() -> Vec<Capability> {
    vec![
        Capability {
            name: "Pipeline Parallelism (PP)",
            preemptable: true,
            faster_prefills: false,
            faster_decodes: false,
            scalability: Scalability::Up,
        },
        Capability {
            name: "Tensor Parallelism (TP)",
            preemptable: true,
            faster_prefills: true,
            faster_decodes: true,
            scalability: Scalability::Down,
        },
        Capability {
            name: "Ring/Striped Attention (RA)",
            preemptable: false,
            faster_prefills: true,
            faster_decodes: false,
            scalability: Scalability::Up,
        },
        Capability {
            name: "Sequence Pipeline Parallelism (SPP)",
            preemptable: true,
            faster_prefills: true,
            faster_decodes: false,
            scalability: Scalability::Up,
        },
        Capability {
            name: "KV Parallelism (KVP)",
            preemptable: true,
            faster_prefills: true,
            faster_decodes: true,
            scalability: Scalability::Down,
        },
        Capability {
            name: "Mnemosyne 3D Parallelism (3DP)",
            preemptable: true,
            faster_prefills: true,
            faster_decodes: true,
            scalability: Scalability::Up,
        },
    ]
}

pub fn render_matrix() -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<38} {:>12} {:>16} {:>15} {:>12}\n",
        "Parallelism Strategy", "Preemptable", "Faster Prefills", "Faster Decodes", "Scalability"
    ));
    for c in capability_matrix() {
        let tick = |b: bool| if b { "yes" } else { "no" };
        out.push_str(&format!(
            "{:<38} {:>12} {:>16} {:>15} {:>12}\n",
            c.name,
            tick(c.preemptable),
            tick(c.faster_prefills),
            tick(c.faster_decodes),
            match c.scalability {
                Scalability::Up => "high",
                Scalability::Down => "low",
            }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_matches_paper() {
        let m = capability_matrix();
        assert_eq!(m.len(), 6);
        let get = |n: &str| m.iter().find(|c| c.name.contains(n)).unwrap().clone();
        // Ring attention: not preemptable, prefill-only, scales up
        let ra = get("Ring");
        assert!(!ra.preemptable && ra.faster_prefills && !ra.faster_decodes);
        // 3DP: everything + scales
        let dp = get("3DP");
        assert!(dp.preemptable && dp.faster_prefills && dp.faster_decodes);
        assert_eq!(dp.scalability, Scalability::Up);
        // TP fast but unscalable
        let tp = get("Tensor");
        assert_eq!(tp.scalability, Scalability::Down);
    }

    #[test]
    fn renders_all_rows() {
        let s = render_matrix();
        assert_eq!(s.lines().count(), 7);
        assert!(s.contains("3DP"));
    }
}
