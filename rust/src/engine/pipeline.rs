//! Multi-threaded SPP pipeline serving (section 4.3 on real hardware).
//!
//! One OS thread per pipeline stage, each with its **own PJRT CPU client**
//! (the `xla` crate's client is Rc-based and not `Send`; separate clients
//! per thread give true parallelism with no unsafe). Stages are connected
//! by mpsc channels; the driver feeds prefill chunks **densely** — chunk
//! i+1 enters stage 0 as soon as stage 0 finishes chunk i — which is
//! exactly the dense schedule of Fig. 9b, measured here with wall clocks.
//!
//! Activations cross stage boundaries as host vectors (the CPU analogue of
//! the paper's inter-node activation hop).
//!
//! Wall-clock note: D2-allowlisted (`medha lint`) — this module serves
//! the *real* model, so its TTFT/TBT are genuine wall-clock readings, not
//! simulator state.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{argmax, chunk_schedule};
use crate::runtime::{lit_f32, lit_i32, lit_zeros_f32, load_weights, to_vec_f32, Runtime};

/// Reserved request id for warmup traffic (compiles every executable before
/// the serving clock starts; stage workers do not retain its cache).
const WARMUP_REQ: usize = usize::MAX;

/// A unit of work flowing through the pipeline.
enum Msg {
    Chunk {
        req: usize,
        /// Hidden states [c, d_model] entering this stage.
        h: Vec<f32>,
        c: usize,
        start: i32,
        /// Marks the request's final prompt chunk or a decode step (the
        /// driver needs logits back for these).
        wants_logits: bool,
    },
    Stop,
}

/// Per-request serving record.
#[derive(Debug, Clone)]
pub struct RequestReport {
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    pub ttft_s: f64,
    pub tbt_s: Vec<f64>,
}

#[derive(Debug)]
pub struct ServeReport {
    pub requests: Vec<RequestReport>,
    pub wall_s: f64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
}

impl ServeReport {
    pub fn decode_tps(&self) -> f64 {
        self.decode_tokens as f64 / self.wall_s
    }

    pub fn total_tps(&self) -> f64 {
        (self.decode_tokens + self.prefill_tokens) as f64 / self.wall_s
    }
}

/// One stage worker: owns a PJRT client, its layers' weights, and the
/// per-request caches for its stage.
fn stage_worker(
    dir: PathBuf,
    stage: usize,
    lps: u32,
    rx: mpsc::Receiver<Msg>,
    tx: mpsc::Sender<Msg>,
) -> Result<()> {
    let rt = Runtime::load(&dir)?;
    let spec = rt.manifest.spec;
    let weights = load_weights(&dir, &rt.manifest)?;
    let mut stage_ws = Vec::new();
    for layer in stage * lps as usize..(stage + 1) * lps as usize {
        for nm in &rt.manifest.layer_weight_names {
            let t = &weights[&format!("layers.{layer}.{nm}")];
            stage_ws.push(lit_f32(&t.shape, &t.data)?);
        }
    }
    let cache_shape = [lps as usize, spec.max_seq, spec.hkv, spec.d_head];
    let mut caches: BTreeMap<usize, (xla::Literal, xla::Literal)> = BTreeMap::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Stop => {
                let _ = tx.send(Msg::Stop);
                break;
            }
            Msg::Chunk {
                req,
                h,
                c,
                start,
                wants_logits,
            } => {
                let entry = format!("stage_c{c}_l{lps}");
                let (ck, cv) = match caches.remove(&req) {
                    Some(x) => x,
                    None => (lit_zeros_f32(&cache_shape)?, lit_zeros_f32(&cache_shape)?),
                };
                let h_lit = lit_f32(&[c, spec.d_model], &h)?;
                let start_lit = lit_i32(&[1], &[start])?;
                // weights/caches by reference — Literal::clone deep-copies
                let mut args: Vec<&xla::Literal> = vec![&h_lit, &ck, &cv, &start_lit];
                args.extend(stage_ws.iter());
                let mut out = rt.call_refs(&entry, &args)?;
                let h_out = to_vec_f32(&out[0])?;
                let cv2 = out.remove(2);
                let ck2 = out.remove(1);
                if req != WARMUP_REQ {
                    caches.insert(req, (ck2, cv2));
                }
                tx.send(Msg::Chunk {
                    req,
                    h: h_out,
                    c,
                    start,
                    wants_logits,
                })
                .map_err(|_| anyhow!("stage {stage}: downstream hung up"))?;
            }
        }
    }
    Ok(())
}

/// A request submitted to the pipeline server.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Serve `requests` through an `n_stages`-deep SPP pipeline.
///
/// Scheduling: prefill chunks of all requests are admitted densely and
/// round-robin interleaved (continuous batching at chunk granularity);
/// decodes are autoregressive (token t+1 admitted when t's logits return),
/// interleaving with other requests' chunks in flight.
pub fn serve(
    dir: impl AsRef<Path>,
    n_stages: usize,
    chunk_cap: u64,
    requests: &[ServeRequest],
) -> Result<ServeReport> {
    let dir = dir.as_ref().to_path_buf();
    // Driver-side runtime for embed / lm_head.
    let rt = Runtime::load(&dir)?;
    let spec = rt.manifest.spec;
    let lps_all = rt.manifest.stage_buckets.clone();
    let lps = (spec.n_layers / n_stages) as u32;
    if !lps_all.contains(&lps) {
        anyhow::bail!(
            "n_stages={n_stages} needs layers-per-stage {lps}, available {lps_all:?}"
        );
    }

    // Build the stage chain: driver -> s0 -> s1 ... -> driver.
    let (tx0, mut prev_rx) = mpsc::channel::<Msg>();
    let mut handles = Vec::new();
    for s in 0..n_stages {
        let (tx_next, rx_next) = mpsc::channel::<Msg>();
        let dir_c = dir.clone();
        let rx = std::mem::replace(&mut prev_rx, rx_next);
        handles.push(std::thread::spawn(move || {
            stage_worker(dir_c, s, lps, rx, tx_next)
        }));
        let _ = s;
    }
    let final_rx = prev_rx;

    let emb_t = {
        let w = load_weights(&dir, &rt.manifest)?;
        (
            lit_f32(&w["embed"].shape, &w["embed"].data)?,
            lit_f32(&w["final_norm"].shape, &w["final_norm"].data)?,
        )
    };

    // ---- warmup: compile every executable on every stage thread BEFORE
    // the serving clock starts (the CUDA-graph-capture analogue; serving
    // metrics must measure steady-state, not compilation).
    {
        let mut sizes: Vec<usize> = requests
            .iter()
            .flat_map(|r| {
                chunk_schedule(r.prompt.len() as u64, &rt.manifest.chunk_buckets, chunk_cap)
            })
            .map(|c| c as usize)
            .collect();
        sizes.push(1);
        sizes.sort_unstable();
        sizes.dedup();
        let mut outstanding = 0usize;
        for &c in &sizes {
            let toks = vec![0i32; c];
            let out = rt.call(
                &format!("embed_c{c}"),
                &[lit_i32(&[c], &toks)?, emb_t.0.clone()],
            )?;
            let h = to_vec_f32(&out[0])?;
            // compile lm_head for this bucket too
            let _ = rt.call(
                &format!("lm_head_c{c}"),
                &[
                    lit_f32(&[c, spec.d_model], &h)?,
                    emb_t.1.clone(),
                    emb_t.0.clone(),
                ],
            )?;
            tx0.send(Msg::Chunk {
                req: WARMUP_REQ,
                h,
                c,
                start: 0,
                wants_logits: false,
            })
            .map_err(|_| anyhow!("pipeline hung up during warmup"))?;
            outstanding += 1;
        }
        while outstanding > 0 {
            let _ = final_rx.recv().map_err(|_| anyhow!("pipeline died in warmup"))?;
            outstanding -= 1;
        }
    }

    // Per-request driver state.
    struct Drive {
        prompt: Vec<i32>,
        schedule: Vec<u64>,
        next_chunk: usize,
        off: usize,
        pos: i32,
        max_new: usize,
        generated: Vec<i32>,
        t_submit: Instant,
        ttft: Option<f64>,
        last_tok_t: Option<Instant>,
        tbt: Vec<f64>,
        done: bool,
    }

    let t0 = Instant::now();
    let mut drives: Vec<Drive> = requests
        .iter()
        .map(|r| Drive {
            prompt: r.prompt.clone(),
            schedule: chunk_schedule(r.prompt.len() as u64, &rt.manifest.chunk_buckets, chunk_cap),
            next_chunk: 0,
            off: 0,
            pos: 0,
            max_new: r.max_new_tokens,
            generated: Vec::new(),
            t_submit: t0,
            ttft: None,
            last_tok_t: None,
            tbt: Vec::new(),
            done: false,
        })
        .collect();

    let embed_chunk = |tokens: &[i32]| -> Result<Vec<f32>> {
        let c = tokens.len();
        let out = rt.call(
            &format!("embed_c{c}"),
            &[lit_i32(&[c], tokens)?, emb_t.0.clone()],
        )?;
        to_vec_f32(&out[0])
    };
    let lm_head_last = |h: &[f32], c: usize| -> Result<Vec<f32>> {
        let out = rt.call(
            &format!("lm_head_c{c}"),
            &[
                lit_f32(&[c, spec.d_model], h)?,
                emb_t.1.clone(),
                emb_t.0.clone(),
            ],
        )?;
        let v = to_vec_f32(&out[0])?;
        Ok(v[(c - 1) * spec.vocab..].to_vec())
    };

    // Feed: round-robin admit each request's next prefill chunk (dense).
    let mut in_flight = 0usize;
    let mut prefill_tokens = 0u64;
    let mut decode_tokens = 0u64;
    let max_in_flight = n_stages + 2; // keep the pipeline full, bounded

    let mut submit_next_prefill = |d: &mut Drive, req: usize, in_flight: &mut usize| -> Result<bool> {
        if d.next_chunk >= d.schedule.len() {
            return Ok(false);
        }
        let c = d.schedule[d.next_chunk] as usize;
        let toks = &d.prompt[d.off..d.off + c];
        let h = embed_chunk(toks)?;
        let wants = d.next_chunk + 1 == d.schedule.len();
        tx0.send(Msg::Chunk {
            req,
            h,
            c,
            start: d.pos,
            wants_logits: wants,
        })
        .map_err(|_| anyhow!("pipeline hung up"))?;
        d.next_chunk += 1;
        d.off += c;
        d.pos += c as i32;
        prefill_tokens += c as u64;
        *in_flight += 1;
        Ok(true)
    };

    // Admission order: shortest remaining prefill first — small requests
    // slot in between a long request's chunks instead of queueing behind
    // them (the anti-HOL property chunked prefill exists to provide).
    let admission_order = |drives: &[Drive]| -> Vec<usize> {
        let mut idx: Vec<usize> = (0..drives.len()).collect();
        idx.sort_by_key(|&i| drives[i].schedule.len() - drives[i].next_chunk);
        idx
    };

    // Prime the pipeline.
    'prime: loop {
        let mut any = false;
        for i in admission_order(&drives) {
            if in_flight >= max_in_flight {
                break 'prime;
            }
            if submit_next_prefill(&mut drives[i], i, &mut in_flight)? {
                any = true;
            }
        }
        if !any {
            break;
        }
    }

    // Main loop: receive completed chunks, admit more work.
    while in_flight > 0 {
        let msg = final_rx.recv().map_err(|_| anyhow!("pipeline died"))?;
        let Msg::Chunk {
            req,
            h,
            c,
            wants_logits,
            ..
        } = msg
        else {
            break;
        };
        in_flight -= 1;
        if wants_logits {
            let logits = lm_head_last(&h, c)?;
            let tok = argmax(&logits);
            let d = &mut drives[req];
            let now = Instant::now();
            if d.ttft.is_none() {
                d.ttft = Some(now.duration_since(d.t_submit).as_secs_f64());
            }
            if let Some(last) = d.last_tok_t {
                d.tbt.push(now.duration_since(last).as_secs_f64());
            }
            d.last_tok_t = Some(now);
            d.generated.push(tok);
            decode_tokens += 1;
            if d.generated.len() < d.max_new {
                // submit the decode step for this request
                let hvec = embed_chunk(&[tok])?;
                tx0.send(Msg::Chunk {
                    req,
                    h: hvec,
                    c: 1,
                    start: d.pos,
                    wants_logits: true,
                })
                .map_err(|_| anyhow!("pipeline hung up"))?;
                d.pos += 1;
                in_flight += 1;
            } else {
                d.done = true;
            }
        }
        // top up prefill work (shortest remaining first)
        for i in admission_order(&drives) {
            if in_flight >= max_in_flight {
                break;
            }
            if submit_next_prefill(&mut drives[i], i, &mut in_flight)? {}
        }
    }

    let _ = tx0.send(Msg::Stop);
    for h in handles {
        h.join().map_err(|_| anyhow!("stage thread panicked"))??;
    }

    let wall_s = t0.elapsed().as_secs_f64();
    Ok(ServeReport {
        requests: drives
            .into_iter()
            .map(|d| RequestReport {
                prompt_len: d.prompt.len(),
                generated: d.generated,
                ttft_s: d.ttft.unwrap_or(f64::NAN),
                tbt_s: d.tbt,
            })
            .collect(),
        wall_s,
        prefill_tokens,
        decode_tokens,
    })
}
