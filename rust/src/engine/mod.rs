//! Real-model serving engine: wires the coordinator to the PJRT runtime to
//! serve the tiny GQA transformer end-to-end on CPU with real numerics.
//!
//! * `Engine` — single-client execution: chunked prefill + greedy decode
//!   with the golden-output check, and the KVP partial/merge orchestration
//!   (the same math the coordinator's KVP manager schedules at scale).
//! * `pipeline::PipelineServer` — multi-threaded SPP serving: one PJRT
//!   client per pipeline stage, dense chunk admission, mixed request
//!   interleaving (in `pipeline.rs`).
//!
//! PJRT note: the `xla` crate's client is `Rc`-based (not `Send`), so
//! cross-thread parallelism uses one client per stage thread rather than a
//! shared client — see pipeline.rs.

pub mod pipeline;

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{
    lit_f32, lit_i32, lit_zeros_f32, load_weights, to_vec_f32, HostTensor, Runtime, TinySpec,
};
use std::collections::BTreeMap;

/// Byte-level tokenizer (vocab = 256) for the demo model.
pub fn tokenize(s: &str) -> Vec<i32> {
    s.bytes().map(|b| b as i32).collect()
}

pub fn detokenize(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| (t.clamp(0, 255) as u8) as char)
        .collect()
}

/// Decompose `len` into a greedy schedule over the available chunk buckets
/// (largest-first; buckets always include 1 so any length is exact).
pub fn chunk_schedule(len: u64, buckets: &[u64], cap: u64) -> Vec<u64> {
    let mut bs: Vec<u64> = buckets.iter().copied().filter(|&b| b <= cap.max(1)).collect();
    bs.sort_unstable_by(|a, b| b.cmp(a));
    assert!(bs.last() == Some(&1), "buckets must include 1");
    let mut out = Vec::new();
    let mut left = len;
    while left > 0 {
        let &b = bs.iter().find(|&&b| b <= left).unwrap();
        out.push(b);
        left -= b;
    }
    out
}

/// Per-sequence state: one (ck, cv) literal pair per pipeline stage.
pub struct SeqState {
    pub caches: Vec<(xla::Literal, xla::Literal)>,
    pub pos: u64,
}

/// Single-client engine over the full model (stage bucket = all layers or a
/// chosen split executed sequentially on one client).
pub struct Engine {
    pub rt: Runtime,
    pub spec: TinySpec,
    /// Layers per stage (must be one of the manifest's stage buckets).
    pub lps: u32,
    pub n_stages: usize,
    weights: BTreeMap<String, HostTensor>,
    /// Prebuilt weight literals per stage, in stage-entry argument order.
    stage_weights: Vec<Vec<xla::Literal>>,
    emb: xla::Literal,
    final_norm: xla::Literal,
}

impl Engine {
    pub fn load(dir: impl AsRef<Path>, lps: u32) -> Result<Engine> {
        let rt = Runtime::load(dir.as_ref())?;
        let spec = rt.manifest.spec;
        if !rt.manifest.stage_buckets.contains(&lps) {
            bail!(
                "layers-per-stage {lps} not in artifact buckets {:?}",
                rt.manifest.stage_buckets
            );
        }
        let weights = load_weights(dir.as_ref(), &rt.manifest)?;
        let n_stages = spec.n_layers / lps as usize;
        let mut stage_weights = Vec::with_capacity(n_stages);
        for s in 0..n_stages {
            let mut ws = Vec::new();
            for layer in s * lps as usize..(s + 1) * lps as usize {
                for nm in &rt.manifest.layer_weight_names {
                    let t = weights
                        .get(&format!("layers.{layer}.{nm}"))
                        .ok_or_else(|| anyhow!("missing weight layers.{layer}.{nm}"))?;
                    ws.push(lit_f32(&t.shape, &t.data)?);
                }
            }
            stage_weights.push(ws);
        }
        let emb = {
            let t = &weights["embed"];
            lit_f32(&t.shape, &t.data)?
        };
        let final_norm = {
            let t = &weights["final_norm"];
            lit_f32(&t.shape, &t.data)?
        };
        Ok(Engine {
            spec,
            lps,
            n_stages,
            weights,
            stage_weights,
            emb,
            final_norm,
            rt,
        })
    }

    pub fn new_state(&self) -> Result<SeqState> {
        let shape = [
            self.lps as usize,
            self.spec.max_seq,
            self.spec.hkv,
            self.spec.d_head,
        ];
        let caches = (0..self.n_stages)
            .map(|_| Ok((lit_zeros_f32(&shape)?, lit_zeros_f32(&shape)?)))
            .collect::<Result<Vec<_>>>()?;
        Ok(SeqState { caches, pos: 0 })
    }

    /// Run one chunk (size must be a manifest bucket) through all stages.
    /// Returns the final logits for the chunk's tokens, row-major [c, vocab].
    pub fn forward_chunk(&self, state: &mut SeqState, tokens: &[i32]) -> Result<Vec<f32>> {
        let c = tokens.len();
        if state.pos as usize + c > self.spec.max_seq {
            bail!(
                "sequence overflow: pos {} + chunk {c} > max_seq {}",
                state.pos,
                self.spec.max_seq
            );
        }
        let tok_lit = lit_i32(&[c], tokens)?;
        let mut h = self
            .rt
            .call_refs(&format!("embed_c{c}"), &[&tok_lit, &self.emb])?
            .remove(0);
        let start = lit_i32(&[1], &[state.pos as i32])?;
        for s in 0..self.n_stages {
            // All big operands (weights, caches) passed by reference —
            // Literal::clone would deep-copy ~MBs per call (§Perf).
            let mut args: Vec<&xla::Literal> =
                vec![&h, &state.caches[s].0, &state.caches[s].1, &start];
            args.extend(self.stage_weights[s].iter());
            let mut out = self
                .rt
                .call_refs(&format!("stage_c{c}_l{}", self.lps), &args)?;
            let h_new = out.remove(0);
            let ck = out.remove(0);
            let cv = out.remove(0);
            h = h_new;
            state.caches[s] = (ck, cv);
        }
        state.pos += c as u64;
        let logits = self
            .rt
            .call_refs(
                &format!("lm_head_c{c}"),
                &[&h, &self.final_norm, &self.emb],
            )?
            .remove(0);
        to_vec_f32(&logits)
    }

    /// Chunked prefill over the whole prompt; returns the last token's logits.
    pub fn prefill(&self, state: &mut SeqState, prompt: &[i32], chunk_cap: u64) -> Result<Vec<f32>> {
        let schedule = chunk_schedule(
            prompt.len() as u64,
            &self.rt.manifest.chunk_buckets,
            chunk_cap,
        );
        let mut off = 0usize;
        let mut last = Vec::new();
        for c in schedule {
            let logits = self.forward_chunk(state, &prompt[off..off + c as usize])?;
            off += c as usize;
            let v = self.spec.vocab;
            last = logits[(c as usize - 1) * v..].to_vec();
        }
        Ok(last)
    }

    /// Greedy generation (prefill + decode). Returns generated token ids.
    pub fn generate(&self, prompt: &[i32], n_new: usize, chunk_cap: u64) -> Result<Vec<i32>> {
        let mut state = self.new_state()?;
        let mut logits = self.prefill(&mut state, prompt, chunk_cap)?;
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            let tok = argmax(&logits);
            out.push(tok);
            let l = self.forward_chunk(&mut state, &[tok])?;
            logits = l;
        }
        Ok(out)
    }

    /// Verify the engine reproduces the golden generation recorded at AOT
    /// time by the pure-JAX reference — the end-to-end correctness gate.
    pub fn verify_golden(&self) -> Result<usize> {
        let g = self
            .rt
            .manifest
            .golden
            .clone()
            .ok_or_else(|| anyhow!("manifest has no golden record"))?;
        let got = self.generate(&g.prompt, g.generated.len(), g.chunk_size)?;
        let matches = got
            .iter()
            .zip(&g.generated)
            .filter(|(a, b)| a == b)
            .count();
        if matches != g.generated.len() {
            bail!(
                "golden mismatch: {matches}/{} tokens (got {:?}, want {:?})",
                g.generated.len(),
                got,
                g.generated
            );
        }
        Ok(matches)
    }

    // --- KVP orchestration over the runtime (section 4.4 numerics) --------

    /// Decode attention for one query against a KV range [0, kv_len) held in
    /// `k`/`v` (host row-major [n, hkv, dh]), sharded across `n_shards`
    /// groups of `shard_cap` rows, merged with online softmax. Returns
    /// [hq * dh]. This is the exact orchestration the KVP manager schedules
    /// across worker groups, executed against real artifacts.
    pub fn kvp_decode_attention(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        kv_len: usize,
        shard_cap: usize,
        n_shards: usize,
    ) -> Result<Vec<f32>> {
        let spec = self.spec;
        let row = spec.hkv * spec.d_head;
        if !self
            .rt
            .manifest
            .kvp_shard_caps
            .contains(&(shard_cap as u64))
        {
            bail!("shard cap {shard_cap} not an artifact bucket");
        }
        if !self
            .rt
            .manifest
            .kvp_merge_counts
            .contains(&(n_shards as u32))
        {
            bail!("merge count {n_shards} not an artifact bucket");
        }
        let q_lit = lit_f32(&[1, spec.hq, spec.d_head], q)?;
        let mut os = Vec::new();
        let mut ms = Vec::new();
        let mut ls = Vec::new();
        for s in 0..n_shards {
            let lo = s * shard_cap;
            let hi = ((s + 1) * shard_cap).min(k.len() / row);
            let mut ks = vec![0f32; shard_cap * row];
            let mut vs = vec![0f32; shard_cap * row];
            if lo < hi {
                ks[..(hi - lo) * row].copy_from_slice(&k[lo * row..hi * row]);
                vs[..(hi - lo) * row].copy_from_slice(&v[lo * row..hi * row]);
            }
            let shard_len = kv_len.saturating_sub(lo).min(shard_cap);
            let out = self.rt.call(
                &format!("kvp_partial_c1_s{shard_cap}"),
                &[
                    q_lit.clone(),
                    lit_f32(&[shard_cap, spec.hkv, spec.d_head], &ks)?,
                    lit_f32(&[shard_cap, spec.hkv, spec.d_head], &vs)?,
                    lit_i32(&[1], &[(kv_len - 1) as i32])?,
                    lit_i32(&[1], &[lo as i32])?,
                    lit_i32(&[1], &[shard_len as i32])?,
                ],
            )?;
            os.push(to_vec_f32(&out[0])?);
            ms.push(to_vec_f32(&out[1])?);
            ls.push(to_vec_f32(&out[2])?);
        }
        let flat = |xs: &[Vec<f32>]| xs.concat();
        let merged = self.rt.call(
            &format!("kvp_merge_s{n_shards}_c1"),
            &[
                lit_f32(&[n_shards, 1, spec.hq, spec.d_head], &flat(&os))?,
                lit_f32(&[n_shards, 1, spec.hq], &flat(&ms))?,
                lit_f32(&[n_shards, 1, spec.hq], &flat(&ls))?,
            ],
        )?;
        to_vec_f32(&merged[0])
    }

    /// Monolithic reference for the same computation (single shard over a
    /// big-enough cap), for equivalence checks.
    pub fn monolithic_decode_attention(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        kv_len: usize,
        cap: usize,
    ) -> Result<Vec<f32>> {
        let spec = self.spec;
        let row = spec.hkv * spec.d_head;
        let mut ks = vec![0f32; cap * row];
        let mut vs = vec![0f32; cap * row];
        let n = (k.len() / row).min(cap);
        ks[..n * row].copy_from_slice(&k[..n * row]);
        vs[..n * row].copy_from_slice(&v[..n * row]);
        let out = self.rt.call(
            &format!("kvp_partial_c1_s{cap}"),
            &[
                lit_f32(&[1, spec.hq, spec.d_head], q)?,
                lit_f32(&[cap, spec.hkv, spec.d_head], &ks)?,
                lit_f32(&[cap, spec.hkv, spec.d_head], &vs)?,
                lit_i32(&[1], &[(kv_len - 1) as i32])?,
                lit_i32(&[1], &[0])?,
                lit_i32(&[1], &[kv_len as i32])?,
            ],
        )?;
        to_vec_f32(&out[0])
    }

    pub fn weight(&self, name: &str) -> Option<&HostTensor> {
        self.weights.get(name)
    }
}

pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_schedule_greedy_largest_first() {
        let buckets = [1, 16, 64, 256];
        assert_eq!(
            chunk_schedule(300, &buckets, 256),
            vec![256, 16, 16, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1]
        );
        // cap limits the largest bucket used
        assert_eq!(chunk_schedule(40, &buckets, 16), vec![16, 16, 1, 1, 1, 1, 1, 1, 1, 1]);
    }

    #[test]
    fn chunk_schedule_sums() {
        let buckets = [1u64, 16, 64, 256];
        for len in [1u64, 5, 16, 100, 300, 1000, 2047] {
            for cap in [1u64, 16, 64, 256] {
                let s = chunk_schedule(len, &buckets, cap);
                assert_eq!(s.iter().sum::<u64>(), len, "len={len} cap={cap}");
                assert!(s.iter().all(|&c| c <= cap));
            }
        }
    }

    #[test]
    fn tokenizer_roundtrip() {
        let s = "Hello, Medha!";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1);
    }
}
