//! KV-parallel worker-group management (section 4.4, Figs. 10 & 19).
//!
//! A long request's KV cache grows as prefill progresses. Rather than
//! pre-allocating all KVP groups, the manager onboards groups *dynamically*:
//! each group holds at most `onboard_threshold` KV tokens of the request;
//! when the active group fills, the next group joins — round-robin,
//! **skipping groups whose capacity ledger is out of KV room** (growth only
//! falls back to overflow-absorbing into the last shard when the whole
//! fleet is full). Groups not serving a long request remain independent
//! replicas that can batch short requests (section 7's scheduling
//! opportunity — exercised by the router).
//!
//! Long requests are keyed by their arena [`Slot`]; the external
//! `RequestId` is kept alongside only for the onboarding log (the Fig. 19
//! timeline reports client-visible ids).
//!
//! The manager is also the per-group **KV-capacity ledger** routing
//! consults: resident long-request shard tokens (`occupancy`, maintained
//! incrementally) plus short-request reservations (`reserve`/`unreserve`,
//! prompt + output tokens held from admission to retirement) against a
//! per-group `capacity`. [`KvpManager::kv_free`] is the O(1) read behind
//! `GroupView::kv_free`, letting `SchedPolicy::route` refuse placements
//! that would not fit. The default capacity is unlimited — the
//! pre-capacity behavior, and what every oracle-parity test runs under.

use super::arena::Slot;
use crate::kvcache::{GroupId, RequestId, ShardMap};
use crate::util::slotvec::SlotVec;

#[derive(Debug, Clone)]
struct LongEntry {
    ext_id: RequestId,
    map: ShardMap,
    /// Set while the request is preempted at a chunk boundary: its shards
    /// stay resident on every onboarded group, waiting for `resume`.
    yielded: bool,
}

#[derive(Debug, Clone)]
pub struct KvpManager {
    /// Max KV tokens of one request per group before onboarding the next.
    pub onboard_threshold: u64,
    /// Total KVP groups available.
    pub n_groups: u32,
    /// Per-group KV-token capacity (long shards + short reservations);
    /// `u64::MAX` disables capacity accounting (the default).
    pub capacity: u64,
    /// Resident long-request KV tokens per group — the incremental mirror
    /// of summing `local_tokens` over every shard map.
    occ: Vec<u64>,
    /// Short-request KV reservations per group (prompt + output tokens,
    /// held from admission to retirement).
    reserved: Vec<u64>,
    /// Shard maps per long request, slot-indexed.
    maps: SlotVec<LongEntry>,
    /// Onboarding events (time, request, group) — the Fig. 19 timeline.
    /// Each (request, group) pair appears at most once: a retained shard is
    /// **never** re-onboarded across yield/resume cycles.
    pub onboard_log: Vec<(f64, RequestId, GroupId)>,
    /// Yield/resume events: (time, request, `true` for yield / `false` for
    /// resume). Chunk-boundary preemption of the active request retains all
    /// shards, so yields never appear in `onboard_log`.
    pub yield_log: Vec<(f64, RequestId, bool)>,
}

impl KvpManager {
    /// Unlimited per-group capacity (the pre-capacity behavior).
    pub fn new(onboard_threshold: u64, n_groups: u32) -> KvpManager {
        KvpManager::with_capacity(onboard_threshold, n_groups, u64::MAX)
    }

    /// Capacity-accounted manager: each group holds at most `capacity` KV
    /// tokens of long-request shards plus short-request reservations.
    pub fn with_capacity(onboard_threshold: u64, n_groups: u32, capacity: u64) -> KvpManager {
        assert!(onboard_threshold > 0 && n_groups > 0 && capacity > 0);
        KvpManager {
            onboard_threshold,
            n_groups,
            capacity,
            occ: vec![0; n_groups as usize],
            reserved: vec![0; n_groups as usize],
            maps: SlotVec::new(),
            onboard_log: Vec::new(),
            yield_log: Vec::new(),
        }
    }

    /// Register a request; it starts on `first_group` only.
    pub fn onboard_request(&mut self, s: Slot, ext_id: RequestId, first_group: GroupId, t: f64) {
        let mut m = ShardMap::default();
        m.shards.push((first_group, 0, 0));
        self.maps.insert(
            s as usize,
            LongEntry {
                ext_id,
                map: m,
                yielded: false,
            },
        );
        self.onboard_log.push((t, ext_id, first_group));
    }

    /// Append `tokens` of processed KV for slot `s` at time `t`, onboarding
    /// new groups as thresholds are crossed. Returns the groups added (the
    /// common no-growth case returns an unallocated empty vector).
    ///
    /// Growth is **capacity-aware**: a candidate group whose KV ledger has
    /// no free tokens (long shards + short reservations at `capacity`) is
    /// skipped, in round-robin order from the last shard's group. Only when
    /// every remaining group is full does the last shard absorb the
    /// overflow — and a later append re-evaluates, so a group that frees
    /// capacity can still onboard then. A group that is onboarded with
    /// *some* room grows its shard to the full threshold (reservations are
    /// worst-case footprints, so bounded over-commit beats fragmenting the
    /// shard map). With unlimited capacity (the default) every candidate
    /// has room and growth is exactly the original round-robin.
    pub fn append_tokens(&mut self, s: Slot, mut tokens: u64, t: f64) -> Vec<GroupId> {
        let e = self.maps.get_mut(s as usize).expect("request not onboarded");
        let mut added = Vec::new();
        while tokens > 0 {
            let (g, _, len) = *e.map.shards.last().unwrap();
            let fleet_exhausted = e.map.shards.len() as u32 >= self.n_groups;
            let room = if fleet_exhausted {
                // No more groups to onboard: the last shard absorbs the rest
                // (the paper grows "until it reaches the max of 128 GPUs").
                u64::MAX
            } else {
                self.onboard_threshold.saturating_sub(len)
            };
            if room == 0 {
                // Onboard the next group: round-robin over the fleet,
                // skipping groups that already hold a shard of this request
                // and groups whose capacity ledger is out of KV room.
                let mut next = None;
                for step in 1..=self.n_groups {
                    let cand = (g + step) % self.n_groups;
                    if e.map.shards.iter().any(|&(gg, _, _)| gg == cand) {
                        continue;
                    }
                    if Self::ledger_kv_free(&self.occ, &self.reserved, self.capacity, cand) == 0 {
                        continue; // capacity-aware growth: skip full groups
                    }
                    next = Some(cand);
                    break;
                }
                match next {
                    Some(next) => {
                        let start = e.map.total_tokens();
                        e.map.shards.push((next, start, 0));
                        self.onboard_log.push((t, e.ext_id, next));
                        added.push(next);
                        continue;
                    }
                    None => {
                        // Whole fleet out of room: overflow-absorb into the
                        // current last shard rather than blowing a full
                        // group's budget. Not permanent — the next append
                        // rescans the fleet.
                        e.map.shards.last_mut().unwrap().2 += tokens;
                        self.occ[g as usize] += tokens;
                        break;
                    }
                }
            }
            let take = tokens.min(room);
            e.map.shards.last_mut().unwrap().2 += take;
            self.occ[g as usize] += take;
            tokens -= take;
        }
        added
    }

    /// Free KV tokens on group `g` per the disaggregated ledger fields —
    /// the borrow-splitting form of [`Self::kv_free`] usable while a shard
    /// map is mutably borrowed.
    fn ledger_kv_free(occ: &[u64], reserved: &[u64], capacity: u64, g: GroupId) -> u64 {
        let o = occ.get(g as usize).copied().unwrap_or(0);
        let r = reserved.get(g as usize).copied().unwrap_or(0);
        capacity.saturating_sub(o.saturating_add(r))
    }

    /// Reserve `tokens` of short-request KV on group `g` (admission).
    pub fn reserve(&mut self, g: GroupId, tokens: u64) {
        self.reserved[g as usize] += tokens;
    }

    /// Release a short-request reservation on group `g` (retirement).
    pub fn unreserve(&mut self, g: GroupId, tokens: u64) {
        let r = &mut self.reserved[g as usize];
        debug_assert!(*r >= tokens, "unreserve of tokens never reserved");
        *r = r.saturating_sub(tokens);
    }

    /// Free KV-token capacity on group `g`: capacity minus resident long
    /// shards minus short reservations. O(1) — the routing hook reads this
    /// for every group on every routed admission.
    pub fn kv_free(&self, g: GroupId) -> u64 {
        Self::ledger_kv_free(&self.occ, &self.reserved, self.capacity, g)
    }

    pub fn shard_map(&self, s: Slot) -> Option<&ShardMap> {
        self.maps.get(s as usize).map(|e| &e.map)
    }

    /// Number of groups currently cooperating on `s` (the p_kvp actually
    /// in use — Fig. 19's y-axis is this times workers/group).
    pub fn active_groups(&self, s: Slot) -> u32 {
        self.maps
            .get(s as usize)
            .map(|e| e.map.shards.len() as u32)
            .unwrap_or(0)
    }

    /// Local KV lengths per group for `s` — what each group's attention
    /// kernel scans during decode. Allocates; the simulator's hot loop
    /// iterates [`Self::shard_map`] directly instead.
    pub fn local_lengths(&self, s: Slot) -> Vec<(GroupId, u64)> {
        self.maps
            .get(s as usize)
            .map(|e| e.map.shards.iter().map(|&(g, _, n)| (g, n)).collect())
            .unwrap_or_default()
    }

    /// The *largest* local shard bounds the parallel decode-attention time.
    pub fn max_local_len(&self, s: Slot) -> u64 {
        self.local_lengths(s)
            .iter()
            .map(|&(_, n)| n)
            .max()
            .unwrap_or(0)
    }

    /// Chunk-boundary yield of the active sharded request `s`: every
    /// per-group KV shard stays exactly where it is (nothing is released,
    /// nothing re-onboarded later), the request merely stops receiving
    /// chunks until [`Self::resume`]. Panics on a request that is not
    /// onboarded or is already yielded — both are scheduler bugs.
    pub fn yield_active(&mut self, s: Slot, t: f64) {
        let e = self.maps.get_mut(s as usize).expect("yield of unknown request");
        assert!(!e.yielded, "double yield of request {}", e.ext_id);
        debug_assert!(e.map.check_contiguous());
        e.yielded = true;
        self.yield_log.push((t, e.ext_id, true));
    }

    /// Resume a previously yielded request: asserts its retained shards
    /// survived intact and clears the yielded flag. Returns `true` when
    /// the request was actually yielded (a fresh request is a no-op, so
    /// the activation path can call this unconditionally).
    pub fn resume(&mut self, s: Slot, t: f64) -> bool {
        let e = self.maps.get_mut(s as usize).expect("resume of unknown request");
        if !e.yielded {
            return false;
        }
        assert!(
            e.map.check_contiguous(),
            "request {} lost KV shards while yielded",
            e.ext_id
        );
        e.yielded = false;
        self.yield_log.push((t, e.ext_id, false));
        true
    }

    pub fn is_yielded(&self, s: Slot) -> bool {
        self.maps.get(s as usize).map(|e| e.yielded).unwrap_or(false)
    }

    /// Whether group `g` holds a KV shard of request `s`.
    pub fn holds(&self, s: Slot, g: GroupId) -> bool {
        self.maps
            .get(s as usize)
            .map(|e| e.map.shards.iter().any(|&(gg, _, _)| gg == g))
            .unwrap_or(false)
    }

    /// Total resident long-request KV tokens on group `g`, across every
    /// onboarded request — active or yielded. The router's occupancy view
    /// and the per-group utilization figure read this. O(1): maintained
    /// incrementally as shards grow and requests release (the sum over
    /// shard maps it mirrors is asserted by the invariant harness).
    pub fn occupancy(&self, g: GroupId) -> u64 {
        self.occ.get(g as usize).copied().unwrap_or(0)
    }

    /// Invariant the test harness leans on: no (request, group) pair ever
    /// appears twice in the onboarding log — a shard retained across a
    /// yield/resume cycle is never re-onboarded.
    pub fn onboard_log_is_duplicate_free(&self) -> bool {
        let mut pairs: Vec<(RequestId, GroupId)> =
            self.onboard_log.iter().map(|&(_, r, g)| (r, g)).collect();
        let n = pairs.len();
        pairs.sort_unstable();
        pairs.dedup();
        pairs.len() == n
    }

    pub fn release(&mut self, s: Slot) {
        if let Some(e) = self.maps.remove(s as usize) {
            for &(g, _, n) in &e.map.shards {
                self.occ[g as usize] -= n;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn grows_one_group_at_a_time() {
        let mut k = KvpManager::new(1000, 4);
        k.onboard_request(7, 7, 0, 0.0);
        assert_eq!(k.active_groups(7), 1);
        assert!(k.append_tokens(7, 999, 1.0).is_empty());
        assert_eq!(k.active_groups(7), 1);
        let added = k.append_tokens(7, 2, 2.0);
        assert_eq!(added, vec![1]);
        assert_eq!(k.active_groups(7), 2);
        assert_eq!(k.local_lengths(7), vec![(0, 1000), (1, 1)]);
    }

    #[test]
    fn fig19_staircase() {
        // 2M tokens, 512K threshold -> 4 groups onboarded progressively.
        let mut k = KvpManager::new(512_000, 4);
        k.onboard_request(1, 1, 0, 0.0);
        let mut t = 0.0;
        let chunk = 4096;
        let mut groups_over_time = Vec::new();
        let mut done = 0u64;
        while done < 2_000_000 {
            let c = chunk.min(2_000_000 - done);
            k.append_tokens(1, c, t);
            done += c;
            t += 0.1;
            groups_over_time.push(k.active_groups(1));
        }
        assert_eq!(*groups_over_time.last().unwrap(), 4);
        // staircase: non-decreasing, hits every level 1..=4
        assert!(groups_over_time.windows(2).all(|w| w[1] >= w[0]));
        for lvl in 1..=4 {
            assert!(groups_over_time.contains(&lvl));
        }
        assert_eq!(k.onboard_log.len(), 4); // initial + 3 growth events
    }

    #[test]
    fn shard_lengths_sum_to_processed() {
        let mut k = KvpManager::new(100, 8);
        k.onboard_request(2, 2, 3, 0.0);
        k.append_tokens(2, 777, 0.0);
        let total: u64 = k.local_lengths(2).iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 777);
        assert_eq!(k.max_local_len(2), 100);
    }

    #[test]
    fn last_group_absorbs_overflow_when_fleet_exhausted() {
        let mut k = KvpManager::new(10, 2);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 25, 0.0);
        assert_eq!(k.active_groups(1), 2);
        assert_eq!(k.local_lengths(1), vec![(0, 10), (1, 15)]);
        assert!(k.shard_map(1).unwrap().check_contiguous());
    }

    #[test]
    fn onboard_log_reports_external_ids() {
        let mut k = KvpManager::new(10, 4);
        // slot 0, external request id 999
        k.onboard_request(0, 999, 2, 1.5);
        k.append_tokens(0, 11, 2.5);
        assert_eq!(k.onboard_log[0], (1.5, 999, 2));
        assert_eq!(k.onboard_log[1], (2.5, 999, 3));
    }

    #[test]
    fn yield_retains_shards_and_never_reonboards_on_resume() {
        let mut k = KvpManager::new(100, 4);
        k.onboard_request(5, 50, 0, 0.0);
        k.append_tokens(5, 250, 1.0); // onboards groups 1 and 2
        assert_eq!(k.active_groups(5), 3);
        let log_before = k.onboard_log.clone();

        k.yield_active(5, 2.0);
        assert!(k.is_yielded(5));
        // retained exactly: shard map untouched, occupancy intact
        assert_eq!(k.local_lengths(5), vec![(0, 100), (1, 100), (2, 50)]);
        assert_eq!(k.occupancy(1), 100);

        assert!(k.resume(5, 3.0));
        assert!(!k.is_yielded(5));
        // resuming and growing logs only the *new* group, never a retained one
        k.append_tokens(5, 100, 4.0);
        assert_eq!(k.onboard_log.len(), log_before.len() + 1);
        assert_eq!(k.onboard_log.last().unwrap(), &(4.0, 50, 3));
        assert_eq!(
            k.yield_log,
            vec![(2.0, 50, true), (3.0, 50, false)]
        );
    }

    #[test]
    fn resume_of_fresh_request_is_a_noop() {
        let mut k = KvpManager::new(100, 2);
        k.onboard_request(1, 1, 0, 0.0);
        assert!(!k.resume(1, 1.0));
        assert!(k.yield_log.is_empty());
    }

    #[test]
    #[should_panic(expected = "double yield")]
    fn double_yield_panics() {
        let mut k = KvpManager::new(100, 2);
        k.onboard_request(1, 1, 0, 0.0);
        k.yield_active(1, 1.0);
        k.yield_active(1, 2.0);
    }

    #[test]
    fn occupancy_sums_across_requests_and_holds_is_per_group() {
        let mut k = KvpManager::new(100, 4);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 150, 0.0); // g0: 100, g1: 50
        k.onboard_request(2, 2, 1, 0.0);
        k.append_tokens(2, 80, 0.0); // g1: 80
        assert_eq!(k.occupancy(0), 100);
        assert_eq!(k.occupancy(1), 130);
        assert_eq!(k.occupancy(2), 0);
        assert!(k.holds(1, 0) && k.holds(1, 1) && !k.holds(1, 2));
        assert!(!k.holds(2, 0) && k.holds(2, 1));
        k.release(1);
        assert_eq!(k.occupancy(1), 80);
        assert!(!k.holds(1, 1));
    }

    #[test]
    fn capacity_ledger_tracks_shards_and_reservations() {
        let mut k = KvpManager::with_capacity(100, 2, 1_000);
        assert_eq!(k.kv_free(0), 1_000);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 150, 0.0); // g0: 100, g1: 50
        assert_eq!(k.kv_free(0), 900);
        assert_eq!(k.kv_free(1), 950);
        // short reservations stack on top of long-shard occupancy
        k.reserve(0, 300);
        assert_eq!(k.kv_free(0), 600);
        k.unreserve(0, 300);
        k.release(1);
        assert_eq!(k.kv_free(0), 1_000);
        assert_eq!(k.kv_free(1), 1_000);
        assert_eq!(k.occupancy(0), 0);
        // out-of-range groups read as empty, never panic
        assert_eq!(k.kv_free(9), 1_000);
        assert_eq!(k.occupancy(9), 0);
    }

    #[test]
    fn unlimited_capacity_never_runs_out() {
        let mut k = KvpManager::new(100, 2);
        k.reserve(0, u64::MAX / 2);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 1_000, 0.0);
        assert!(k.kv_free(0) > u64::MAX / 4, "free={}", k.kv_free(0));
    }

    #[test]
    fn capacity_full_group_is_skipped_at_growth() {
        let mut k = KvpManager::with_capacity(100, 4, 1_000);
        k.onboard_request(1, 1, 0, 0.0);
        // group 1 — the round-robin next — is out of KV room
        k.reserve(1, 1_000);
        assert_eq!(k.kv_free(1), 0);
        let added = k.append_tokens(1, 250, 1.0);
        // growth skipped the full group: 0 -> 2 -> 3
        assert_eq!(added, vec![2, 3]);
        assert_eq!(k.local_lengths(1), vec![(0, 100), (2, 100), (3, 50)]);
        assert!(k.shard_map(1).unwrap().check_contiguous());
        assert!(k.onboard_log_is_duplicate_free());
    }

    #[test]
    fn growth_overflow_absorbs_when_every_other_group_is_full() {
        let mut k = KvpManager::with_capacity(100, 3, 1_000);
        k.onboard_request(1, 1, 0, 0.0);
        k.reserve(1, 1_000);
        k.reserve(2, 1_000);
        let added = k.append_tokens(1, 250, 1.0);
        assert!(added.is_empty(), "onboarded into a full group: {added:?}");
        // the last (only) shard absorbed the overflow past its threshold
        assert_eq!(k.local_lengths(1), vec![(0, 250)]);
        assert_eq!(k.occupancy(0), 250);
        // capacity freeing later lets a subsequent append resume growth
        // onto the freed group — overflow-absorb is not permanent
        k.unreserve(1, 1_000);
        let added = k.append_tokens(1, 50, 2.0);
        assert_eq!(added, vec![1]);
        assert_eq!(k.local_lengths(1), vec![(0, 250), (1, 50)]);
        assert!(k.shard_map(1).unwrap().check_contiguous());
        assert!(k.onboard_log_is_duplicate_free());
    }

    #[test]
    fn growth_never_revisits_a_group_already_holding_a_shard() {
        // Groups 1 and 2 full: growth from group 0 must overflow-absorb
        // rather than "onboarding" group 0 again through the wrap-around.
        let mut k = KvpManager::with_capacity(10, 3, 50);
        k.onboard_request(1, 1, 0, 0.0);
        k.reserve(1, 50);
        k.reserve(2, 50);
        let added = k.append_tokens(1, 30, 1.0);
        assert!(added.is_empty());
        assert_eq!(k.local_lengths(1), vec![(0, 30)]);
        assert!(k.onboard_log_is_duplicate_free());
    }

    #[test]
    fn prop_shards_stay_contiguous_and_bounded() {
        check("kvp shards contiguous+bounded", 200, |rng| {
            let threshold = rng.range_u64(10, 5_000);
            let groups = rng.range_u64(2, 16) as u32;
            let mut k = KvpManager::new(threshold, groups);
            k.onboard_request(1, 1, rng.below(groups as u64) as GroupId, 0.0);
            let budget = threshold * groups as u64;
            let mut appended = 0u64;
            for _ in 0..rng.range_u64(1, 50) {
                let c = rng.range_u64(1, threshold);
                if appended + c > budget {
                    break;
                }
                k.append_tokens(1, c, 0.0);
                appended += c;
                let m = k.shard_map(1).unwrap();
                assert!(m.check_contiguous());
                assert_eq!(m.total_tokens(), appended);
                // every shard respects the threshold (last may overflow only
                // when the fleet is exhausted; budget-capped appends avoid it)
                assert!(m.shards.iter().all(|&(_, _, n)| n <= threshold));
                // all but the last shard are full
                for &(_, _, n) in &m.shards[..m.shards.len() - 1] {
                    assert_eq!(n, threshold);
                }
            }
        });
    }
}
