//! KV-parallel worker-group management (section 4.4, Figs. 10 & 19).
//!
//! A long request's KV cache grows as prefill progresses. Rather than
//! pre-allocating all KVP groups, the manager onboards groups *dynamically*:
//! each group holds at most `onboard_threshold` KV tokens of the request;
//! when the active group fills, the next group joins — round-robin,
//! **skipping groups whose capacity ledger is out of KV room** (growth only
//! falls back to overflow-absorbing into the last shard when the whole
//! fleet is full). Groups not serving a long request remain independent
//! replicas that can batch short requests (section 7's scheduling
//! opportunity — exercised by the router).
//!
//! Long requests are keyed by their arena [`Slot`]; the external
//! `RequestId` is kept alongside only for the onboarding log (the Fig. 19
//! timeline reports client-visible ids).
//!
//! The manager is also the per-group **KV-capacity ledger** routing
//! consults: resident long-request shard tokens (`occupancy`, maintained
//! incrementally) plus short-request reservations (`reserve`/`unreserve`,
//! prompt + output tokens held from admission to retirement) against a
//! per-group `capacity`. [`KvpManager::kv_free`] is the O(1) read behind
//! `GroupView::kv_free`, letting `SchedPolicy::route` refuse placements
//! that would not fit. The default capacity is unlimited — the
//! pre-capacity behavior, and what every oracle-parity test runs under.
//!
//! # Group lifecycle (elastic fleet)
//!
//! The fleet is a *runtime object*: each group slot carries a
//! [`GroupState`] and every placement decision — shard growth in
//! [`KvpManager::append_tokens`], routed admission, round-robin spreading —
//! consults live membership instead of `0..n_groups`:
//!
//! * `Active` — serving and placeable; the only state a fresh fleet has.
//! * `Draining` — autoscale-down in progress: takes **no** new KV (neither
//!   shard growth nor short reservations), but keeps what it holds until
//!   the work finishes. `occ == reserved == 0` marks the drain complete.
//! * `Down` — crashed (or drained out): holds nothing, receives nothing.
//!   [`KvpManager::crash_group`] is the transition — it drops the group's
//!   ledger occupancy *and every shard it holds*, truncating each affected
//!   request's shard map at the first dead shard (KV after a hole is
//!   useless), and returns a [`CrashReport`] so the scheduler can re-route
//!   reservations and re-prefill the lost ranges from the surviving
//!   chunk-boundary prefix.
//! * `Joining` — announced but not yet serving (warm-up); excluded from
//!   placement until promoted to `Active`.
//!
//! Crashes append to `drop_log`, which relaxes the exactly-once onboarding
//! invariant per lost shard: a (request, group) pair may be re-onboarded
//! once per recorded drop — never for a surviving shard.

use super::arena::Slot;
use crate::kvcache::{GroupId, RequestId, ShardMap};
use crate::util::slotvec::SlotVec;

/// Lifecycle state of one KVP worker group. See the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupState {
    /// Serving and placeable.
    Active,
    /// Autoscale-down: no new placements, existing work finishes.
    Draining,
    /// Crashed or drained out: holds nothing, receives nothing.
    Down,
    /// Announced but still warming up: excluded from placement.
    Joining,
}

/// What [`KvpManager::crash_group`] tore down — everything the scheduler
/// needs to recover without leaking ledger state.
#[derive(Debug, Clone, Default)]
pub struct CrashReport {
    /// Ledger occupancy the dead group itself held (zeroed by the crash).
    pub occ_dropped: u64,
    /// Outstanding short-request reservations on the dead group, returned
    /// so admission can re-reserve elsewhere — the ledger entry is zeroed
    /// in the same step, so the leak is impossible by construction.
    pub reserved_dropped: u64,
    /// Shared prefix-chain KV tokens the dead group held (zeroed by the
    /// crash). The caller must drop the group's chains from the
    /// `PrefixIndex` in the same step and re-prefill the shared span for
    /// every in-flight holder.
    pub shared_dropped: u64,
    /// KV shards dropped fleet-wide: every shard on the dead group plus
    /// post-hole shards on survivors (KV after a missing range is useless).
    pub shards_lost: u64,
    /// Per affected long request: (slot, KV tokens before the crash, KV
    /// tokens surviving). The surviving prefix always ends at a shard
    /// boundary, which is itself a chunk boundary — re-prefill restarts
    /// there, not at token zero.
    pub victims: Vec<(Slot, u64, u64)>,
}

#[derive(Debug, Clone)]
struct LongEntry {
    ext_id: RequestId,
    map: ShardMap,
    /// Set while the request is preempted at a chunk boundary: its shards
    /// stay resident on every onboarded group, waiting for `resume`.
    yielded: bool,
}

#[derive(Debug, Clone)]
pub struct KvpManager {
    /// Max KV tokens of one request per group before onboarding the next.
    pub onboard_threshold: u64,
    /// Total group *slots* (live or down) — the bound on group ids, not the
    /// live-fleet size; see [`Self::n_active`] for placeable membership.
    pub n_groups: u32,
    /// Lifecycle state per group slot.
    states: Vec<GroupState>,
    /// Per-group KV-token capacity (long shards + short reservations);
    /// `u64::MAX` disables capacity accounting (the default).
    pub capacity: u64,
    /// Resident long-request KV tokens per group — the incremental mirror
    /// of summing `local_tokens` over every shard map.
    occ: Vec<u64>,
    /// Short-request KV reservations per group (prompt + output tokens,
    /// held from admission to retirement).
    reserved: Vec<u64>,
    /// Shared prefix-chain KV tokens per group (`kvcache::PrefixIndex`
    /// blocks, counted **once** per block no matter how many requests hold
    /// the chain). Requests placed on a chain's owner group reserve only
    /// their non-shared remainder, so double counting the shared span is
    /// impossible by construction.
    shared: Vec<u64>,
    /// Shard maps per long request, slot-indexed.
    maps: SlotVec<LongEntry>,
    /// Onboarding events (time, request, group) — the Fig. 19 timeline.
    /// Each (request, group) pair appears at most once: a retained shard is
    /// **never** re-onboarded across yield/resume cycles.
    pub onboard_log: Vec<(f64, RequestId, GroupId)>,
    /// Yield/resume events: (time, request, `true` for yield / `false` for
    /// resume). Chunk-boundary preemption of the active request retains all
    /// shards, so yields never appear in `onboard_log`.
    pub yield_log: Vec<(f64, RequestId, bool)>,
    /// Shard-drop events from crashes: (time, request, group). Each entry
    /// licenses exactly one re-onboarding of that (request, group) pair in
    /// `onboard_log` — see [`Self::onboard_log_is_duplicate_free`].
    pub drop_log: Vec<(f64, RequestId, GroupId)>,
    /// KV tokens absorbed past a group's free ledger room (overflow-absorb
    /// with the fleet full, or threshold-filling a nearly-full group).
    /// Zero whenever capacity is sized to the workload — the routing
    /// signal the metrics surface as `kv_overcommit_tokens`.
    pub kv_overcommit_tokens: u64,
}

impl KvpManager {
    /// Unlimited per-group capacity (the pre-capacity behavior).
    pub fn new(onboard_threshold: u64, n_groups: u32) -> KvpManager {
        KvpManager::with_capacity(onboard_threshold, n_groups, u64::MAX)
    }

    /// Capacity-accounted manager: each group holds at most `capacity` KV
    /// tokens of long-request shards plus short-request reservations.
    pub fn with_capacity(onboard_threshold: u64, n_groups: u32, capacity: u64) -> KvpManager {
        assert!(onboard_threshold > 0 && n_groups > 0 && capacity > 0);
        KvpManager {
            onboard_threshold,
            n_groups,
            states: vec![GroupState::Active; n_groups as usize],
            capacity,
            occ: vec![0; n_groups as usize],
            reserved: vec![0; n_groups as usize],
            shared: vec![0; n_groups as usize],
            maps: SlotVec::new(),
            onboard_log: Vec::new(),
            yield_log: Vec::new(),
            drop_log: Vec::new(),
            kv_overcommit_tokens: 0,
        }
    }

    /// Lifecycle state of group `g` (out-of-range reads as `Down`).
    pub fn state(&self, g: GroupId) -> GroupState {
        self.states
            .get(g as usize)
            .copied()
            .unwrap_or(GroupState::Down)
    }

    /// Whether group `g` may receive new placements (shards, reservations,
    /// short routing). Only `Active` groups qualify.
    pub fn is_placeable(&self, g: GroupId) -> bool {
        self.state(g) == GroupState::Active
    }

    /// Whether group `g` still participates in serving (holds or may hold
    /// work): everything but `Down`.
    pub fn is_live(&self, g: GroupId) -> bool {
        self.state(g) != GroupState::Down
    }

    /// Number of `Active` (placeable) groups.
    pub fn n_active(&self) -> u32 {
        self.states
            .iter()
            .filter(|&&s| s == GroupState::Active)
            .count() as u32
    }

    /// Begin autoscale-down of group `g`: no new placements land on it;
    /// resident shards and reservations stay until they finish. Contrast
    /// with [`Self::crash_group`], which drops state instantly.
    pub fn begin_drain(&mut self, g: GroupId) {
        assert_eq!(
            self.state(g),
            GroupState::Active,
            "drain of group {g} which is not active"
        );
        self.states[g as usize] = GroupState::Draining;
    }

    /// A draining group with nothing resident can leave the fleet.
    pub fn drain_idle(&self, g: GroupId) -> bool {
        self.state(g) == GroupState::Draining
            && self.occupancy(g) == 0
            && self.reserved_on(g) == 0
            && self.shared_on(g) == 0
    }

    /// Complete a drain: the group leaves the fleet. Panics if it still
    /// holds KV — migrate or finish that first ([`Self::drain_idle`]).
    pub fn finish_drain(&mut self, g: GroupId) {
        assert!(self.drain_idle(g), "finish_drain of a non-idle group {g}");
        self.states[g as usize] = GroupState::Down;
    }

    /// Announce a joining group: revive slot `g` if it is `Down`, or grow
    /// the fleet by one slot when `g` is `None` / past the end. Returns
    /// the slot joined. The group is `Joining` — excluded from placement —
    /// until [`Self::activate`].
    pub fn announce_join(&mut self, g: Option<GroupId>) -> GroupId {
        let g = g.unwrap_or(self.n_groups);
        if (g as usize) < self.states.len() {
            assert_eq!(
                self.state(g),
                GroupState::Down,
                "join into occupied group slot {g}"
            );
            debug_assert!(
                self.occ[g as usize] == 0
                    && self.reserved[g as usize] == 0
                    && self.shared[g as usize] == 0
            );
            self.states[g as usize] = GroupState::Joining;
            g
        } else {
            let g = self.states.len() as GroupId;
            self.states.push(GroupState::Joining);
            self.occ.push(0);
            self.reserved.push(0);
            self.shared.push(0);
            self.n_groups = self.states.len() as u32;
            g
        }
    }

    /// Promote a `Joining` group to `Active` (warm-up complete).
    pub fn activate(&mut self, g: GroupId) {
        assert_eq!(
            self.state(g),
            GroupState::Joining,
            "activate of group {g} which is not joining"
        );
        self.states[g as usize] = GroupState::Active;
    }

    /// Crash group `g`: its ledger occupancy and short reservations are
    /// zeroed, every shard it holds is dropped, and so is every *later*
    /// shard of each affected request (KV after the hole is useless — the
    /// surviving prefix ends at a shard boundary, which is where re-prefill
    /// restarts). Returns everything the scheduler needs to recover; see
    /// [`CrashReport`]. Works from any non-`Down` state.
    pub fn crash_group(&mut self, g: GroupId, t: f64) -> CrashReport {
        assert!(self.is_live(g), "crash of group {g} which is already down");
        let mut report = CrashReport {
            reserved_dropped: std::mem::take(&mut self.reserved[g as usize]),
            shared_dropped: std::mem::take(&mut self.shared[g as usize]),
            ..CrashReport::default()
        };
        let affected: Vec<usize> = self
            .maps
            .iter()
            .filter(|(_, e)| e.map.shards.iter().any(|&(gg, _, _)| gg == g))
            .map(|(s, _)| s)
            .collect();
        for s in affected {
            let e = self.maps.get_mut(s).expect("affected slot vanished");
            let cut = e
                .map
                .shards
                .iter()
                .position(|&(gg, _, _)| gg == g)
                .expect("affected map lost its dead shard");
            let before = e.map.total_tokens();
            for &(gg, _, n) in &e.map.shards[cut..] {
                self.occ[gg as usize] -= n;
                if gg == g {
                    report.occ_dropped += n;
                }
                report.shards_lost += 1;
                self.drop_log.push((t, e.ext_id, gg));
            }
            e.map.shards.truncate(cut);
            debug_assert!(e.map.check_contiguous());
            report.victims.push((s as Slot, before, e.map.total_tokens()));
        }
        debug_assert_eq!(self.occ[g as usize], 0, "crash left occupancy behind");
        self.states[g as usize] = GroupState::Down;
        report
    }

    /// Register a request; it starts on `first_group` only.
    pub fn onboard_request(&mut self, s: Slot, ext_id: RequestId, first_group: GroupId, t: f64) {
        let mut m = ShardMap::default();
        m.shards.push((first_group, 0, 0));
        self.maps.insert(
            s as usize,
            LongEntry {
                ext_id,
                map: m,
                yielded: false,
            },
        );
        self.onboard_log.push((t, ext_id, first_group));
    }

    /// Append `tokens` of processed KV for slot `s` at time `t`, onboarding
    /// new groups as thresholds are crossed. Returns the groups added (the
    /// common no-growth case returns an unallocated empty vector).
    ///
    /// Growth is **capacity-aware**: a candidate group whose KV ledger has
    /// no free tokens (long shards + short reservations at `capacity`) is
    /// skipped, in round-robin order from the last shard's group. Only when
    /// every remaining group is full does the last shard absorb the
    /// overflow — and a later append re-evaluates, so a group that frees
    /// capacity can still onboard then. A group that is onboarded with
    /// *some* room grows its shard to the full threshold (reservations are
    /// worst-case footprints, so bounded over-commit beats fragmenting the
    /// shard map). With unlimited capacity (the default) every candidate
    /// has room and growth is exactly the original round-robin.
    ///
    /// Growth is also **lifecycle-aware**: only `Active` groups onboard new
    /// shards, and a last shard whose group left `Active` (draining) takes
    /// no further KV — growth moves to the next live group immediately.
    /// Tokens landed past a group's free ledger room (either overflow
    /// absorption or threshold-filling a nearly-full group) accumulate in
    /// [`Self::kv_overcommit_tokens`].
    pub fn append_tokens(&mut self, s: Slot, mut tokens: u64, t: f64) -> Vec<GroupId> {
        let e = self.maps.get_mut(s as usize).expect("request not onboarded");
        assert!(
            !e.map.shards.is_empty(),
            "append to request {} with no shards (crash-orphaned, not re-onboarded)",
            e.ext_id
        );
        let mut added = Vec::new();
        while tokens > 0 {
            let (g, _, len) = *e.map.shards.last().unwrap();
            let room = if self.states[g as usize] == GroupState::Active {
                self.onboard_threshold.saturating_sub(len)
            } else {
                0 // non-Active groups take no new KV: move on immediately
            };
            if room == 0 {
                // Onboard the next group: round-robin over the fleet,
                // skipping non-Active groups, groups that already hold a
                // shard of this request, and groups whose capacity ledger
                // is out of KV room.
                let mut next = None;
                let n_slots = self.states.len() as u32;
                for step in 1..=n_slots {
                    let cand = (g + step) % n_slots;
                    if self.states[cand as usize] != GroupState::Active {
                        continue;
                    }
                    if e.map.shards.iter().any(|&(gg, _, _)| gg == cand) {
                        continue;
                    }
                    if Self::ledger_kv_free(&self.occ, &self.reserved, &self.shared, self.capacity, cand) == 0 {
                        continue; // capacity-aware growth: skip full groups
                    }
                    next = Some(cand);
                    break;
                }
                match next {
                    Some(next) => {
                        let start = e.map.total_tokens();
                        e.map.shards.push((next, start, 0));
                        self.onboard_log.push((t, e.ext_id, next));
                        added.push(next);
                        continue;
                    }
                    None => {
                        // Whole fleet out of room: overflow-absorb into the
                        // current last shard rather than blowing a full
                        // group's budget. Not permanent — the next append
                        // rescans the fleet.
                        let free = Self::ledger_kv_free(
                            &self.occ,
                            &self.reserved,
                            &self.shared,
                            self.capacity,
                            g,
                        );
                        self.kv_overcommit_tokens += tokens.saturating_sub(free);
                        e.map.shards.last_mut().unwrap().2 += tokens;
                        self.occ[g as usize] += tokens;
                        break;
                    }
                }
            }
            let take = tokens.min(room);
            let free = Self::ledger_kv_free(&self.occ, &self.reserved, &self.shared, self.capacity, g);
            self.kv_overcommit_tokens += take.saturating_sub(free);
            e.map.shards.last_mut().unwrap().2 += take;
            self.occ[g as usize] += take;
            tokens -= take;
        }
        added
    }

    /// Free KV tokens on group `g` per the disaggregated ledger fields —
    /// the borrow-splitting form of [`Self::kv_free`] usable while a shard
    /// map is mutably borrowed. Shared prefix-chain blocks count against
    /// capacity exactly once, alongside long shards and reservations.
    fn ledger_kv_free(occ: &[u64], reserved: &[u64], shared: &[u64], capacity: u64, g: GroupId) -> u64 {
        let o = occ.get(g as usize).copied().unwrap_or(0);
        let r = reserved.get(g as usize).copied().unwrap_or(0);
        let s = shared.get(g as usize).copied().unwrap_or(0);
        capacity.saturating_sub(o.saturating_add(r).saturating_add(s))
    }

    /// Charge `tokens` of shared prefix-chain KV to group `g` — called once
    /// per *new block* when a finished request's chain is inserted into the
    /// prefix index, never per holder.
    pub fn charge_shared(&mut self, g: GroupId, tokens: u64) {
        self.shared[g as usize] += tokens;
    }

    /// Release shared prefix-chain KV on group `g` — eviction of a
    /// refcount-0 chain gives its blocks back to the ledger.
    pub fn release_shared(&mut self, g: GroupId, tokens: u64) {
        let s = &mut self.shared[g as usize];
        debug_assert!(*s >= tokens, "release of shared tokens never charged");
        *s = s.saturating_sub(tokens);
    }

    /// Shared prefix-chain KV tokens resident on group `g`.
    pub fn shared_on(&self, g: GroupId) -> u64 {
        self.shared.get(g as usize).copied().unwrap_or(0)
    }

    /// Reserve `tokens` of short-request KV on group `g` (admission).
    pub fn reserve(&mut self, g: GroupId, tokens: u64) {
        self.reserved[g as usize] += tokens;
    }

    /// Release a short-request reservation on group `g` (retirement).
    pub fn unreserve(&mut self, g: GroupId, tokens: u64) {
        let r = &mut self.reserved[g as usize];
        debug_assert!(*r >= tokens, "unreserve of tokens never reserved");
        *r = r.saturating_sub(tokens);
    }

    /// Free KV-token capacity on group `g`: capacity minus resident long
    /// shards minus short reservations. O(1) — the routing hook reads this
    /// for every group on every routed admission.
    pub fn kv_free(&self, g: GroupId) -> u64 {
        Self::ledger_kv_free(&self.occ, &self.reserved, &self.shared, self.capacity, g)
    }

    pub fn shard_map(&self, s: Slot) -> Option<&ShardMap> {
        self.maps.get(s as usize).map(|e| &e.map)
    }

    /// Number of groups currently cooperating on `s` (the p_kvp actually
    /// in use — Fig. 19's y-axis is this times workers/group).
    pub fn active_groups(&self, s: Slot) -> u32 {
        self.maps
            .get(s as usize)
            .map(|e| e.map.shards.len() as u32)
            .unwrap_or(0)
    }

    /// Local KV lengths per group for `s` — what each group's attention
    /// kernel scans during decode. Allocates; the simulator's hot loop
    /// iterates [`Self::shard_map`] directly instead.
    pub fn local_lengths(&self, s: Slot) -> Vec<(GroupId, u64)> {
        self.maps
            .get(s as usize)
            .map(|e| e.map.shards.iter().map(|&(g, _, n)| (g, n)).collect())
            .unwrap_or_default()
    }

    /// The *largest* local shard bounds the parallel decode-attention time.
    pub fn max_local_len(&self, s: Slot) -> u64 {
        self.local_lengths(s)
            .iter()
            .map(|&(_, n)| n)
            .max()
            .unwrap_or(0)
    }

    /// Chunk-boundary yield of the active sharded request `s`: every
    /// per-group KV shard stays exactly where it is (nothing is released,
    /// nothing re-onboarded later), the request merely stops receiving
    /// chunks until [`Self::resume`]. Panics on a request that is not
    /// onboarded or is already yielded — both are scheduler bugs.
    pub fn yield_active(&mut self, s: Slot, t: f64) {
        let e = self.maps.get_mut(s as usize).expect("yield of unknown request");
        assert!(!e.yielded, "double yield of request {}", e.ext_id);
        debug_assert!(e.map.check_contiguous());
        e.yielded = true;
        self.yield_log.push((t, e.ext_id, true));
    }

    /// Resume a previously yielded request: asserts its retained shards
    /// survived intact and clears the yielded flag. Returns `true` when
    /// the request was actually yielded (a fresh request is a no-op, so
    /// the activation path can call this unconditionally).
    pub fn resume(&mut self, s: Slot, t: f64) -> bool {
        let e = self.maps.get_mut(s as usize).expect("resume of unknown request");
        if !e.yielded {
            return false;
        }
        assert!(
            e.map.check_contiguous(),
            "request {} lost KV shards while yielded",
            e.ext_id
        );
        e.yielded = false;
        self.yield_log.push((t, e.ext_id, false));
        true
    }

    pub fn is_yielded(&self, s: Slot) -> bool {
        self.maps.get(s as usize).map(|e| e.yielded).unwrap_or(false)
    }

    /// Whether group `g` holds a KV shard of request `s`.
    pub fn holds(&self, s: Slot, g: GroupId) -> bool {
        self.maps
            .get(s as usize)
            .map(|e| e.map.shards.iter().any(|&(gg, _, _)| gg == g))
            .unwrap_or(false)
    }

    /// Total resident long-request KV tokens on group `g`, across every
    /// onboarded request — active or yielded. The router's occupancy view
    /// and the per-group utilization figure read this. O(1): maintained
    /// incrementally as shards grow and requests release (the sum over
    /// shard maps it mirrors is asserted by the invariant harness).
    pub fn occupancy(&self, g: GroupId) -> u64 {
        self.occ.get(g as usize).copied().unwrap_or(0)
    }

    /// Outstanding short-request reservation tokens on group `g`.
    pub fn reserved_on(&self, g: GroupId) -> u64 {
        self.reserved.get(g as usize).copied().unwrap_or(0)
    }

    /// Invariant the test harness leans on: a (request, group) pair appears
    /// in the onboarding log at most once **per shard lifetime** — once,
    /// plus once more per crash-drop of that pair recorded in `drop_log`.
    /// A shard retained across a yield/resume cycle is never re-onboarded,
    /// and with no crashes this is the strict at-most-once property.
    pub fn onboard_log_is_duplicate_free(&self) -> bool {
        let mut drops: Vec<(RequestId, GroupId)> =
            self.drop_log.iter().map(|&(_, r, g)| (r, g)).collect();
        drops.sort_unstable();
        let mut pairs: Vec<(RequestId, GroupId)> =
            self.onboard_log.iter().map(|&(_, r, g)| (r, g)).collect();
        pairs.sort_unstable();
        let mut i = 0;
        while i < pairs.len() {
            let mut n = 1;
            while i + n < pairs.len() && pairs[i + n] == pairs[i] {
                n += 1;
            }
            let lo = drops.partition_point(|&p| p < pairs[i]);
            let hi = drops.partition_point(|&p| p <= pairs[i]);
            if n > 1 + (hi - lo) {
                return false;
            }
            i += n;
        }
        true
    }

    /// Ledger conservation, checked by the invariant harness after every
    /// step: the incremental `occ` mirrors the sum of shard tokens per
    /// group across every onboarded map; `Down` groups hold nothing (no
    /// long shards, no reservations, no shared prefix blocks); and for a
    /// finite capacity, `occ + reserved + shared + kv_free == capacity` on
    /// every group (free saturates at zero only when over-commit was
    /// actually absorbed, i.e. `kv_overcommit_tokens > 0`).
    pub fn ledger_is_conserved(&self) -> bool {
        let mut sums = vec![0u64; self.states.len()];
        for (_, e) in self.maps.iter() {
            for &(g, _, n) in &e.map.shards {
                sums[g as usize] += n;
            }
        }
        for g in 0..self.states.len() {
            if sums[g] != self.occ[g] {
                return false;
            }
            if self.states[g] == GroupState::Down
                && (self.occ[g] != 0 || self.reserved[g] != 0 || self.shared[g] != 0)
            {
                return false;
            }
            if self.capacity != u64::MAX {
                let used = self.occ[g]
                    .saturating_add(self.reserved[g])
                    .saturating_add(self.shared[g]);
                let free = Self::ledger_kv_free(
                    &self.occ,
                    &self.reserved,
                    &self.shared,
                    self.capacity,
                    g as GroupId,
                );
                if used <= self.capacity {
                    if used + free != self.capacity {
                        return false;
                    }
                } else if free != 0 || self.kv_overcommit_tokens == 0 {
                    return false;
                }
            }
        }
        true
    }

    pub fn release(&mut self, s: Slot) {
        if let Some(e) = self.maps.remove(s as usize) {
            for &(g, _, n) in &e.map.shards {
                self.occ[g as usize] -= n;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn grows_one_group_at_a_time() {
        let mut k = KvpManager::new(1000, 4);
        k.onboard_request(7, 7, 0, 0.0);
        assert_eq!(k.active_groups(7), 1);
        assert!(k.append_tokens(7, 999, 1.0).is_empty());
        assert_eq!(k.active_groups(7), 1);
        let added = k.append_tokens(7, 2, 2.0);
        assert_eq!(added, vec![1]);
        assert_eq!(k.active_groups(7), 2);
        assert_eq!(k.local_lengths(7), vec![(0, 1000), (1, 1)]);
    }

    #[test]
    fn fig19_staircase() {
        // 2M tokens, 512K threshold -> 4 groups onboarded progressively.
        let mut k = KvpManager::new(512_000, 4);
        k.onboard_request(1, 1, 0, 0.0);
        let mut t = 0.0;
        let chunk = 4096;
        let mut groups_over_time = Vec::new();
        let mut done = 0u64;
        while done < 2_000_000 {
            let c = chunk.min(2_000_000 - done);
            k.append_tokens(1, c, t);
            done += c;
            t += 0.1;
            groups_over_time.push(k.active_groups(1));
        }
        assert_eq!(*groups_over_time.last().unwrap(), 4);
        // staircase: non-decreasing, hits every level 1..=4
        assert!(groups_over_time.windows(2).all(|w| w[1] >= w[0]));
        for lvl in 1..=4 {
            assert!(groups_over_time.contains(&lvl));
        }
        assert_eq!(k.onboard_log.len(), 4); // initial + 3 growth events
    }

    #[test]
    fn shard_lengths_sum_to_processed() {
        let mut k = KvpManager::new(100, 8);
        k.onboard_request(2, 2, 3, 0.0);
        k.append_tokens(2, 777, 0.0);
        let total: u64 = k.local_lengths(2).iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 777);
        assert_eq!(k.max_local_len(2), 100);
    }

    #[test]
    fn last_group_absorbs_overflow_when_fleet_exhausted() {
        let mut k = KvpManager::new(10, 2);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 25, 0.0);
        assert_eq!(k.active_groups(1), 2);
        assert_eq!(k.local_lengths(1), vec![(0, 10), (1, 15)]);
        assert!(k.shard_map(1).unwrap().check_contiguous());
    }

    #[test]
    fn onboard_log_reports_external_ids() {
        let mut k = KvpManager::new(10, 4);
        // slot 0, external request id 999
        k.onboard_request(0, 999, 2, 1.5);
        k.append_tokens(0, 11, 2.5);
        assert_eq!(k.onboard_log[0], (1.5, 999, 2));
        assert_eq!(k.onboard_log[1], (2.5, 999, 3));
    }

    #[test]
    fn yield_retains_shards_and_never_reonboards_on_resume() {
        let mut k = KvpManager::new(100, 4);
        k.onboard_request(5, 50, 0, 0.0);
        k.append_tokens(5, 250, 1.0); // onboards groups 1 and 2
        assert_eq!(k.active_groups(5), 3);
        let log_before = k.onboard_log.clone();

        k.yield_active(5, 2.0);
        assert!(k.is_yielded(5));
        // retained exactly: shard map untouched, occupancy intact
        assert_eq!(k.local_lengths(5), vec![(0, 100), (1, 100), (2, 50)]);
        assert_eq!(k.occupancy(1), 100);

        assert!(k.resume(5, 3.0));
        assert!(!k.is_yielded(5));
        // resuming and growing logs only the *new* group, never a retained one
        k.append_tokens(5, 100, 4.0);
        assert_eq!(k.onboard_log.len(), log_before.len() + 1);
        assert_eq!(k.onboard_log.last().unwrap(), &(4.0, 50, 3));
        assert_eq!(
            k.yield_log,
            vec![(2.0, 50, true), (3.0, 50, false)]
        );
    }

    #[test]
    fn resume_of_fresh_request_is_a_noop() {
        let mut k = KvpManager::new(100, 2);
        k.onboard_request(1, 1, 0, 0.0);
        assert!(!k.resume(1, 1.0));
        assert!(k.yield_log.is_empty());
    }

    #[test]
    #[should_panic(expected = "double yield")]
    fn double_yield_panics() {
        let mut k = KvpManager::new(100, 2);
        k.onboard_request(1, 1, 0, 0.0);
        k.yield_active(1, 1.0);
        k.yield_active(1, 2.0);
    }

    #[test]
    fn occupancy_sums_across_requests_and_holds_is_per_group() {
        let mut k = KvpManager::new(100, 4);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 150, 0.0); // g0: 100, g1: 50
        k.onboard_request(2, 2, 1, 0.0);
        k.append_tokens(2, 80, 0.0); // g1: 80
        assert_eq!(k.occupancy(0), 100);
        assert_eq!(k.occupancy(1), 130);
        assert_eq!(k.occupancy(2), 0);
        assert!(k.holds(1, 0) && k.holds(1, 1) && !k.holds(1, 2));
        assert!(!k.holds(2, 0) && k.holds(2, 1));
        k.release(1);
        assert_eq!(k.occupancy(1), 80);
        assert!(!k.holds(1, 1));
    }

    #[test]
    fn capacity_ledger_tracks_shards_and_reservations() {
        let mut k = KvpManager::with_capacity(100, 2, 1_000);
        assert_eq!(k.kv_free(0), 1_000);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 150, 0.0); // g0: 100, g1: 50
        assert_eq!(k.kv_free(0), 900);
        assert_eq!(k.kv_free(1), 950);
        // short reservations stack on top of long-shard occupancy
        k.reserve(0, 300);
        assert_eq!(k.kv_free(0), 600);
        k.unreserve(0, 300);
        k.release(1);
        assert_eq!(k.kv_free(0), 1_000);
        assert_eq!(k.kv_free(1), 1_000);
        assert_eq!(k.occupancy(0), 0);
        // out-of-range groups read as empty, never panic
        assert_eq!(k.kv_free(9), 1_000);
        assert_eq!(k.occupancy(9), 0);
    }

    #[test]
    fn shared_ledger_counts_blocks_once_and_crash_returns_them() {
        let mut k = KvpManager::with_capacity(100, 2, 1_000);
        // two requests share a 256-token prefix chain on group 0: the
        // ledger charges the blocks once, not per holder
        k.charge_shared(0, 256);
        assert_eq!(k.shared_on(0), 256);
        assert_eq!(k.kv_free(0), 744);
        assert!(k.ledger_is_conserved());
        // shared stacks with long shards and short reservations
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 100, 0.5);
        k.reserve(0, 144);
        assert_eq!(k.kv_free(0), 500);
        assert!(k.ledger_is_conserved());
        // eviction releases exactly what was charged
        k.release_shared(0, 256);
        assert_eq!(k.shared_on(0), 0);
        assert_eq!(k.kv_free(0), 756);
        // crash zeroes the column and reports the drop exactly once
        k.charge_shared(0, 128);
        let rep = k.crash_group(0, 1.0);
        assert_eq!(rep.shared_dropped, 128);
        assert_eq!(k.shared_on(0), 0);
        assert!(k.ledger_is_conserved());
    }

    #[test]
    fn shared_blocks_hold_a_drain_open() {
        let mut k = KvpManager::new(100, 2);
        k.charge_shared(0, 64);
        k.begin_drain(0);
        assert!(!k.drain_idle(0), "shared chains still resident");
        k.release_shared(0, 64);
        assert!(k.drain_idle(0));
        k.finish_drain(0);
        assert!(k.ledger_is_conserved());
    }

    #[test]
    fn unlimited_capacity_never_runs_out() {
        let mut k = KvpManager::new(100, 2);
        k.reserve(0, u64::MAX / 2);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 1_000, 0.0);
        assert!(k.kv_free(0) > u64::MAX / 4, "free={}", k.kv_free(0));
    }

    #[test]
    fn capacity_full_group_is_skipped_at_growth() {
        let mut k = KvpManager::with_capacity(100, 4, 1_000);
        k.onboard_request(1, 1, 0, 0.0);
        // group 1 — the round-robin next — is out of KV room
        k.reserve(1, 1_000);
        assert_eq!(k.kv_free(1), 0);
        let added = k.append_tokens(1, 250, 1.0);
        // growth skipped the full group: 0 -> 2 -> 3
        assert_eq!(added, vec![2, 3]);
        assert_eq!(k.local_lengths(1), vec![(0, 100), (2, 100), (3, 50)]);
        assert!(k.shard_map(1).unwrap().check_contiguous());
        assert!(k.onboard_log_is_duplicate_free());
    }

    #[test]
    fn growth_overflow_absorbs_when_every_other_group_is_full() {
        let mut k = KvpManager::with_capacity(100, 3, 1_000);
        k.onboard_request(1, 1, 0, 0.0);
        k.reserve(1, 1_000);
        k.reserve(2, 1_000);
        let added = k.append_tokens(1, 250, 1.0);
        assert!(added.is_empty(), "onboarded into a full group: {added:?}");
        // the last (only) shard absorbed the overflow past its threshold
        assert_eq!(k.local_lengths(1), vec![(0, 250)]);
        assert_eq!(k.occupancy(0), 250);
        // capacity freeing later lets a subsequent append resume growth
        // onto the freed group — overflow-absorb is not permanent
        k.unreserve(1, 1_000);
        let added = k.append_tokens(1, 50, 2.0);
        assert_eq!(added, vec![1]);
        assert_eq!(k.local_lengths(1), vec![(0, 250), (1, 50)]);
        assert!(k.shard_map(1).unwrap().check_contiguous());
        assert!(k.onboard_log_is_duplicate_free());
    }

    #[test]
    fn growth_never_revisits_a_group_already_holding_a_shard() {
        // Groups 1 and 2 full: growth from group 0 must overflow-absorb
        // rather than "onboarding" group 0 again through the wrap-around.
        let mut k = KvpManager::with_capacity(10, 3, 50);
        k.onboard_request(1, 1, 0, 0.0);
        k.reserve(1, 50);
        k.reserve(2, 50);
        let added = k.append_tokens(1, 30, 1.0);
        assert!(added.is_empty());
        assert_eq!(k.local_lengths(1), vec![(0, 30)]);
        assert!(k.onboard_log_is_duplicate_free());
    }

    #[test]
    fn crash_drops_dead_and_post_hole_shards() {
        let mut k = KvpManager::new(100, 4);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 250, 1.0); // g0: 100, g1: 100, g2: 50
        assert_eq!(k.active_groups(1), 3);

        let rep = k.crash_group(1, 2.0);
        // the dead shard AND the post-hole shard on surviving group 2 drop
        assert_eq!(rep.shards_lost, 2);
        assert_eq!(rep.occ_dropped, 100);
        assert_eq!(rep.victims, vec![(1, 250, 100)]);
        assert_eq!(k.local_lengths(1), vec![(0, 100)]);
        assert_eq!(k.occupancy(1), 0);
        assert_eq!(k.occupancy(2), 0);
        assert_eq!(k.state(1), GroupState::Down);
        assert!(k.ledger_is_conserved());
        assert_eq!(k.drop_log.len(), 2);

        // regrowth skips the dead group and may revisit dropped group 2
        let added = k.append_tokens(1, 150, 3.0);
        assert_eq!(added, vec![2]);
        assert_eq!(k.local_lengths(1), vec![(0, 100), (2, 150)]);
        assert!(k.shard_map(1).unwrap().check_contiguous());
        assert!(k.onboard_log_is_duplicate_free());
    }

    #[test]
    fn crash_returns_reservations_and_zeroes_ledger() {
        let mut k = KvpManager::with_capacity(100, 3, 1_000);
        k.reserve(2, 400);
        k.onboard_request(1, 1, 2, 0.0);
        k.append_tokens(1, 60, 0.5);
        let rep = k.crash_group(2, 1.0);
        assert_eq!(rep.reserved_dropped, 400);
        assert_eq!(rep.occ_dropped, 60);
        assert_eq!(rep.victims, vec![(1, 60, 0)]);
        assert_eq!(k.reserved_on(2), 0);
        assert_eq!(k.occupancy(2), 0);
        assert!(k.ledger_is_conserved());
        // the fully wiped victim must be re-onboarded before appending
        k.release(1);
        k.onboard_request(1, 1, 0, 2.0);
        k.append_tokens(1, 60, 2.5);
        assert_eq!(k.local_lengths(1), vec![(0, 60)]);
        assert!(k.onboard_log_is_duplicate_free());
    }

    #[test]
    fn surviving_shard_reonboard_is_flagged_as_duplicate() {
        let mut k = KvpManager::new(100, 4);
        k.onboard_request(1, 7, 0, 0.0);
        k.append_tokens(1, 150, 1.0); // g0, g1
        assert!(k.onboard_log_is_duplicate_free());
        // a re-onboard with no recorded drop is exactly the bug class the
        // invariant exists to catch
        k.onboard_log.push((2.0, 7, 0));
        assert!(!k.onboard_log_is_duplicate_free());
    }

    #[test]
    fn draining_group_takes_no_new_kv_but_keeps_resident() {
        let mut k = KvpManager::new(100, 3);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 50, 0.5);
        k.begin_drain(0);
        assert!(!k.is_placeable(0) && k.is_live(0));
        // the half-full draining shard stops growing: growth moves to g1
        let added = k.append_tokens(1, 30, 1.0);
        assert_eq!(added, vec![1]);
        assert_eq!(k.local_lengths(1), vec![(0, 50), (1, 30)]);
        assert_eq!(k.occupancy(0), 50);
        assert!(!k.drain_idle(0));
        k.release(1);
        assert!(k.drain_idle(0));
        k.finish_drain(0);
        assert_eq!(k.state(0), GroupState::Down);
        assert!(k.ledger_is_conserved());
    }

    #[test]
    fn join_revives_a_down_slot_and_grows_the_fleet() {
        let mut k = KvpManager::new(100, 2);
        k.crash_group(1, 1.0);
        assert_eq!(k.n_active(), 1);
        let g = k.announce_join(Some(1));
        assert_eq!(g, 1);
        assert_eq!(k.state(1), GroupState::Joining);
        assert!(!k.is_placeable(1)); // warm-up: excluded from placement
        k.activate(1);
        assert!(k.is_placeable(1));
        // None / past-the-end grows the fleet by a slot
        let g = k.announce_join(None);
        assert_eq!(g, 2);
        assert_eq!(k.n_groups, 3);
        k.activate(2);
        assert_eq!(k.n_active(), 3);
        // the revived and the new slot both accept growth
        k.onboard_request(1, 1, 0, 2.0);
        let added = k.append_tokens(1, 250, 3.0);
        assert_eq!(added, vec![1, 2]);
        assert!(k.ledger_is_conserved());
    }

    #[test]
    fn overcommit_counter_tracks_absorbed_tokens_only() {
        // capacity sized to the workload: zero over-commit
        let mut k = KvpManager::with_capacity(100, 2, 200);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 200, 1.0);
        assert_eq!(k.kv_overcommit_tokens, 0);

        // fleet full: the absorbed overflow past free room is counted
        let mut k = KvpManager::with_capacity(100, 2, 100);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 230, 1.0); // g0: 100, g1: 100 + 30 absorbed
        assert_eq!(k.kv_overcommit_tokens, 30);
        assert!(k.ledger_is_conserved());

        // unlimited capacity never over-commits by definition
        let mut k = KvpManager::new(10, 2);
        k.onboard_request(1, 1, 0, 0.0);
        k.append_tokens(1, 500, 1.0);
        assert_eq!(k.kv_overcommit_tokens, 0);
    }

    #[test]
    fn prop_crash_recover_keeps_ledger_conserved() {
        check("kvp crash/recover ledger conserved", 100, |rng| {
            let groups = rng.range_u64(3, 8) as u32;
            let threshold = rng.range_u64(10, 500);
            let mut k = KvpManager::new(threshold, groups);
            for s in 0..3u64 {
                k.onboard_request(s as u32, s, rng.below(groups as u64) as GroupId, 0.0);
                k.append_tokens(s as u32, rng.range_u64(1, threshold * 3), 0.1);
            }
            let victim = rng.below(groups as u64) as GroupId;
            let rep = k.crash_group(victim, 1.0);
            assert!(k.ledger_is_conserved());
            assert_eq!(k.occupancy(victim), 0);
            // orphaned requests (no surviving prefix) must re-onboard fresh
            for &(s, _, kept) in &rep.victims {
                if kept == 0 {
                    let ext = s as u64;
                    k.release(s);
                    let mut first = (victim + 1) % groups;
                    while !k.is_placeable(first) {
                        first = (first + 1) % groups;
                    }
                    k.onboard_request(s, ext, first, 2.0);
                }
            }
            for s in 0..3u32 {
                k.append_tokens(s, rng.range_u64(1, threshold * 2), 3.0);
                assert!(k.shard_map(s).unwrap().check_contiguous());
            }
            assert!(k.ledger_is_conserved());
            assert!(k.onboard_log_is_duplicate_free());
        });
    }

    #[test]
    fn prop_shards_stay_contiguous_and_bounded() {
        check("kvp shards contiguous+bounded", 200, |rng| {
            let threshold = rng.range_u64(10, 5_000);
            let groups = rng.range_u64(2, 16) as u32;
            let mut k = KvpManager::new(threshold, groups);
            k.onboard_request(1, 1, rng.below(groups as u64) as GroupId, 0.0);
            let budget = threshold * groups as u64;
            let mut appended = 0u64;
            for _ in 0..rng.range_u64(1, 50) {
                let c = rng.range_u64(1, threshold);
                if appended + c > budget {
                    break;
                }
                k.append_tokens(1, c, 0.0);
                appended += c;
                let m = k.shard_map(1).unwrap();
                assert!(m.check_contiguous());
                assert_eq!(m.total_tokens(), appended);
                // every shard respects the threshold (last may overflow only
                // when the fleet is exhausted; budget-capped appends avoid it)
                assert!(m.shards.iter().all(|&(_, _, n)| n <= threshold));
                // all but the last shard are full
                for &(_, _, n) in &m.shards[..m.shards.len() - 1] {
                    assert_eq!(n, threshold);
                }
            }
        });
    }
}
