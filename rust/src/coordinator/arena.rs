//! Dense request storage for the scheduling/simulation hot path.
//!
//! Every in-flight request lives in one `Vec` slot; schedulers, the router,
//! and the KVP manager all refer to requests by [`Slot`] — a small integer
//! handle — instead of the external `RequestId`. Touching a request is an
//! array index (one cache line) rather than a `BTreeMap` descent, and
//! finished requests' slots are recycled through a free list so the arena's
//! footprint tracks the number of *concurrent* requests, not the total
//! workload size. That is what lets million-request traces run without the
//! per-request map overhead dominating the iteration loop.

use super::request::Request;

/// Arena handle for an in-flight request. Slots are recycled after a
/// request is retired, so a `Slot` is only meaningful while the request it
/// was issued for is still live.
pub type Slot = u32;

#[derive(Debug, Default)]
pub struct RequestArena {
    slots: Vec<Option<Request>>,
    free: Vec<Slot>,
    live: usize,
}

impl RequestArena {
    pub fn new() -> RequestArena {
        RequestArena::default()
    }

    pub fn with_capacity(n: usize) -> RequestArena {
        RequestArena {
            slots: Vec::with_capacity(n),
            free: Vec::new(),
            live: 0,
        }
    }

    /// Store a request, reusing a vacated slot when one is available.
    pub fn insert(&mut self, r: Request) -> Slot {
        self.live += 1;
        match self.free.pop() {
            Some(s) => {
                debug_assert!(self.slots[s as usize].is_none());
                self.slots[s as usize] = Some(r);
                s
            }
            None => {
                self.slots.push(Some(r));
                (self.slots.len() - 1) as Slot
            }
        }
    }

    /// Retire a request, recycling its slot.
    pub fn remove(&mut self, s: Slot) -> Request {
        let r = self.slots[s as usize].take().expect("removing vacant slot");
        self.free.push(s);
        self.live -= 1;
        r
    }

    /// Hot-path accessor: panics on a vacant slot (a stale handle is a
    /// scheduler bug, not a recoverable condition).
    pub fn get(&self, s: Slot) -> &Request {
        self.slots[s as usize].as_ref().expect("vacant request slot")
    }

    pub fn get_mut(&mut self, s: Slot) -> &mut Request {
        self.slots[s as usize].as_mut().expect("vacant request slot")
    }

    pub fn try_get(&self, s: Slot) -> Option<&Request> {
        self.slots.get(s as usize).and_then(|x| x.as_ref())
    }

    /// Live requests.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Total slots ever allocated (high-water mark of concurrency).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Iterate live requests in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (Slot, &Request)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.as_ref().map(|r| (i as Slot, r)))
    }
}

impl std::ops::Index<Slot> for RequestArena {
    type Output = Request;
    fn index(&self, s: Slot) -> &Request {
        self.get(s)
    }
}

impl std::ops::IndexMut<Slot> for RequestArena {
    fn index_mut(&mut self, s: Slot) -> &mut Request {
        self.get_mut(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, 100, 4, 0.0)
    }

    #[test]
    fn slots_are_recycled() {
        let mut a = RequestArena::new();
        let s0 = a.insert(req(10));
        let s1 = a.insert(req(11));
        assert_eq!((s0, s1), (0, 1));
        assert_eq!(a.len(), 2);
        let r = a.remove(s0);
        assert_eq!(r.id, 10);
        // freed slot is reused before the vector grows
        let s2 = a.insert(req(12));
        assert_eq!(s2, s0);
        assert_eq!(a.len(), 2);
        assert_eq!(a.capacity(), 2);
        assert_eq!(a[s2].id, 12);
        assert_eq!(a[s1].id, 11);
    }

    #[test]
    fn iter_skips_vacant() {
        let mut a = RequestArena::new();
        let s0 = a.insert(req(1));
        let _s1 = a.insert(req(2));
        a.remove(s0);
        let ids: Vec<u64> = a.iter().map(|(_, r)| r.id).collect();
        assert_eq!(ids, vec![2]);
    }

    #[test]
    fn capacity_tracks_high_water_mark() {
        let mut a = RequestArena::new();
        let mut slots = Vec::new();
        for i in 0..100 {
            slots.push(a.insert(req(i)));
        }
        for &s in &slots {
            a.remove(s);
        }
        for i in 0..100 {
            a.insert(req(1000 + i));
        }
        // churn reuses slots: still only 100 ever allocated
        assert_eq!(a.capacity(), 100);
        assert_eq!(a.len(), 100);
    }

    #[test]
    #[should_panic(expected = "vacant request slot")]
    fn stale_handle_panics() {
        let mut a = RequestArena::new();
        let s = a.insert(req(1));
        a.remove(s);
        let _ = a.get(s);
    }
}
