//! Chunk-size policies (section 4.2).
//!
//! Static policies fix one chunk size and live with the prefill/decode
//! latency trade-off of Fig. 8a. **Adaptive chunking** queries the runtime
//! predictor (our perf model, standing in for Vidur's) and picks the largest
//! chunk whose predicted *mixed-batch* execution time stays under the TBT
//! SLO — so chunks start large when the KV prefix is short and shrink as
//! attention time grows (Fig. 8b).

use crate::config::SloConfig;
use crate::perfmodel::{BatchShape, PerfModel, PrefillWork};

pub trait ChunkPolicy: Send + Sync {
    /// Choose the next chunk size for a prefill with `kv_done` tokens
    /// already processed and `remaining` tokens to go, sharing the batch
    /// with `decode_ctxs` (local KV lengths of piggybacked decodes).
    ///
    /// `deadline_remaining_s` is the *live* time left until the request's
    /// TTFT deadline (negative once overdue, `INFINITY` when the request
    /// has no deadline) — callers recompute it every iteration from the
    /// request being chunked, so deadline-aware policies always see the
    /// current request, not state frozen at construction.
    fn next_chunk(
        &self,
        kv_done: u64,
        remaining: u64,
        decode_ctxs: &[u64],
        deadline_remaining_s: f64,
        pm: &PerfModel,
        slo: &SloConfig,
    ) -> u64;

    fn name(&self) -> &'static str;
}

/// Fixed chunk size (Sarathi-style).
#[derive(Debug, Clone, Copy)]
pub struct StaticChunk(pub u64);

impl ChunkPolicy for StaticChunk {
    fn next_chunk(
        &self,
        _kv_done: u64,
        remaining: u64,
        _decode_ctxs: &[u64],
        _deadline_remaining_s: f64,
        _pm: &PerfModel,
        _slo: &SloConfig,
    ) -> u64 {
        self.0.min(remaining)
    }

    fn name(&self) -> &'static str {
        "static"
    }
}

/// Adaptive chunking: largest bucket that keeps the predicted batch
/// execution time within the decode-latency budget.
#[derive(Debug, Clone)]
pub struct AdaptiveChunk {
    /// Candidate sizes, ascending.
    pub buckets: Vec<u64>,
    /// Fraction of the TBT SLO budgeted for a mixed iteration (leave room
    /// for pipeline hops and merge costs charged elsewhere).
    pub budget_frac: f64,
}

impl AdaptiveChunk {
    pub fn new(buckets: Vec<u64>) -> AdaptiveChunk {
        assert!(!buckets.is_empty());
        assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets must ascend");
        AdaptiveChunk {
            buckets,
            budget_frac: 0.9,
        }
    }

    /// Predicted execution time of the mixed batch for chunk size `c`.
    fn predict(
        &self,
        c: u64,
        kv_done: u64,
        decode_ctxs: &[u64],
        pm: &PerfModel,
    ) -> f64 {
        let batch = BatchShape {
            prefills: vec![PrefillWork {
                chunk: c,
                kv_len: kv_done + c,
            }],
            decodes: decode_ctxs
                .iter()
                .map(|&kv_len| crate::perfmodel::DecodeWork { kv_len })
                .collect(),
        };
        // The policy must bound *every* stage's iteration time; stages run
        // layers/spp layers each, and a token hits all of them, so budget
        // against the per-stage time times the pipeline depth is equivalent
        // to budgeting the full-model iteration.
        pm.iteration_time(&batch).total()
    }

    pub fn slo_budget(&self, slo: &SloConfig) -> f64 {
        slo.tbt_s * self.budget_frac
    }
}

impl ChunkPolicy for AdaptiveChunk {
    fn next_chunk(
        &self,
        kv_done: u64,
        remaining: u64,
        decode_ctxs: &[u64],
        _deadline_remaining_s: f64,
        pm: &PerfModel,
        slo: &SloConfig,
    ) -> u64 {
        let budget = self.slo_budget(slo);
        let mut best = self.buckets[0].min(remaining).max(1);
        for &c in &self.buckets {
            let cand = c.min(remaining).max(1);
            let t = self.predict(cand, kv_done, decode_ctxs, pm);
            if t <= budget {
                best = best.max(cand);
            } else {
                break; // predicted time is monotone in c
            }
            if c >= remaining {
                break;
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "adaptive"
    }
}

/// Deadline-aware chunking (the section 4.2 extension the paper points to:
/// "more complex scheduling objectives, such as fairness or deadline-aware
/// scheduling"). Wraps the adaptive policy: while the prefill is on track
/// for its TTFT deadline it behaves exactly like [`AdaptiveChunk`]; once the
/// projected finish time would miss the deadline it escalates to the largest
/// bucket, deliberately trading batched-decode latency for the deadline.
///
/// The deadline is the live `deadline_remaining_s` argument of
/// [`ChunkPolicy::next_chunk`], recomputed by the scheduler from the
/// request being chunked every iteration — one policy instance serves any
/// number of requests.
#[derive(Debug, Clone)]
pub struct DeadlineChunk {
    pub inner: AdaptiveChunk,
}

impl DeadlineChunk {
    pub fn new(buckets: Vec<u64>) -> DeadlineChunk {
        DeadlineChunk {
            inner: AdaptiveChunk::new(buckets),
        }
    }

    /// Projected time to finish `remaining` tokens at chunk size `c`.
    fn projected_finish(&self, c: u64, kv_done: u64, remaining: u64, pm: &PerfModel) -> f64 {
        // One mid-prefill sample scaled by chunk count — cheap and
        // monotone, which is all escalation needs.
        let mid = kv_done + remaining / 2;
        let per = self.inner.predict(c.max(1), mid, &[], pm);
        per * remaining.div_ceil(c.max(1)) as f64
    }
}

impl ChunkPolicy for DeadlineChunk {
    fn next_chunk(
        &self,
        kv_done: u64,
        remaining: u64,
        decode_ctxs: &[u64],
        deadline_remaining_s: f64,
        pm: &PerfModel,
        slo: &SloConfig,
    ) -> u64 {
        let tbt_choice =
            self.inner
                .next_chunk(kv_done, remaining, decode_ctxs, deadline_remaining_s, pm, slo);
        let on_track =
            self.projected_finish(tbt_choice, kv_done, remaining, pm) <= deadline_remaining_s;
        if on_track {
            tbt_choice
        } else {
            // behind schedule: escalate to the largest bucket
            (*self.inner.buckets.last().unwrap()).min(remaining).max(1)
        }
    }

    fn name(&self) -> &'static str {
        "deadline"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;

    fn setup() -> (PerfModel, SloConfig) {
        let d = DeploymentConfig::llama3_8b_tp8();
        (
            PerfModel::new(d.model, d.hardware, d.parallel),
            SloConfig {
                ttft_s: 30.0,
                tbt_s: 0.030,
                ..SloConfig::default()
            },
        )
    }

    /// No live deadline: policies that ignore it get `INFINITY`.
    const NO_DEADLINE: f64 = f64::INFINITY;

    fn buckets() -> Vec<u64> {
        vec![32, 64, 128, 256, 512, 1024, 2048, 4096]
    }

    #[test]
    fn adaptive_shrinks_as_prefix_grows() {
        // The signature behavior of Fig. 8b: early chunks big, late chunks
        // small.
        let (pm, slo) = setup();
        let pol = AdaptiveChunk::new(buckets());
        let early = pol.next_chunk(0, u64::MAX / 2, &[], NO_DEADLINE, &pm, &slo);
        let late = pol.next_chunk(4_000_000, u64::MAX / 2, &[], NO_DEADLINE, &pm, &slo);
        assert!(early >= 2048, "early={early}");
        assert!(late < early, "late={late} early={early}");
    }

    #[test]
    fn adaptive_respects_decode_load() {
        // More batched decodes -> less budget -> smaller chunk.
        let (pm, slo) = setup();
        let pol = AdaptiveChunk::new(buckets());
        let alone = pol.next_chunk(1_000_000, 1 << 40, &[], NO_DEADLINE, &pm, &slo);
        let busy_ctxs: Vec<u64> = (0..64).map(|_| 500_000).collect();
        let busy = pol.next_chunk(1_000_000, 1 << 40, &busy_ctxs, NO_DEADLINE, &pm, &slo);
        assert!(busy <= alone, "busy={busy} alone={alone}");
    }

    #[test]
    fn adaptive_never_exceeds_remaining() {
        let (pm, slo) = setup();
        let pol = AdaptiveChunk::new(buckets());
        assert_eq!(pol.next_chunk(0, 100, &[], NO_DEADLINE, &pm, &slo), 100);
        assert_eq!(pol.next_chunk(0, 1, &[], NO_DEADLINE, &pm, &slo), 1);
    }

    #[test]
    fn adaptive_falls_back_to_min_bucket_when_budget_tight() {
        let (pm, _) = setup();
        let pol = AdaptiveChunk::new(buckets());
        let tight = SloConfig {
            ttft_s: 30.0,
            tbt_s: 1e-6,
            ..SloConfig::default()
        };
        assert_eq!(pol.next_chunk(5_000_000, 1 << 40, &[], NO_DEADLINE, &pm, &tight), 32);
    }

    #[test]
    fn static_is_constant() {
        let (pm, slo) = setup();
        let pol = StaticChunk(512);
        assert_eq!(pol.next_chunk(0, 1 << 40, &[], NO_DEADLINE, &pm, &slo), 512);
        assert_eq!(pol.next_chunk(9_999_999, 1 << 40, &[], NO_DEADLINE, &pm, &slo), 512);
        assert_eq!(pol.next_chunk(0, 100, &[], NO_DEADLINE, &pm, &slo), 100);
    }

    #[test]
    fn deadline_policy_relaxed_when_on_track() {
        // Generous deadline: behaves like the adaptive policy.
        let (pm, slo) = setup();
        let adaptive = AdaptiveChunk::new(buckets());
        let pol = DeadlineChunk::new(buckets());
        let busy: Vec<u64> = (0..32).map(|_| 500_000).collect();
        assert_eq!(
            pol.next_chunk(2_000_000, 1 << 30, &busy, 1e9, &pm, &slo),
            adaptive.next_chunk(2_000_000, 1 << 30, &busy, NO_DEADLINE, &pm, &slo)
        );
    }

    #[test]
    fn deadline_policy_escalates_when_behind() {
        // 1 second left for a 4M prefill: must escalate to the max bucket
        // even with decodes batched along.
        let (pm, slo) = setup();
        let pol = DeadlineChunk::new(buckets());
        let busy: Vec<u64> = (0..32).map(|_| 500_000).collect();
        let c = pol.next_chunk(0, 4_000_000, &busy, 1.0, &pm, &slo);
        assert_eq!(c, *buckets().last().unwrap());
    }

    #[test]
    fn deadline_policy_tracks_the_live_request() {
        // The same policy instance serves two requests with different
        // live deadlines — the stale-constructor-state bug this replaces.
        let (pm, slo) = setup();
        let pol = DeadlineChunk::new(buckets());
        let relaxed = pol.next_chunk(2_000_000, 1 << 30, &[], 1e9, &pm, &slo);
        let urgent = pol.next_chunk(2_000_000, 1 << 30, &[], 0.5, &pm, &slo);
        assert_eq!(urgent, *buckets().last().unwrap());
        assert!(relaxed <= urgent);
    }

    #[test]
    fn predicted_batch_time_monotone_in_chunk() {
        let (pm, _) = setup();
        let pol = AdaptiveChunk::new(buckets());
        let mut prev = 0.0;
        for &c in &pol.buckets {
            let t = pol.predict(c, 2_000_000, &[], &pm);
            assert!(t >= prev, "c={c}: {t} < {prev}");
            prev = t;
        }
    }
}
