//! 3D-parallel worker topology (Fig. 12): kvp groups x spp stages x tp
//! workers, with node placement (TP groups never cross the NVLink domain).

use crate::config::{HardwareConfig, ParallelismConfig};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerId {
    pub kvp: u32,
    pub stage: u32,
    pub tp: u32,
}

#[derive(Debug, Clone)]
pub struct Topology {
    pub parallel: ParallelismConfig,
    pub gpus_per_node: u32,
}

impl Topology {
    pub fn new(parallel: ParallelismConfig, hw: &HardwareConfig) -> Topology {
        Topology {
            parallel,
            gpus_per_node: hw.gpus_per_node,
        }
    }

    pub fn total_workers(&self) -> u32 {
        self.parallel.total_workers()
    }

    pub fn workers(&self) -> impl Iterator<Item = WorkerId> + '_ {
        let p = self.parallel;
        (0..p.kvp).flat_map(move |kvp| {
            (0..p.spp).flat_map(move |stage| (0..p.tp).map(move |tp| WorkerId { kvp, stage, tp }))
        })
    }

    /// Global linear index (placement order: kvp-major, then stage, then tp
    /// — keeps each TP group contiguous so it lands inside one node).
    pub fn linear(&self, w: WorkerId) -> u32 {
        (w.kvp * self.parallel.spp + w.stage) * self.parallel.tp + w.tp
    }

    pub fn node_of(&self, w: WorkerId) -> u32 {
        self.linear(w) / self.gpus_per_node
    }

    /// Does the stage->stage+1 hop cross a node boundary?
    pub fn stage_hop_crosses_node(&self, kvp: u32, stage: u32) -> bool {
        let a = self.node_of(WorkerId { kvp, stage, tp: 0 });
        let b = self.node_of(WorkerId {
            kvp,
            stage: stage + 1,
            tp: 0,
        });
        a != b
    }

    /// GPUs in use when `active_kvp` groups participate (Fig. 19 y-axis).
    pub fn gpus_active(&self, active_kvp: u32) -> u32 {
        active_kvp.min(self.parallel.kvp) * self.parallel.workers_per_replica()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    fn topo(tp: u32, spp: u32, kvp: u32) -> Topology {
        Topology::new(
            ParallelismConfig::new(tp, spp, kvp),
            &HardwareConfig::dgx_h100(),
        )
    }

    #[test]
    fn enumerates_all_workers_uniquely() {
        let t = topo(8, 4, 4);
        let ws: Vec<_> = t.workers().collect();
        assert_eq!(ws.len(), 128);
        let mut idx: Vec<u32> = ws.iter().map(|&w| t.linear(w)).collect();
        idx.sort_unstable();
        assert_eq!(idx, (0..128).collect::<Vec<_>>());
    }

    #[test]
    fn tp_groups_stay_within_nodes() {
        let t = topo(8, 4, 4);
        for w in t.workers() {
            let n0 = t.node_of(WorkerId { tp: 0, ..w });
            assert_eq!(t.node_of(w), n0, "TP group split across nodes: {w:?}");
        }
    }

    #[test]
    fn stage_hops_cross_nodes_at_tp8() {
        let t = topo(8, 4, 1);
        assert!(t.stage_hop_crosses_node(0, 0));
        // tp=4: two stages share a node
        let t2 = topo(4, 4, 1);
        assert!(!t2.stage_hop_crosses_node(0, 0));
        assert!(t2.stage_hop_crosses_node(0, 1));
    }

    #[test]
    fn fig19_gpu_accounting() {
        let t = topo(8, 4, 4);
        assert_eq!(t.gpus_active(1), 32);
        assert_eq!(t.gpus_active(4), 128);
        assert_eq!(t.gpus_active(9), 128); // clamped
    }
}
