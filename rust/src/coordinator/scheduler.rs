//! Mixed continuous-batching scheduler (sections 2.4, 4.1–4.2, 5).
//!
//! Every iteration the scheduler forms one mixed batch per replica:
//! all active decodes (continuous batching, Orca-style) plus one chunk of
//! one prefill, sized by the chunk policy. Chunking is what eliminates
//! head-of-line blocking: a newly arrived request waits at most one
//! bounded iteration, never behind a monolithic multi-minute prefill
//! (Fig. 14b).
//!
//! **Which** prefill runs is decided by a pluggable [`SchedPolicy`]
//! (section 5): each iteration the most urgent ready request is selected,
//! and preemptive policies (SRPT, EDF, LARS) may switch away from a
//! partially-prefilled request at the chunk boundary — its KV stays
//! resident and it resumes from the same boundary later. The default FCFS
//! policy is non-preemptive and preserves the original strict queue-order
//! behavior (and its hot path: no selection at all).
//!
//! # The indexed ready set
//!
//! Selection is served by a [`ReadySet`] keyed per the policy's
//! [`KeyShape`](super::policy::KeyShape) — an ordered index for the
//! static-key policies (SRPT/EDF), the pruned critical-time walk for
//! LARS's time-varying slack, a plain FIFO for FCFS — replacing the O(n)
//! priority scan per iteration that collapsed at million-request
//! backlogs. The invariants the scheduler upholds for the index:
//!
//! * a request enters the set once, at [`Scheduler::enqueue`], and leaves
//!   exactly when its prefill completes (or its owner retires it);
//! * the only request whose keys can change between iterations is the one
//!   whose chunk just executed — [`Scheduler::complete_iteration_into`]
//!   re-keys it at that boundary (`remaining_work_s` is a pure function of
//!   prefill progress; deadlines are immutable after admission);
//! * selection must equal the canonical `(priority, enqueue-order)` argmin
//!   — re-asserted against the O(n) scan by a `debug_assert` on **every
//!   preemptive selection** in debug builds, and by the randomized
//!   differential harness in `tests/invariants.rs`.
//!
//! The scheduler is built for a hot loop that runs millions of times per
//! simulated trace: requests are referenced by arena [`Slot`]s, batch plans
//! and shapes are written into caller-provided buffers (`next_batch_into`,
//! `batch_shape_into`), and the decode-context list the chunk policy needs
//! is maintained *incrementally* — updated when a request enters or leaves
//! decode — instead of being rebuilt (and reallocated) every iteration.

use super::arena::{RequestArena, Slot};
use super::chunking::ChunkPolicy;
use super::policy::{Fcfs, SchedPolicy};
use super::readyset::ReadySet;
use super::request::{Phase, Request};
use crate::config::SloConfig;
use crate::perfmodel::{BatchShape, DecodeWork, PerfModel, PrefillWork};

/// What the scheduler decided to run this iteration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BatchPlan {
    /// (request, chunk size) — at most one chunked prefill per iteration
    /// (Sarathi-style; the chunk budget is the knob, not the count).
    pub prefill: Option<(Slot, u64)>,
    /// Requests getting one decode token each.
    pub decodes: Vec<Slot>,
}

impl BatchPlan {
    pub fn is_empty(&self) -> bool {
        self.prefill.is_none() && self.decodes.is_empty()
    }

    /// Empty the plan, keeping the decode-list allocation for reuse.
    pub fn clear(&mut self) {
        self.prefill = None;
        self.decodes.clear();
    }
}

/// Iteration-level scheduler state for one replica (one KVP group).
pub struct Scheduler {
    pub policy: Box<dyn ChunkPolicy>,
    /// Ready-set ordering + preemption policy (section 5). FCFS by default.
    pub sched: Box<dyn SchedPolicy>,
    pub max_batch: usize,
    /// Requests awaiting/undergoing prefill, indexed for O(log n)
    /// selection by the policy's key shape (see the module docs).
    ready: ReadySet,
    /// Requests in decode phase, in the order they entered decode.
    decoding: Vec<Slot>,
    /// Local KV length per decoding request, parallel to `decoding`.
    /// Maintained incrementally so batch formation never walks the arena.
    decode_ctxs: Vec<u64>,
    /// The prefill scheduled last iteration, while it is still mid-prefill
    /// (cleared when it leaves the ready set). Switching away from it
    /// counts as a preemption.
    running_prefill: Option<Slot>,
    /// Chunk-boundary switches away from a partially-prefilled request.
    pub preemptions: u64,
}

impl Scheduler {
    /// FCFS scheduler (the non-preemptive default the recorded golden
    /// snapshots pin down).
    pub fn new(policy: Box<dyn ChunkPolicy>, max_batch: usize) -> Scheduler {
        Scheduler::with_policy(policy, Box::new(Fcfs), max_batch)
    }

    pub fn with_policy(
        policy: Box<dyn ChunkPolicy>,
        sched: Box<dyn SchedPolicy>,
        max_batch: usize,
    ) -> Scheduler {
        let ready = ReadySet::new(sched.key_shape());
        Scheduler {
            policy,
            sched,
            max_batch,
            ready,
            decoding: Vec::new(),
            decode_ctxs: Vec::new(),
            running_prefill: None,
            preemptions: 0,
        }
    }

    /// Admit `s` to the ready set, keying it from its current request
    /// state (deadline/work estimates must already be assigned).
    pub fn enqueue(&mut self, s: Slot, requests: &RequestArena) {
        self.ready.push(s, self.sched.as_ref(), requests);
    }

    pub fn queue_len(&self) -> usize {
        self.ready.len()
    }

    /// Slots in this group's ready set (FIFO order under FCFS, slot order
    /// otherwise — the set is an index, not a queue). Diagnostics only;
    /// the router reads the O(1) urgency counter ([`Self::n_urgent`])
    /// instead of scanning this.
    pub fn queued_slots(&self) -> impl Iterator<Item = Slot> + '_ {
        self.ready.iter()
    }

    /// Queued requests already past their policy critical time — the
    /// incrementally maintained urgency counter behind the router's
    /// `GroupView::more_urgent_queued` (O(1) read; amortized O(log n)
    /// maintenance as `now` advances).
    pub fn n_urgent(&mut self, now: f64) -> usize {
        self.ready.n_urgent(now)
    }

    pub fn n_decoding(&self) -> usize {
        self.decoding.len()
    }

    pub fn has_work(&self) -> bool {
        !self.ready.is_empty() || !self.decoding.is_empty()
    }

    /// Local KV lengths of *all* decoding requests on this replica, in
    /// decode-entry order (what a chunk policy sees as the resident decode
    /// load).
    pub fn decode_ctxs(&self) -> &[u64] {
        &self.decode_ctxs
    }

    /// Form the next mixed batch into `out` (allocation-free once `out`'s
    /// decode list has warmed up).
    ///
    /// The prefill slot goes to the most urgent request in the ready set
    /// at time `now` (minimum policy priority, ties toward the earlier
    /// enqueue). Under a preemptive policy that request may differ from
    /// the one that ran last iteration even if the latter is mid-prefill —
    /// that is a chunk-boundary preemption: the preempted request keeps
    /// its ready-set position and its computed KV, and resumes from the
    /// same boundary when it wins again. Non-preemptive policies (FCFS)
    /// run the head to completion with no selection work at all.
    ///
    /// The chunk policy sees the incrementally-tracked decode contexts,
    /// whose values are defined by the `local_kv` closure passed to
    /// [`Self::complete_iteration_into`] — batch formation itself never
    /// walks the arena for them.
    pub fn next_batch_into(
        &mut self,
        requests: &RequestArena,
        pm: &PerfModel,
        slo: &SloConfig,
        now: f64,
        out: &mut BatchPlan,
    ) {
        out.clear();
        // Continuous batching: every decoding request gets a token.
        let k = self.decoding.len().min(self.max_batch);
        out.decodes.extend_from_slice(&self.decoding[..k]);
        let decode_ctxs = &self.decode_ctxs[..k];

        // Indexed priority selection (O(log n); see the module docs). The
        // debug assertion is the standing differential proof that the
        // index serves the same request the O(n) scan would.
        let best = self.ready.select(self.sched.as_ref(), requests, now);
        debug_assert_eq!(
            best,
            self.ready.select_via_scan(self.sched.as_ref(), requests, now),
            "{}: indexed selection diverged from the scan at now={now}",
            self.sched.name()
        );

        // Piggyback one chunk of the selected prefill.
        out.prefill = best.and_then(|s| {
            let r = requests.get(s);
            let remaining = r.remaining_prefill();
            if remaining == 0 {
                return None;
            }
            let c = self.policy.next_chunk(
                r.kv_len(),
                remaining,
                decode_ctxs,
                r.deadline_remaining_s(now),
                pm,
                slo,
            );
            Some((s, c.max(1).min(remaining)))
        });
    }

    /// Convenience wrapper allocating a fresh plan (tests / cold paths).
    pub fn next_batch(
        &mut self,
        requests: &RequestArena,
        pm: &PerfModel,
        slo: &SloConfig,
        now: f64,
    ) -> BatchPlan {
        let mut plan = BatchPlan::default();
        self.next_batch_into(requests, pm, slo, now, &mut plan);
        plan
    }

    /// Write the `BatchShape` (perf-model view) of a plan into `out`, using
    /// local KV lengths. `out` is cleared first.
    pub fn batch_shape_into<F: Fn(&Request) -> u64>(
        &self,
        plan: &BatchPlan,
        requests: &RequestArena,
        local_kv: F,
        out: &mut BatchShape,
    ) {
        out.clear();
        if let Some((s, c)) = plan.prefill {
            let r = requests.get(s);
            out.prefills.push(PrefillWork {
                chunk: c,
                kv_len: local_kv(r) + c,
            });
        }
        for &s in &plan.decodes {
            out.decodes.push(DecodeWork {
                kv_len: local_kv(requests.get(s)).max(1),
            });
        }
    }

    /// Convenience wrapper allocating a fresh shape.
    pub fn batch_shape<F: Fn(&Request) -> u64>(
        &self,
        plan: &BatchPlan,
        requests: &RequestArena,
        local_kv: F,
    ) -> BatchShape {
        let mut shape = BatchShape::default();
        self.batch_shape_into(plan, requests, local_kv, &mut shape);
        shape
    }

    /// Apply request state transitions after a plan's iteration completes
    /// at time `t`, appending requests that finished to `finished` (cleared
    /// first). `plan` must be the plan most recently formed by
    /// `next_batch_into` on this scheduler's current state.
    ///
    /// `local_kv` maps a request to the KV length *this replica* scans for
    /// it (identity for unsharded requests; the KVP manager's local shard
    /// length for sharded ones) and defines the decode-context values the
    /// chunk policy sees on subsequent `next_batch_into` calls — pass the
    /// same closure every iteration.
    pub fn complete_iteration_into<F: Fn(&Request) -> u64>(
        &mut self,
        plan: &BatchPlan,
        requests: &mut RequestArena,
        t: f64,
        local_kv: F,
        finished: &mut Vec<Slot>,
    ) {
        finished.clear();
        let mut any_decode_finished = false;
        if let Some((s, c)) = plan.prefill {
            // Preemption accounting, at the moment the switch takes effect:
            // a different request than the mid-prefill one actually ran.
            // (Counting here, not at plan formation, keeps re-forming an
            // unexecuted plan from inflating the metric.)
            if matches!(self.running_prefill, Some(prev) if prev != s) {
                self.preemptions += 1;
            }
            requests.get_mut(s).complete_chunk(c, t);
            match requests.get(s).phase {
                Phase::Decoding => {
                    self.ready.remove(s);
                    self.decoding.push(s);
                    self.decode_ctxs.push(local_kv(requests.get(s)).max(1));
                    self.running_prefill = None;
                }
                Phase::Finished => {
                    self.ready.remove(s);
                    finished.push(s);
                    self.running_prefill = None;
                }
                _ => {
                    // Still mid-prefill: its remaining work changed, so its
                    // index keys must follow (the only re-key point — see
                    // the module invariants).
                    self.ready.rekey(s, self.sched.as_ref(), requests);
                    self.running_prefill = Some(s);
                }
            }
        }
        for (i, &s) in plan.decodes.iter().enumerate() {
            debug_assert_eq!(
                self.decoding.get(i).copied(),
                Some(s),
                "plan does not match scheduler state"
            );
            let r = requests.get_mut(s);
            r.complete_decode(t);
            if r.is_finished() {
                finished.push(s);
                any_decode_finished = true;
            } else {
                self.decode_ctxs[i] = local_kv(requests.get(s)).max(1);
            }
        }
        if any_decode_finished {
            // Compact `decoding`/`decode_ctxs` in place, dropping finished
            // requests. One linear pass using the per-request phase flag —
            // not the O(n·m) `finished.contains` retain this replaces.
            let mut w = 0;
            for i in 0..self.decoding.len() {
                let s = self.decoding[i];
                if requests.get(s).is_finished() {
                    continue;
                }
                self.decoding[w] = s;
                self.decode_ctxs[w] = self.decode_ctxs[i];
                w += 1;
            }
            self.decoding.truncate(w);
            self.decode_ctxs.truncate(w);
        }
    }

    /// Crash teardown for this replica: strip every queued and decoding
    /// request out and append their slots to `out` (cleared first; ready
    /// requests first, then decoding requests in decode-entry order). The
    /// scheduler is left empty and reusable — a group rejoining after a
    /// crash starts from a clean slate. The caller owns re-routing the
    /// evicted requests and rewinding their KV progress.
    pub fn evict_all(&mut self, out: &mut Vec<Slot>) {
        out.clear();
        out.extend(self.ready.iter());
        for &s in out.iter() {
            self.ready.remove(s);
        }
        out.append(&mut self.decoding);
        self.decode_ctxs.clear();
        self.running_prefill = None;
    }

    /// Convenience wrapper for unsharded replicas (tests / cold paths):
    /// decode contexts track plain `kv_len`, finished set returned fresh.
    pub fn complete_iteration(
        &mut self,
        plan: &BatchPlan,
        requests: &mut RequestArena,
        t: f64,
    ) -> Vec<Slot> {
        let mut finished = Vec::new();
        self.complete_iteration_into(plan, requests, t, |r| r.kv_len(), &mut finished);
        finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::coordinator::chunking::{AdaptiveChunk, StaticChunk};
    use crate::coordinator::policy::{Lars, Srpt};

    fn setup() -> (PerfModel, SloConfig, RequestArena) {
        let d = DeploymentConfig::llama3_8b_tp8();
        (
            PerfModel::new(d.model, d.hardware, d.parallel),
            SloConfig::default(),
            RequestArena::new(),
        )
    }

    fn static_sched(c: u64) -> Scheduler {
        Scheduler::new(Box::new(StaticChunk(c)), 128)
    }

    #[test]
    fn drains_prefill_then_decodes() {
        let (pm, slo, mut reqs) = setup();
        let s1 = reqs.insert(Request::new(1, 100, 3, 0.0));
        let mut s = static_sched(64);
        s.enqueue(s1, &reqs);

        let p1 = s.next_batch(&reqs, &pm, &slo, 0.0);
        assert_eq!(p1.prefill, Some((s1, 64)));
        assert!(p1.decodes.is_empty());
        s.complete_iteration(&p1, &mut reqs, 0.1);

        let p2 = s.next_batch(&reqs, &pm, &slo, 0.0);
        assert_eq!(p2.prefill, Some((s1, 36))); // clipped to remaining
        s.complete_iteration(&p2, &mut reqs, 0.2);
        assert_eq!(reqs[s1].phase, Phase::Decoding);

        // now it decodes; no prefill left
        let p3 = s.next_batch(&reqs, &pm, &slo, 0.0);
        assert_eq!(p3.prefill, None);
        assert_eq!(p3.decodes, vec![s1]);
        s.complete_iteration(&p3, &mut reqs, 0.3);
        let p4 = s.next_batch(&reqs, &pm, &slo, 0.0);
        let fin = s.complete_iteration(&p4, &mut reqs, 0.4);
        assert_eq!(fin, vec![s1]);
        assert!(!s.has_work());
    }

    #[test]
    fn mixed_batch_piggybacks_prefill_on_decodes() {
        let (pm, slo, mut reqs) = setup();
        // request 1 decoding, request 2 long prefill arrives
        let s1 = reqs.insert(Request::new(1, 10, 50, 0.0));
        let s2 = reqs.insert(Request::new(2, 1_000_000, 10, 1.0));
        let mut s = static_sched(512);
        s.enqueue(s1, &reqs);
        let p = s.next_batch(&reqs, &pm, &slo, 0.0);
        s.complete_iteration(&p, &mut reqs, 0.1); // prefills 1 fully
        s.enqueue(s2, &reqs);

        let plan = s.next_batch(&reqs, &pm, &slo, 0.0);
        assert_eq!(plan.prefill, Some((s2, 512)));
        assert_eq!(plan.decodes, vec![s1]); // decode not blocked by long prefill
    }

    #[test]
    fn adaptive_policy_shrinks_chunks_late_in_prefill() {
        let (pm, slo, mut reqs) = setup();
        let s1 = reqs.insert(Request::new(1, 8_000_000, 1, 0.0));
        let mut s = Scheduler::new(
            Box::new(AdaptiveChunk::new(vec![32, 256, 2048, 4096])),
            128,
        );
        s.enqueue(s1, &reqs);
        let first = s.next_batch(&reqs, &pm, &slo, 0.0);
        let (_, c_first) = first.prefill.unwrap();
        // fast-forward most of the prefill
        reqs[s1].complete_chunk(6_000_000, 100.0);
        let late = s.next_batch(&reqs, &pm, &slo, 0.0);
        let (_, c_late) = late.prefill.unwrap();
        assert!(c_late < c_first, "late={c_late} first={c_first}");
    }

    #[test]
    fn max_batch_caps_decodes() {
        let (pm, slo, mut reqs) = setup();
        let mut s = Scheduler::new(Box::new(StaticChunk(64)), 4);
        for id in 0..8 {
            let slot = reqs.insert(Request::new(id, 1, 100, 0.0));
            s.enqueue(slot, &reqs);
            let p = s.next_batch(&reqs, &pm, &slo, 0.0);
            s.complete_iteration(&p, &mut reqs, 0.1);
        }
        assert_eq!(s.n_decoding(), 8);
        let plan = s.next_batch(&reqs, &pm, &slo, 0.0);
        assert_eq!(plan.decodes.len(), 4);
    }

    #[test]
    fn batch_shape_uses_local_kv() {
        let (pm, slo, mut reqs) = setup();
        let s1 = reqs.insert(Request::new(1, 1, 100, 0.0));
        let mut s = static_sched(64);
        s.enqueue(s1, &reqs);
        let p = s.next_batch(&reqs, &pm, &slo, 0.0);
        s.complete_iteration(&p, &mut reqs, 0.1);
        reqs[s1].decoded = 50; // pretend long decode
        let plan = s.next_batch(&reqs, &pm, &slo, 0.0);
        // KVP view: local shard is half the KV
        let shape = s.batch_shape(&plan, &reqs, |r| r.kv_len() / 2);
        assert_eq!(shape.decodes[0].kv_len, reqs[s1].kv_len() / 2);
    }

    #[test]
    fn decode_ctxs_track_incrementally() {
        let (pm, slo, mut reqs) = setup();
        let s1 = reqs.insert(Request::new(1, 10, 100, 0.0));
        let s2 = reqs.insert(Request::new(2, 20, 100, 0.0));
        let mut s = static_sched(64);
        s.enqueue(s1, &reqs);
        s.enqueue(s2, &reqs);
        for _ in 0..2 {
            let p = s.next_batch(&reqs, &pm, &slo, 0.0);
            s.complete_iteration(&p, &mut reqs, 0.1);
        }
        // both decoding: ctxs mirror kv lengths, in decode-entry order
        assert_eq!(s.decode_ctxs(), &[reqs[s1].kv_len(), reqs[s2].kv_len()]);
        let p = s.next_batch(&reqs, &pm, &slo, 0.0);
        s.complete_iteration(&p, &mut reqs, 0.2);
        assert_eq!(s.decode_ctxs(), &[reqs[s1].kv_len(), reqs[s2].kv_len()]);
    }

    #[test]
    fn finished_decodes_compact_without_reorder() {
        let (pm, slo, mut reqs) = setup();
        let mut s = static_sched(64);
        let mut slots = Vec::new();
        // the middle request finishes first; neighbors run longer
        for (id, out) in [(1u64, 8u64), (2, 3), (3, 8)] {
            let slot = reqs.insert(Request::new(id, 4, out, 0.0));
            s.enqueue(slot, &reqs);
            let p = s.next_batch(&reqs, &pm, &slo, 0.0);
            s.complete_iteration(&p, &mut reqs, 0.1);
            slots.push(slot);
        }
        let p = s.next_batch(&reqs, &pm, &slo, 0.0);
        let fin = s.complete_iteration(&p, &mut reqs, 0.2);
        assert_eq!(fin, vec![slots[1]]);
        // survivors keep their relative order and their ctxs
        let p = s.next_batch(&reqs, &pm, &slo, 0.0);
        assert_eq!(p.decodes, vec![slots[0], slots[2]]);
        assert_eq!(s.decode_ctxs(), &[reqs[slots[0]].kv_len(), reqs[slots[2]].kv_len()]);
    }

    #[test]
    fn evict_all_empties_the_scheduler_for_reuse() {
        let (pm, slo, mut reqs) = setup();
        let mut s = static_sched(64);
        // one decoding, one mid-prefill, one still queued
        let deco = reqs.insert(Request::new(1, 4, 8, 0.0));
        s.enqueue(deco, &reqs);
        let p = s.next_batch(&reqs, &pm, &slo, 0.0);
        s.complete_iteration(&p, &mut reqs, 0.1);
        let mid = reqs.insert(Request::new(2, 256, 1, 0.1));
        let queued = reqs.insert(Request::new(3, 64, 1, 0.2));
        s.enqueue(mid, &reqs);
        s.enqueue(queued, &reqs);
        let p = s.next_batch(&reqs, &pm, &slo, 0.2);
        s.complete_iteration(&p, &mut reqs, 0.3); // mid is now running_prefill

        let mut evicted = Vec::new();
        s.evict_all(&mut evicted);
        evicted.sort_unstable();
        let mut want = vec![deco, mid, queued];
        want.sort_unstable();
        assert_eq!(evicted, want);
        assert!(!s.has_work());
        assert_eq!(s.n_decoding(), 0);
        assert!(s.decode_ctxs().is_empty());

        // the scheduler is reusable after teardown
        s.enqueue(queued, &reqs);
        let p = s.next_batch(&reqs, &pm, &slo, 0.4);
        assert_eq!(p.prefill, Some((queued, 64)));
        // re-running the evicted mid-prefill elsewhere is not a preemption
        assert_eq!(s.preemptions, 0);
    }

    #[test]
    fn lars_preempts_long_prefill_for_urgent_short() {
        let (pm, slo, mut reqs) = setup();
        let mut s =
            Scheduler::with_policy(Box::new(StaticChunk(64)), Box::new(Lars::default()), 128);
        // 10 chunks of estimated work, generous proportional deadline
        let long = reqs.insert(Request::new(1, 640, 4, 0.0).with_slo(10.0, 50.0));
        s.enqueue(long, &reqs);
        for t in [0.1, 0.2] {
            let p = s.next_batch(&reqs, &pm, &slo, t - 0.1);
            assert_eq!(p.prefill, Some((long, 64)));
            s.complete_iteration(&p, &mut reqs, t);
        }
        assert_eq!(reqs[long].prefilled, 128);

        // urgent short arrives: tiny remaining work, deadline nearly blown
        let short = reqs.insert(Request::new(2, 64, 2, 0.2).with_slo(0.05, 0.3));
        s.enqueue(short, &reqs);
        let p = s.next_batch(&reqs, &pm, &slo, 0.25);
        assert_eq!(p.prefill, Some((short, 64)), "urgent short must preempt");
        assert_eq!(s.preemptions, 0, "counted only when the switch executes");
        // preemption point is the chunk boundary: the long request's KV is
        // retained exactly as computed
        assert_eq!(reqs[long].prefilled, 128);
        assert_eq!(reqs[long].phase, Phase::Prefilling);
        s.complete_iteration(&p, &mut reqs, 0.3);
        assert_eq!(s.preemptions, 1);
        assert_eq!(reqs[short].phase, Phase::Decoding);

        // the long request resumes from its exact boundary, KV intact
        let p = s.next_batch(&reqs, &pm, &slo, 0.35);
        assert_eq!(p.prefill, Some((long, 64)));
        assert_eq!(p.decodes, vec![short]);
        s.complete_iteration(&p, &mut reqs, 0.4);
        assert_eq!(reqs[long].prefilled, 192);
        assert_eq!(s.preemptions, 1, "resuming is not a preemption");
    }

    #[test]
    fn srpt_runs_shortest_first_without_counting_false_preemptions() {
        let (pm, slo, mut reqs) = setup();
        let mut s = Scheduler::with_policy(Box::new(StaticChunk(64)), Box::new(Srpt), 128);
        let big = reqs.insert(Request::new(1, 1_000, 1, 0.0).with_slo(1.0, 100.0));
        let small = reqs.insert(Request::new(2, 64, 1, 0.0).with_slo(0.05, 100.0));
        s.enqueue(big, &reqs);
        s.enqueue(small, &reqs);
        // the small request runs first even though it arrived second
        let p = s.next_batch(&reqs, &pm, &slo, 0.0);
        assert_eq!(p.prefill, Some((small, 64)));
        s.complete_iteration(&p, &mut reqs, 0.1);
        // nothing had started when the small one won: no preemption
        assert_eq!(s.preemptions, 0);
        let p = s.next_batch(&reqs, &pm, &slo, 0.1);
        assert_eq!(p.prefill, Some((big, 64)));
    }

    #[test]
    fn fcfs_never_reorders_or_preempts() {
        let (pm, slo, mut reqs) = setup();
        let mut s = static_sched(64);
        // second request is far more urgent under any deadline policy —
        // FCFS must ignore that entirely
        let a = reqs.insert(Request::new(1, 256, 1, 0.0).with_slo(10.0, 1_000.0));
        let b = reqs.insert(Request::new(2, 64, 1, 0.1).with_slo(0.01, 0.2));
        s.enqueue(a, &reqs);
        s.enqueue(b, &reqs);
        for t in [1.0, 2.0, 3.0, 4.0] {
            let p = s.next_batch(&reqs, &pm, &slo, t);
            if reqs[a].remaining_prefill() > 0 {
                assert_eq!(p.prefill, Some((a, reqs[a].remaining_prefill().min(64))));
            }
            s.complete_iteration(&p, &mut reqs, t);
        }
        assert_eq!(s.preemptions, 0);
        assert!(reqs[a].is_finished());
    }

    #[test]
    fn urgency_counter_tracks_deadline_critical_backlog() {
        let (pm, slo, mut reqs) = setup();
        let mut s =
            Scheduler::with_policy(Box::new(StaticChunk(64)), Box::new(Lars::default()), 128);
        // deadlines at 1.0 and 100.0 (LARS critical times pulled in by the
        // headroom fraction)
        let tight = reqs.insert(Request::new(1, 640, 1, 0.0).with_slo(0.1, 1.0));
        let loose = reqs.insert(Request::new(2, 640, 1, 0.0).with_slo(0.1, 100.0));
        s.enqueue(tight, &reqs);
        s.enqueue(loose, &reqs);
        assert_eq!(s.n_urgent(0.0), 0);
        assert_eq!(s.n_urgent(2.0), 1, "tight deadline has passed");
        assert_eq!(s.n_urgent(200.0), 2);
        // the counter shrinks as critical requests drain
        let p = s.next_batch(&reqs, &pm, &slo, 200.0);
        assert_eq!(p.prefill.map(|(x, _)| x), Some(tight));
        for t in [200.1; 10] {
            let p = s.next_batch(&reqs, &pm, &slo, t);
            if p.is_empty() {
                break;
            }
            s.complete_iteration(&p, &mut reqs, t);
        }
        assert!(s.n_urgent(200.2) <= 1);
    }
}
