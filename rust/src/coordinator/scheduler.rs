//! Mixed continuous-batching scheduler (sections 2.4, 4.1–4.2).
//!
//! Every iteration the scheduler forms one mixed batch per replica:
//! all active decodes (continuous batching, Orca-style) plus one chunk of
//! the head-of-queue prefill, sized by the chunk policy. Chunking is what
//! eliminates head-of-line blocking: a newly arrived request waits at most
//! one bounded iteration, never behind a monolithic multi-minute prefill
//! (Fig. 14b).

use std::collections::{BTreeMap, VecDeque};

use super::chunking::ChunkPolicy;
use super::request::{Phase, Request};
use crate::config::SloConfig;
use crate::kvcache::RequestId;
use crate::perfmodel::{BatchShape, DecodeWork, PerfModel, PrefillWork};

/// What the scheduler decided to run this iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchPlan {
    /// (request, chunk size) — at most one chunked prefill per iteration
    /// (Sarathi-style; the chunk budget is the knob, not the count).
    pub prefill: Option<(RequestId, u64)>,
    /// Requests getting one decode token each.
    pub decodes: Vec<RequestId>,
}

impl BatchPlan {
    pub fn is_empty(&self) -> bool {
        self.prefill.is_none() && self.decodes.is_empty()
    }
}

/// Iteration-level scheduler state for one replica (one KVP group).
pub struct Scheduler {
    pub policy: Box<dyn ChunkPolicy>,
    pub max_batch: usize,
    /// FIFO of requests awaiting/undergoing prefill.
    prefill_queue: VecDeque<RequestId>,
    /// Requests in decode phase.
    decoding: Vec<RequestId>,
}

impl Scheduler {
    pub fn new(policy: Box<dyn ChunkPolicy>, max_batch: usize) -> Scheduler {
        Scheduler {
            policy,
            max_batch,
            prefill_queue: VecDeque::new(),
            decoding: Vec::new(),
        }
    }

    pub fn enqueue(&mut self, id: RequestId) {
        self.prefill_queue.push_back(id);
    }

    pub fn queue_len(&self) -> usize {
        self.prefill_queue.len()
    }

    pub fn n_decoding(&self) -> usize {
        self.decoding.len()
    }

    pub fn has_work(&self) -> bool {
        !self.prefill_queue.is_empty() || !self.decoding.is_empty()
    }

    /// Form the next mixed batch. `local_kv` maps a request to the KV
    /// length *this replica* scans for it (identity for unsharded requests;
    /// the KVP manager's local shard length for sharded ones).
    pub fn next_batch<F: Fn(&Request) -> u64>(
        &mut self,
        requests: &BTreeMap<RequestId, Request>,
        pm: &PerfModel,
        slo: &SloConfig,
        local_kv: F,
    ) -> BatchPlan {
        // Continuous batching: every decoding request gets a token.
        let decodes: Vec<RequestId> = self
            .decoding
            .iter()
            .copied()
            .take(self.max_batch)
            .collect();
        let decode_ctxs: Vec<u64> = decodes
            .iter()
            .map(|id| local_kv(&requests[id]).max(1))
            .collect();

        // Piggyback one prefill chunk from the head of the queue.
        let prefill = self.prefill_queue.front().and_then(|&id| {
            let r = &requests[&id];
            let remaining = r.remaining_prefill();
            if remaining == 0 {
                return None;
            }
            let c = self
                .policy
                .next_chunk(r.kv_len(), remaining, &decode_ctxs, pm, slo);
            Some((id, c.max(1).min(remaining)))
        });

        BatchPlan { prefill, decodes }
    }

    /// The `BatchShape` (perf-model view) of a plan, using local KV lengths.
    pub fn batch_shape<F: Fn(&Request) -> u64>(
        &self,
        plan: &BatchPlan,
        requests: &BTreeMap<RequestId, Request>,
        local_kv: F,
    ) -> BatchShape {
        let mut shape = BatchShape::default();
        if let Some((id, c)) = plan.prefill {
            let r = &requests[&id];
            shape.prefills.push(PrefillWork {
                chunk: c,
                kv_len: local_kv(r) + c,
            });
        }
        for id in &plan.decodes {
            shape.decodes.push(DecodeWork {
                kv_len: local_kv(&requests[id]).max(1),
            });
        }
        shape
    }

    /// Apply request state transitions after a plan's iteration completes
    /// at time `t`. Returns requests that finished.
    pub fn complete_iteration(
        &mut self,
        plan: &BatchPlan,
        requests: &mut BTreeMap<RequestId, Request>,
        t: f64,
    ) -> Vec<RequestId> {
        let mut finished = Vec::new();
        if let Some((id, c)) = plan.prefill {
            let r = requests.get_mut(&id).expect("prefill req");
            r.complete_chunk(c, t);
            match r.phase {
                Phase::Decoding => {
                    self.prefill_queue.pop_front();
                    self.decoding.push(id);
                }
                Phase::Finished => {
                    self.prefill_queue.pop_front();
                    finished.push(id);
                }
                _ => {}
            }
        }
        for &id in &plan.decodes {
            let r = requests.get_mut(&id).expect("decode req");
            r.complete_decode(t);
            if r.is_finished() {
                finished.push(id);
            }
        }
        self.decoding.retain(|id| !finished.contains(id));
        finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::coordinator::chunking::{AdaptiveChunk, StaticChunk};

    fn setup() -> (PerfModel, SloConfig, BTreeMap<RequestId, Request>) {
        let d = DeploymentConfig::llama3_8b_tp8();
        (
            PerfModel::new(d.model, d.hardware, d.parallel),
            SloConfig::default(),
            BTreeMap::new(),
        )
    }

    fn static_sched(c: u64) -> Scheduler {
        Scheduler::new(Box::new(StaticChunk(c)), 128)
    }

    #[test]
    fn drains_prefill_then_decodes() {
        let (pm, slo, mut reqs) = setup();
        reqs.insert(1, Request::new(1, 100, 3, 0.0));
        let mut s = static_sched(64);
        s.enqueue(1);

        let p1 = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        assert_eq!(p1.prefill, Some((1, 64)));
        assert!(p1.decodes.is_empty());
        s.complete_iteration(&p1, &mut reqs, 0.1);

        let p2 = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        assert_eq!(p2.prefill, Some((1, 36))); // clipped to remaining
        s.complete_iteration(&p2, &mut reqs, 0.2);
        assert_eq!(reqs[&1].phase, Phase::Decoding);

        // now it decodes; no prefill left
        let p3 = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        assert_eq!(p3.prefill, None);
        assert_eq!(p3.decodes, vec![1]);
        s.complete_iteration(&p3, &mut reqs, 0.3);
        let p4 = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        let fin = s.complete_iteration(&p4, &mut reqs, 0.4);
        assert_eq!(fin, vec![1]);
        assert!(!s.has_work());
    }

    #[test]
    fn mixed_batch_piggybacks_prefill_on_decodes() {
        let (pm, slo, mut reqs) = setup();
        // request 1 decoding, request 2 long prefill arrives
        reqs.insert(1, Request::new(1, 10, 50, 0.0));
        reqs.insert(2, Request::new(2, 1_000_000, 10, 1.0));
        let mut s = static_sched(512);
        s.enqueue(1);
        let p = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        s.complete_iteration(&p, &mut reqs, 0.1); // prefills 1 fully
        s.enqueue(2);

        let plan = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        assert_eq!(plan.prefill, Some((2, 512)));
        assert_eq!(plan.decodes, vec![1]); // decode not blocked by long prefill
    }

    #[test]
    fn adaptive_policy_shrinks_chunks_late_in_prefill() {
        let (pm, slo, mut reqs) = setup();
        reqs.insert(1, Request::new(1, 8_000_000, 1, 0.0));
        let mut s = Scheduler::new(
            Box::new(AdaptiveChunk::new(vec![32, 256, 2048, 4096])),
            128,
        );
        s.enqueue(1);
        let first = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        let (_, c_first) = first.prefill.unwrap();
        // fast-forward most of the prefill
        reqs.get_mut(&1).unwrap().complete_chunk(6_000_000, 100.0);
        let late = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        let (_, c_late) = late.prefill.unwrap();
        assert!(c_late < c_first, "late={c_late} first={c_first}");
    }

    #[test]
    fn max_batch_caps_decodes() {
        let (pm, slo, mut reqs) = setup();
        let mut s = Scheduler::new(Box::new(StaticChunk(64)), 4);
        for id in 0..8 {
            reqs.insert(id, Request::new(id, 1, 100, 0.0));
            s.enqueue(id);
            let p = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
            s.complete_iteration(&p, &mut reqs, 0.1);
        }
        assert_eq!(s.n_decoding(), 8);
        let plan = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        assert_eq!(plan.decodes.len(), 4);
    }

    #[test]
    fn batch_shape_uses_local_kv() {
        let (pm, slo, mut reqs) = setup();
        reqs.insert(1, Request::new(1, 1, 100, 0.0));
        let mut s = static_sched(64);
        s.enqueue(1);
        let p = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        s.complete_iteration(&p, &mut reqs, 0.1);
        reqs.get_mut(&1).unwrap().decoded = 50; // pretend long decode
        let plan = s.next_batch(&reqs, &pm, &slo, |r| r.kv_len());
        // KVP view: local shard is half the KV
        let shape = s.batch_shape(&plan, &reqs, |r| r.kv_len() / 2);
        assert_eq!(shape.decodes[0].kv_len, reqs[&1].kv_len() / 2);
    }
}
