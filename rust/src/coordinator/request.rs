//! Request lifecycle: the state machine every inference request walks
//! through, with the latency bookkeeping (TTFT / TBT) the evaluation reports.

use crate::kvcache::RequestId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting to be scheduled.
    Queued,
    /// Prefill in progress (chunked; `prefilled` tracks progress).
    Prefilling,
    /// Autoregressive decode.
    Decoding,
    Finished,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt_len: u64,
    pub max_new_tokens: u64,
    pub arrival_s: f64,
    pub phase: Phase,
    /// Prompt tokens whose KV is computed so far.
    pub prefilled: u64,
    /// Output tokens produced so far.
    pub decoded: u64,
    /// Set when the first output token is produced.
    pub first_token_s: Option<f64>,
    /// Completion time.
    pub finished_s: Option<f64>,
    /// Time the previous token was produced (for TBT samples).
    pub last_token_s: Option<f64>,
    /// Per-token inter-arrival latencies (TBT samples).
    pub tbt_samples: Vec<f64>,
    /// Absolute TTFT deadline (arrival + length-aware budget, see
    /// `SloConfig::ttft_deadline_for`). `INFINITY` until assigned at
    /// admission; scheduling policies and attainment metrics read it.
    pub deadline_s: f64,
    /// Perf-model estimate of the full isolated prefill time, set at
    /// admission. Scaled by prefill progress via [`Self::remaining_work_s`].
    pub est_prefill_s: f64,
    /// Prompt tokens served from a prefix-cache hit at admission
    /// ([`crate::kvcache::PrefixIndex`]): their KV was never computed by
    /// this request, `prefilled` starts here, and `est_prefill_s` covers
    /// only the remaining span. Zero when reuse is off or missed.
    pub reused_tokens: u64,
}

impl Request {
    pub fn new(id: RequestId, prompt_len: u64, max_new_tokens: u64, arrival_s: f64) -> Request {
        assert!(prompt_len > 0, "empty prompt");
        Request {
            id,
            prompt_len,
            max_new_tokens,
            arrival_s,
            phase: Phase::Queued,
            prefilled: 0,
            decoded: 0,
            first_token_s: None,
            finished_s: None,
            last_token_s: None,
            tbt_samples: Vec::new(),
            deadline_s: f64::INFINITY,
            est_prefill_s: 0.0,
            reused_tokens: 0,
        }
    }

    /// Grant a prefix-cache hit at admission: the first `tokens` prompt
    /// tokens are served from the shared chain, so prefill starts past
    /// them. Must happen before any chunk executes; at least one token is
    /// always left to prefill (the hit is clamped by the lookup).
    pub fn grant_reuse(&mut self, tokens: u64) {
        assert_eq!(self.phase, Phase::Queued, "reuse granted after scheduling");
        assert_eq!(self.prefilled, 0, "reuse granted twice");
        assert!(tokens < self.prompt_len, "reuse must leave a token to prefill");
        self.reused_tokens = tokens;
        self.prefilled = tokens;
    }

    /// The shared span died with its owning group (crash): the request
    /// must recompute it, so the span re-enters this request's own work.
    /// Returns the tokens that become re-prefill. The caller pairs this
    /// with `rewind_prefill(0)` and meters the span.
    pub fn clear_reuse(&mut self) -> u64 {
        std::mem::take(&mut self.reused_tokens)
    }

    /// Attach admission-time SLO state: the perf-model prefill estimate and
    /// the absolute TTFT deadline derived from it.
    pub fn with_slo(mut self, est_prefill_s: f64, deadline_s: f64) -> Request {
        self.est_prefill_s = est_prefill_s;
        self.deadline_s = deadline_s;
        self
    }

    /// Estimated seconds of prefill work remaining: the admission estimate
    /// scaled by how much of the *admitted* work span (the prompt minus any
    /// prefix-cache hit) is still unprocessed. With no reuse this is the
    /// classic `est * remaining / prompt_len`; with a hit, `est_prefill_s`
    /// already covers only the post-hit span, so the denominator shrinks to
    /// match. After a crash clears the reuse grant the denominator grows
    /// back to the full prompt (the span is this request's work again).
    pub fn remaining_work_s(&self) -> f64 {
        let span = (self.prompt_len - self.reused_tokens).max(1);
        self.est_prefill_s * self.remaining_prefill() as f64 / span as f64
    }

    /// Seconds until the TTFT deadline at time `now` (negative once overdue).
    pub fn deadline_remaining_s(&self, now: f64) -> f64 {
        self.deadline_s - now
    }

    /// The TTFT budget this request was admitted under (deadline − arrival).
    pub fn ttft_budget_s(&self) -> f64 {
        self.deadline_s - self.arrival_s
    }

    pub fn remaining_prefill(&self) -> u64 {
        self.prompt_len - self.prefilled
    }

    /// Total KV length once `extra` more prompt tokens are processed.
    pub fn kv_after_chunk(&self, extra: u64) -> u64 {
        self.prefilled + extra + self.decoded
    }

    /// Current total KV length (prompt progress + generated tokens).
    pub fn kv_len(&self) -> u64 {
        self.prefilled + self.decoded
    }

    /// Record a prefill chunk of `c` tokens completing at time `t`.
    pub fn complete_chunk(&mut self, c: u64, t: f64) {
        assert!(matches!(self.phase, Phase::Queued | Phase::Prefilling));
        assert!(c <= self.remaining_prefill(), "chunk overruns prompt");
        self.phase = Phase::Prefilling;
        self.prefilled += c;
        if self.prefilled == self.prompt_len {
            // Prefill completion produces the first output token. After a
            // crash rewind the first token was already delivered — TTFT is
            // a client-visible latency and a re-prefill cannot undo it.
            self.phase = Phase::Decoding;
            if self.first_token_s.is_none() {
                self.first_token_s = Some(t);
            }
            self.last_token_s = Some(t);
            self.decoded = self.decoded.max(1);
            if self.decoded >= self.max_new_tokens {
                self.phase = Phase::Finished;
                self.finished_s = Some(t);
            }
        }
    }

    /// Crash recovery: roll KV progress back to `kv_prefix` total tokens
    /// (the surviving shard prefix — always a chunk boundary). Prompt KV
    /// past the prefix re-enters as prefill work; lost decode-range KV is
    /// regenerated token by token. Returns the KV tokens that must be
    /// recomputed (the re-prefill cost). Latency bookkeeping is untouched:
    /// delivered tokens stay delivered, so TTFT/TBT history survives and
    /// `remaining_work_s` grows to keep LARS slack honest.
    pub fn rewind_prefill(&mut self, kv_prefix: u64) -> u64 {
        assert!(self.phase != Phase::Finished, "rewind of a finished request");
        let lost = self.kv_len().saturating_sub(kv_prefix);
        if lost == 0 {
            return 0;
        }
        if kv_prefix >= self.prompt_len {
            // Prompt KV intact; only decode-range KV was lost.
            self.decoded = kv_prefix - self.prompt_len;
        } else {
            self.prefilled = kv_prefix;
            self.decoded = 0;
            self.phase = if kv_prefix == 0 {
                Phase::Queued
            } else {
                Phase::Prefilling
            };
        }
        lost
    }

    /// Record one decode token completing at time `t`.
    pub fn complete_decode(&mut self, t: f64) {
        assert_eq!(self.phase, Phase::Decoding);
        if let Some(last) = self.last_token_s {
            self.tbt_samples.push(t - last);
        }
        self.last_token_s = Some(t);
        self.decoded += 1;
        if self.decoded >= self.max_new_tokens {
            self.phase = Phase::Finished;
            self.finished_s = Some(t);
        }
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_s.map(|t| t - self.arrival_s)
    }

    pub fn is_finished(&self) -> bool {
        self.phase == Phase::Finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_prefill_to_decode_to_finish() {
        let mut r = Request::new(1, 100, 3, 10.0);
        r.complete_chunk(64, 11.0);
        assert_eq!(r.phase, Phase::Prefilling);
        assert_eq!(r.remaining_prefill(), 36);
        r.complete_chunk(36, 12.0);
        assert_eq!(r.phase, Phase::Decoding);
        assert_eq!(r.ttft(), Some(2.0));
        assert_eq!(r.decoded, 1);
        r.complete_decode(12.05);
        r.complete_decode(12.10);
        assert!(r.is_finished());
        assert_eq!(r.finished_s, Some(12.10));
        assert_eq!(r.tbt_samples.len(), 2);
        assert!((r.tbt_samples[0] - 0.05).abs() < 1e-9);
    }

    #[test]
    fn kv_accounting() {
        let mut r = Request::new(2, 50, 10, 0.0);
        assert_eq!(r.kv_after_chunk(32), 32);
        r.complete_chunk(32, 1.0);
        assert_eq!(r.kv_len(), 32);
        r.complete_chunk(18, 2.0);
        assert_eq!(r.kv_len(), 51); // 50 prompt + 1 generated
    }

    #[test]
    #[should_panic(expected = "chunk overruns prompt")]
    fn chunk_cannot_overrun() {
        let mut r = Request::new(3, 10, 1, 0.0);
        r.complete_chunk(11, 0.0);
    }

    #[test]
    fn slo_state_tracks_prefill_progress() {
        let mut r = Request::new(5, 1_000, 8, 10.0).with_slo(4.0, 30.0);
        assert_eq!(r.ttft_budget_s(), 20.0);
        assert_eq!(r.deadline_remaining_s(12.0), 18.0);
        assert!((r.remaining_work_s() - 4.0).abs() < 1e-12);
        r.complete_chunk(500, 11.0);
        assert!((r.remaining_work_s() - 2.0).abs() < 1e-12);
        r.complete_chunk(500, 12.0);
        assert_eq!(r.remaining_work_s(), 0.0);
    }

    #[test]
    fn unassigned_slo_is_infinitely_lax() {
        let r = Request::new(6, 100, 1, 0.0);
        assert!(r.deadline_s.is_infinite());
        assert_eq!(r.remaining_work_s(), 0.0);
        assert!(r.ttft_budget_s().is_infinite());
    }

    #[test]
    fn single_token_request_finishes_at_prefill() {
        let mut r = Request::new(4, 10, 1, 0.0);
        r.complete_chunk(10, 1.0);
        assert!(r.is_finished());
        assert_eq!(r.ttft(), Some(1.0));
    }

    #[test]
    fn rewind_mid_prefill_restarts_from_the_boundary() {
        let mut r = Request::new(7, 1_000, 4, 0.0).with_slo(4.0, 30.0);
        r.complete_chunk(500, 1.0);
        r.complete_chunk(250, 2.0);
        let lost = r.rewind_prefill(500);
        assert_eq!(lost, 250);
        assert_eq!(r.prefilled, 500);
        assert_eq!(r.phase, Phase::Prefilling);
        // LARS slack stays honest: lost work re-enters the estimate
        assert!((r.remaining_work_s() - 2.0).abs() < 1e-12);
        // deadline unchanged — rewind is rekey-legal in the ready set
        assert_eq!(r.deadline_s, 30.0);
        r.complete_chunk(500, 3.0);
        assert_eq!(r.phase, Phase::Decoding);
        assert_eq!(r.ttft(), Some(3.0));
    }

    #[test]
    fn rewind_to_zero_requeues_and_noop_rewind_is_free() {
        let mut r = Request::new(8, 100, 2, 0.0);
        r.complete_chunk(50, 1.0);
        assert_eq!(r.rewind_prefill(50), 0); // nothing lost
        assert_eq!(r.phase, Phase::Prefilling);
        assert_eq!(r.rewind_prefill(0), 50);
        assert_eq!(r.phase, Phase::Queued);
        assert_eq!(r.kv_len(), 0);
    }

    #[test]
    fn reuse_grant_scales_remaining_work_over_the_admitted_span() {
        let mut r = Request::new(10, 1_000, 4, 0.0);
        r.grant_reuse(600);
        // est covers only the 400-token post-hit span
        r = r.with_slo(2.0, 10.0);
        assert_eq!(r.prefilled, 600);
        assert_eq!(r.remaining_prefill(), 400);
        assert!((r.remaining_work_s() - 2.0).abs() < 1e-12);
        r.complete_chunk(200, 1.0);
        assert!((r.remaining_work_s() - 1.0).abs() < 1e-12);
        r.complete_chunk(200, 2.0);
        assert_eq!(r.phase, Phase::Decoding);
        assert_eq!(r.remaining_work_s(), 0.0);
    }

    #[test]
    fn crash_clears_reuse_and_the_span_reenters_as_work() {
        let mut r = Request::new(11, 1_000, 4, 0.0);
        r.grant_reuse(600);
        r = r.with_slo(2.0, 10.0);
        r.complete_chunk(100, 1.0);
        assert_eq!(r.clear_reuse(), 600);
        assert_eq!(r.rewind_prefill(0), 700);
        assert_eq!(r.phase, Phase::Queued);
        // the whole prompt is this request's work again; est (unchanged)
        // now spreads over the full prompt — a deterministic underestimate
        assert!((r.remaining_work_s() - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "reuse must leave a token")]
    fn full_prompt_reuse_is_rejected() {
        let mut r = Request::new(12, 100, 1, 0.0);
        r.grant_reuse(100);
    }

    #[test]
    fn rewind_during_decode_keeps_ttft_and_regenerates_lost_tokens() {
        let mut r = Request::new(9, 100, 5, 0.0);
        r.complete_chunk(100, 1.0);
        r.complete_decode(1.1);
        r.complete_decode(1.2); // decoded = 3, kv = 103
        let lost = r.rewind_prefill(101); // lose 2 decode-range tokens
        assert_eq!(lost, 2);
        assert_eq!(r.phase, Phase::Decoding);
        assert_eq!(r.decoded, 1);
        assert_eq!(r.ttft(), Some(1.0));
        // losing prompt KV too sends it back through prefill, but the
        // delivered first token keeps its timestamp
        let lost = r.rewind_prefill(60);
        assert_eq!(lost, 41);
        assert_eq!(r.phase, Phase::Prefilling);
        r.complete_chunk(40, 5.0);
        assert_eq!(r.ttft(), Some(1.0), "TTFT must not be overwritten");
        assert_eq!(r.decoded, 1);
    }
}
