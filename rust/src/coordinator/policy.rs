//! Preemptive scheduling policies (section 5): the priority order a
//! scheduler imposes on its ready set of prefill work, and whether it may
//! switch away from a partially-prefilled request at a chunk boundary.
//!
//! Chunked prefills make preemption nearly free: the only legal switch
//! point is a chunk boundary, the preempted request's KV stays resident,
//! and resuming is just scheduling its next chunk. A policy therefore
//! reduces to a single urgency key re-evaluated every iteration:
//!
//! * [`Fcfs`] — arrival order, never switches away mid-prefill (the
//!   pre-policy behavior; convoy effect: a long document blocks every
//!   short interactive request behind it).
//! * [`Srpt`] — least remaining estimated work first. Optimal for mean
//!   latency, but a steady stream of short requests starves long ones.
//! * [`Edf`] — earliest deadline first. Honors heterogeneous deadlines
//!   until one is missed; an overdue long request then monopolizes the
//!   server and recreates the convoy for everything behind it.
//! * [`Lars`] — Length-Aware Relative Slack, the paper's scheduler:
//!   slack relative to remaining work, so short requests gain urgency
//!   quickly (eliminating the convoy) while overdue long requests still
//!   win against *fresh* short ones (starvation freedom).
//!
//! Deadlines and work estimates are assigned at admission (see
//! [`SloConfig::ttft_deadline_for`](crate::config::SloConfig) and the
//! simulator's perf-model prefill estimate) and carried on the
//! [`Request`]; policies are pure functions of that state plus `now`.
//!
//! # Indexed selection (the heap-backed ready set)
//!
//! At million-request backlogs a per-iteration O(n) scan of the ready set
//! dominates the simulator, so selection is served by an indexed
//! [`ReadySet`](super::readyset::ReadySet) instead. Each policy declares
//! its [`KeyShape`] — how its priority key varies with time — and the
//! ready set picks the matching index:
//!
//! * `Fifo` (FCFS): no index; selection is the queue head.
//! * `Static` (SRPT, EDF): `priority(r, now)` is independent of `now` and
//!   changes only when the request's own state changes (a chunk of *its*
//!   prefill completes). One ordered index on [`SchedPolicy::static_key`],
//!   re-keyed only for the request that progressed: O(log n) exact.
//! * `Slack` (LARS): the slack `(C − now − W)/W` is time-varying, but its
//!   time-invariant parts `(C, W)` ([`SchedPolicy::slack_parts`]) bound it:
//!   for any two requests the slack order can drift only while their
//!   remaining works differ, and the drift is one-directional (smaller `W`
//!   only gains urgency). The ready set keeps requests ordered by the
//!   critical time `C` and prunes the selection walk with `W`-range bounds
//!   — see `readyset.rs` for the invariant and the proof sketch.
//!
//! Selection through any index is **bit-identical** to the O(n) scan under
//! the canonical rule — argmin of `(priority(r, now), enqueue_seq)` with
//! `f64::total_cmp` — asserted by a `debug_assert` on every selection and
//! a randomized differential harness (`tests/invariants.rs`).

use std::collections::VecDeque;

use super::arena::{RequestArena, Slot};
use super::request::Request;
use crate::kvcache::GroupId;

/// Per-group occupancy snapshot handed to a policy's routing hook when a
/// request is admitted under `RoutingMode::Routed` (see
/// `coordinator::router`): everything placement needs to know about one
/// KVP group, gathered in O(groups) per admission — every field is an O(1)
/// read of incrementally maintained state (no backlog rescans).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupView {
    pub group: GroupId,
    /// Outstanding token load (router accounting: KV-resident + queued
    /// prompt work).
    pub load: u64,
    /// Prefills queued in the group's ready set.
    pub queue_len: usize,
    /// Requests currently decoding on the group.
    pub n_decoding: usize,
    /// Whether the group holds a KV shard of the **active** sharded long
    /// request — it iterates in lockstep with the cooperative prefill, so
    /// a short request placed here waits out chunk-scale iterations.
    pub active_long: bool,
    /// Queued requests on this group already past their policy
    /// [`critical time`](SchedPolicy::critical_time) — the incrementally
    /// maintained urgency counter. A fresh arrival is never past its own
    /// critical time at admission, so under the deadline-relative policies
    /// (EDF, LARS) every counted request is provably more urgent than the
    /// request being routed; the counter is a conservative stand-in for
    /// the per-admission backlog rescan it replaced (which was O(total
    /// queued) per admission; this is an O(1) read).
    pub more_urgent_queued: usize,
    /// Free KV-token capacity on the group (`u64::MAX` when capacity
    /// accounting is off) — placements needing more than this are refused.
    pub kv_free: u64,
    /// Prompt tokens of the request being routed whose KV already lives on
    /// this group as a shared prefix chain (`kvcache::PrefixIndex`). Zero
    /// everywhere when reuse is off or the request misses; nonzero on at
    /// most one group (chains are single-group). Placement here skips that
    /// much prefill, so routing subtracts it from both the effective load
    /// and the capacity the placement needs — cache affinity, weighed
    /// against load rather than overriding the urgency ordering.
    pub prefix_hit_tokens: u64,
}

/// Cache-affinity effective load: the group's outstanding tokens minus the
/// prompt span this request would *not* have to prefill there. With no hit
/// this is exactly `load`, so reuse-off routing is bit-identical.
fn affinity_load(v: &GroupView) -> u64 {
    v.load.saturating_sub(v.prefix_hit_tokens)
}

/// Cache-affinity capacity check: the hit span is already resident on the
/// group (accounted once in the shared ledger), so the placement only
/// needs room for the remainder.
fn affinity_fits(v: &GroupView, need: u64) -> bool {
    v.kv_free >= need.saturating_sub(v.prefix_hit_tokens)
}

/// KV tokens request `r` will occupy at completion (prompt + every output
/// token): the footprint capacity-aware placement must find room for.
pub fn kv_need(r: &Request) -> u64 {
    r.prompt_len + r.max_new_tokens
}

/// Blind least-loaded placement (ties to the lowest group id) — the
/// pre-routing behavior and the non-preemptive default — over the groups
/// with at least `need` free KV tokens. `None` when no group fits.
pub fn route_least_loaded(groups: &[GroupView], need: u64) -> Option<GroupId> {
    groups
        .iter()
        .filter(|v| affinity_fits(v, need))
        .min_by_key(|v| (affinity_load(v), v.group))
        .map(|v| v.group)
}

/// Policy-aware placement: among the groups with room, avoid the groups
/// cooperating on the active sharded long request (they only complete work
/// at chunk boundaries), then minimize the deadline-critical work already
/// queued, then load. A fully occupied fleet degrades to least-loaded;
/// `None` when no group has `need` free KV tokens.
pub fn route_policy_aware(groups: &[GroupView], need: u64) -> Option<GroupId> {
    groups
        .iter()
        .filter(|v| affinity_fits(v, need))
        .min_by_key(|v| (v.active_long, v.more_urgent_queued, affinity_load(v), v.group))
        .map(|v| v.group)
}

/// How a policy's priority key varies with time — selects the ready-set
/// index that serves `select` without a linear scan (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeyShape {
    /// Non-preemptive: selection is the FIFO head; no index.
    Fifo,
    /// `priority(r, now)` ignores `now`; it changes only when the
    /// request's own state changes. Indexed by [`SchedPolicy::static_key`].
    Static,
    /// Slack form `(C − now − W)/W` over the time-invariant
    /// [`SchedPolicy::slack_parts`] `(C, W)`.
    Slack,
}

/// Floor on the remaining-work denominator. At or below it the request is
/// effectively one chunk from its first token.
pub const MIN_WORK_S: f64 = 1e-9;

/// Slack assigned to a request whose remaining estimated work has shrunk
/// to (numerically) nothing: finishing it costs one chunk, so it outranks
/// everything with real work left. Finite — never ±inf/NaN — so it can't
/// poison an ordered index, and far below any slack reachable with a real
/// denominator (`|C − now|/MIN_WORK_S` stays well above −1e300 for any
/// sane simulated horizon).
pub const DONE_SLACK: f64 = -1e300;

/// Whether the slack form bottoms out at [`DONE_SLACK`]: finite critical
/// time, no measurable work left. The single definition shared by
/// [`slack_priority`] and the ready set's sentinel classification.
pub fn slack_is_done(critical: f64, rem_work: f64) -> bool {
    critical.is_finite() && rem_work <= MIN_WORK_S
}

/// The canonical slack priority over time-invariant parts `(critical,
/// rem_work)` at time `now` — the one definition both [`Lars::priority`]
/// and the ready set's pruning bounds are built on. Non-finite critical
/// times (no deadline assigned) are infinitely lax.
pub fn slack_priority(critical: f64, rem_work: f64, now: f64) -> f64 {
    if !critical.is_finite() {
        return f64::INFINITY;
    }
    if slack_is_done(critical, rem_work) {
        return DONE_SLACK;
    }
    (critical - now - rem_work) / rem_work
}

/// Priority ordering + preemption decision over a scheduler's ready set.
pub trait SchedPolicy: Send + Sync {
    /// Urgency key for a queued (possibly partially-prefilled) request at
    /// time `now`. The scheduler runs the request with the **minimum**
    /// key; ties break toward the earlier enqueue order.
    fn priority(&self, r: &Request, now: f64) -> f64;

    /// Whether the scheduler may switch away from a partially-prefilled
    /// request at a chunk boundary (its KV is retained and it resumes from
    /// the same boundary). Non-preemptive policies run the head to
    /// completion and skip priority selection entirely.
    fn preemptive(&self) -> bool {
        true
    }

    /// How `priority` varies with time (drives the ready-set index).
    fn key_shape(&self) -> KeyShape {
        if self.preemptive() {
            KeyShape::Static
        } else {
            KeyShape::Fifo
        }
    }

    /// `KeyShape::Static` contract: `static_key(r) == priority(r, now)`
    /// for every `now`. The ready set stores this key and re-derives it
    /// only when the request's own state changes.
    fn static_key(&self, r: &Request) -> f64 {
        self.priority(r, 0.0)
    }

    /// `KeyShape::Slack` contract: `priority(r, now) ==
    /// slack_priority(c, w, now)` for `(c, w) = slack_parts(r)`. `c` must
    /// be time-invariant for the life of the request; `w` may change only
    /// when the request's own prefill progresses.
    fn slack_parts(&self, r: &Request) -> (f64, f64) {
        (r.deadline_s, r.remaining_work_s())
    }

    /// The instant this request becomes overdue under the policy's notion
    /// of urgency — drives the incrementally maintained per-group
    /// `more_urgent_queued` counters (a queued request is counted once
    /// `now` passes its critical time). Must be time-invariant.
    fn critical_time(&self, r: &Request) -> f64 {
        r.deadline_s
    }

    /// Placement hook (section 7): which KVP group should serve `r`, given
    /// that it needs `need` free KV tokens? Routing decisions are made
    /// jointly with the scheduling policy — preemptive policies place by
    /// urgency ranking and keep short traffic off the groups sharding the
    /// active long prefill; non-preemptive policies keep the blind
    /// least-loaded placement. Returns `None` — a **capacity refusal** —
    /// when no group has `need` free KV tokens; the caller defers the
    /// admission until capacity frees (or waives the check for requests
    /// larger than a whole group's capacity).
    fn route(&self, _r: &Request, groups: &[GroupView], need: u64, _now: f64) -> Option<GroupId> {
        if self.preemptive() {
            route_policy_aware(groups, need)
        } else {
            route_least_loaded(groups, need)
        }
    }

    fn name(&self) -> &'static str;
}

/// First-come-first-served: strict arrival order, non-preemptive.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fcfs;

impl SchedPolicy for Fcfs {
    fn priority(&self, r: &Request, _now: f64) -> f64 {
        r.arrival_s
    }

    fn preemptive(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "fcfs"
    }
}

/// Shortest remaining processing time: least estimated prefill work left.
#[derive(Debug, Clone, Copy, Default)]
pub struct Srpt;

impl SchedPolicy for Srpt {
    fn priority(&self, r: &Request, _now: f64) -> f64 {
        r.remaining_work_s()
    }

    fn name(&self) -> &'static str {
        "srpt"
    }
}

/// Earliest deadline first over the length-aware TTFT deadlines.
#[derive(Debug, Clone, Copy, Default)]
pub struct Edf;

impl SchedPolicy for Edf {
    fn priority(&self, r: &Request, _now: f64) -> f64 {
        r.deadline_s
    }

    fn name(&self) -> &'static str {
        "edf"
    }
}

/// Length-Aware Relative Slack:
/// `slack = (deadline − headroom − now − remaining_work) / remaining_work`.
///
/// With proportional deadlines (`deadline ≈ scale × estimated work`) every
/// fresh request starts at the same slack regardless of length, and
/// waiting erodes slack at a rate inversely proportional to remaining
/// work: a short interactive request becomes urgent within seconds and
/// preempts a long document prefill at the next chunk boundary, while the
/// document's slowly-decaying slack eventually goes below every fresh
/// short request's, so it cannot be starved.
///
/// `headroom_frac` schedules against a deadline pulled in by that fraction
/// of the request's TTFT budget. Without it a tiny request only wins the
/// slack race milliseconds before its deadline and the chunk already in
/// flight pushes it just past; with it the preemption fires early enough
/// that the deadline is met, not grazed.
///
/// A request whose estimated remaining work has shrunk below
/// [`MIN_WORK_S`] gets the finite [`DONE_SLACK`] sentinel instead of the
/// ratio: the raw division would swing to ±huge values (least-urgent while
/// fresh, starving a request that is one chunk from done), and an actual
/// 0/0 would put NaN into the ready-set order.
#[derive(Debug, Clone, Copy)]
pub struct Lars {
    pub headroom_frac: f64,
}

impl Default for Lars {
    fn default() -> Lars {
        Lars { headroom_frac: 0.2 }
    }
}

impl SchedPolicy for Lars {
    fn priority(&self, r: &Request, now: f64) -> f64 {
        let (c, w) = self.slack_parts(r);
        slack_priority(c, w, now)
    }

    fn key_shape(&self) -> KeyShape {
        KeyShape::Slack
    }

    fn slack_parts(&self, r: &Request) -> (f64, f64) {
        (
            r.deadline_s - self.headroom_frac * r.ttft_budget_s(),
            r.remaining_work_s(),
        )
    }

    fn critical_time(&self, r: &Request) -> f64 {
        self.slack_parts(r).0
    }

    fn name(&self) -> &'static str {
        "lars"
    }
}

/// Index of the most urgent (minimum-priority) request in `queue` at time
/// `now`, ties breaking toward the earlier index. Returns 0 — the FCFS
/// head — for empty or singleton queues and for non-preemptive policies,
/// which skip the scan entirely. This is the *canonical O(n) definition*
/// of the selection rule; the simulator's long-request queue is served by
/// the indexed [`ReadySet`](super::readyset::ReadySet) (bit-identical
/// under the `(priority, enqueue-order)` rule, re-asserted by a
/// `debug_assert` on every selection), and this scan remains as the
/// differential oracle the unit tests exercise.
pub fn select_most_urgent(
    policy: &dyn SchedPolicy,
    requests: &RequestArena,
    queue: &VecDeque<Slot>,
    now: f64,
) -> usize {
    if !policy.preemptive() || queue.len() < 2 {
        return 0;
    }
    let mut best = 0usize;
    let mut best_p = policy.priority(requests.get(queue[0]), now);
    for i in 1..queue.len() {
        let p = policy.priority(requests.get(queue[i]), now);
        if p < best_p {
            best = i;
            best_p = p;
        }
    }
    best
}

/// Active-request preemption decision (section 4.4 + 5 combined): should
/// the scheduler switch the cooperative slot away from the **currently
/// executing** long request `active` at this chunk boundary? Returns the
/// queue index of the strictly-more-urgent challenger, or `None` to keep
/// running `active`. Strict inequality keeps FCFS-adjacent stability: a tie
/// never evicts the request already holding KV shards on its groups. Like
/// [`select_most_urgent`] this is the canonical scan definition; the
/// simulator realizes the same rule over its indexed long-request queue.
pub fn would_preempt_active(
    policy: &dyn SchedPolicy,
    requests: &RequestArena,
    active: Slot,
    queue: &VecDeque<Slot>,
    now: f64,
) -> Option<usize> {
    if !policy.preemptive() || queue.is_empty() {
        return None;
    }
    let best = select_most_urgent(policy, requests, queue, now);
    let p_best = policy.priority(requests.get(queue[best]), now);
    let p_active = policy.priority(requests.get(active), now);
    if p_best < p_active {
        Some(best)
    } else {
        None
    }
}

/// Config/CLI-selectable policy identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicyKind {
    Fcfs,
    Srpt,
    Edf,
    Lars,
}

impl SchedPolicyKind {
    pub const ALL: [SchedPolicyKind; 4] = [
        SchedPolicyKind::Fcfs,
        SchedPolicyKind::Srpt,
        SchedPolicyKind::Edf,
        SchedPolicyKind::Lars,
    ];

    pub fn parse(s: &str) -> Option<SchedPolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" | "fifo" => Some(SchedPolicyKind::Fcfs),
            "srpt" => Some(SchedPolicyKind::Srpt),
            "edf" => Some(SchedPolicyKind::Edf),
            "lars" => Some(SchedPolicyKind::Lars),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedPolicyKind::Fcfs => "fcfs",
            SchedPolicyKind::Srpt => "srpt",
            SchedPolicyKind::Edf => "edf",
            SchedPolicyKind::Lars => "lars",
        }
    }

    pub fn build(self) -> Box<dyn SchedPolicy> {
        match self {
            SchedPolicyKind::Fcfs => Box::new(Fcfs),
            SchedPolicyKind::Srpt => Box::new(Srpt),
            SchedPolicyKind::Edf => Box::new(Edf),
            SchedPolicyKind::Lars => Box::new(Lars::default()),
        }
    }
}

/// EWMA correction of the perf model's iteration-time predictions against
/// observed iteration times (`scheduler.headroom_autotune`).
///
/// The analytical model drifts when the fleet degrades — a slowdown fault
/// (PR 6) stretches every iteration, so admission-time prefill estimates
/// (and the TTFT deadlines derived from them) run systematically short.
/// The tuner tracks the ratio `actual / predicted` per completed iteration
/// and exposes a multiplicative `factor()` applied to *admission-time*
/// estimates only. It never touches `Lars` or any live request: LARS
/// requires `critical_time` to be time-invariant per request (the ready-set
/// index contract), so corrections may only shape how *new* requests enter.
///
/// Off by default; entirely deterministic (pure arithmetic over simulated
/// durations, no clocks).
#[derive(Debug, Clone, Copy)]
pub struct HeadroomTuner {
    factor: f64,
}

/// EWMA smoothing weight for each new observation.
const TUNE_ALPHA: f64 = 0.1;
/// Per-observation ratio clamp: one absurd iteration (division by a tiny
/// prediction, a crash-stalled step) must not poison the estimate.
const TUNE_RATIO_MIN: f64 = 0.25;
const TUNE_RATIO_MAX: f64 = 4.0;

impl Default for HeadroomTuner {
    fn default() -> Self {
        HeadroomTuner { factor: 1.0 }
    }
}

impl HeadroomTuner {
    /// Fold one completed iteration into the correction. Non-positive or
    /// non-finite samples are dropped — they carry no timing signal.
    pub fn observe(&mut self, predicted_s: f64, actual_s: f64) {
        if !(predicted_s > 0.0) || !actual_s.is_finite() || actual_s <= 0.0 {
            return;
        }
        let ratio = (actual_s / predicted_s).clamp(TUNE_RATIO_MIN, TUNE_RATIO_MAX);
        self.factor += TUNE_ALPHA * (ratio - self.factor);
    }

    /// Multiplier for admission-time work estimates: >1 when the fleet runs
    /// slower than modeled, 1.0 until the first observation.
    pub fn factor(&self) -> f64 {
        self.factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt_len: u64, arrival_s: f64, est_s: f64, budget_s: f64) -> Request {
        Request::new(1, prompt_len, 4, arrival_s).with_slo(est_s, arrival_s + budget_s)
    }

    #[test]
    fn kind_parse_roundtrips() {
        for k in SchedPolicyKind::ALL {
            assert_eq!(SchedPolicyKind::parse(k.name()), Some(k));
            assert_eq!(k.build().name(), k.name());
        }
        assert_eq!(SchedPolicyKind::parse("FIFO"), Some(SchedPolicyKind::Fcfs));
        assert_eq!(SchedPolicyKind::parse("wfq"), None);
    }

    #[test]
    fn fcfs_is_arrival_order_and_non_preemptive() {
        let p = Fcfs;
        assert!(!p.preemptive());
        assert_eq!(p.key_shape(), KeyShape::Fifo);
        let a = req(100, 1.0, 0.1, 2.0);
        let b = req(100, 2.0, 0.1, 2.0);
        assert!(p.priority(&a, 5.0) < p.priority(&b, 5.0));
    }

    #[test]
    fn srpt_prefers_less_remaining_work() {
        let p = Srpt;
        let short = req(100, 0.0, 0.1, 2.0);
        let long = req(1_000_000, 0.0, 60.0, 300.0);
        assert!(p.priority(&short, 0.0) < p.priority(&long, 0.0));
    }

    #[test]
    fn static_key_contract_holds_for_static_policies() {
        // KeyShape::Static promises priority is now-independent and equal
        // to static_key — the property the ordered index leans on.
        let r = req(4_096, 3.0, 1.5, 9.0);
        for p in [&Srpt as &dyn SchedPolicy, &Edf] {
            assert_eq!(p.key_shape(), KeyShape::Static);
            for now in [0.0, 2.5, 1e6] {
                assert_eq!(p.priority(&r, now), p.static_key(&r));
            }
        }
    }

    #[test]
    fn slack_parts_contract_holds_for_lars() {
        let p = Lars::default();
        assert_eq!(p.key_shape(), KeyShape::Slack);
        for r in [
            req(100, 0.0, 0.1, 0.5),
            req(1_000_000, 3.0, 60.0, 300.0),
            Request::new(1, 10, 1, 0.0), // no SLO
        ] {
            let (c, w) = p.slack_parts(&r);
            for now in [0.0, 1.0, 500.0] {
                let direct = p.priority(&r, now);
                let via_parts = slack_priority(c, w, now);
                assert!(
                    direct.to_bits() == via_parts.to_bits()
                        || (direct.is_nan() && via_parts.is_nan()),
                    "{direct} != {via_parts}"
                );
            }
            let ct = p.critical_time(&r);
            // NaN-tolerant: an unassigned deadline makes both NaN
            assert!(ct.to_bits() == c.to_bits() || (ct.is_nan() && c.is_nan()));
        }
    }

    #[test]
    fn lars_fresh_requests_tie_regardless_of_length() {
        // With proportional deadlines (budget = scale × est), every fresh
        // request's relative slack is (1 − headroom) × scale − 1.
        let p = Lars::default();
        let short = req(100, 0.0, 0.1, 0.5); // 5× its work
        let long = req(1_000_000, 0.0, 60.0, 300.0); // 5× its work
        let ps = p.priority(&short, 0.0);
        let pl = p.priority(&long, 0.0);
        let fresh = (1.0 - p.headroom_frac) * 5.0 - 1.0;
        assert!((ps - fresh).abs() < 1e-6, "short fresh slack {ps}");
        assert!((pl - fresh).abs() < 1e-6, "long fresh slack {pl}");
    }

    #[test]
    fn lars_short_gains_urgency_faster_than_long() {
        let p = Lars::default();
        let short = req(100, 0.0, 0.1, 0.5);
        let long = req(1_000_000, 0.0, 60.0, 300.0);
        // after 2 seconds of waiting the short request is far more urgent
        assert!(p.priority(&short, 2.0) < p.priority(&long, 2.0) - 1.0);
        // and an overdue long request beats a *fresh* short one
        let fresh_short = req(100, 310.0, 0.1, 0.5);
        assert!(p.priority(&long, 310.0) < p.priority(&fresh_short, 310.0));
    }

    #[test]
    fn edf_orders_by_absolute_deadline() {
        let p = Edf;
        let tight = req(100, 0.0, 0.1, 1.0);
        let loose = req(100, 0.0, 0.1, 10.0);
        assert!(p.priority(&tight, 0.0) < p.priority(&loose, 0.0));
    }

    #[test]
    fn select_most_urgent_scans_preemptive_only() {
        let mut arena = RequestArena::new();
        let mut q = VecDeque::new();
        // queue order: early long arrival first, urgent short second
        q.push_back(arena.insert(req(1_000_000, 0.0, 60.0, 300.0)));
        q.push_back(arena.insert(req(100, 10.0, 0.1, 0.5)));
        // FCFS: non-preemptive, always the head
        assert_eq!(select_most_urgent(&Fcfs, &arena, &q, 11.0), 0);
        // SRPT: the short request has less remaining work
        assert_eq!(select_most_urgent(&Srpt, &arena, &q, 11.0), 1);
        // LARS: the short request is near its deadline, the long is fresh
        assert_eq!(select_most_urgent(&Lars::default(), &arena, &q, 11.0), 1);
        // singleton queue short-circuits to the head
        q.pop_back();
        assert_eq!(select_most_urgent(&Lars::default(), &arena, &q, 11.0), 0);
    }

    #[test]
    fn lars_handles_zero_estimate() {
        let p = Lars::default();
        let r = Request::new(1, 10, 1, 0.0); // no SLO state: infinite deadline
        assert!(p.priority(&r, 100.0).is_infinite());
    }

    #[test]
    fn lars_nearly_complete_request_gets_finite_maximal_urgency() {
        let p = Lars::default();
        // 1e6-token prompt, one token left: remaining work rounds below the
        // MIN_WORK_S floor while the deadline is still comfortably ahead
        let mut r = req(1_000_000, 0.0, 1e-4, 100.0);
        r.complete_chunk(999_999, 1.0);
        assert!(r.remaining_work_s() <= MIN_WORK_S);
        let slack = p.priority(&r, 1.0);
        assert_eq!(slack, DONE_SLACK);
        assert!(slack.is_finite(), "sentinel must stay arithmetic-safe");
        // maximal urgency: beats a deeply overdue short request
        let overdue = req(100, 0.0, 0.1, 0.2);
        assert!(slack < p.priority(&overdue, 1_000.0));
        // and the raw-ratio path is untouched for real denominators
        let fresh = req(100, 1.0, 0.1, 0.5);
        assert!(p.priority(&fresh, 1.0) > -1.0);
    }

    #[test]
    fn slack_priority_is_never_nan_for_finite_inputs() {
        for (c, w) in [(5.0, 0.0), (5.0, 1e-12), (0.0, 0.0), (-3.0, 1e-10)] {
            let s = slack_priority(c, w, 2.0);
            assert!(s.is_finite(), "slack({c}, {w}) = {s}");
            assert_eq!(s, DONE_SLACK);
        }
        assert!(slack_priority(f64::INFINITY, 0.0, 2.0).is_infinite());
    }

    fn view(group: u32, load: u64, active_long: bool, urgent: usize) -> GroupView {
        GroupView {
            group,
            load,
            queue_len: urgent,
            n_decoding: 0,
            active_long,
            more_urgent_queued: urgent,
            kv_free: u64::MAX,
            prefix_hit_tokens: 0,
        }
    }

    #[test]
    fn affinity_pulls_placement_toward_the_chain_owner() {
        let r = req(100, 0.0, 0.1, 0.5);
        let need = kv_need(&r);
        // group 1 is busier, but holds enough of the prompt that its
        // effective (post-reuse) load undercuts group 0
        let mut views = vec![view(0, 100, false, 0), view(1, 160, false, 0)];
        views[1].prefix_hit_tokens = 80;
        assert_eq!(route_least_loaded(&views, need), Some(1));
        assert_eq!(route_policy_aware(&views, need), Some(1));
        // a small hit that does not close the load gap changes nothing
        views[1].prefix_hit_tokens = 40;
        assert_eq!(route_least_loaded(&views, need), Some(0));
    }

    #[test]
    fn affinity_never_overrides_the_urgency_ordering() {
        let r = req(100, 0.0, 0.1, 0.5);
        let need = kv_need(&r);
        // the chain owner shards the active long request: the policy-aware
        // ranking still routes around it, affinity only breaks load ties
        let mut views = vec![view(0, 500, false, 0), view(1, 10, true, 0)];
        views[1].prefix_hit_tokens = 90;
        assert_eq!(route_policy_aware(&views, need), Some(0));
        // same for deadline-critical work already queued ahead
        let mut views = vec![view(0, 500, false, 0), view(1, 10, false, 2)];
        views[1].prefix_hit_tokens = 90;
        assert_eq!(route_policy_aware(&views, need), Some(0));
    }

    #[test]
    fn affinity_relaxes_the_capacity_check_by_the_resident_span() {
        let r = req(100, 0.0, 0.1, 0.5);
        let need = kv_need(&r); // 104
        // group 0 cannot fit the full footprint, but 80 prompt tokens are
        // already resident there: only the remainder needs free capacity
        let mut views = vec![view(0, 10, false, 0)];
        views[0].kv_free = need - 80;
        assert_eq!(route_least_loaded(&views, need), None);
        views[0].prefix_hit_tokens = 80;
        assert_eq!(route_least_loaded(&views, need), Some(0));
        // a hit never conjures capacity beyond the remainder
        views[0].kv_free = need - 81;
        assert_eq!(route_least_loaded(&views, need), None);
    }

    #[test]
    fn headroom_tuner_tracks_slowdown_and_clamps_outliers() {
        let mut t = HeadroomTuner::default();
        assert_eq!(t.factor(), 1.0);
        // fleet consistently 2x slower than modeled: factor climbs toward 2
        for _ in 0..200 {
            t.observe(1.0, 2.0);
        }
        assert!((t.factor() - 2.0).abs() < 1e-6, "factor {}", t.factor());
        // one absurd sample moves the EWMA by at most alpha * (max - f)
        let before = t.factor();
        t.observe(1e-12, 1.0e6);
        assert!(t.factor() <= before + TUNE_ALPHA * (TUNE_RATIO_MAX - before) + 1e-9);
        // degenerate samples are ignored outright
        let frozen = t.factor();
        t.observe(0.0, 1.0);
        t.observe(1.0, f64::NAN);
        t.observe(1.0, -1.0);
        assert_eq!(t.factor(), frozen);
    }

    #[test]
    fn routing_hook_policy_aware_avoids_active_long_groups() {
        let r = req(100, 0.0, 0.1, 0.5);
        let need = kv_need(&r);
        // group 0 is least loaded but shards the active long request
        let views = vec![view(0, 10, true, 0), view(1, 500, false, 0), view(2, 800, false, 0)];
        // preemptive policies route around the busy group
        assert_eq!(Lars::default().route(&r, &views, need, 0.0), Some(1));
        assert_eq!(Srpt.route(&r, &views, need, 0.0), Some(1));
        // FCFS keeps the blind least-loaded placement
        assert_eq!(Fcfs.route(&r, &views, need, 0.0), Some(0));
    }

    #[test]
    fn routing_hook_ranks_by_urgency_ahead_then_load() {
        let r = req(100, 0.0, 0.1, 0.5);
        let need = kv_need(&r);
        // neither group is long-busy; group 1 has less critical work ahead
        let views = vec![view(0, 10, false, 3), view(1, 900, false, 0)];
        assert_eq!(Lars::default().route(&r, &views, need, 0.0), Some(1));
        // equal urgency ahead: lighter load wins, ties to the low id
        let views = vec![view(0, 50, false, 1), view(1, 50, false, 1), view(2, 90, false, 1)];
        assert_eq!(Lars::default().route(&r, &views, need, 0.0), Some(0));
    }

    #[test]
    fn routing_hook_degrades_to_least_loaded_when_fleet_is_occupied() {
        let r = req(100, 0.0, 0.1, 0.5);
        let views = vec![view(0, 700, true, 0), view(1, 300, true, 0)];
        assert_eq!(Lars::default().route(&r, &views, kv_need(&r), 0.0), Some(1));
    }

    #[test]
    fn routing_refuses_groups_without_kv_capacity() {
        let r = req(100, 0.0, 0.1, 0.5);
        let need = kv_need(&r);
        assert_eq!(need, 104); // prompt 100 + 4 output tokens
        let mut views = vec![view(0, 10, false, 0), view(1, 900, false, 0)];
        // the otherwise-best group is out of capacity: placement moves on
        views[0].kv_free = need - 1;
        views[1].kv_free = need;
        assert_eq!(Lars::default().route(&r, &views, need, 0.0), Some(1));
        assert_eq!(Fcfs.route(&r, &views, need, 0.0), Some(1));
        // no group fits: the placement is refused outright
        views[1].kv_free = 0;
        assert_eq!(Lars::default().route(&r, &views, need, 0.0), None);
        assert_eq!(Fcfs.route(&r, &views, need, 0.0), None);
        assert_eq!(route_policy_aware(&views, need), None);
        assert_eq!(route_least_loaded(&views, need), None);
    }

    #[test]
    fn critical_time_is_the_effective_deadline() {
        let r = req(100, 2.0, 0.1, 1.0); // deadline 3.0, budget 1.0
        assert_eq!(Edf.critical_time(&r), 3.0);
        assert_eq!(Srpt.critical_time(&r), 3.0);
        let lars = Lars::default();
        // LARS schedules against the headroom-advanced deadline
        assert!((lars.critical_time(&r) - (3.0 - 0.2)).abs() < 1e-12);
    }

    #[test]
    fn would_preempt_active_requires_strictly_more_urgent() {
        let mut arena = RequestArena::new();
        let active = arena.insert(req(1_000_000, 0.0, 60.0, 300.0));
        let mut q = VecDeque::new();
        // an identical challenger never evicts the shard-holding incumbent
        q.push_back(arena.insert(req(1_000_000, 0.0, 60.0, 300.0)));
        assert_eq!(would_preempt_active(&Srpt, &arena, active, &q, 1.0), None);
        // a near-deadline short one does
        q.push_back(arena.insert(req(100, 10.0, 0.1, 0.5)));
        assert_eq!(
            would_preempt_active(&Lars::default(), &arena, active, &q, 11.0),
            Some(1)
        );
        // non-preemptive policies never preempt the active request
        assert_eq!(would_preempt_active(&Fcfs, &arena, active, &q, 11.0), None);
        // empty queue: nothing to switch to
        let empty = VecDeque::new();
        assert_eq!(
            would_preempt_active(&Lars::default(), &arena, active, &empty, 11.0),
            None
        );
    }
}
