//! Sequence Pipeline Parallelism schedule (section 4.3, Fig. 9).
//!
//! The pipeline is a chain of `spp` stage timelines. Conventional PP
//! inference admits chunk i+1 only after chunk i drains the whole pipeline
//! (needed for autoregressive decode). SPP's insight: prefill chunks have
//! no cross-chunk data dependency through the *model output* — chunk i+1
//! only needs chunk i's KV at each stage, which is available as soon as
//! chunk i leaves that stage. So stage 0 accepts chunk i+1 the moment it
//! finishes chunk i: the dense schedule.
//!
//! `PipelineTimeline` is the shared machinery for both schedules; the
//! simulator drives it with perf-model stage times, the real engine drives
//! it with wall-clock PJRT executions.

/// Per-stage next-free times.
#[derive(Debug, Clone)]
pub struct PipelineTimeline {
    stage_free: Vec<f64>,
}

/// When one batch/chunk finished each stage.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowResult {
    /// Exit time from each stage.
    pub stage_exit: Vec<f64>,
}

impl FlowResult {
    pub fn exit(&self) -> f64 {
        *self.stage_exit.last().unwrap()
    }

    pub fn first_stage_exit(&self) -> f64 {
        self.stage_exit[0]
    }
}

impl PipelineTimeline {
    pub fn new(stages: usize, start: f64) -> PipelineTimeline {
        assert!(stages >= 1);
        PipelineTimeline {
            stage_free: vec![start; stages],
        }
    }

    pub fn stages(&self) -> usize {
        self.stage_free.len()
    }

    /// Earliest time stage 0 can accept new work (the dense-SPP admission
    /// point).
    pub fn stage0_free(&self) -> f64 {
        self.stage_free[0]
    }

    /// Flow one unit of work (a chunk or a batch) through all stages:
    /// enters stage s at max(prev stage exit + hop, stage s free), holds it
    /// for `stage_time(s)`, and frees it. Returns per-stage exit times.
    pub fn flow<F: Fn(usize) -> f64>(
        &mut self,
        ready: f64,
        stage_time: F,
        hop_s: f64,
    ) -> FlowResult {
        let mut exits = Vec::with_capacity(self.stage_free.len());
        let mut avail = ready;
        for s in 0..self.stage_free.len() {
            let enter = avail.max(self.stage_free[s]);
            let exit = enter + stage_time(s);
            self.stage_free[s] = exit;
            exits.push(exit);
            avail = exit + hop_s;
        }
        FlowResult { stage_exit: exits }
    }

    /// Advance all stage-free times to at least `t` (idle gap).
    pub fn advance_to(&mut self, t: f64) {
        for f in &mut self.stage_free {
            *f = f.max(t);
        }
    }

    /// Allocation-free [`Self::flow`]: identical stage math, but returns
    /// only `(first_stage_exit, last_stage_exit)` instead of materializing
    /// the per-stage exit vector — the simulator's hot loop needs nothing
    /// else.
    pub fn flow_compact<F: Fn(usize) -> f64>(
        &mut self,
        ready: f64,
        stage_time: F,
        hop_s: f64,
    ) -> (f64, f64) {
        let mut avail = ready;
        let mut first = ready;
        let mut exit = ready;
        for s in 0..self.stage_free.len() {
            let enter = avail.max(self.stage_free[s]);
            exit = enter + stage_time(s);
            self.stage_free[s] = exit;
            if s == 0 {
                first = exit;
            }
            avail = exit + hop_s;
        }
        (first, exit)
    }
}

/// Prefill completion times under the **dense SPP schedule**: chunks are
/// admitted back-to-back at stage 0. Returns (ttft_relative, per-chunk exit
/// times) for chunk stage-times given by `chunk_stage_time(chunk_idx)`.
pub fn spp_prefill_schedule<F: Fn(usize) -> f64>(
    n_chunks: usize,
    stages: usize,
    chunk_stage_time: F,
    hop_s: f64,
) -> (f64, Vec<f64>) {
    let mut tl = PipelineTimeline::new(stages, 0.0);
    let mut exits = Vec::with_capacity(n_chunks);
    for i in 0..n_chunks {
        let t = chunk_stage_time(i);
        let ready = tl.stage0_free(); // dense admission
        let r = tl.flow(ready, |_| t, hop_s);
        exits.push(r.exit());
    }
    (exits.last().copied().unwrap_or(0.0), exits)
}

/// Prefill completion under **conventional micro-batch PP** (Fig. 9a):
/// chunk i+1 is admitted only after chunk i exits the last stage.
pub fn conventional_pp_prefill_schedule<F: Fn(usize) -> f64>(
    n_chunks: usize,
    stages: usize,
    chunk_stage_time: F,
    hop_s: f64,
) -> (f64, Vec<f64>) {
    let mut tl = PipelineTimeline::new(stages, 0.0);
    let mut exits = Vec::with_capacity(n_chunks);
    let mut ready = 0.0;
    for i in 0..n_chunks {
        let t = chunk_stage_time(i);
        let r = tl.flow(ready, |_| t, hop_s);
        ready = r.exit(); // serialized admission
        exits.push(r.exit());
    }
    (exits.last().copied().unwrap_or(0.0), exits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn dense_overlaps_conventional_serializes() {
        // 8 chunks, 4 stages, unit stage time, no hops:
        // dense: 8 (stage-0 busy) + 3 (drain) = 11
        // conventional: 8 chunks x 4 stages = 32
        let (dense, _) = spp_prefill_schedule(8, 4, |_| 1.0, 0.0);
        let (conv, _) = conventional_pp_prefill_schedule(8, 4, |_| 1.0, 0.0);
        assert!((dense - 11.0).abs() < 1e-9, "{dense}");
        assert!((conv - 32.0).abs() < 1e-9, "{conv}");
    }

    #[test]
    fn dense_speedup_near_linear_in_stages() {
        // Eq. 8: many chunks => TTFT ~ total/stages.
        let n = 256;
        let (t1, _) = spp_prefill_schedule(n, 1, |_| 1.0, 0.0);
        let (t8, _) = spp_prefill_schedule(n, 8, |_| 0.125, 0.0);
        // 8 stages each 1/8 the work
        let eff = t1 / (8.0 * t8) * 8.0; // = t1 / (8 * t8)
        let speedup = t1 / t8;
        assert!(speedup > 0.9 * 8.0, "speedup={speedup} eff={eff}");
    }

    #[test]
    fn flow_respects_stage_occupancy() {
        let mut tl = PipelineTimeline::new(2, 0.0);
        let a = tl.flow(0.0, |_| 2.0, 0.0);
        assert_eq!(a.stage_exit, vec![2.0, 4.0]);
        // second unit enters stage 0 at t=2 (dense), stage 1 at t=4
        let b = tl.flow(tl.stage0_free(), |_| 2.0, 0.0);
        assert_eq!(b.stage_exit, vec![4.0, 6.0]);
    }

    #[test]
    fn hops_delay_downstream_stages() {
        let mut tl = PipelineTimeline::new(2, 0.0);
        let r = tl.flow(0.0, |_| 1.0, 0.5);
        assert_eq!(r.stage_exit, vec![1.0, 2.5]);
    }

    #[test]
    fn exits_monotone_nondecreasing() {
        check("spp exits monotone", 200, |rng| {
            let n = rng.range_u64(1, 40) as usize;
            let stages = rng.range_u64(1, 8) as usize;
            let times: Vec<f64> = (0..n).map(|_| rng.range_f64(0.01, 2.0)).collect();
            let hop = rng.range_f64(0.0, 0.1);
            let (_, dense) = spp_prefill_schedule(n, stages, |i| times[i], hop);
            let (_, conv) = conventional_pp_prefill_schedule(n, stages, |i| times[i], hop);
            for w in dense.windows(2) {
                assert!(w[1] >= w[0]);
            }
            // dense is never slower than conventional
            assert!(*dense.last().unwrap() <= conv.last().unwrap() + 1e-12);
        });
    }

    #[test]
    fn flow_compact_matches_flow_exactly() {
        check("flow_compact == flow", 200, |rng| {
            let stages = rng.range_u64(1, 8) as usize;
            let mut a = PipelineTimeline::new(stages, 0.0);
            let mut b = PipelineTimeline::new(stages, 0.0);
            for _ in 0..rng.range_u64(1, 20) {
                let ready = rng.range_f64(0.0, 5.0);
                let t = rng.range_f64(0.01, 2.0);
                let hop = rng.range_f64(0.0, 0.1);
                let r = a.flow(ready, |_| t, hop);
                let (first, exit) = b.flow_compact(ready, |_| t, hop);
                assert_eq!(r.first_stage_exit().to_bits(), first.to_bits());
                assert_eq!(r.exit().to_bits(), exit.to_bits());
                assert_eq!(a.stage0_free().to_bits(), b.stage0_free().to_bits());
            }
        });
    }

    #[test]
    fn single_stage_dense_equals_conventional() {
        let (d, _) = spp_prefill_schedule(16, 1, |i| (i + 1) as f64 * 0.1, 0.0);
        let (c, _) = conventional_pp_prefill_schedule(16, 1, |i| (i + 1) as f64 * 0.1, 0.0);
        assert!((d - c).abs() < 1e-12);
    }
}
