//! Admission control for open-loop serving: the layer between an arrival
//! stream the system does not control and the coordinator's admission path
//! (which assumes every request it sees will be served).
//!
//! Three mechanisms, applied in order to every arrival:
//!
//! 1. **SLO-feedback load shedding** — when the rolling deferral-wait p95
//!    (the observed queueing delay of capacity-deferred admissions,
//!    [`crate::metrics::Metrics::deferral_wait`]) crosses a configured
//!    fraction of the arrival's own length-aware TTFT deadline, arrivals
//!    whose *projected* LARS slack is already negative — the deadline
//!    cannot be met even if service starts after the observed wait — are
//!    rejected at the door. Shedding the provably-late keeps the fleet's
//!    work conserving for requests that can still make their SLO: goodput
//!    plateaus instead of collapsing.
//! 2. **Per-class queue limits** — short/interactive and document arrivals
//!    wait in separate bounded queues; an arrival to a full queue is
//!    rejected (`503`, in HTTP terms). Bounding the backlog bounds the
//!    worst-case wait of everything behind it.
//! 3. **Per-class token buckets** — queued arrivals are released to the
//!    coordinator at a sustained per-class rate with bounded burst, so a
//!    document flood cannot crowd shorts out of the admission path (and
//!    vice versa). An unpaced class (`rate_per_s = ∞`) releases
//!    immediately.
//!
//! A default-constructed [`AdmissionConfig`] is a pure pass-through —
//! unbounded queues, unpaced buckets, shedding disabled — under which the
//! open-loop driver reproduces closed-loop replay bit-identically
//! (asserted in `tests/sim_serve.rs`). Everything is deterministic: no
//! randomness, no wall clock; decisions depend only on the arrival stream
//! and the metrics observed so far.

use std::collections::VecDeque;

use crate::util::json::Json;
use crate::workload::RequestSpec;

/// Request class, by prompt length against [`AdmissionConfig::doc_threshold`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqClass {
    Short,
    Doc,
}

/// What happened to one offered arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionOutcome {
    /// Queued for paced release to the coordinator.
    Enqueued,
    /// Shed by SLO feedback: deferral pressure high and projected slack
    /// negative.
    Shed,
    /// The class queue was at its limit.
    RejectedQueueFull,
}

/// One class's pacing and backlog knobs.
#[derive(Debug, Clone)]
pub struct BucketConfig {
    /// Sustained release rate (requests/s). `f64::INFINITY` = unpaced.
    pub rate_per_s: f64,
    /// Bucket depth: releases that may happen back-to-back after idle.
    pub burst: f64,
    /// Max arrivals waiting in this class's queue (`usize::MAX` = unbounded).
    pub queue_limit: usize,
}

impl BucketConfig {
    /// No pacing, no backlog bound.
    pub fn unlimited() -> BucketConfig {
        BucketConfig {
            rate_per_s: f64::INFINITY,
            burst: 1.0,
            queue_limit: usize::MAX,
        }
    }
}

#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    pub short: BucketConfig,
    pub doc: BucketConfig,
    /// Prompt length at/above which an arrival is document class.
    pub doc_threshold: u64,
    /// Shedding arms when the rolling deferral-wait p95 exceeds this
    /// fraction of the arrival's TTFT deadline. `0` (or non-finite)
    /// disables shedding.
    pub shed_deferral_frac: f64,
    /// LARS headroom fraction used in the projected-slack check (mirrors
    /// [`crate::coordinator::policy::Lars::headroom_frac`]).
    pub headroom_frac: f64,
}

impl Default for AdmissionConfig {
    /// Pure pass-through: open-loop serving under this config is
    /// bit-identical to closed-loop replay of the same trace.
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            short: BucketConfig::unlimited(),
            doc: BucketConfig::unlimited(),
            doc_threshold: 16_384,
            shed_deferral_frac: 0.0,
            headroom_frac: 0.2,
        }
    }
}

impl AdmissionConfig {
    /// Overload-protective defaults, scaled to a target sustainable rate:
    /// shorts paced at the full target rate, documents at 1/16th of it
    /// (one document costs orders of magnitude more prefill work), bounded
    /// queues, shedding armed at half the TTFT deadline.
    pub fn protective(target_rate_per_s: f64, doc_threshold: u64) -> AdmissionConfig {
        AdmissionConfig {
            short: BucketConfig {
                rate_per_s: target_rate_per_s,
                burst: (target_rate_per_s * 2.0).max(4.0),
                queue_limit: 64,
            },
            doc: BucketConfig {
                rate_per_s: (target_rate_per_s / 16.0).max(0.05),
                burst: 2.0,
                queue_limit: 8,
            },
            doc_threshold,
            shed_deferral_frac: 0.5,
            headroom_frac: 0.2,
        }
    }

    /// Parse from a JSON object; absent keys keep the pass-through
    /// defaults. Shape:
    /// `{"short": {"rate_per_s": 8, "burst": 16, "queue_limit": 64},
    ///   "doc": {...}, "doc_threshold": 131072, "shed_deferral_frac": 0.5}`
    pub fn from_json(j: &Json) -> anyhow::Result<AdmissionConfig> {
        let d = AdmissionConfig::default();
        let bucket = |key: &str, d: &BucketConfig| -> anyhow::Result<BucketConfig> {
            let Some(b) = j.get(key) else {
                return Ok(d.clone());
            };
            Ok(BucketConfig {
                rate_per_s: b.get("rate_per_s").and_then(|x| x.as_f64()).unwrap_or(d.rate_per_s),
                burst: b.get("burst").and_then(|x| x.as_f64()).unwrap_or(d.burst),
                queue_limit: b
                    .get("queue_limit")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(d.queue_limit),
            })
        };
        let cfg = AdmissionConfig {
            short: bucket("short", &d.short)?,
            doc: bucket("doc", &d.doc)?,
            doc_threshold: j
                .get("doc_threshold")
                .and_then(|x| x.as_u64())
                .unwrap_or(d.doc_threshold),
            shed_deferral_frac: j
                .get("shed_deferral_frac")
                .and_then(|x| x.as_f64())
                .unwrap_or(d.shed_deferral_frac),
            headroom_frac: j
                .get("headroom_frac")
                .and_then(|x| x.as_f64())
                .unwrap_or(d.headroom_frac),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, b) in [("short", &self.short), ("doc", &self.doc)] {
            anyhow::ensure!(
                b.rate_per_s > 0.0,
                "admission.{name}.rate_per_s must be > 0 (use infinity for unpaced)"
            );
            anyhow::ensure!(
                b.burst >= 1.0,
                "admission.{name}.burst must be >= 1 (a bucket that can never hold a whole token never releases)"
            );
            anyhow::ensure!(b.queue_limit >= 1, "admission.{name}.queue_limit must be >= 1");
        }
        anyhow::ensure!(self.doc_threshold > 0, "admission.doc_threshold must be > 0");
        anyhow::ensure!(
            self.shed_deferral_frac >= 0.0,
            "admission.shed_deferral_frac must be >= 0"
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.headroom_frac),
            "admission.headroom_frac must be in [0, 1)"
        );
        Ok(())
    }

    pub fn class_of(&self, prompt_len: u64) -> ReqClass {
        if prompt_len >= self.doc_threshold {
            ReqClass::Doc
        } else {
            ReqClass::Short
        }
    }
}

/// Standard token bucket: `tokens` refills at `rate` up to `burst`; one
/// release costs one token. Unpaced (`rate = ∞`) always has a token.
#[derive(Debug, Clone)]
struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last_s: f64,
}

impl TokenBucket {
    fn new(cfg: &BucketConfig) -> TokenBucket {
        TokenBucket {
            rate: cfg.rate_per_s,
            burst: cfg.burst,
            // starts full: an idle system admits a burst immediately
            tokens: cfg.burst,
            last_s: 0.0,
        }
    }

    fn unpaced(&self) -> bool {
        !self.rate.is_finite()
    }

    fn refill(&mut self, now: f64) {
        if self.unpaced() {
            return;
        }
        let dt = (now - self.last_s).max(0.0);
        self.tokens = (self.tokens + self.rate * dt).min(self.burst);
        self.last_s = now;
    }

    fn has_token(&self) -> bool {
        self.unpaced() || self.tokens >= 1.0
    }

    fn take(&mut self) {
        if !self.unpaced() {
            self.tokens -= 1.0;
        }
    }

    /// Time at which the next token will exist (== `now` if one already
    /// does). Call after `refill(now)`.
    fn next_ready_s(&self, now: f64) -> f64 {
        if self.has_token() {
            now
        } else {
            now + (1.0 - self.tokens) / self.rate
        }
    }
}

/// Admission state: one token bucket + bounded FIFO queue per class.
/// Counters are written into the caller's [`crate::metrics::Metrics`] at
/// decision time; high-water marks are kept here for invariant tests.
#[derive(Debug)]
pub struct Admission {
    cfg: AdmissionConfig,
    short_bucket: TokenBucket,
    doc_bucket: TokenBucket,
    short_q: VecDeque<RequestSpec>,
    doc_q: VecDeque<RequestSpec>,
    /// Deepest the short queue ever got (post-enqueue).
    pub short_q_high_water: usize,
    /// Deepest the doc queue ever got (post-enqueue).
    pub doc_q_high_water: usize,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Admission {
        Admission {
            short_bucket: TokenBucket::new(&cfg.short),
            doc_bucket: TokenBucket::new(&cfg.doc),
            short_q: VecDeque::new(),
            doc_q: VecDeque::new(),
            short_q_high_water: 0,
            doc_q_high_water: 0,
            cfg,
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Arrivals currently waiting for paced release, both classes.
    pub fn queued(&self) -> usize {
        self.short_q.len() + self.doc_q.len()
    }

    pub fn queue_len(&self, class: ReqClass) -> usize {
        match class {
            ReqClass::Short => self.short_q.len(),
            ReqClass::Doc => self.doc_q.len(),
        }
    }

    /// Offer one arrival. `est_prefill_s` and `ttft_deadline_rel_s` are
    /// the perf model's prefill estimate and the length-aware TTFT budget
    /// this request *would* be admitted under; `deferral_p95_s` is the
    /// rolling deferral-wait p95 (NaN when nothing has been deferred yet).
    /// Shed/reject decisions are final — a dropped arrival never enters
    /// the coordinator. The caller meters the outcome
    /// ([`crate::metrics::Metrics::record_shed`] /
    /// [`record_queue_reject`](crate::metrics::Metrics::record_queue_reject)).
    pub fn offer(
        &mut self,
        spec: RequestSpec,
        est_prefill_s: f64,
        ttft_deadline_rel_s: f64,
        deferral_p95_s: f64,
    ) -> AdmissionOutcome {
        let class = self.cfg.class_of(spec.prompt_len);
        // 1. SLO-feedback shedding: only under measured deferral pressure,
        // and only for arrivals that are already projected late. NaN p95
        // (no deferrals observed) fails both comparisons — disarmed.
        let frac = self.cfg.shed_deferral_frac;
        if frac > 0.0 && frac.is_finite() && deferral_p95_s > frac * ttft_deadline_rel_s {
            let budget = ttft_deadline_rel_s * (1.0 - self.cfg.headroom_frac);
            let work = est_prefill_s.max(1e-12);
            let projected_slack = (budget - deferral_p95_s - work) / work;
            if projected_slack < 0.0 {
                return AdmissionOutcome::Shed;
            }
        }
        // 2. per-class queue limit
        let (q, limit) = match class {
            ReqClass::Short => (&mut self.short_q, self.cfg.short.queue_limit),
            ReqClass::Doc => (&mut self.doc_q, self.cfg.doc.queue_limit),
        };
        if q.len() >= limit {
            return AdmissionOutcome::RejectedQueueFull;
        }
        q.push_back(spec);
        match class {
            ReqClass::Short => {
                self.short_q_high_water = self.short_q_high_water.max(self.short_q.len())
            }
            ReqClass::Doc => self.doc_q_high_water = self.doc_q_high_water.max(self.doc_q.len()),
        }
        AdmissionOutcome::Enqueued
    }

    /// Release every queued arrival whose class bucket has a token,
    /// preserving global `(arrival_s, id)` order whenever both classes are
    /// eligible (so a pass-through config reproduces the source order
    /// exactly). Appends to `out`.
    pub fn release(&mut self, now: f64, out: &mut Vec<RequestSpec>) {
        self.short_bucket.refill(now);
        self.doc_bucket.refill(now);
        loop {
            let s = self.short_bucket.has_token().then(|| self.short_q.front()).flatten();
            let d = self.doc_bucket.has_token().then(|| self.doc_q.front()).flatten();
            let take_short = match (s, d) {
                (None, None) => break,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (Some(a), Some(b)) => (a.arrival_s, a.id) <= (b.arrival_s, b.id),
            };
            let spec = if take_short {
                self.short_bucket.take();
                self.short_q.pop_front().unwrap()
            } else {
                self.doc_bucket.take();
                self.doc_q.pop_front().unwrap()
            };
            out.push(spec);
        }
    }

    /// Earliest future time a queued arrival could be released (`None`
    /// when nothing is queued). Lets an idle driver jump straight to the
    /// next admission event instead of polling.
    pub fn next_release_s(&self, now: f64) -> Option<f64> {
        let mut t: Option<f64> = None;
        for (q, b) in [
            (&self.short_q, &self.short_bucket),
            (&self.doc_q, &self.doc_bucket),
        ] {
            if !q.is_empty() {
                let ready = b.next_ready_s(now);
                t = Some(t.map_or(ready, |x: f64| x.min(ready)));
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(id: u64, prompt_len: u64, arrival_s: f64) -> RequestSpec {
        RequestSpec {
            id,
            prompt_len,
            max_new_tokens: 8,
            arrival_s,
            ..RequestSpec::default()
        }
    }

    fn offer_plain(a: &mut Admission, s: RequestSpec) -> AdmissionOutcome {
        // no deferral pressure, generous deadline
        a.offer(s, 0.1, 10.0, f64::NAN)
    }

    #[test]
    fn pass_through_releases_everything_in_order() {
        let mut a = Admission::new(AdmissionConfig::default());
        // offered out of class but in (arrival, id) order
        assert_eq!(offer_plain(&mut a, spec(0, 512, 0.0)), AdmissionOutcome::Enqueued);
        assert_eq!(offer_plain(&mut a, spec(1, 500_000, 0.1)), AdmissionOutcome::Enqueued);
        assert_eq!(offer_plain(&mut a, spec(2, 512, 0.2)), AdmissionOutcome::Enqueued);
        let mut out = Vec::new();
        a.release(0.2, &mut out);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(a.queued(), 0);
        assert_eq!(a.next_release_s(0.2), None);
    }

    #[test]
    fn token_bucket_paces_a_burst() {
        let cfg = AdmissionConfig {
            short: BucketConfig {
                rate_per_s: 1.0,
                burst: 2.0,
                queue_limit: usize::MAX,
            },
            ..AdmissionConfig::default()
        };
        let mut a = Admission::new(cfg);
        for i in 0..5 {
            offer_plain(&mut a, spec(i, 512, 0.0));
        }
        let mut out = Vec::new();
        a.release(0.0, &mut out);
        assert_eq!(out.len(), 2, "burst depth releases immediately");
        assert_eq!(a.queued(), 3);
        // one more token exists at t=1
        let next = a.next_release_s(0.0).unwrap();
        assert!((next - 1.0).abs() < 1e-9, "next={next}");
        a.release(1.0, &mut out);
        assert_eq!(out.len(), 3);
        // full drain after enough refill time
        a.release(10.0, &mut out);
        assert_eq!(out.len(), 5);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn queue_limit_rejects_only_the_full_class() {
        let cfg = AdmissionConfig {
            short: BucketConfig {
                rate_per_s: 1.0, // paced so the queue actually fills
                burst: 1.0,
                queue_limit: 2,
            },
            doc_threshold: 16_384,
            ..AdmissionConfig::default()
        };
        let mut a = Admission::new(cfg);
        offer_plain(&mut a, spec(0, 512, 0.0));
        offer_plain(&mut a, spec(1, 512, 0.0));
        assert_eq!(
            offer_plain(&mut a, spec(2, 512, 0.0)),
            AdmissionOutcome::RejectedQueueFull
        );
        // the doc class is unaffected
        assert_eq!(offer_plain(&mut a, spec(3, 500_000, 0.0)), AdmissionOutcome::Enqueued);
        assert_eq!(a.queue_len(ReqClass::Short), 2);
        assert_eq!(a.queue_len(ReqClass::Doc), 1);
        assert_eq!(a.short_q_high_water, 2);
    }

    #[test]
    fn shedding_requires_pressure_and_negative_slack() {
        let cfg = AdmissionConfig {
            shed_deferral_frac: 0.5,
            ..AdmissionConfig::default()
        };
        let mut a = Admission::new(cfg);
        // deadline 10s: pressure threshold is p95 > 5s
        // no pressure recorded yet (NaN p95): admit
        assert_eq!(a.offer(spec(0, 512, 0.0), 1.0, 10.0, f64::NAN), AdmissionOutcome::Enqueued);
        // pressure below the threshold: admit
        assert_eq!(a.offer(spec(1, 512, 0.0), 1.0, 10.0, 4.0), AdmissionOutcome::Enqueued);
        // pressure above threshold but slack still positive
        // (budget 8 - wait 6 - work 1 = +1): admit
        assert_eq!(a.offer(spec(2, 512, 0.0), 1.0, 10.0, 6.0), AdmissionOutcome::Enqueued);
        // pressure above threshold and projected late
        // (budget 8 - wait 7.5 - work 1 < 0): shed
        assert_eq!(a.offer(spec(3, 512, 0.0), 1.0, 10.0, 7.5), AdmissionOutcome::Shed);
        assert_eq!(a.queued(), 3);
    }

    #[test]
    fn shedding_disabled_by_default() {
        let mut a = Admission::new(AdmissionConfig::default());
        // crushing pressure, hopeless slack — still admitted: frac = 0
        assert_eq!(
            a.offer(spec(0, 512, 0.0), 5.0, 1.0, 100.0),
            AdmissionOutcome::Enqueued
        );
    }

    #[test]
    fn per_class_pacing_is_independent() {
        let cfg = AdmissionConfig {
            doc: BucketConfig {
                rate_per_s: 0.1,
                burst: 1.0,
                queue_limit: usize::MAX,
            },
            doc_threshold: 16_384,
            ..AdmissionConfig::default()
        };
        let mut a = Admission::new(cfg);
        offer_plain(&mut a, spec(0, 500_000, 0.0)); // doc, takes the one doc token
        offer_plain(&mut a, spec(1, 500_000, 0.0)); // doc, must wait ~10s
        offer_plain(&mut a, spec(2, 512, 0.5)); // short, arrives later
        let mut out = Vec::new();
        a.release(0.5, &mut out);
        // doc 0 (earlier arrival, token available) then short 2; doc 1 blocked
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(a.queue_len(ReqClass::Doc), 1);
        let next = a.next_release_s(0.5).unwrap();
        assert!(next > 0.5, "doc token refills in the future, next={next}");
    }

    #[test]
    fn config_json_round_trip_and_validation() {
        let j = Json::parse(
            r#"{"short": {"rate_per_s": 8.0, "burst": 16.0, "queue_limit": 64},
                "doc": {"rate_per_s": 0.5, "burst": 2.0, "queue_limit": 8},
                "doc_threshold": 131072, "shed_deferral_frac": 0.5}"#,
        )
        .unwrap();
        let cfg = AdmissionConfig::from_json(&j).unwrap();
        assert_eq!(cfg.short.queue_limit, 64);
        assert_eq!(cfg.doc.queue_limit, 8);
        assert_eq!(cfg.doc_threshold, 131_072);
        assert!((cfg.shed_deferral_frac - 0.5).abs() < 1e-12);
        assert_eq!(cfg.class_of(131_072), ReqClass::Doc);
        assert_eq!(cfg.class_of(512), ReqClass::Short);
        // empty object = pass-through defaults
        let d = AdmissionConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(d.short.rate_per_s.is_infinite());
        assert_eq!(d.shed_deferral_frac, 0.0);
        // invalid knobs are rejected
        let bad = Json::parse(r#"{"short": {"rate_per_s": -1.0}}"#).unwrap();
        assert!(AdmissionConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"short": {"burst": 0.5}}"#).unwrap();
        assert!(AdmissionConfig::from_json(&bad).is_err());
        assert!(AdmissionConfig::protective(8.0, 131_072).validate().is_ok());
    }
}
