//! The paper's coordination layer (L3): request lifecycle, mixed
//! continuous-batching with chunked prefills, adaptive chunk sizing, the
//! dense SPP pipeline schedule, dynamic KVP group management, request
//! routing across replicas, and the 3D topology. Pure logic — time comes
//! from either the cluster simulator (`crate::sim`) or wall-clock PJRT
//! execution (`crate::engine`).

pub mod arena;
pub mod chunking;
pub mod kvp;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod spp;
pub mod topology;

pub use arena::{RequestArena, Slot};
pub use chunking::{AdaptiveChunk, ChunkPolicy, DeadlineChunk, StaticChunk};
pub use kvp::KvpManager;
pub use request::{Phase, Request};
pub use router::Router;
pub use scheduler::{BatchPlan, Scheduler};
pub use spp::{conventional_pp_prefill_schedule, spp_prefill_schedule, PipelineTimeline};
pub use topology::{Topology, WorkerId};
