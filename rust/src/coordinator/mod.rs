//! The paper's coordination layer (L3): request lifecycle, mixed
//! continuous-batching with chunked prefills, adaptive chunk sizing,
//! preemptive scheduling policies, the dense SPP pipeline schedule, dynamic
//! KVP group management, request routing across replicas, and the 3D
//! topology. Pure logic — time comes from either the cluster simulator
//! (`crate::sim`) or wall-clock PJRT execution (`crate::engine`).
//!
//! # Scheduling policies (section 5)
//!
//! Which prefill a replica runs each iteration is decided by a pluggable
//! [`SchedPolicy`] (see [`policy`]): a single urgency key re-evaluated over
//! the ready set every iteration, with preemption only ever happening at a
//! chunk boundary (the preempted request's KV stays resident and it resumes
//! from the same boundary). Shipped policies:
//!
//! | policy | key (min runs first)                  | preemptive |
//! |--------|---------------------------------------|------------|
//! | `fcfs` | arrival time                          | no         |
//! | `srpt` | remaining estimated prefill work      | yes        |
//! | `edf`  | absolute TTFT deadline                | yes        |
//! | `lars` | relative slack `(D − now − W) / W`    | yes        |
//!
//! LARS (Length-Aware Relative Slack) is the paper's scheduler: with
//! length-aware deadlines (`SloConfig::ttft_deadline_for`) every fresh
//! request starts at the same slack, short requests gain urgency fast
//! (convoy elimination), and overdue long requests beat fresh short ones
//! (starvation freedom).
//!
//! **Adding a policy**: implement [`SchedPolicy`] (a `priority` key and,
//! optionally, `preemptive = false` to pin the head like FCFS), declare
//! its [`KeyShape`] so the indexed [`ReadySet`] can serve selection
//! without a per-iteration scan (`Static` with a `static_key` when the
//! key ignores `now`; `Slack` with `slack_parts` for LARS-shaped ratios),
//! add a variant to [`SchedPolicyKind`] (`parse`/`name`/`build`) so it is
//! selectable from config JSON (`scheduler.policy`) and the
//! `simulate --policy` CLI flag, and it composes automatically with every
//! chunk policy and the simulator. Deadline/work state lives on
//! [`Request`] (`deadline_s`, `est_prefill_s`), assigned at admission from
//! the perf model's prefill estimate.
//!
//! # Policy-aware KVP routing (section 7)
//!
//! Placement across KVP groups is the [`RoutingMode`]
//! (`scheduler.routing`, `simulate --routing`): `blind` is least-loaded
//! placement with every group in the simulator's cooperative set (the
//! per-group clocks stay equal, degenerating to the original lockstep
//! schedule — pinned by recorded golden snapshots), `round-robin` is the
//! policy-blind pooled baseline, and `routed` delegates placement to the
//! scheduling policy's [`SchedPolicy::route`] hook over per-group
//! [`GroupView`] snapshots. Non-blind modes run the groups not holding the
//! active sharded long request as an independent short-request serving
//! pool, and a **preemptive** policy may additionally yield the *active*
//! sharded long request at a chunk boundary ([`KvpManager::yield_active`]
//! retains every per-group shard; resume is bit-exact). All three modes
//! execute through the one pool-scheduled `Simulation::step`.
//!
//! # Elastic fleet & failure injection
//!
//! Group membership is a runtime object: each group carries a
//! [`GroupState`] lifecycle (`Active`/`Draining`/`Down`/`Joining`) and
//! every placement path consults it. [`KvpManager::crash_group`] models a
//! group loss — ledger and shards dropped, a [`CrashReport`] handed to the
//! scheduler so victims re-enter as re-prefill work from their last
//! surviving chunk boundary. See `crate::config::FaultPlan` for the
//! deterministic injection schedule and the [`kvp`] module docs for the
//! lifecycle rules.

pub mod admission;
pub mod arena;
pub mod chunking;
pub mod kvp;
pub mod policy;
pub mod readyset;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod spp;
pub mod topology;

pub use admission::{Admission, AdmissionConfig, AdmissionOutcome, BucketConfig, ReqClass};
pub use arena::{RequestArena, Slot};
pub use chunking::{AdaptiveChunk, ChunkPolicy, DeadlineChunk, StaticChunk};
pub use kvp::{CrashReport, GroupState, KvpManager};
pub use policy::{Edf, Fcfs, GroupView, KeyShape, Lars, SchedPolicy, SchedPolicyKind, Srpt};
pub use readyset::ReadySet;
pub use request::{Phase, Request};
pub use router::{Router, RoutingMode};
pub use scheduler::{BatchPlan, Scheduler};
pub use spp::{conventional_pp_prefill_schedule, spp_prefill_schedule, PipelineTimeline};
pub use topology::{Topology, WorkerId};
