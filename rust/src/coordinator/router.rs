//! Request router across KVP replica groups (section 7, "independent
//! scheduling of KVP instances").
//!
//! Each KVP group holds a full model replica. Short requests are routed to
//! a single group; a long request claims its primary group and grows across
//! groups via the KvpManager while the remaining groups keep serving short
//! traffic independently — the throughput opportunity the paper highlights.
//!
//! *How* the serving group is chosen is the [`RoutingMode`]:
//!
//! * [`RoutingMode::Blind`] — least-loaded by outstanding tokens, the
//!   pre-routing behavior the recorded golden snapshots pin down. Under
//!   this mode the simulator runs **every** group in the cooperative set
//!   of its single pool-scheduled step, so the per-group clocks stay equal
//!   and the schedule degenerates to the original lockstep iteration
//!   semantics.
//! * [`RoutingMode::RoundRobin`] — strictly alternating placement, the
//!   policy-blind baseline the routed comparison is measured against.
//! * [`RoutingMode::Routed`] — placement delegated to the scheduling
//!   policy's [`route`](super::policy::SchedPolicy::route) hook over
//!   per-group [`GroupView`](super::policy::GroupView) occupancy snapshots:
//!   urgency ranking drives *where* a request runs, not just its queue
//!   order, groups holding the active sharded long request are avoided,
//!   and — with a finite `scheduler.kvp_capacity_tokens` — groups without
//!   room for the request's KV footprint are refused outright (the
//!   simulator defers such admissions until capacity frees). Every signal
//!   in a `GroupView` is an O(1) read of incrementally maintained state:
//!   the schedulers' deadline-critical urgency counters and the KVP
//!   manager's capacity ledger, never a backlog rescan.
//!
//! Every mode runs through the simulator's single pool-scheduled step; the
//! non-blind modes narrow the cooperative set to the active long request's
//! shard holders, so the remaining groups iterate independently as a
//! short-request serving pool instead of in lockstep with the sharded
//! prefill.
//!
//! State is flat: per-group load is a plain vector (groups are dense ids)
//! and request placement is slot-indexed, so routing and release are O(1)
//! array touches in the simulator's hot loop.

use super::arena::Slot;
use crate::kvcache::GroupId;
use crate::util::slotvec::SlotVec;

/// Config/CLI-selectable placement strategy across KVP groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingMode {
    /// Least-loaded placement with every group in the cooperative set —
    /// the per-group clocks stay equal, degenerating to the original
    /// lockstep iteration semantics (the default).
    Blind,
    /// Policy-blind alternating placement with pool scheduling — the
    /// baseline the routed mode is compared against.
    RoundRobin,
    /// Policy-aware placement (`SchedPolicy::route`) with pool scheduling
    /// and active-long-request preemption.
    Routed,
}

impl RoutingMode {
    pub const ALL: [RoutingMode; 3] =
        [RoutingMode::Blind, RoutingMode::RoundRobin, RoutingMode::Routed];

    pub fn parse(s: &str) -> Option<RoutingMode> {
        match s.to_ascii_lowercase().as_str() {
            "blind" | "least-loaded" => Some(RoutingMode::Blind),
            "rr" | "round-robin" | "round_robin" => Some(RoutingMode::RoundRobin),
            "routed" | "policy" | "policy-aware" => Some(RoutingMode::Routed),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutingMode::Blind => "blind",
            RoutingMode::RoundRobin => "round-robin",
            RoutingMode::Routed => "routed",
        }
    }

    /// Non-blind modes narrow the cooperative set to the active shard
    /// holders, running every other group as an independent short-request
    /// serving pool; blind cooperates all groups (the lockstep barrier).
    pub fn pooled(self) -> bool {
        self != RoutingMode::Blind
    }
}

#[derive(Debug, Clone)]
pub struct Router {
    /// Outstanding token load per group (KV-resident + queued prompt work).
    load: Vec<u64>,
    /// Request slot -> primary group.
    placement: SlotVec<GroupId>,
    /// Next group for round-robin placement.
    rr_next: GroupId,
}

impl Router {
    pub fn new(n_groups: u32) -> Router {
        Router {
            load: vec![0; n_groups as usize],
            placement: SlotVec::new(),
            rr_next: 0,
        }
    }

    pub fn n_groups(&self) -> u32 {
        self.load.len() as u32
    }

    /// Route a request with `prompt_len` tokens: least-loaded group wins
    /// (ties break to the lowest id for determinism).
    pub fn route(&mut self, s: Slot, prompt_len: u64) -> GroupId {
        let (g, _) = self
            .load
            .iter()
            .enumerate()
            .min_by_key(|&(g, &l)| (l, g))
            .expect("router has no groups");
        let g = g as GroupId;
        self.route_to(s, prompt_len, g);
        g
    }

    /// Strictly alternating placement (the policy-blind round-robin
    /// baseline): group ids cycle regardless of load or occupancy.
    pub fn route_round_robin(&mut self, s: Slot, prompt_len: u64) -> GroupId {
        let g = self.rr_next;
        self.rr_next = (self.rr_next + 1) % self.load.len() as GroupId;
        self.route_to(s, prompt_len, g);
        g
    }

    /// Round-robin over a live-membership mask (the elastic fleet): the
    /// cursor scans forward to the first placeable group and advances past
    /// it. With every group placeable this is exactly
    /// [`Self::route_round_robin`]. Returns `None` when no group is
    /// placeable (the caller defers the admission).
    pub fn route_round_robin_masked(
        &mut self,
        s: Slot,
        prompt_len: u64,
        placeable: &[bool],
    ) -> Option<GroupId> {
        let n = self.load.len() as GroupId;
        debug_assert_eq!(placeable.len(), n as usize);
        for step in 0..n {
            let g = (self.rr_next + step) % n;
            if placeable.get(g as usize).copied().unwrap_or(false) {
                self.rr_next = (g + 1) % n;
                self.route_to(s, prompt_len, g);
                return Some(g);
            }
        }
        None
    }

    /// Grow the per-group load ledger to `n_groups` slots (a joining group
    /// past the current fleet end starts with zero load). Shrinking never
    /// happens — a departed group keeps its slot, `Down` and empty.
    pub fn grow_to(&mut self, n_groups: u32) {
        while self.load.len() < n_groups as usize {
            self.load.push(0);
        }
    }

    /// Record an externally chosen placement (the policy-aware routed mode
    /// picks `g` via `SchedPolicy::route`; the router only does the load
    /// and placement accounting).
    pub fn route_to(&mut self, s: Slot, prompt_len: u64, g: GroupId) {
        assert!((g as usize) < self.load.len(), "route_to unknown group {g}");
        self.load[g as usize] += prompt_len;
        self.placement.insert(s as usize, g);
    }

    pub fn group_of(&self, s: Slot) -> Option<GroupId> {
        self.placement.get(s as usize).copied()
    }

    /// Account additional load (e.g. KVP growth claiming another group).
    pub fn add_load(&mut self, g: GroupId, tokens: u64) {
        self.load[g as usize] += tokens;
    }

    pub fn release(&mut self, s: Slot, tokens: u64) {
        if let Some(g) = self.placement.remove(s as usize) {
            let l = &mut self.load[g as usize];
            *l = l.saturating_sub(tokens);
        }
    }

    pub fn load_of(&self, g: GroupId) -> u64 {
        self.load.get(g as usize).copied().unwrap_or(0)
    }

    pub fn total_load(&self) -> u64 {
        self.load.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn routes_to_least_loaded() {
        let mut r = Router::new(3);
        assert_eq!(r.route(1, 100), 0);
        assert_eq!(r.route(2, 10), 1);
        assert_eq!(r.route(3, 10), 2);
        // group 1 and 2 have 10 each, group 0 has 100; tie breaks low id
        assert_eq!(r.route(4, 1), 1);
        // now g1=11, g2=10 -> least loaded is g2
        assert_eq!(r.route(5, 1), 2);
    }

    #[test]
    fn long_request_does_not_block_other_groups() {
        let mut r = Router::new(4);
        let g_long = r.route(1, 10_000_000);
        for s in 2..20 {
            let g = r.route(s, 1_000);
            assert_ne!(g, g_long, "short request landed on the loaded group");
        }
    }

    #[test]
    fn release_restores_capacity() {
        let mut r = Router::new(2);
        let g = r.route(1, 500);
        assert_eq!(r.load_of(g), 500);
        r.release(1, 500);
        assert_eq!(r.load_of(g), 0);
        assert_eq!(r.group_of(1), None);
    }

    #[test]
    fn round_robin_alternates_regardless_of_load() {
        let mut r = Router::new(3);
        assert_eq!(r.route_round_robin(1, 1_000_000), 0);
        // blind to the huge load on group 0: strict alternation
        assert_eq!(r.route_round_robin(2, 10), 1);
        assert_eq!(r.route_round_robin(3, 10), 2);
        assert_eq!(r.route_round_robin(4, 10), 0);
        assert_eq!(r.load_of(0), 1_000_010);
    }

    #[test]
    fn masked_round_robin_skips_dead_groups() {
        let mut r = Router::new(4);
        let mask = [true, false, true, true]; // group 1 is down
        assert_eq!(r.route_round_robin_masked(1, 10, &mask), Some(0));
        assert_eq!(r.route_round_robin_masked(2, 10, &mask), Some(2));
        assert_eq!(r.route_round_robin_masked(3, 10, &mask), Some(3));
        assert_eq!(r.route_round_robin_masked(4, 10, &mask), Some(0));
        assert_eq!(r.load_of(1), 0, "dead group received load");
        // an all-dead fleet defers rather than placing
        assert_eq!(r.route_round_robin_masked(5, 10, &[false; 4]), None);
        // all-live mask is exactly the unmasked round-robin
        let mut a = Router::new(3);
        let mut b = Router::new(3);
        for s in 0..7 {
            assert_eq!(
                a.route_round_robin_masked(s, 5, &[true; 3]),
                Some(b.route_round_robin(s, 5))
            );
        }
    }

    #[test]
    fn grow_to_extends_the_fleet() {
        let mut r = Router::new(2);
        r.grow_to(4);
        assert_eq!(r.n_groups(), 4);
        r.route_to(1, 100, 3);
        assert_eq!(r.load_of(3), 100);
        r.grow_to(3); // never shrinks
        assert_eq!(r.n_groups(), 4);
    }

    #[test]
    fn route_to_records_placement_and_load() {
        let mut r = Router::new(4);
        r.route_to(9, 500, 2);
        assert_eq!(r.group_of(9), Some(2));
        assert_eq!(r.load_of(2), 500);
        r.release(9, 500);
        assert_eq!(r.load_of(2), 0);
    }

    #[test]
    fn routing_mode_parse_roundtrips() {
        for m in RoutingMode::ALL {
            assert_eq!(RoutingMode::parse(m.name()), Some(m));
        }
        assert_eq!(RoutingMode::parse("rr"), Some(RoutingMode::RoundRobin));
        assert_eq!(RoutingMode::parse("policy-aware"), Some(RoutingMode::Routed));
        assert_eq!(RoutingMode::parse("random"), None);
        assert!(!RoutingMode::Blind.pooled());
        assert!(RoutingMode::RoundRobin.pooled() && RoutingMode::Routed.pooled());
    }

    #[test]
    fn prop_load_conservation() {
        check("router load conserved", 200, |rng| {
            let n = rng.range_u64(1, 8) as u32;
            let mut r = Router::new(n);
            let mut live: Vec<(Slot, u64)> = Vec::new();
            let mut expected: u64 = 0;
            for step in 0..rng.range_u64(1, 80) {
                if rng.bool(0.6) || live.is_empty() {
                    let tokens = rng.range_u64(1, 100_000);
                    r.route(step as Slot, tokens);
                    live.push((step as Slot, tokens));
                    expected += tokens;
                } else {
                    let i = rng.below(live.len() as u64) as usize;
                    let (s, tokens) = live.swap_remove(i);
                    r.release(s, tokens);
                    expected -= tokens;
                }
                assert_eq!(r.total_load(), expected);
            }
        });
    }
}
