//! Request router across KVP replica groups (section 7, "independent
//! scheduling of KVP instances").
//!
//! Each KVP group holds a full model replica. Short requests are routed to
//! the least-loaded single group; a long request claims its primary group
//! and grows across groups via the KvpManager while the remaining groups
//! keep serving short traffic independently — the throughput opportunity
//! the paper highlights.
//!
//! State is flat: per-group load is a plain vector (groups are dense ids)
//! and request placement is slot-indexed, so routing and release are O(1)
//! array touches in the simulator's hot loop.

use super::arena::Slot;
use crate::kvcache::GroupId;
use crate::util::slotvec::SlotVec;

#[derive(Debug, Clone)]
pub struct Router {
    /// Outstanding token load per group (KV-resident + queued prompt work).
    load: Vec<u64>,
    /// Request slot -> primary group.
    placement: SlotVec<GroupId>,
}

impl Router {
    pub fn new(n_groups: u32) -> Router {
        Router {
            load: vec![0; n_groups as usize],
            placement: SlotVec::new(),
        }
    }

    pub fn n_groups(&self) -> u32 {
        self.load.len() as u32
    }

    /// Route a request with `prompt_len` tokens: least-loaded group wins
    /// (ties break to the lowest id for determinism).
    pub fn route(&mut self, s: Slot, prompt_len: u64) -> GroupId {
        let (g, _) = self
            .load
            .iter()
            .enumerate()
            .min_by_key(|&(g, &l)| (l, g))
            .expect("router has no groups");
        let g = g as GroupId;
        self.load[g as usize] += prompt_len;
        self.placement.insert(s as usize, g);
        g
    }

    pub fn group_of(&self, s: Slot) -> Option<GroupId> {
        self.placement.get(s as usize).copied()
    }

    /// Account additional load (e.g. KVP growth claiming another group).
    pub fn add_load(&mut self, g: GroupId, tokens: u64) {
        self.load[g as usize] += tokens;
    }

    pub fn release(&mut self, s: Slot, tokens: u64) {
        if let Some(g) = self.placement.remove(s as usize) {
            let l = &mut self.load[g as usize];
            *l = l.saturating_sub(tokens);
        }
    }

    pub fn load_of(&self, g: GroupId) -> u64 {
        self.load.get(g as usize).copied().unwrap_or(0)
    }

    pub fn total_load(&self) -> u64 {
        self.load.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn routes_to_least_loaded() {
        let mut r = Router::new(3);
        assert_eq!(r.route(1, 100), 0);
        assert_eq!(r.route(2, 10), 1);
        assert_eq!(r.route(3, 10), 2);
        // group 1 and 2 have 10 each, group 0 has 100; tie breaks low id
        assert_eq!(r.route(4, 1), 1);
        // now g1=11, g2=10 -> least loaded is g2
        assert_eq!(r.route(5, 1), 2);
    }

    #[test]
    fn long_request_does_not_block_other_groups() {
        let mut r = Router::new(4);
        let g_long = r.route(1, 10_000_000);
        for s in 2..20 {
            let g = r.route(s, 1_000);
            assert_ne!(g, g_long, "short request landed on the loaded group");
        }
    }

    #[test]
    fn release_restores_capacity() {
        let mut r = Router::new(2);
        let g = r.route(1, 500);
        assert_eq!(r.load_of(g), 500);
        r.release(1, 500);
        assert_eq!(r.load_of(g), 0);
        assert_eq!(r.group_of(1), None);
    }

    #[test]
    fn prop_load_conservation() {
        check("router load conserved", 200, |rng| {
            let n = rng.range_u64(1, 8) as u32;
            let mut r = Router::new(n);
            let mut live: Vec<(Slot, u64)> = Vec::new();
            let mut expected: u64 = 0;
            for step in 0..rng.range_u64(1, 80) {
                if rng.bool(0.6) || live.is_empty() {
                    let tokens = rng.range_u64(1, 100_000);
                    r.route(step as Slot, tokens);
                    live.push((step as Slot, tokens));
                    expected += tokens;
                } else {
                    let i = rng.below(live.len() as u64) as usize;
                    let (s, tokens) = live.swap_remove(i);
                    r.release(s, tokens);
                    expected -= tokens;
                }
                assert_eq!(r.total_load(), expected);
            }
        });
    }
}
