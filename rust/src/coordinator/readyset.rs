//! Indexed ready set: O(log n) priority selection over a scheduler's
//! queued prefills, bit-identical to the O(n) scan it replaces.
//!
//! # The selection rule
//!
//! Selection is defined as the argmin of `(priority(r, now), seq)` where
//! `seq` is the monotone enqueue order and `f64`s compare by `total_cmp`.
//! Every index below is a different way of serving that same rule; a
//! `debug_assert` in `Scheduler::next_batch_into` and the randomized
//! differential harness in `tests/invariants.rs` hold the indexes to it
//! against [`ReadySet::select_via_scan`], the naive scan.
//!
//! # Indexes by [`KeyShape`]
//!
//! * **`Fifo`** (FCFS) — a plain `VecDeque`; selection is the head.
//! * **`Static`** (SRPT, EDF) — `priority` is independent of `now` and
//!   changes only when the request's own prefill progresses, so a single
//!   ordered set on `(static_key, seq)` is exact: `select` is `first()`,
//!   and only the request that completed a chunk is re-keyed.
//! * **`Slack`** (LARS) — `priority = (C − now − W)/W` over the
//!   time-invariant critical time `C` and the remaining work `W`. No
//!   single static order serves every `now` (two requests with different
//!   `W` swap order exactly once as `now` passes their crossing; equal-`W`
//!   pairs never swap), so the set is kept ordered by `C` and selection
//!   walks that order with a **pruning bound**: once every not-yet-visited
//!   entry provably has a larger priority than the best found, the walk
//!   stops.
//!
//! # The slack pruning invariant
//!
//! All entries keep `W ∈ [W_min, W_max]` (tracked by an ordered index on
//! `W`). Walking entries in ascending `C`, every unvisited entry has
//! `C ≥ C_cur`, hence — in real arithmetic —
//!
//! ```text
//! priority ≥ bound(C_cur) = (C_cur − now) / denom − 1,
//!            denom = W_max if C_cur ≥ now else W_min
//! ```
//!
//! and `bound` is non-decreasing in `C`, so the walk may stop at the
//! first entry whose bound (minus a floating-point guard margin that
//! dwarfs the few-ulp evaluation error; see `PRUNE_MARGIN`) strictly
//! exceeds the best priority found. Requests whose remaining work has
//! collapsed to the [`DONE_SLACK`](super::policy::DONE_SLACK) sentinel
//! sit in a dedicated min-`seq` set and win outright — their constant
//! priority is below anything the ratio can reach — so the bound never
//! has to reason about them. The walk is worst-case O(n) but terminates
//! after a handful of entries on real backlogs (deep queues share `W`
//! classes, and the most-overdue small-`W` entries come first in `C`
//! order); the `sched/select` bench records the measured win.
//!
//! Urgency counters ride on the same `C` order: entries migrate one-way
//! from a fresh set to an urgent set as `now` passes their critical time
//! (amortized O(log n) per request, O(1) to read), giving the router's
//! `GroupView::more_urgent_queued` without rescanning backlogs.

use std::collections::{BTreeSet, VecDeque};

use super::arena::{RequestArena, Slot};
use super::policy::{slack_is_done, KeyShape, SchedPolicy};
use crate::util::slotvec::SlotVec;

/// Map an `f64` to a `u64` whose unsigned order equals `f64::total_cmp`
/// order (sign-magnitude → biased two's-complement trick).
#[inline]
pub fn key_bits(x: f64) -> u64 {
    let b = x.to_bits();
    if b & (1u64 << 63) != 0 {
        !b
    } else {
        b | (1u64 << 63)
    }
}

/// Inverse of [`key_bits`].
#[inline]
pub fn bits_key(b: u64) -> f64 {
    let raw = if b & (1u64 << 63) != 0 {
        b & !(1u64 << 63)
    } else {
        !b
    };
    f64::from_bits(raw)
}

/// Relative guard subtracted from the pruning bound before it is allowed
/// to stop the slack walk: orders of magnitude above the few-ulp error of
/// evaluating the slack ratio, orders of magnitude below any urgency
/// difference the simulator can act on. Erring low only lengthens the
/// walk; it can never change the selected request.
const PRUNE_MARGIN: f64 = 1e-9;

#[derive(Debug, Clone, Copy)]
struct Entry {
    /// Enqueue order — the tie-break. Preserved across re-keys.
    seq: u64,
    /// `Static`: ordered bits of the policy's static key.
    key_bits: u64,
    /// Ordered bits of the policy's critical time (urgency split + the
    /// slack walk order).
    c_bits: u64,
    /// `Slack`, non-sentinel: ordered bits of the remaining work.
    r_bits: u64,
    /// Which side of the urgency split the entry is filed under.
    urgent: bool,
    /// `Slack`: remaining work at/below the `MIN_WORK_S` floor — priority
    /// is the constant `DONE_SLACK` sentinel.
    sentinel: bool,
}

/// See the module docs. One instance per scheduler (per KVP group).
#[derive(Debug, Default)]
pub struct ReadySet {
    shape: Option<KeyShape>,
    /// `Fifo` only: enqueue order, head is the selection.
    fifo: VecDeque<Slot>,
    /// `Static` only: `(static_key bits, seq, slot)`.
    by_key: BTreeSet<(u64, u64, Slot)>,
    /// Critical time split: `fresh` holds entries whose critical time is
    /// still ahead of the drained high-water `now`; `urgent` the rest.
    /// Every urgent `c_bits` ≤ every fresh `c_bits`, so chaining the two
    /// iterators walks the whole set in ascending critical time.
    urgent: BTreeSet<(u64, u64, Slot)>,
    fresh: BTreeSet<(u64, u64, Slot)>,
    /// `Slack`, non-sentinel entries: `(remaining-work bits, seq, slot)` —
    /// supplies the `[W_min, W_max]` pruning range.
    by_r: BTreeSet<(u64, u64, Slot)>,
    /// `Slack`, sentinel entries: `(seq, slot)` — all tied at
    /// `DONE_SLACK`, so the min-`seq` entry wins outright.
    done: BTreeSet<(u64, Slot)>,
    live: SlotVec<Entry>,
    next_seq: u64,
    /// High-water `key_bits(now)` the urgency split has been drained to.
    boundary: u64,
}

impl ReadySet {
    pub fn new(shape: KeyShape) -> ReadySet {
        ReadySet {
            shape: Some(shape),
            boundary: key_bits(f64::NEG_INFINITY),
            ..ReadySet::default()
        }
    }

    fn shape(&self) -> KeyShape {
        self.shape.expect("ReadySet::new not used")
    }

    pub fn len(&self) -> usize {
        match self.shape() {
            KeyShape::Fifo => self.fifo.len(),
            _ => self.live.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Queued slots. FIFO order under `Fifo`; slot order otherwise (the
    /// set is an index, not a queue — callers needing priority order use
    /// [`Self::select`]).
    pub fn iter(&self) -> impl Iterator<Item = Slot> + '_ {
        self.fifo
            .iter()
            .copied()
            .chain(self.live.iter().map(|(i, _)| i as Slot))
    }

    /// Enqueue `s`, keying it from its current request state.
    pub fn push(&mut self, s: Slot, policy: &dyn SchedPolicy, requests: &RequestArena) {
        if self.shape() == KeyShape::Fifo {
            self.fifo.push_back(s);
            return;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        let e = self.make_entry(s, seq, policy, requests);
        let prev = self.live.insert(s as usize, e);
        debug_assert!(prev.is_none(), "slot {s} enqueued twice");
        self.insert_into_sets(s, e);
    }

    fn make_entry(
        &self,
        s: Slot,
        seq: u64,
        policy: &dyn SchedPolicy,
        requests: &RequestArena,
    ) -> Entry {
        let r = requests.get(s);
        let c_bits = key_bits(policy.critical_time(r));
        match self.shape() {
            KeyShape::Fifo => unreachable!("fifo entries are not keyed"),
            KeyShape::Static => Entry {
                seq,
                key_bits: key_bits(policy.static_key(r)),
                c_bits,
                r_bits: 0,
                urgent: c_bits <= self.boundary,
                sentinel: false,
            },
            KeyShape::Slack => {
                let (c, w) = policy.slack_parts(r);
                debug_assert_eq!(key_bits(c), c_bits, "critical_time != slack critical");
                Entry {
                    seq,
                    key_bits: 0,
                    c_bits,
                    r_bits: key_bits(w),
                    urgent: c_bits <= self.boundary,
                    sentinel: slack_is_done(c, w),
                }
            }
        }
    }

    fn insert_into_sets(&mut self, s: Slot, e: Entry) {
        let c_entry = (e.c_bits, e.seq, s);
        if e.urgent {
            self.urgent.insert(c_entry);
        } else {
            self.fresh.insert(c_entry);
        }
        match self.shape() {
            KeyShape::Fifo => unreachable!(),
            KeyShape::Static => {
                self.by_key.insert((e.key_bits, e.seq, s));
            }
            KeyShape::Slack => {
                if e.sentinel {
                    self.done.insert((e.seq, s));
                } else {
                    self.by_r.insert((e.r_bits, e.seq, s));
                }
            }
        }
    }

    fn remove_from_sets(&mut self, s: Slot, e: Entry) {
        let c_entry = (e.c_bits, e.seq, s);
        let hit = if e.urgent {
            self.urgent.remove(&c_entry)
        } else {
            self.fresh.remove(&c_entry)
        };
        debug_assert!(hit, "slot {s} missing from its urgency set");
        match self.shape() {
            KeyShape::Fifo => unreachable!(),
            KeyShape::Static => {
                self.by_key.remove(&(e.key_bits, e.seq, s));
            }
            KeyShape::Slack => {
                if e.sentinel {
                    self.done.remove(&(e.seq, s));
                } else {
                    self.by_r.remove(&(e.r_bits, e.seq, s));
                }
            }
        }
    }

    /// Drop `s` from the set (it finished its prefill or was retired).
    pub fn remove(&mut self, s: Slot) {
        if self.shape() == KeyShape::Fifo {
            // The departing request is the head in every legal schedule;
            // the positional fallback keeps arbitrary removal correct.
            match self.fifo.front() {
                Some(&head) if head == s => {
                    self.fifo.pop_front();
                }
                _ => {
                    if let Some(pos) = self.fifo.iter().position(|&x| x == s) {
                        self.fifo.remove(pos);
                    }
                }
            }
            return;
        }
        if let Some(e) = self.live.remove(s as usize) {
            self.remove_from_sets(s, e);
        }
    }

    /// Refresh `s`'s keys after its own state changed (a chunk of its
    /// prefill completed). Its enqueue order — the tie-break — survives.
    pub fn rekey(&mut self, s: Slot, policy: &dyn SchedPolicy, requests: &RequestArena) {
        if self.shape() == KeyShape::Fifo {
            return;
        }
        let old = *self.live.get(s as usize).expect("rekey of unqueued slot");
        let mut new = self.make_entry(s, old.seq, policy, requests);
        // Critical time is invariant; the urgency filing must survive the
        // re-key rather than being re-derived from the drain boundary.
        debug_assert_eq!(new.c_bits, old.c_bits, "critical time drifted on rekey");
        new.urgent = old.urgent;
        if new.key_bits == old.key_bits
            && new.r_bits == old.r_bits
            && new.sentinel == old.sentinel
        {
            return;
        }
        self.remove_from_sets(s, old);
        self.live.insert(s as usize, new);
        self.insert_into_sets(s, new);
    }

    /// Migrate entries whose critical time `now` has passed into the
    /// urgent set. One-way and monotone in the high-water `now`: each
    /// entry crosses at most once (amortized O(log n) per request).
    fn drain_urgent(&mut self, now: f64) {
        let nb = key_bits(now);
        if nb > self.boundary {
            self.boundary = nb;
        }
        while let Some(&entry) = self.fresh.first() {
            if entry.0 > self.boundary {
                break;
            }
            self.fresh.remove(&entry);
            self.urgent.insert(entry);
            if let Some(e) = self.live.get_mut(entry.2 as usize) {
                e.urgent = true;
            }
        }
    }

    /// Queued requests whose critical time has passed — the O(1)-read
    /// urgency counter behind `GroupView::more_urgent_queued`.
    pub fn n_urgent(&mut self, now: f64) -> usize {
        if self.shape() == KeyShape::Fifo {
            return 0;
        }
        self.drain_urgent(now);
        self.urgent.len()
    }

    /// The selected request under the canonical rule — argmin of
    /// `(priority(r, now), seq)` — served by the shape's index (see the
    /// module docs). Bit-identical to [`Self::select_via_scan`].
    pub fn select(
        &self,
        policy: &dyn SchedPolicy,
        requests: &RequestArena,
        now: f64,
    ) -> Option<Slot> {
        match self.shape() {
            KeyShape::Fifo => self.fifo.front().copied(),
            KeyShape::Static => self.by_key.first().map(|&(_, _, s)| s),
            KeyShape::Slack => {
                if let Some(&(_, s)) = self.done.first() {
                    // Sentinel priorities are a constant below anything the
                    // ratio form can produce: the earliest-enqueued wins.
                    return Some(s);
                }
                self.select_slack(policy, requests, now)
            }
        }
    }

    /// The pruned ascending-critical-time walk (module docs). `done` is
    /// empty here, so every entry is in `by_r` and the bound applies.
    fn select_slack(
        &self,
        policy: &dyn SchedPolicy,
        requests: &RequestArena,
        now: f64,
    ) -> Option<Slot> {
        let (w_min, w_max) = match (self.by_r.first(), self.by_r.last()) {
            (Some(&(lo, _, _)), Some(&(hi, _, _))) => (bits_key(lo), bits_key(hi)),
            _ => return None, // no entries at all
        };
        let mut best: Option<(f64, u64, Slot)> = None;
        for &(c_bits, seq, slot) in self.urgent.iter().chain(self.fresh.iter()) {
            if let Some((best_p, _, _)) = best {
                let diff = bits_key(c_bits) - now;
                let denom = if diff >= 0.0 { w_max } else { w_min };
                let bound = diff / denom - 1.0;
                let cutoff = if bound.is_finite() {
                    bound - PRUNE_MARGIN * (bound.abs() + 1.0)
                } else {
                    bound
                };
                if cutoff > best_p {
                    break;
                }
            }
            let p = policy.priority(requests.get(slot), now);
            let better = match &best {
                None => true,
                Some((best_p, best_seq, _)) => match p.total_cmp(best_p) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Equal => seq < *best_seq,
                    std::cmp::Ordering::Greater => false,
                },
            };
            if better {
                best = Some((p, seq, slot));
            }
        }
        best.map(|(_, _, s)| s)
    }

    /// The naive O(n) realization of the selection rule — the oracle the
    /// indexes are differentially tested against (and the baseline the
    /// `sched/select` bench measures the indexes' win over). Under `Fifo`
    /// selection is the head by definition (FCFS never scans).
    pub fn select_via_scan(
        &self,
        policy: &dyn SchedPolicy,
        requests: &RequestArena,
        now: f64,
    ) -> Option<Slot> {
        if self.shape() == KeyShape::Fifo {
            return self.fifo.front().copied();
        }
        let mut best: Option<(f64, u64, Slot)> = None;
        for (i, e) in self.live.iter() {
            let slot = i as Slot;
            let p = policy.priority(requests.get(slot), now);
            let better = match &best {
                None => true,
                Some((best_p, best_seq, _)) => match p.total_cmp(best_p) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Equal => e.seq < *best_seq,
                    std::cmp::Ordering::Greater => false,
                },
            };
            if better {
                best = Some((p, e.seq, slot));
            }
        }
        best.map(|(_, _, s)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{Edf, Fcfs, Lars, Srpt};
    use crate::coordinator::request::Request;
    use crate::util::proptest::check;

    #[test]
    fn key_bits_realizes_total_cmp_order() {
        let xs = [
            f64::NEG_INFINITY,
            -1e300,
            -1.5,
            -1e-300,
            -0.0,
            0.0,
            1e-300,
            1.5,
            1e300,
            f64::INFINITY,
            f64::NAN,
        ];
        for &a in &xs {
            assert_eq!(
                bits_key(key_bits(a)).to_bits(),
                a.to_bits(),
                "roundtrip of {a}"
            );
            for &b in &xs {
                assert_eq!(
                    key_bits(a).cmp(&key_bits(b)),
                    a.total_cmp(&b),
                    "order of {a} vs {b}"
                );
            }
        }
    }

    fn req(id: u64, prompt: u64, arrival: f64, est: f64, budget: f64) -> Request {
        Request::new(id, prompt, 4, arrival).with_slo(est, arrival + budget)
    }

    #[test]
    fn fifo_shape_is_a_plain_queue() {
        let mut arena = RequestArena::new();
        let mut rs = ReadySet::new(KeyShape::Fifo);
        let a = arena.insert(req(1, 100, 5.0, 0.1, 1.0));
        let b = arena.insert(req(2, 100, 0.0, 0.1, 1.0)); // earlier arrival
        rs.push(a, &Fcfs, &arena);
        rs.push(b, &Fcfs, &arena);
        // enqueue order wins regardless of keys; no urgency tracking
        assert_eq!(rs.select(&Fcfs, &arena, 10.0), Some(a));
        assert_eq!(rs.n_urgent(10.0), 0);
        assert_eq!(rs.len(), 2);
        rs.remove(a);
        assert_eq!(rs.select(&Fcfs, &arena, 10.0), Some(b));
        rs.remove(b);
        assert!(rs.is_empty());
    }

    #[test]
    fn static_index_selects_min_key_with_seq_ties() {
        let mut arena = RequestArena::new();
        let mut rs = ReadySet::new(KeyShape::Static);
        let big = arena.insert(req(1, 1_000_000, 0.0, 60.0, 300.0));
        let small_late = arena.insert(req(2, 100, 0.0, 0.1, 1.0));
        let small_tie = arena.insert(req(3, 100, 0.0, 0.1, 1.0)); // same key, later seq
        for s in [big, small_late, small_tie] {
            rs.push(s, &Srpt, &arena);
        }
        assert_eq!(rs.select(&Srpt, &arena, 0.0), Some(small_late));
        assert_eq!(
            rs.select(&Srpt, &arena, 0.0),
            rs.select_via_scan(&Srpt, &arena, 0.0)
        );
        // progress the winner's prefill past the loser: selection follows
        arena.get_mut(small_late).complete_chunk(50, 0.1);
        rs.rekey(small_late, &Srpt, &arena);
        assert_eq!(rs.select(&Srpt, &arena, 0.5), Some(small_late));
        assert_eq!(
            rs.select(&Srpt, &arena, 0.5),
            rs.select_via_scan(&Srpt, &arena, 0.5)
        );
    }

    #[test]
    fn slack_walk_matches_scan_on_mixed_backlog() {
        let lars = Lars::default();
        let mut arena = RequestArena::new();
        let mut rs = ReadySet::new(KeyShape::Slack);
        // deeply overdue document, mildly overdue short, fresh short
        let doc = arena.insert(req(1, 1_000_000, 0.0, 60.0, 300.0));
        let overdue_short = arena.insert(req(2, 100, 10.0, 0.1, 0.5));
        let fresh_short = arena.insert(req(3, 100, 11.9, 0.1, 0.5));
        for s in [doc, overdue_short, fresh_short] {
            rs.push(s, &lars, &arena);
        }
        for now in [0.0, 5.0, 12.0, 200.0, 400.0] {
            assert_eq!(
                rs.select(&lars, &arena, now),
                rs.select_via_scan(&lars, &arena, now),
                "now={now}"
            );
        }
        assert_eq!(rs.select(&lars, &arena, 12.0), Some(overdue_short));
    }

    #[test]
    fn slack_sentinels_win_by_enqueue_order() {
        let lars = Lars::default();
        let mut arena = RequestArena::new();
        let mut rs = ReadySet::new(KeyShape::Slack);
        let urgent = arena.insert(req(1, 100, 0.0, 0.1, 0.2));
        rs.push(urgent, &lars, &arena);
        // two requests whose remaining work collapses below the floor
        let mut done_reqs = Vec::new();
        for id in [2, 3] {
            let s = arena.insert(req(id, 1_000_000, 0.0, 1e-4, 100.0));
            rs.push(s, &lars, &arena);
            arena.get_mut(s).complete_chunk(999_999, 0.5);
            rs.rekey(s, &lars, &arena);
            done_reqs.push(s);
        }
        // earliest-enqueued sentinel beats even a deeply overdue request
        assert_eq!(rs.select(&lars, &arena, 1_000.0), Some(done_reqs[0]));
        assert_eq!(
            rs.select(&lars, &arena, 1_000.0),
            rs.select_via_scan(&lars, &arena, 1_000.0)
        );
        rs.remove(done_reqs[0]);
        assert_eq!(rs.select(&lars, &arena, 1_000.0), Some(done_reqs[1]));
        rs.remove(done_reqs[1]);
        assert_eq!(rs.select(&lars, &arena, 1_000.0), Some(urgent));
    }

    #[test]
    fn urgency_counter_migrates_one_way_with_now() {
        let mut arena = RequestArena::new();
        let mut rs = ReadySet::new(KeyShape::Static);
        // deadlines at 1.0, 2.0, 3.0
        let slots: Vec<Slot> = (0..3)
            .map(|i| {
                let s = arena.insert(req(i, 100, 0.0, 0.1, 1.0 + i as f64));
                rs.push(s, &Edf, &arena);
                s
            })
            .collect();
        assert_eq!(rs.n_urgent(0.5), 0);
        assert_eq!(rs.n_urgent(1.0), 1); // critical time inclusive
        assert_eq!(rs.n_urgent(2.5), 2);
        // removal keeps the split consistent
        rs.remove(slots[0]);
        assert_eq!(rs.n_urgent(2.5), 1);
        // a request pushed already-overdue files straight into urgent
        let late = arena.insert(req(9, 100, 0.0, 0.1, 2.0));
        rs.push(late, &Edf, &arena);
        assert_eq!(rs.n_urgent(2.5), 2);
        assert_eq!(rs.n_urgent(10.0), 3);
    }

    /// Randomized per-structure differential: every mutation pattern the
    /// scheduler can produce (push, chunk-progress re-key, remove), with
    /// selection checked against the scan at every step. The heavyweight
    /// cross-policy lifecycle version lives in `tests/invariants.rs`.
    #[test]
    fn prop_index_matches_scan_under_churn() {
        check("readyset index ≡ scan", 120, |rng| {
            let policies: [(KeyShape, Box<dyn SchedPolicy>); 3] = [
                (KeyShape::Static, Box::new(Srpt)),
                (KeyShape::Static, Box::new(Edf)),
                (KeyShape::Slack, Box::new(Lars::default())),
            ];
            let (shape, policy) = &policies[rng.below(3) as usize];
            let policy = policy.as_ref();
            let mut arena = RequestArena::new();
            let mut rs = ReadySet::new(*shape);
            let mut live: Vec<Slot> = Vec::new();
            let mut now = 0.0;
            for id in 0..rng.range_u64(2, 60) {
                now += rng.range_f64(0.0, 2.0);
                match rng.below(10) {
                    0..=5 => {
                        let prompt = rng.range_u64(1, 200_000);
                        let est = rng.range_f64(1e-7, 50.0);
                        let budget = rng.range_f64(0.01, 20.0);
                        let s = arena.insert(req(id, prompt, now, est, budget));
                        rs.push(s, policy, &arena);
                        live.push(s);
                    }
                    6..=7 if !live.is_empty() => {
                        // progress a random request's prefill one chunk,
                        // keeping it queued (mirror of a preempted prefill)
                        let s = live[rng.below(live.len() as u64) as usize];
                        let rem = arena.get(s).remaining_prefill();
                        if rem > 1 {
                            let c = rng.range_u64(1, rem - 1);
                            arena.get_mut(s).complete_chunk(c, now);
                            rs.rekey(s, policy, &arena);
                        }
                    }
                    _ if !live.is_empty() => {
                        let i = rng.below(live.len() as u64) as usize;
                        let s = live.swap_remove(i);
                        rs.remove(s);
                        arena.remove(s);
                    }
                    _ => {}
                }
                assert_eq!(
                    rs.select(policy, &arena, now),
                    rs.select_via_scan(policy, &arena, now),
                    "{} diverged at now={now}",
                    policy.name()
                );
                assert_eq!(rs.len(), live.len());
                let urgent = rs.n_urgent(now);
                assert!(urgent <= live.len());
            }
        });
    }
}
