//! Hash-consed, ref-counted prefix block chains: the deterministic prefix
//! index behind cross-request KV reuse.
//!
//! A request's KV prefix is modeled as a chain of fixed-size block nodes.
//! Each node is identified by the **chain hash** of its path from the root
//! (a stable SplitMix64-style mix over per-block content keys), so two
//! requests whose token streams share a prefix resolve to the *same* nodes
//! — hash-consing. Content keys come from `(stream namespace, block
//! index)`: a multi-turn session's turns share a namespace (so turn t+1's
//! prompt extends turn t's chain), and the leading system-prompt span uses
//! a global namespace (so *every* session shares the system-prefix nodes).
//!
//! Lifecycle is explicit reference counting:
//!
//! * a request that is granted reuse `acquire`s the deepest matched node
//!   for its lifetime and `release`s it exactly once at finish/abort;
//! * interior nodes are pinned structurally by their child count;
//! * a node with zero holders and zero children is *evictable*: it enters
//!   an LRU keyed by a monotone sim-sequence number (no wall clock), and
//!   [`PrefixIndex::evict_over_capacity`] trims oldest-first until the
//!   global block budget is met, collapsing chains leaf-first;
//! * chains are **single-group**: a chain's blocks physically live on the
//!   worker group that computed them, so extension is only allowed by that
//!   group (a foreign group recomputes and simply does not index). A group
//!   crash drops every chain it owns via [`PrefixIndex::drop_group`].
//!
//! Determinism contract: ordered maps only (`BTreeMap` keyed by the stable
//! chain hash / LRU sequence), no wall clock, no float comparisons — the
//! index is replayable state and is covered by `medha lint` D1/D2.

use std::collections::BTreeMap;

use super::GroupId;

/// Namespace for the globally shared system-prompt span. Session stream
/// namespaces are `1..`; `0` means "does not participate in reuse".
pub const SYS_STREAM: u64 = u64::MAX;

/// Stable 64-bit mix (SplitMix64 finalizer over `a ^ f(b)`); the basis of
/// both content keys and chain hashes. Pure integer arithmetic: identical
/// on every platform and run.
fn mix2(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

const CHAIN_SEED: u64 = 0x6d65_6468_615f_6b76; // "medha_kv"

/// Content key for block `i` of a stream: the leading blocks that lie
/// entirely inside the shared system prompt key off the global
/// [`SYS_STREAM`] namespace, the rest off the session stream.
fn block_key(ns: u64, sys_tokens: u64, block_tokens: u64, i: u64) -> u64 {
    if (i + 1) * block_tokens <= sys_tokens {
        mix2(SYS_STREAM, i)
    } else {
        mix2(ns, i)
    }
}

/// Handle to a chain node. Carries the slot generation so a stale handle
/// (node evicted or dropped with a crash) can never alias a recycled slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeRef {
    idx: u32,
    gen: u32,
}

/// Result of a prefix lookup: the deepest matched node, the token span it
/// covers, and the group whose KV pool physically holds those blocks.
#[derive(Debug, Clone, Copy)]
pub struct PrefixHit {
    pub node: NodeRef,
    pub tokens: u64,
    pub group: GroupId,
}

/// What an insert changed: blocks newly indexed (charged to the owning
/// group's shared ledger by the caller).
#[derive(Debug, Clone, Copy, Default)]
pub struct InsertOutcome {
    pub new_blocks: u64,
}

#[derive(Debug, Clone)]
struct Node {
    parent: Option<u32>,
    key: u64,
    hash: u64,
    /// Blocks on the path from the root through this node (inclusive).
    depth: u32,
    group: GroupId,
    holders: u32,
    children: u32,
    /// LRU stamp: the sequence at which this node last became evictable.
    last_use: u64,
    gen: u32,
    alive: bool,
}

/// The prefix index itself. One per fleet (chains name their owning group);
/// all state is in ordered containers keyed by stable integers.
#[derive(Debug, Clone)]
pub struct PrefixIndex {
    block_tokens: u64,
    capacity_blocks: u64,
    nodes: Vec<Node>,
    free: Vec<u32>,
    /// chain hash -> slot of the (unique) live node with that hash.
    by_hash: BTreeMap<u64, u32>,
    /// LRU of evictable nodes: last_use sequence -> slot. Sequences are
    /// globally unique, so the key never collides.
    evictable: BTreeMap<u64, u32>,
    seq: u64,
    total_blocks: u64,
}

impl PrefixIndex {
    pub fn new(block_tokens: u64, capacity_blocks: u64) -> PrefixIndex {
        assert!(block_tokens > 0, "prefix block size must be positive");
        PrefixIndex {
            block_tokens,
            capacity_blocks,
            nodes: Vec::new(),
            free: Vec::new(),
            by_hash: BTreeMap::new(),
            evictable: BTreeMap::new(),
            seq: 0,
            total_blocks: 0,
        }
    }

    pub fn block_tokens(&self) -> u64 {
        self.block_tokens
    }

    /// Live indexed blocks across all chains.
    pub fn total_blocks(&self) -> u64 {
        self.total_blocks
    }

    /// Nodes currently eligible for eviction (rc-0 leaves).
    pub fn evictable_len(&self) -> usize {
        self.evictable.len()
    }

    pub fn is_live(&self, r: NodeRef) -> bool {
        self.node(r).is_some()
    }

    fn node(&self, r: NodeRef) -> Option<&Node> {
        let n = self.nodes.get(r.idx as usize)?;
        (n.alive && n.gen == r.gen).then_some(n)
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Walk the chain for `(ns, sys_tokens)` as far as it matches and as
    /// far as full blocks fit strictly inside `prompt_len` (at least one
    /// token must remain to prefill, or the request could never produce
    /// its first output token). Returns the deepest match, if any.
    pub fn lookup(&self, ns: u64, sys_tokens: u64, prompt_len: u64) -> Option<PrefixHit> {
        if ns == 0 {
            return None;
        }
        let max_blocks = prompt_len.saturating_sub(1) / self.block_tokens;
        let mut hash = CHAIN_SEED;
        let mut prev: Option<u32> = None;
        let mut best: Option<u32> = None;
        for i in 0..max_blocks {
            let key = block_key(ns, sys_tokens, self.block_tokens, i);
            hash = mix2(hash, key);
            let Some(&idx) = self.by_hash.get(&hash) else {
                break;
            };
            let n = &self.nodes[idx as usize];
            // Collision guard: the stored node must really be this path.
            if !n.alive || n.key != key || n.parent != prev {
                break;
            }
            prev = Some(idx);
            best = Some(idx);
        }
        best.map(|idx| {
            let n = &self.nodes[idx as usize];
            PrefixHit {
                node: NodeRef { idx, gen: n.gen },
                tokens: n.depth as u64 * self.block_tokens,
                group: n.group,
            }
        })
    }

    /// Pin a node for a request's lifetime. Must be paired with exactly
    /// one [`release`](Self::release) (the refcount-lifecycle tests assert
    /// no leak and no double-free).
    pub fn acquire(&mut self, r: NodeRef) {
        let n = self.node(r).expect("acquire on a dead prefix node");
        let (holders, children, last_use) = (n.holders, n.children, n.last_use);
        if holders == 0 && children == 0 {
            self.evictable.remove(&last_use);
        }
        self.nodes[r.idx as usize].holders = holders + 1;
    }

    /// Unpin a node; when the last holder of a leaf leaves, the node
    /// becomes evictable with a fresh LRU stamp.
    pub fn release(&mut self, r: NodeRef) {
        let n = self.node(r).expect("release on a dead prefix node");
        assert!(n.holders > 0, "double release of a prefix node");
        let idx = r.idx as usize;
        self.nodes[idx].holders -= 1;
        if self.nodes[idx].holders == 0 && self.nodes[idx].children == 0 {
            let stamp = self.next_seq();
            self.nodes[idx].last_use = stamp;
            self.evictable.insert(stamp, r.idx);
        }
    }

    /// Index the first `tokens / block_tokens` blocks of a finished
    /// request's KV as a chain owned by `group`. Extends the existing
    /// chain where it matches; a chain whose deepest existing node lives
    /// on a *different* group is left untouched (its blocks are not on
    /// `group`, and overwriting the hash entries would alias KV across
    /// groups). Returns how many blocks were newly indexed.
    pub fn insert(
        &mut self,
        ns: u64,
        sys_tokens: u64,
        tokens: u64,
        group: GroupId,
    ) -> InsertOutcome {
        if ns == 0 {
            return InsertOutcome::default();
        }
        let target = tokens / self.block_tokens;
        let mut hash = CHAIN_SEED;
        let mut prev: Option<u32> = None;
        let mut depth = 0u64;
        // Phase 1: follow the existing chain.
        while depth < target {
            let key = block_key(ns, sys_tokens, self.block_tokens, depth);
            let h = mix2(hash, key);
            match self.by_hash.get(&h) {
                Some(&idx) => {
                    let n = &self.nodes[idx as usize];
                    if !n.alive || n.key != key || n.parent != prev {
                        // Hash collision with a different path: refuse to
                        // overwrite — deterministic no-op from here down.
                        return InsertOutcome::default();
                    }
                    prev = Some(idx);
                    hash = h;
                    depth += 1;
                }
                None => break,
            }
        }
        if depth == target {
            return InsertOutcome::default();
        }
        // Single-group chains: only the owning group may extend.
        if let Some(p) = prev {
            if self.nodes[p as usize].group != group {
                return InsertOutcome::default();
            }
        }
        // Phase 2: append new nodes for the unindexed blocks.
        let mut new_blocks = 0u64;
        while depth < target {
            let key = block_key(ns, sys_tokens, self.block_tokens, depth);
            hash = mix2(hash, key);
            // Unpin the parent from the LRU: it gains a child.
            if let Some(p) = prev {
                let pn = &self.nodes[p as usize];
                if pn.holders == 0 && pn.children == 0 {
                    self.evictable.remove(&pn.last_use);
                }
                self.nodes[p as usize].children += 1;
            }
            let node = Node {
                parent: prev,
                key,
                hash,
                depth: (depth + 1) as u32,
                group,
                holders: 0,
                children: 0,
                last_use: 0,
                gen: 0,
                alive: true,
            };
            let idx = match self.free.pop() {
                Some(i) => {
                    let gen = self.nodes[i as usize].gen;
                    self.nodes[i as usize] = Node { gen, ..node };
                    i
                }
                None => {
                    self.nodes.push(node);
                    (self.nodes.len() - 1) as u32
                }
            };
            self.by_hash.insert(hash, idx);
            prev = Some(idx);
            depth += 1;
            new_blocks += 1;
        }
        // The fresh leaf starts unheld: evictable with a fresh stamp.
        let leaf = prev.expect("depth < target implies at least one new node");
        let stamp = self.next_seq();
        self.nodes[leaf as usize].last_use = stamp;
        self.evictable.insert(stamp, leaf);
        self.total_blocks += new_blocks;
        InsertOutcome { new_blocks }
    }

    fn kill(&mut self, idx: u32) -> GroupId {
        let n = &self.nodes[idx as usize];
        debug_assert!(n.alive && n.holders == 0 && n.children == 0);
        let (hash, parent, group) = (n.hash, n.parent, n.group);
        if self.by_hash.get(&hash) == Some(&idx) {
            self.by_hash.remove(&hash);
        }
        let slot = &mut self.nodes[idx as usize];
        slot.alive = false;
        slot.gen = slot.gen.wrapping_add(1);
        self.free.push(idx);
        self.total_blocks -= 1;
        if let Some(p) = parent {
            let pn = &mut self.nodes[p as usize];
            pn.children -= 1;
            if pn.alive && pn.children == 0 && pn.holders == 0 {
                let stamp = self.next_seq();
                self.nodes[p as usize].last_use = stamp;
                self.evictable.insert(stamp, p);
            }
        }
        group
    }

    /// Evict oldest rc-0 leaves until the index fits its block budget.
    /// Chains collapse leaf-first (a parent becomes evictable only once
    /// its last child is gone). Returns blocks freed per group, in group
    /// order, for the caller to credit back to the shared KV ledger.
    pub fn evict_over_capacity(&mut self) -> Vec<(GroupId, u64)> {
        let mut freed: BTreeMap<GroupId, u64> = BTreeMap::new();
        while self.total_blocks > self.capacity_blocks {
            let Some((&stamp, &idx)) = self.evictable.iter().next() else {
                break; // everything left is pinned
            };
            self.evictable.remove(&stamp);
            let g = self.kill(idx);
            *freed.entry(g).or_insert(0) += 1;
        }
        freed.into_iter().collect()
    }

    /// A group crashed: drop every chain it owns (the blocks are gone with
    /// its KV pool). Holders of dropped nodes are necessarily requests
    /// placed on that group — the caller rewinds them and meters the
    /// re-prefill of the shared span. Returns the blocks dropped.
    pub fn drop_group(&mut self, g: GroupId) -> u64 {
        let mut dropped = 0u64;
        for idx in 0..self.nodes.len() as u32 {
            let n = &self.nodes[idx as usize];
            if !n.alive || n.group != g {
                continue;
            }
            let (hash, last_use, holders, children) = (n.hash, n.last_use, n.holders, n.children);
            if holders == 0 && children == 0 {
                self.evictable.remove(&last_use);
            }
            if self.by_hash.get(&hash) == Some(&idx) {
                self.by_hash.remove(&hash);
            }
            let slot = &mut self.nodes[idx as usize];
            slot.alive = false;
            slot.gen = slot.gen.wrapping_add(1);
            slot.holders = 0;
            slot.children = 0;
            self.free.push(idx);
            dropped += 1;
        }
        // Parents are always in the same chain (single-group), so no
        // cross-group child counts need repair.
        self.total_blocks -= dropped;
        dropped
    }

    /// Test/debug invariant: every live node's refcounts are consistent
    /// with the tree and the LRU contains exactly the rc-0 leaves.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut child_counts: BTreeMap<u32, u32> = BTreeMap::new();
        let mut live = 0u64;
        for (i, n) in self.nodes.iter().enumerate() {
            if !n.alive {
                continue;
            }
            live += 1;
            if let Some(p) = n.parent {
                let pn = &self.nodes[p as usize];
                if !pn.alive || pn.group != n.group {
                    return Err(format!("node {i}: dangling or cross-group parent {p}"));
                }
                *child_counts.entry(p).or_insert(0) += 1;
            }
            if self.by_hash.get(&n.hash) != Some(&(i as u32)) {
                return Err(format!("node {i}: not indexed by its hash"));
            }
        }
        if live != self.total_blocks {
            return Err(format!("live {live} != total_blocks {}", self.total_blocks));
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if !n.alive {
                continue;
            }
            let actual = child_counts.get(&(i as u32)).copied().unwrap_or(0);
            if n.children != actual {
                return Err(format!("node {i}: children {} != actual {actual}", n.children));
            }
            let evictable = n.holders == 0 && n.children == 0;
            let in_lru = self.evictable.get(&n.last_use) == Some(&(i as u32));
            if evictable != in_lru {
                return Err(format!("node {i}: evictable={evictable} but in_lru={in_lru}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const B: u64 = 256;

    fn idx(cap: u64) -> PrefixIndex {
        PrefixIndex::new(B, cap)
    }

    #[test]
    fn lookup_misses_on_empty_and_on_ns_zero() {
        let p = idx(1024);
        assert!(p.lookup(1, 0, 10 * B).is_none());
        assert!(p.lookup(0, 0, 10 * B).is_none());
    }

    #[test]
    fn insert_then_lookup_hits_full_blocks_only() {
        let mut p = idx(1024);
        let out = p.insert(1, 0, 10 * B + 17, 0);
        assert_eq!(out.new_blocks, 10);
        assert_eq!(p.total_blocks(), 10);
        p.check_invariants().unwrap();
        // a same-stream longer prompt hits the whole chain
        let hit = p.lookup(1, 0, 20 * B).unwrap();
        assert_eq!(hit.tokens, 10 * B);
        assert_eq!(hit.group, 0);
        // a prompt of exactly 10 blocks must keep one token to prefill
        let hit = p.lookup(1, 0, 10 * B).unwrap();
        assert_eq!(hit.tokens, 9 * B);
        // different stream: no hit
        assert!(p.lookup(2, 0, 20 * B).is_none());
    }

    #[test]
    fn sys_prefix_is_shared_across_streams() {
        let mut p = idx(1024);
        // stream 1 indexes sys (4 blocks) + 4 private blocks
        p.insert(1, 4 * B, 8 * B, 0);
        // stream 2 shares only the sys span
        let hit = p.lookup(2, 4 * B, 8 * B).unwrap();
        assert_eq!(hit.tokens, 4 * B);
        // extending stream 2 reuses the sys nodes: only 4 new blocks
        let out = p.insert(2, 4 * B, 8 * B, 0);
        assert_eq!(out.new_blocks, 4);
        assert_eq!(p.total_blocks(), 12);
        p.check_invariants().unwrap();
    }

    #[test]
    fn refcount_lifecycle_no_leak() {
        let mut p = idx(1024);
        p.insert(1, 0, 4 * B, 0);
        let hit = p.lookup(1, 0, 100 * B).unwrap();
        p.acquire(hit.node);
        // held leaf is not evictable
        assert_eq!(p.evictable_len(), 0);
        p.check_invariants().unwrap();
        p.release(hit.node);
        assert_eq!(p.evictable_len(), 1);
        p.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut p = idx(1024);
        p.insert(1, 0, 2 * B, 0);
        let hit = p.lookup(1, 0, 100 * B).unwrap();
        p.acquire(hit.node);
        p.release(hit.node);
        p.release(hit.node);
    }

    #[test]
    fn eviction_is_lru_and_collapses_leaf_first() {
        let mut p = idx(u64::MAX);
        p.insert(1, 0, 4 * B, 0); // chain A, older
        p.insert(2, 0, 2 * B, 0); // chain B, newer
        assert_eq!(p.total_blocks(), 6);
        // shrink the budget: only 3 blocks may stay
        p.capacity_blocks = 3;
        let freed = p.evict_over_capacity();
        assert_eq!(freed, vec![(0, 3)]);
        assert_eq!(p.total_blocks(), 3);
        p.check_invariants().unwrap();
        // chain A (older leaf) collapsed leaf-first down to 1 block;
        // chain B untouched
        assert_eq!(p.lookup(1, 0, 100 * B).unwrap().tokens, B);
        assert_eq!(p.lookup(2, 0, 100 * B).unwrap().tokens, 2 * B);
    }

    #[test]
    fn pinned_chains_survive_capacity_pressure() {
        let mut p = idx(u64::MAX);
        p.insert(1, 0, 4 * B, 0);
        let hit = p.lookup(1, 0, 100 * B).unwrap();
        p.acquire(hit.node);
        p.capacity_blocks = 0;
        let freed: u64 = p.evict_over_capacity().iter().map(|&(_, n)| n).sum();
        // the held leaf pins the whole chain
        assert_eq!(freed, 0);
        assert_eq!(p.total_blocks(), 4);
        p.release(hit.node);
        let freed: u64 = p.evict_over_capacity().iter().map(|&(_, n)| n).sum();
        assert_eq!(freed, 4);
        assert_eq!(p.total_blocks(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn foreign_group_does_not_extend_a_chain() {
        let mut p = idx(1024);
        p.insert(1, 0, 4 * B, 0);
        // group 1 recomputed the same stream deeper: must not index
        let out = p.insert(1, 0, 8 * B, 1);
        assert_eq!(out.new_blocks, 0);
        assert_eq!(p.total_blocks(), 4);
        assert_eq!(p.lookup(1, 0, 100 * B).unwrap().group, 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn drop_group_removes_chains_and_invalidates_handles() {
        let mut p = idx(1024);
        p.insert(1, 0, 4 * B, 0);
        p.insert(2, 0, 3 * B, 1);
        let hit = p.lookup(1, 0, 100 * B).unwrap();
        p.acquire(hit.node);
        assert_eq!(p.drop_group(0), 4);
        assert!(!p.is_live(hit.node));
        assert!(p.lookup(1, 0, 100 * B).is_none());
        // group 1's chain is untouched
        assert_eq!(p.lookup(2, 0, 100 * B).unwrap().tokens, 3 * B);
        assert_eq!(p.total_blocks(), 3);
        p.check_invariants().unwrap();
    }

    #[test]
    fn reinsert_after_eviction_reuses_slots_safely() {
        let mut p = idx(u64::MAX);
        p.insert(1, 0, 2 * B, 0);
        let stale = p.lookup(1, 0, 100 * B).unwrap();
        p.capacity_blocks = 0;
        p.evict_over_capacity();
        p.capacity_blocks = u64::MAX;
        p.insert(2, 0, 2 * B, 0);
        // the stale handle's slot was recycled: generation protects it
        assert!(!p.is_live(stale.node));
        assert_eq!(p.lookup(2, 0, 100 * B).unwrap().tokens, 2 * B);
        p.check_invariants().unwrap();
    }
}
