//! Paged KV-cache management (vLLM-style block tables) with the paper's
//! platform optimizations modeled explicitly, plus cross-request prefix
//! reuse:
//!
//! * block allocator + per-request block tables ([`BlockPool`] /
//!   [`KvManager`]);
//! * **hash-consed prefix block chains** ([`prefix::PrefixIndex`]): a
//!   request's KV prefix is a chain of content-hashed block nodes shared
//!   across requests — multi-turn sessions extend their previous turn's
//!   chain, and every session shares the system-prompt span. Nodes are
//!   ref-counted (request holders + structural child pins), rc-0 leaves
//!   age out of an LRU keyed by a monotone sim-sequence (never wall
//!   clock), and chains are single-group so a group crash drops exactly
//!   the chains whose blocks died with its pool. The scheduler layers
//!   above subtract the matched span from prefill work estimates and
//!   route toward the chain's owner (cache affinity);
//! * **GPU-side page tables with delta updates** (section 5): the manager
//!   tracks how many table entries must be shipped to workers per iteration
//!   — full tables for the naive scheme, only the new blocks for Medha's —
//!   so the ablation bench can show the data-movement difference;
//! * KVP shard ownership: a long request's cache spans multiple worker
//!   groups along the sequence dimension (section 4.4, Fig. 10).
//!
//! Everything here is replayable state under the `medha lint` determinism
//! contract: ordered containers only, no wall-clock reads.

pub mod prefix;

pub use prefix::{InsertOutcome, NodeRef, PrefixHit, PrefixIndex};

use crate::util::slotvec::SlotVec;

pub type RequestId = u64;
pub type GroupId = u32;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum KvError {
    #[error("out of KV blocks: need {need}, free {free}")]
    OutOfBlocks { need: u64, free: u64 },
    #[error("unknown request {0}")]
    UnknownRequest(RequestId),
}

/// Block allocator for one worker group's KV pool.
#[derive(Debug, Clone)]
pub struct BlockPool {
    pub block_tokens: u64,
    pub total_blocks: u64,
    free_blocks: u64,
}

impl BlockPool {
    pub fn new(block_tokens: u64, total_blocks: u64) -> BlockPool {
        BlockPool {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
        }
    }

    /// Pool sized from per-worker HBM left after weights.
    pub fn for_capacity(block_tokens: u64, kv_capacity_bytes: u64, bytes_per_token: u64) -> BlockPool {
        let tokens = kv_capacity_bytes / bytes_per_token.max(1);
        BlockPool::new(block_tokens, tokens / block_tokens.max(1))
    }

    pub fn free_blocks(&self) -> u64 {
        self.free_blocks
    }

    pub fn used_blocks(&self) -> u64 {
        self.total_blocks - self.free_blocks
    }

    fn alloc(&mut self, n: u64) -> Result<(), KvError> {
        if n > self.free_blocks {
            return Err(KvError::OutOfBlocks {
                need: n,
                free: self.free_blocks,
            });
        }
        self.free_blocks -= n;
        Ok(())
    }

    fn release(&mut self, n: u64) {
        self.free_blocks += n;
        debug_assert!(self.free_blocks <= self.total_blocks);
    }
}

/// Per-request block table on one group.
#[derive(Debug, Clone, Default)]
struct BlockTable {
    blocks: u64,
    tokens: u64,
    /// Blocks appended since the last `take_delta` (the delta-update
    /// optimization ships only these to the GPU).
    dirty_blocks: u64,
}

/// KV-cache manager for a single worker group. Requests are expected to be
/// identified by dense ids (arena slots); block tables live in a flat
/// slot-indexed vector rather than a `BTreeMap`, so the per-iteration
/// append/ship accounting is pointer-chase-free.
#[derive(Debug, Clone)]
pub struct KvManager {
    pub pool: BlockPool,
    tables: SlotVec<BlockTable>,
    /// Cumulative page-table entries shipped to workers (delta scheme).
    pub delta_entries_shipped: u64,
    /// What the naive full-copy scheme would have shipped.
    pub full_entries_shipped: u64,
}

impl KvManager {
    pub fn new(pool: BlockPool) -> KvManager {
        KvManager {
            pool,
            tables: SlotVec::new(),
            delta_entries_shipped: 0,
            full_entries_shipped: 0,
        }
    }

    pub fn onboard(&mut self, id: RequestId) {
        // Ids index a dense vector: a sparse huge id would resize it to the
        // id's magnitude. Fail loudly instead of aborting on OOM.
        assert!(
            id < (1 << 28),
            "KvManager ids must be dense slot-like ids (got {id}); \
             map external request ids through a RequestArena slot first"
        );
        self.tables.get_or_insert_default(id as usize);
    }

    pub fn is_onboarded(&self, id: RequestId) -> bool {
        self.tables.contains(id as usize)
    }

    /// Append `tokens` of KV for request `id`, allocating blocks as needed.
    pub fn append(&mut self, id: RequestId, tokens: u64) -> Result<(), KvError> {
        let t = self
            .tables
            .get_mut(id as usize)
            .ok_or(KvError::UnknownRequest(id))?;
        let new_tokens = t.tokens + tokens;
        let need_blocks = new_tokens.div_ceil(self.pool.block_tokens);
        let extra = need_blocks.saturating_sub(t.blocks);
        if extra > 0 {
            self.pool.alloc(extra)?;
        }
        t.blocks = need_blocks;
        t.tokens = new_tokens;
        t.dirty_blocks += extra;
        Ok(())
    }

    pub fn tokens(&self, id: RequestId) -> u64 {
        self.tables.get(id as usize).map(|t| t.tokens).unwrap_or(0)
    }

    pub fn blocks(&self, id: RequestId) -> u64 {
        self.tables.get(id as usize).map(|t| t.blocks).unwrap_or(0)
    }

    /// Free a finished/preempted request's cache.
    pub fn release(&mut self, id: RequestId) -> Result<(), KvError> {
        let t = self
            .tables
            .remove(id as usize)
            .ok_or(KvError::UnknownRequest(id))?;
        self.pool.release(t.blocks);
        Ok(())
    }

    /// Account one iteration's page-table communication for the active
    /// requests: the delta scheme ships only dirty entries; the naive scheme
    /// re-ships every table every iteration (section 5).
    pub fn account_table_shipment(&mut self, active: &[RequestId]) {
        for &id in active {
            if let Some(t) = self.tables.get_mut(id as usize) {
                self.delta_entries_shipped += t.dirty_blocks;
                t.dirty_blocks = 0;
                self.full_entries_shipped += t.blocks;
            }
        }
    }

    /// Tokens of KV capacity still free.
    pub fn free_tokens(&self) -> u64 {
        self.pool.free_blocks() * self.pool.block_tokens
    }

    pub fn active_requests(&self) -> usize {
        self.tables.len()
    }
}

/// KVP shard map: which groups hold which sequence ranges of one request
/// (Fig. 10's dynamic growth is driven by `KvpManager` in the coordinator;
/// this records the resulting ownership).
#[derive(Debug, Clone, Default)]
pub struct ShardMap {
    /// (group, start_token, tokens) in sequence order.
    pub shards: Vec<(GroupId, u64, u64)>,
}

impl ShardMap {
    pub fn total_tokens(&self) -> u64 {
        self.shards.iter().map(|s| s.2).sum()
    }

    pub fn groups(&self) -> impl Iterator<Item = GroupId> + '_ {
        self.shards.iter().map(|s| s.0)
    }

    pub fn local_tokens(&self, g: GroupId) -> u64 {
        self.shards.iter().filter(|s| s.0 == g).map(|s| s.2).sum()
    }

    /// Append `tokens` to the last shard (owned by `g`), or start a new one.
    pub fn append(&mut self, g: GroupId, tokens: u64) {
        if let Some(last) = self.shards.last_mut() {
            if last.0 == g {
                last.2 += tokens;
                return;
            }
        }
        let start = self.total_tokens();
        self.shards.push((g, start, tokens));
    }

    /// Invariant: shards tile [0, total) contiguously in order.
    pub fn check_contiguous(&self) -> bool {
        let mut expect = 0;
        for &(_, start, tokens) in &self.shards {
            if start != expect {
                return false;
            }
            expect += tokens;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn mgr(blocks: u64) -> KvManager {
        KvManager::new(BlockPool::new(16, blocks))
    }

    #[test]
    fn append_allocates_blocks_lazily() {
        let mut m = mgr(100);
        m.onboard(1);
        m.append(1, 10).unwrap();
        assert_eq!(m.blocks(1), 1);
        m.append(1, 6).unwrap(); // exactly fills block 1
        assert_eq!(m.blocks(1), 1);
        m.append(1, 1).unwrap();
        assert_eq!(m.blocks(1), 2);
        assert_eq!(m.pool.used_blocks(), 2);
    }

    #[test]
    fn oom_is_reported_not_panicked() {
        let mut m = mgr(2);
        m.onboard(1);
        assert_eq!(
            m.append(1, 100),
            Err(KvError::OutOfBlocks { need: 7, free: 2 })
        );
    }

    #[test]
    fn release_returns_blocks() {
        let mut m = mgr(10);
        m.onboard(1);
        m.append(1, 100).unwrap();
        assert_eq!(m.pool.free_blocks(), 3);
        m.release(1).unwrap();
        assert_eq!(m.pool.free_blocks(), 10);
        assert_eq!(m.release(1), Err(KvError::UnknownRequest(1)));
    }

    #[test]
    fn delta_updates_ship_less_than_full_copies() {
        let mut m = mgr(1000);
        m.onboard(1);
        for _ in 0..50 {
            m.append(1, 16).unwrap(); // one block per iteration
            m.account_table_shipment(&[1]);
        }
        // delta: 1 entry/iter = 50; full: 1+2+...+50 = 1275
        assert_eq!(m.delta_entries_shipped, 50);
        assert_eq!(m.full_entries_shipped, 1275);
    }

    #[test]
    fn shard_map_contiguity() {
        let mut s = ShardMap::default();
        s.append(0, 100);
        s.append(0, 50);
        s.append(1, 75);
        assert_eq!(s.shards.len(), 2);
        assert_eq!(s.total_tokens(), 225);
        assert_eq!(s.local_tokens(0), 150);
        assert!(s.check_contiguous());
    }

    #[test]
    fn prop_blocks_never_leak() {
        check("kv blocks never leak", 200, |rng: &mut Rng| {
            let total = rng.range_u64(4, 64);
            let mut m = mgr(total);
            let mut live: Vec<u64> = Vec::new();
            for step in 0..rng.range_u64(1, 60) {
                match rng.below(3) {
                    0 => {
                        let id = step;
                        m.onboard(id);
                        live.push(id);
                    }
                    1 => {
                        if let Some(&id) = live.get(rng.below(live.len().max(1) as u64) as usize) {
                            let _ = m.append(id, rng.range_u64(1, 40)); // may OOM: fine
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let id = live.swap_remove(i);
                            m.release(id).unwrap();
                        }
                    }
                }
                // invariant: used == sum of table blocks
                let table_blocks: u64 = live.iter().map(|&id| m.blocks(id)).sum();
                assert_eq!(m.pool.used_blocks(), table_blocks);
            }
            for id in live {
                m.release(id).unwrap();
            }
            assert_eq!(m.pool.free_blocks(), total);
        });
    }

    #[test]
    fn prop_shard_append_keeps_contiguity() {
        check("shard map stays contiguous", 200, |rng: &mut Rng| {
            let mut s = ShardMap::default();
            for _ in 0..rng.range_u64(1, 30) {
                s.append(rng.below(4) as u32, rng.range_u64(1, 1000));
                assert!(s.check_contiguous());
            }
        });
    }
}
