//! Roofline execution-time model for one batch iteration on one worker
//! group (a TP group executing `layers` transformer layers).
//!
//! This is the Vidur-style runtime predictor the adaptive chunking policy
//! (section 4.2) queries, and the time source the cluster simulator charges
//! for every stage execution. Attention and linear phases are modeled as
//! separate roofline terms because their arithmetic intensities differ by
//! orders of magnitude in mixed batches.

use super::counts;
use crate::config::{HardwareConfig, ModelConfig, ParallelismConfig};

/// One prefill chunk's worth of work in a batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillWork {
    /// Chunk size (query tokens) processed this iteration.
    pub chunk: u64,
    /// KV length the chunk attends to, *including itself* (local to this
    /// worker group if the request is KVP-sharded).
    pub kv_len: u64,
}

/// One decode request's work in a batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeWork {
    /// KV length scanned (local shard length if KVP-sharded).
    pub kv_len: u64,
}

/// The shape of a mixed batch (section 2.4: chunked prefill piggybacked on
/// decodes).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BatchShape {
    pub prefills: Vec<PrefillWork>,
    pub decodes: Vec<DecodeWork>,
}

impl BatchShape {
    pub fn decode_only(ctxs: &[u64]) -> BatchShape {
        BatchShape {
            prefills: Vec::new(),
            decodes: ctxs.iter().map(|&kv_len| DecodeWork { kv_len }).collect(),
        }
    }

    pub fn prefill_only(chunk: u64, kv_len: u64) -> BatchShape {
        BatchShape {
            prefills: vec![PrefillWork { chunk, kv_len }],
            decodes: Vec::new(),
        }
    }

    pub fn tokens(&self) -> u64 {
        self.prefills.iter().map(|p| p.chunk).sum::<u64>() + self.decodes.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.prefills.is_empty() && self.decodes.is_empty()
    }

    /// Empty the shape, keeping the allocations for reuse (the simulator
    /// refills one scratch shape per group per iteration).
    pub fn clear(&mut self) {
        self.prefills.clear();
        self.decodes.clear();
    }

    /// Append all of `other`'s work items to this shape.
    pub fn extend_from(&mut self, other: &BatchShape) {
        self.prefills.extend_from_slice(&other.prefills);
        self.decodes.extend_from_slice(&other.decodes);
    }
}

/// Decomposed execution time for one iteration (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IterationTime {
    pub attn_s: f64,
    pub linear_s: f64,
    pub tp_comm_s: f64,
    pub overhead_s: f64,
}

impl IterationTime {
    pub fn total(&self) -> f64 {
        self.attn_s + self.linear_s + self.tp_comm_s + self.overhead_s
    }
}

/// The runtime predictor.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub model: ModelConfig,
    pub hw: HardwareConfig,
    pub parallel: ParallelismConfig,
}

impl PerfModel {
    pub fn new(model: ModelConfig, hw: HardwareConfig, parallel: ParallelismConfig) -> PerfModel {
        PerfModel {
            model,
            hw,
            parallel,
        }
    }

    /// Execution time of `batch` over `layers` consecutive layers on one TP
    /// group (i.e. one pipeline-stage execution).
    pub fn stage_time(&self, batch: &BatchShape, layers: u32) -> IterationTime {
        if batch.is_empty() {
            return IterationTime::default();
        }
        let m = &self.model;
        let tp = self.parallel.tp as f64;
        let flops = self.hw.sustained_flops();
        let bw = self.hw.sustained_bw();

        // --- attention phase (per layer): each item is its own kernel ---
        let mut attn_flops = 0.0;
        let mut attn_bytes = 0.0;
        for p in &batch.prefills {
            attn_flops += counts::attn_flops(m, p.chunk, p.kv_len);
            attn_bytes += counts::attn_read_bytes(m, p.kv_len);
        }
        for d in &batch.decodes {
            attn_flops += counts::attn_flops(m, 1, d.kv_len);
            attn_bytes += counts::attn_read_bytes(m, d.kv_len);
        }
        // TP shards heads: flops and KV bytes split across the group.
        // Each prefill chunk is its own kernel launch (tile/wave
        // quantization makes tiny chunks pay a fixed cost — Fig. 7's ~11%);
        // batched decodes share one launch.
        let n_attn_kernels =
            batch.prefills.len() as f64 + if batch.decodes.is_empty() { 0.0 } else { 1.0 };
        let attn_s_layer = (attn_flops / tp / flops).max(attn_bytes / tp / bw)
            + self.hw.attn_fixed_s * n_attn_kernels;

        // --- linear phase (per layer): all tokens batched through GEMMs ---
        let tokens = batch.tokens();
        let lin_flops = counts::linear_flops(m, tokens);
        // weights are read once per iteration regardless of batch size
        let lin_bytes = counts::weight_bytes_per_layer(m)
            + tokens as f64 * m.d_model as f64 * m.dtype_bytes as f64;
        let linear_s_layer = (lin_flops / tp / flops).max(lin_bytes / tp / bw);

        // --- TP collective (per layer): 2 all-reduces of activations ---
        let tp_comm_s_layer = if self.parallel.tp > 1 {
            let bytes = tokens as f64 * m.d_model as f64 * m.dtype_bytes as f64;
            let link = &self.hw.intra_node;
            2.0 * (2.0 * (tp - 1.0) / tp * bytes / link.bandwidth + link.latency_s)
        } else {
            0.0
        };

        let l = layers as f64;
        IterationTime {
            attn_s: attn_s_layer * l,
            linear_s: linear_s_layer * l,
            tp_comm_s: tp_comm_s_layer * l,
            overhead_s: self.hw.cpu_overhead_s,
        }
    }

    /// Full-model iteration time (all layers on one group; spp == 1 view).
    pub fn iteration_time(&self, batch: &BatchShape) -> IterationTime {
        self.stage_time(batch, self.model.n_layers)
    }

    /// Pipeline-stage hop: ship activations of `tokens` tokens to the next
    /// stage (section 4.3's T_comm^pp(c)).
    pub fn stage_hop_s(&self, tokens: u64) -> f64 {
        if self.parallel.spp <= 1 {
            return 0.0;
        }
        let link = self.hw.link(self.parallel.stage_hop_same_node(&self.hw));
        let bytes = tokens as f64 * self.model.d_model as f64 * self.model.dtype_bytes as f64;
        bytes / link.bandwidth + link.latency_s
    }

    /// KVP merge cost for `n_queries` query tokens (section 4.4's
    /// T_comm^kvp): replicate queries + gather (o, m, l) partials. The
    /// volume is independent of context length.
    pub fn kvp_merge_s(&self, n_queries: u64) -> f64 {
        if self.parallel.kvp <= 1 {
            return 0.0;
        }
        let m = &self.model;
        let link = &self.hw.inter_node;
        let q_bytes = n_queries as f64
            * m.hq as f64
            * m.d_head as f64
            * m.dtype_bytes as f64;
        // o (+ m and l stats, f32 each) per shard, per layer merged by the
        // owner; volume modeled as one round of gather + one broadcast.
        let partial_bytes =
            n_queries as f64 * m.hq as f64 * (m.d_head as f64 + 2.0) * 4.0;
        let per_layer = (q_bytes + partial_bytes * (self.parallel.kvp as f64 - 1.0))
            / link.bandwidth
            + 2.0 * link.latency_s;
        per_layer * m.n_layers as f64
    }

    // --- memory feasibility (Fig. 15 red crosses) -------------------------

    /// Bytes resident per worker for a single request of `ctx` tokens, given
    /// the layout: weights split over tp*spp, KV split over tp*spp*kvp.
    pub fn per_worker_bytes(&self, ctx: u64) -> f64 {
        let p = &self.parallel;
        let weights = self.model.param_bytes() as f64 / (p.tp as f64 * p.spp as f64);
        let kv = self.model.kv_bytes(ctx) as f64
            / (p.tp as f64 * p.spp as f64 * p.kvp as f64);
        // activation workspace ~ 2% of capacity; rounding slack included
        let act = 0.02 * self.hw.hbm_capacity as f64;
        weights + kv + act
    }

    pub fn fits_memory(&self, ctx: u64) -> bool {
        self.per_worker_bytes(ctx) <= self.hw.hbm_capacity as f64
    }

    /// Max context length that fits (binary search over per_worker_bytes).
    pub fn max_context(&self) -> u64 {
        if !self.fits_memory(0) {
            return 0;
        }
        let (mut lo, mut hi) = (0u64, 1u64 << 36);
        while lo + 1 < hi {
            let mid = lo + (hi - lo) / 2;
            if self.fits_memory(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    // --- utilization (Figs. 20, 21) ---------------------------------------

    /// Model FLOPs Utilization: useful model FLOPs / (elapsed * total peak).
    pub fn mfu(&self, batch: &BatchShape, elapsed_s: f64, gpus: u32) -> f64 {
        let m = &self.model;
        let mut f = 0.0;
        for p in &batch.prefills {
            f += counts::attn_flops(m, p.chunk, p.kv_len);
        }
        for d in &batch.decodes {
            f += counts::attn_flops(m, 1, d.kv_len);
        }
        f += counts::linear_flops(m, batch.tokens());
        f *= m.n_layers as f64;
        f / (elapsed_s * self.hw.peak_flops * gpus as f64)
    }

    /// Model Bandwidth Utilization: bytes that must move / (elapsed * peak BW).
    pub fn mbu(&self, batch: &BatchShape, elapsed_s: f64, gpus: u32) -> f64 {
        let m = &self.model;
        let mut b = 0.0;
        for p in &batch.prefills {
            b += counts::attn_read_bytes(m, p.kv_len);
        }
        for d in &batch.decodes {
            b += counts::attn_read_bytes(m, d.kv_len);
        }
        b += counts::weight_bytes_per_layer(m);
        b *= m.n_layers as f64;
        b / (elapsed_s * self.hw.hbm_bw * gpus as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;

    fn pm(tp: u32, spp: u32, kvp: u32) -> PerfModel {
        let d = DeploymentConfig::llama3_8b_tp8().with_parallel(tp, spp, kvp);
        PerfModel::new(d.model, d.hardware, d.parallel)
    }

    #[test]
    fn decode_time_scales_with_context() {
        let m = pm(8, 1, 1);
        let t1 = m.iteration_time(&BatchShape::decode_only(&[100_000])).total();
        let t2 = m.iteration_time(&BatchShape::decode_only(&[1_000_000])).total();
        // At small ctx, weight reads + fixed overhead dominate, so scaling
        // is sublinear — but 10x the context must still cost >3x.
        assert!(t2 > t1 * 3.0, "t1={t1} t2={t2}");
    }

    #[test]
    fn decode_is_memory_bound() {
        let m = pm(8, 1, 1);
        let b = BatchShape::decode_only(&[1_000_000]);
        let it = m.stage_time(&b, m.model.n_layers);
        // attention dominated by KV reads, and reads/bw >> flops/peak
        let attn_flop_time = super::counts::attn_flops(&m.model, 1, 1_000_000)
            / m.parallel.tp as f64
            / m.hw.sustained_flops()
            * m.model.n_layers as f64;
        assert!(it.attn_s > attn_flop_time * 10.0);
    }

    #[test]
    fn big_prefill_chunk_is_compute_bound() {
        let m = pm(8, 1, 1);
        let b = BatchShape::prefill_only(4096, 1_000_000);
        let it = m.stage_time(&b, m.model.n_layers);
        let attn_mem_time = super::counts::attn_read_bytes(&m.model, 1_000_000)
            / m.parallel.tp as f64
            / m.hw.sustained_bw()
            * m.model.n_layers as f64;
        assert!(it.attn_s > attn_mem_time * 0.99);
        // compute term should dominate at c=4096 (intensity >> ridge)
        assert!(
            super::counts::attn_intensity(&m.model, 4096, 1_000_000)
                > m.hw.sustained_flops() / m.hw.sustained_bw()
        );
    }

    #[test]
    fn mixed_batch_costs_more_than_parts_would_separately_save() {
        let m = pm(8, 1, 1);
        let mixed = BatchShape {
            prefills: vec![PrefillWork {
                chunk: 512,
                kv_len: 500_000,
            }],
            decodes: (0..32).map(|_| DecodeWork { kv_len: 1_000 }).collect(),
        };
        let t_mixed = m.iteration_time(&mixed).total();
        let t_prefill = m
            .iteration_time(&BatchShape::prefill_only(512, 500_000))
            .total();
        // Piggybacking 32 small decodes should cost only a small delta
        // (section 2.4 / Fig. 22).
        assert!(t_mixed < t_prefill * 1.10, "{t_mixed} vs {t_prefill}");
    }

    #[test]
    fn kvp_merge_independent_of_context() {
        let m = pm(8, 1, 4);
        // merge cost has no context parameter by construction; assert it is
        // small vs a 1M-token decode's attention time
        let merge = m.kvp_merge_s(1);
        let dec = m.iteration_time(&BatchShape::decode_only(&[1_000_000]));
        assert!(merge < dec.attn_s, "merge={merge} attn={}", dec.attn_s);
    }

    #[test]
    fn memory_feasibility_ordering() {
        // more spp => more capacity => larger max context
        let small = pm(8, 1, 1).max_context();
        let big = pm(8, 4, 1).max_context();
        assert!(big > small * 3, "small={small} big={big}");
    }

    #[test]
    fn llama70b_memory_feasibility_matches_fig15() {
        // Fig. 15b red crosses: 70B fits 1M on one DGX (tp=8), but 10M
        // does not fit even at spp=4; spp=8 is required (section 6.3).
        let d = DeploymentConfig::llama3_70b_tp8();
        let m1 = PerfModel::new(d.model.clone(), d.hardware.clone(), d.parallel);
        assert!(m1.fits_memory(1_000_000));
        assert!(!m1.fits_memory(10_000_000));
        let d4 = DeploymentConfig::llama3_70b_tp8().with_parallel(8, 4, 1);
        let m4 = PerfModel::new(d4.model, d4.hardware, d4.parallel);
        assert!(!m4.fits_memory(10_000_000));
        let d8 = DeploymentConfig::llama3_70b_tp8().with_parallel(8, 8, 1);
        let m8 = PerfModel::new(d8.model, d8.hardware, d8.parallel);
        assert!(m8.fits_memory(10_000_000));
    }

    #[test]
    fn mfu_mbu_bounded() {
        let m = pm(8, 1, 1);
        let b = BatchShape::prefill_only(4096, 100_000);
        let t = m.iteration_time(&b).total();
        let mfu = m.mfu(&b, t, 8);
        let mbu = m.mbu(&b, t, 8);
        assert!(mfu > 0.05 && mfu <= 1.0, "mfu={mfu}");
        assert!(mbu > 0.0 && mbu <= 1.0, "mbu={mbu}");
    }
}
