//! Analytical performance model (the paper's Eq. 1–10) — roofline execution
//! times for mixed batches on an H100/DGX substrate, plus SPP/KVP scaling
//! laws, memory feasibility, and MFU/MBU accounting.
//!
//! This model plays two roles:
//!  1. it is the *runtime predictor* the adaptive chunking policy queries
//!     (the paper uses Vidur's predictor for the same purpose), and
//!  2. it is the time source for the cluster simulator that regenerates the
//!     paper's figures at 128-GPU scale (DESIGN.md §3 substitution table).

pub mod analysis;
pub mod counts;
pub mod iteration;

pub use analysis::{gpus_required, resource_limits, GpuRequirement, ResourceLimits};
pub use iteration::{BatchShape, DecodeWork, IterationTime, PerfModel, PrefillWork};
