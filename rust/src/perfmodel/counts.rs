//! Operation counts — the paper's Eq. 1–7, per transformer layer.
//!
//! All counts are *totals* for one layer before dividing across TP workers;
//! the iteration model applies parallelism. FLOPs use the 2-flops-per-MAC
//! convention the paper uses (Eq. 1: F_a(n) = 4 n^2 d h_q counts QK^T and
//! PV, 2 each).

use crate::config::ModelConfig;

/// Attention FLOPs for `nq` query tokens attending to `nkv` KV tokens
/// (one layer). Eq. 1 is the special case nq == nkv == n.
pub fn attn_flops(m: &ModelConfig, nq: u64, nkv: u64) -> f64 {
    4.0 * nq as f64 * nkv as f64 * m.d_head as f64 * m.hq as f64
}

/// Bytes of KV cache read for attention over `nkv` KV tokens (one layer).
/// Eq. 3: R_a(n) = M_kv(n) — K and V, h_kv heads, d_head wide.
pub fn attn_read_bytes(m: &ModelConfig, nkv: u64) -> f64 {
    2.0 * nkv as f64 * m.hkv as f64 * m.d_head as f64 * m.dtype_bytes as f64
}

/// Arithmetic intensity of an attention op (Eq. 4 / Eq. 7): FLOPs per byte.
/// For a prefill chunk this depends only on the chunk size — the paper's
/// central observation.
pub fn attn_intensity(m: &ModelConfig, nq: u64, nkv: u64) -> f64 {
    attn_flops(m, nq, nkv) / attn_read_bytes(m, nkv)
}

/// Parameters in one layer's linear weights (attention projections + SwiGLU).
pub fn linear_params_per_layer(m: &ModelConfig) -> f64 {
    let dm = m.d_model as f64;
    let dh = m.d_head as f64;
    let attn = dm * m.hq as f64 * dh // wq
        + 2.0 * dm * m.hkv as f64 * dh // wk, wv
        + m.hq as f64 * dh * dm; // wo
    let mlp = 3.0 * dm * m.d_ff as f64;
    attn + mlp
}

/// Linear-layer FLOPs for `tokens` tokens in one layer (2 flops per MAC).
pub fn linear_flops(m: &ModelConfig, tokens: u64) -> f64 {
    2.0 * tokens as f64 * linear_params_per_layer(m)
}

/// Weight bytes read per layer (decode iterations are bound by this).
pub fn weight_bytes_per_layer(m: &ModelConfig) -> f64 {
    linear_params_per_layer(m) * m.dtype_bytes as f64
}

/// Total KV-cache read bytes for a *chunked* prefill of `n` tokens with
/// chunk size `c`, all layers — Eq. 6's read amplification:
/// R_cp(n, c) = sum_i R_a(i * c) = O(n^2 / c).
pub fn chunked_prefill_total_reads(m: &ModelConfig, n: u64, c: u64) -> f64 {
    let chunks = n.div_ceil(c);
    let mut total = 0.0;
    for i in 1..=chunks {
        let kv = (i * c).min(n);
        total += attn_read_bytes(m, kv) * m.n_layers as f64;
    }
    total
}

/// Total prefill attention FLOPs for `n` tokens, all layers (Eq. 1 summed
/// over causal structure: each token attends to its prefix, n^2/2 pairs,
/// but the paper's F_a(n) = 4 n^2 d h_q counts the full causal prefill as
/// run by kernels that skip masked tiles — we follow the causal count).
pub fn prefill_attn_flops(m: &ModelConfig, n: u64) -> f64 {
    // sum over chunks of attn_flops(c, prefix) telescopes to ~n^2/2 * 4 d hq
    2.0 * (n as f64) * (n as f64) * m.d_head as f64 * m.hq as f64 * m.n_layers as f64
}

/// Total prefill FLOPs including linear layers, all layers.
pub fn prefill_total_flops(m: &ModelConfig, n: u64) -> f64 {
    prefill_attn_flops(m, n) + linear_flops(m, n) * m.n_layers as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m8b() -> ModelConfig {
        ModelConfig::llama3_8b()
    }

    #[test]
    fn eq1_quadratic_form() {
        let m = m8b();
        // F_a(n) = 4 n^2 d h_q for square attention
        let n = 1024;
        let f = attn_flops(&m, n, n);
        assert_eq!(f, 4.0 * 1024.0 * 1024.0 * 128.0 * 32.0);
    }

    #[test]
    fn eq7_intensity_depends_only_on_chunk() {
        // The paper's key insight: I(c, n) == I(c, 10n).
        let m = m8b();
        let i1 = attn_intensity(&m, 128, 100_000);
        let i2 = attn_intensity(&m, 128, 1_000_000);
        assert!((i1 - i2).abs() < 1e-9);
        // and scales linearly with chunk size
        let i3 = attn_intensity(&m, 256, 1_000_000);
        assert!((i3 / i1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gqa_boosts_intensity() {
        // Eq. 7: intensity proportional to hq/hkv (x4 for Llama-3 8B)
        let mut mha = m8b();
        mha.hkv = mha.hq;
        let m = m8b();
        let r = attn_intensity(&m, 64, 10_000) / attn_intensity(&mha, 64, 10_000);
        assert!((r - 4.0).abs() < 1e-9);
    }

    #[test]
    fn eq6_read_amplification_quadratic() {
        // Halving the chunk size should roughly double total reads for the
        // same n (O(n^2 / c)).
        let m = m8b();
        let n = 1 << 20;
        let r1 = chunked_prefill_total_reads(&m, n, 2048);
        let r2 = chunked_prefill_total_reads(&m, n, 1024);
        assert!((r2 / r1 - 2.0).abs() < 0.01, "{}", r2 / r1);
    }

    #[test]
    fn paper_2_4_exaflops_example() {
        // Paper section 2.1: Llama-3 70B, 1M-token prefill ~ 2.4 exaFLOPs.
        let m = ModelConfig::llama3_70b();
        let f = prefill_total_flops(&m, 1_000_000);
        assert!(
            (1.0e18..4.0e18).contains(&f),
            "expected ~2.4e18, got {f:e}"
        );
    }

    #[test]
    fn linear_params_match_model_totals() {
        let m = m8b();
        let per_layer = linear_params_per_layer(&m);
        let total = per_layer * m.n_layers as f64;
        // within ~3% of n_params minus embeddings
        let non_embed = m.n_params() as f64 - (m.vocab as f64 * m.d_model as f64);
        assert!((total / non_embed - 1.0).abs() < 0.03);
    }
}
