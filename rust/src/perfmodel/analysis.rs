//! Closed-form analyses built on the iteration model: SPP prefill time
//! (Eq. 8), KVP decode time (Eq. 9/10), and the resource-requirement curves
//! behind Fig. 5.

use super::iteration::{BatchShape, PerfModel};
use crate::config::{HardwareConfig, ModelConfig, SloConfig};

impl PerfModel {
    /// Monolithic (non-pipelined) chunked prefill time for `n` tokens with
    /// a fixed chunk size: sum over chunks of full-model iteration time.
    pub fn prefill_time_monolithic(&self, n: u64, chunk: u64) -> f64 {
        let mut t = 0.0;
        let mut done = 0u64;
        while done < n {
            let c = chunk.min(n - done);
            t += self
                .iteration_time(&BatchShape::prefill_only(c, done + c))
                .total();
            done += c;
        }
        t
    }

    /// SPP prefill time (Eq. 8): with dense pipelining, stage 0 starts chunk
    /// i+1 as soon as chunk i leaves stage 0, so the prefill completes after
    /// all chunks pass one stage plus the last chunk drains the remaining
    /// spp-1 stages. Near-linear speedup in p_spp for large n.
    pub fn prefill_time_spp(&self, n: u64, chunk: u64) -> f64 {
        let spp = self.parallel.spp.max(1);
        let layers_per_stage = self.model.n_layers / spp;
        let mut sum_stage = 0.0;
        let mut last_stage = 0.0;
        let mut done = 0u64;
        while done < n {
            let c = chunk.min(n - done);
            let st = self
                .stage_time(&BatchShape::prefill_only(c, done + c), layers_per_stage)
                .total()
                + self.stage_hop_s(c);
            sum_stage += st;
            last_stage = st;
            done += c;
        }
        sum_stage + (spp as f64 - 1.0) * last_stage
    }

    /// SPP prefill time for a request resuming from a reused KV prefix:
    /// the first `reused` tokens already sit in cache (prefix-chain hit),
    /// so only chunks past them are computed — but each computed chunk
    /// still attends over the full context before it. Expressed as the
    /// difference of two Eq. 8 sums so the chunk schedule matches the one
    /// the simulator actually executes; `reused = 0` is exactly
    /// [`prefill_time_spp`](Self::prefill_time_spp).
    pub fn prefill_time_spp_resume(&self, n: u64, reused: u64, chunk: u64) -> f64 {
        if reused == 0 {
            return self.prefill_time_spp(n, chunk);
        }
        let reused = reused.min(n.saturating_sub(1));
        (self.prefill_time_spp(n, chunk) - self.prefill_time_spp(reused, chunk)).max(0.0)
    }

    /// Full-3D prefill (Eq. 10): SPP dense pipelining with the chunk's
    /// attention additionally parallelized across the kvp groups (each
    /// group holds a sequence shard; chunk queries are broadcast and
    /// partials merged, at a per-chunk merge cost independent of context).
    pub fn prefill_time_3d(&self, n: u64, chunk: u64) -> f64 {
        let spp = self.parallel.spp.max(1);
        let kvp = self.parallel.kvp.max(1) as u64;
        let layers_per_stage = self.model.n_layers / spp;
        let mut sum_stage = 0.0;
        let mut last_stage = 0.0;
        let mut done = 0u64;
        while done < n {
            let c = chunk.min(n - done);
            // local KV shard this group scans for the chunk
            let local = (done + c).div_ceil(kvp);
            let st = self
                .stage_time(
                    &BatchShape::prefill_only(c, local),
                    layers_per_stage,
                )
                .total()
                + self.stage_hop_s(c)
                + self.kvp_merge_s(c) / spp as f64; // merge amortized per stage
            sum_stage += st;
            last_stage = st;
            done += c;
        }
        sum_stage + (spp as f64 - 1.0) * last_stage
    }

    /// Decode latency (TBT) for one token of a request with `ctx` KV tokens
    /// under the configured layout, including SPP bubble and KVP merge
    /// (Eq. 9: attention parallelized by kvp; the rest is not).
    pub fn decode_tbt(&self, ctx: u64) -> f64 {
        let kvp = self.parallel.kvp.max(1) as u64;
        let local = ctx.div_ceil(kvp);
        let spp = self.parallel.spp.max(1);
        let layers_per_stage = self.model.n_layers / spp;
        let per_stage = self
            .stage_time(&BatchShape::decode_only(&[local]), layers_per_stage)
            .total()
            + self.stage_hop_s(1);
        // A single decode token traverses all stages sequentially.
        per_stage * spp as f64 + self.kvp_merge_s(1)
    }

    /// TBT for a decode-only *batch* of requests with the given (local)
    /// contexts, traversing all stages.
    pub fn batch_tbt(&self, local_ctxs: &[u64]) -> f64 {
        let spp = self.parallel.spp.max(1);
        let layers_per_stage = self.model.n_layers / spp;
        let per_stage = self
            .stage_time(&BatchShape::decode_only(local_ctxs), layers_per_stage)
            .total()
            + self.stage_hop_s(local_ctxs.len() as u64);
        per_stage * spp as f64 + self.kvp_merge_s(local_ctxs.len() as u64)
    }
}

/// Fig. 5a: for a fixed GPU budget, the max context length each resource
/// type supports under the SLOs.
#[derive(Debug, Clone, Copy)]
pub struct ResourceLimits {
    /// Max n such that prefill compute meets TTFT.
    pub compute_tokens: u64,
    /// Max n such that decode KV scan meets TBT.
    pub bandwidth_tokens: u64,
    /// Max n such that weights + KV fit in aggregate HBM.
    pub capacity_tokens: u64,
}

pub fn resource_limits(
    model: &ModelConfig,
    hw: &HardwareConfig,
    gpus: u32,
    slo: &SloConfig,
) -> ResourceLimits {
    let g = gpus as f64;
    // compute: prefill_total_flops(n) / (g * sustained) <= ttft
    let solve = |pred: &dyn Fn(u64) -> bool| -> u64 {
        let (mut lo, mut hi) = (0u64, 1u64 << 36);
        if !pred(1) {
            return 0;
        }
        while lo + 1 < hi {
            let mid = lo + (hi - lo) / 2;
            if pred(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    };
    let compute_tokens = solve(&|n| {
        super::counts::prefill_total_flops(model, n) / (g * hw.sustained_flops()) <= slo.ttft_s
    });
    let bandwidth_tokens = solve(&|n| {
        (super::counts::attn_read_bytes(model, n) * model.n_layers as f64
            + super::counts::weight_bytes_per_layer(model) * model.n_layers as f64)
            / (g * hw.sustained_bw())
            <= slo.tbt_s
    });
    let capacity_tokens = solve(&|n| {
        model.param_bytes() as f64 + model.kv_bytes(n) as f64
            <= g * hw.hbm_capacity as f64 * 0.95
    });
    ResourceLimits {
        compute_tokens,
        bandwidth_tokens,
        capacity_tokens,
    }
}

/// Fig. 5b: GPUs needed per resource type for a given context length.
#[derive(Debug, Clone, Copy)]
pub struct GpuRequirement {
    pub compute: u32,
    pub bandwidth: u32,
    pub capacity: u32,
}

impl GpuRequirement {
    pub fn max(&self) -> u32 {
        self.compute.max(self.bandwidth).max(self.capacity)
    }
}

pub fn gpus_required(
    model: &ModelConfig,
    hw: &HardwareConfig,
    ctx: u64,
    slo: &SloConfig,
) -> GpuRequirement {
    let compute = (super::counts::prefill_total_flops(model, ctx)
        / (hw.sustained_flops() * slo.ttft_s))
        .ceil() as u32;
    let bandwidth = ((super::counts::attn_read_bytes(model, ctx)
        + super::counts::weight_bytes_per_layer(model))
        * model.n_layers as f64
        / (hw.sustained_bw() * slo.tbt_s))
        .ceil() as u32;
    let capacity = ((model.param_bytes() as f64 + model.kv_bytes(ctx) as f64)
        / (hw.hbm_capacity as f64 * 0.95))
        .ceil() as u32;
    GpuRequirement {
        compute: compute.max(1),
        bandwidth: bandwidth.max(1),
        capacity: capacity.max(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentConfig;
    use crate::perfmodel::PerfModel;

    fn pm(tp: u32, spp: u32, kvp: u32) -> PerfModel {
        let d = DeploymentConfig::llama3_8b_tp8().with_parallel(tp, spp, kvp);
        PerfModel::new(d.model, d.hardware, d.parallel)
    }

    #[test]
    fn spp_near_linear_speedup() {
        // Eq. 8 / Fig. 15: scaling efficiency >= 80% going 1 -> 8 stages.
        let n = 1_000_000;
        let t1 = pm(8, 1, 1).prefill_time_spp(n, 4096);
        let t8 = pm(8, 8, 1).prefill_time_spp(n, 4096);
        let eff = t1 / (8.0 * t8);
        assert!(eff > 0.8, "efficiency {eff}");
    }

    #[test]
    fn spp_equals_monolithic_at_depth_1() {
        let m = pm(8, 1, 1);
        let a = m.prefill_time_spp(100_000, 2048);
        let b = m.prefill_time_monolithic(100_000, 2048);
        assert!((a - b).abs() / b < 1e-9);
    }

    #[test]
    fn resume_prefill_subtracts_the_skipped_span() {
        let m = pm(8, 4, 1);
        let full = m.prefill_time_spp(100_000, 4096);
        let resumed = m.prefill_time_spp_resume(100_000, 40_960, 4096);
        // strictly cheaper than full, strictly dearer than the tail alone
        // (the tail chunks attend over the reused context too)
        assert!(resumed < full, "resumed {resumed} vs full {full}");
        let tail_alone = m.prefill_time_spp(100_000 - 40_960, 4096);
        assert!(resumed > tail_alone * 0.99, "resumed {resumed} vs tail {tail_alone}");
        // degenerate cases: no reuse = full; reuse >= n-1 clamps, stays >= 0
        assert_eq!(m.prefill_time_spp_resume(100_000, 0, 4096), full);
        assert!(m.prefill_time_spp_resume(100_000, 100_000, 4096) >= 0.0);
    }

    #[test]
    fn kvp_reduces_long_context_tbt() {
        // Fig. 17: kvp=4 helps more at 10M than at 4M, sublinearly (Amdahl).
        let t4_1 = pm(8, 4, 1).decode_tbt(4_000_000);
        let t4_4 = pm(8, 4, 4).decode_tbt(4_000_000);
        let t10_1 = pm(8, 4, 1).decode_tbt(10_000_000);
        let t10_4 = pm(8, 4, 4).decode_tbt(10_000_000);
        let s4 = t4_1 / t4_4;
        let s10 = t10_1 / t10_4;
        assert!(s4 > 1.3 && s4 < 4.0, "s4={s4}");
        assert!(s10 > s4, "s10={s10} should exceed s4={s4}");
    }

    #[test]
    fn spp_hurts_tbt_only_marginally() {
        // Fig. 16: decode latency only marginally affected by pipeline depth.
        let t1 = pm(8, 1, 1).decode_tbt(2_000_000);
        let t16 = pm(8, 16, 1).decode_tbt(2_000_000);
        assert!(t16 < t1 * 2.0, "t1={t1} t16={t16}");
        assert!(t16 > t1 * 0.9);
    }

    #[test]
    fn fig5a_compute_binds_first() {
        // Paper: on 8xH100 / Llama-3 8B, compute caps out around ~768K
        // tokens while capacity scales furthest.
        let m = crate::config::ModelConfig::llama3_8b();
        let hw = crate::config::HardwareConfig::dgx_h100();
        let slo = SloConfig {
            ttft_s: 30.0,
            tbt_s: 0.020,
            ..SloConfig::default()
        };
        let r = resource_limits(&m, &hw, 8, &slo);
        assert!(
            (300_000..1_500_000).contains(&r.compute_tokens),
            "compute {}",
            r.compute_tokens
        );
        assert!(r.capacity_tokens > r.compute_tokens);
        assert!(r.bandwidth_tokens > r.compute_tokens);
    }

    #[test]
    fn fig5b_gpu_counts_match_paper_scale() {
        // Paper: ~20 GPUs at 1M, ~80 at 2M (quadratic growth).
        let m = crate::config::ModelConfig::llama3_8b();
        let hw = crate::config::HardwareConfig::dgx_h100();
        let slo = SloConfig {
            ttft_s: 30.0,
            tbt_s: 0.020,
            ..SloConfig::default()
        };
        let g1 = gpus_required(&m, &hw, 1_000_000, &slo).max();
        let g2 = gpus_required(&m, &hw, 2_000_000, &slo).max();
        assert!((10..40).contains(&g1), "g1={g1}");
        assert!((40..160).contains(&g2), "g2={g2}");
        assert!(g2 >= 3 * g1, "quadratic-ish growth: {g1} -> {g2}");
    }
}
