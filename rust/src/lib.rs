//! # Medha / Mnemosyne — long-context LLM inference serving, reproduced
//!
//! Rust coordinator (L3) + JAX/Pallas AOT compute (L2/L1) implementing the
//! paper's three contributions — adaptive chunked prefills, Sequence
//! Pipeline Parallelism (SPP), and KV-cache Parallelism (KVP) — composed
//! into 3D parallelism, plus the substrates needed to reproduce every
//! table and figure of the evaluation. See DESIGN.md for the full map.

// Determinism/safety contract (enforced statically by `medha lint`, rule
// U1): unsafe code is denied crate-wide; the only modules that may opt
// back in — with a `// SAFETY:` comment on every block — are
// `util::threadpool` and `runtime`.
#![deny(unsafe_code)]

pub mod config;
pub mod perfmodel;
pub mod util;
pub mod coordinator;
pub mod kvcache;
pub mod metrics;
pub mod sim;
pub mod workload;
pub mod baselines;
pub mod runtime;
pub mod engine;
pub mod figures;
