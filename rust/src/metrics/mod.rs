//! Serving metrics: TTFT / TBT percentile recorders, per-request SLO
//! attainment and goodput (section 6's "no request left behind" yardstick),
//! per-iteration traces (the Fig. 19 timeline), and MFU/MBU aggregation
//! (Figs. 20–21).
//!
//! Attainment is judged against per-request **length-aware** TTFT deadlines
//! (assigned at admission, carried on the request) and the deployment's TBT
//! SLO; goodput counts only requests that met both, per second of simulated
//! span — the metric that separates a scheduler that merely finishes
//! requests from one that finishes them *in time*.
//!
//! Ingestion is O(1) amortized: percentile sorting is deferred to query
//! time, and the wall-clock span is tracked incrementally instead of being
//! recomputed from the iteration trace. For multi-million-request runs,
//! [`Metrics::streaming`] bounds memory by reservoir-sampling the latency
//! populations and dropping the per-iteration trace (aggregate counters
//! are always exact).

use crate::coordinator::request::Request;
use crate::util::stats::{P2Quantile, Samples};

/// Why a prefill lost the compute slot it was holding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptionKind {
    /// A queued (not yet active) request was re-ordered past another —
    /// the group schedulers' chunk-boundary switch among ready requests.
    QueuedReorder,
    /// The **actively executing** sharded long request yielded its
    /// cooperative slot at a chunk boundary: every per-group KV shard is
    /// retained and the request resumes bit-exactly from the boundary.
    ActiveYield,
}

/// One preemption, as it took effect (the "no request left behind" audit
/// trail: who lost the slot, when, and how).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PreemptionEvent {
    pub t: f64,
    /// Client-visible id of the request that was preempted.
    pub request: u64,
    pub kind: PreemptionKind,
}

/// One scheduler iteration's record (drives Figs. 8, 19, 22).
#[derive(Debug, Clone, PartialEq)]
pub struct IterRecord {
    /// Completion time of the iteration (sim seconds).
    pub t: f64,
    /// Iteration execution time.
    pub dur_s: f64,
    /// Prefill chunk size scheduled, if any.
    pub chunk: Option<u64>,
    /// Decode tokens in the batch.
    pub n_decodes: usize,
    /// GPUs participating at this time (KVP growth staircase).
    pub active_gpus: u32,
}

#[derive(Debug)]
pub struct Metrics {
    pub ttft: Samples,
    pub tbt: Samples,
    /// Full iteration trace; empty when `keep_iter_records` is off.
    pub iters: Vec<IterRecord>,
    /// Retain per-iteration records (figure reproduction needs them; the
    /// million-request throughput benches turn this off).
    pub keep_iter_records: bool,
    pub mfu: Samples,
    pub mbu: Samples,
    pub finished_requests: u64,
    pub decode_tokens: u64,
    pub prefill_tokens: u64,
    /// Iterations recorded (exact even when the trace is dropped).
    pub n_iters: u64,
    /// TBT SLO threshold for per-token attainment accounting (`INFINITY`
    /// until the simulator installs the deployment's `slo.tbt_s`).
    pub tbt_slo_s: f64,
    /// TBT samples at or under `tbt_slo_s` (exact in all modes).
    pub tbt_within_slo: u64,
    /// Finished requests whose TTFT met their length-aware deadline.
    pub ttft_deadline_met: u64,
    /// Finished requests whose TTFT missed their deadline.
    pub ttft_deadline_missed: u64,
    /// Finished requests that met the TTFT deadline AND kept every TBT
    /// sample within the SLO — the goodput numerator.
    pub slo_good_requests: u64,
    /// Chunk-boundary prefill preemptions across all schedulers — the
    /// **queued re-ordering** count (a ready request lost the next-chunk
    /// slot before it was the one executing).
    pub preemptions: u64,
    /// Chunk-boundary yields of the **actively executing** sharded long
    /// request (pool-scheduled routing modes only; the distinction the
    /// `preemptions` counter alone cannot make).
    pub active_preemptions: u64,
    /// Admissions refused for lack of per-group KV capacity: the routing
    /// hook (`SchedPolicy::route`) found no fitting group, or older
    /// refused admissions were already waiting (a new arrival joins the
    /// priority-ordered deferred set rather than taking the capacity that
    /// frees). Each such request is counted once, when it is deferred —
    /// or overflow-placed with the check waived, for requests larger than
    /// a whole group's capacity. Always zero under blind routing or
    /// unlimited capacity (the defaults).
    pub routing_refusals: u64,
    /// Wait times of capacity-deferred admissions: deferral (first
    /// refusal) to successful placement, one sample per deferred request.
    /// The deferred set is retried in scheduling-policy priority order, so
    /// this distribution is what the deferred-queue urgency ordering is
    /// judged by.
    pub deferral_wait: Samples,
    /// KVP group crashes applied by the fault plan (one per `crash` event
    /// that fired). Zero in fault-free runs.
    pub group_crashes: u64,
    /// KV shards dropped by crashes fleet-wide: shards on the dead groups
    /// plus post-hole shards on survivors.
    pub shards_lost: u64,
    /// KV tokens that had to be recomputed after crashes: each victim's
    /// progress past its last surviving chunk boundary, summed at rewind
    /// time. The graceful-degradation cost a full-restart baseline pays as
    /// the *entire* context instead.
    pub reprefill_tokens: u64,
    /// KV tokens the KVP manager absorbed past a group's free ledger room
    /// (overflow-absorb with the fleet full). Synced from the manager at
    /// run end; zero whenever capacity is sized to the workload.
    pub kv_overcommit_tokens: u64,
    /// Per-victim recovery waits: crash time to the first chunk of
    /// re-prefill progress after it, one sample per crash victim.
    pub recovery_wait: Samples,
    /// Arrivals shed at the door by SLO-feedback admission control: the
    /// rolling deferral-wait p95 had crossed the shed threshold and the
    /// arrival's projected LARS slack was already negative. Open-loop
    /// serving only (`sim::serve`); always zero in closed-loop replay.
    pub n_shed: u64,
    /// Shed arrivals that were short/interactive class.
    pub n_shed_short: u64,
    /// Shed arrivals that were document class.
    pub n_shed_doc: u64,
    /// Arrivals rejected because their class's admission queue was at its
    /// configured limit. Open-loop serving only.
    pub n_rejected_queue_full: u64,
    /// Queue-full rejections of short/interactive arrivals.
    pub n_rejected_short: u64,
    /// Queue-full rejections of document arrivals.
    pub n_rejected_doc: u64,
    /// Prompt tokens served from prefix-cache hits at admission
    /// (`kvcache::PrefixIndex`): their prefill was skipped entirely. Zero
    /// with reuse off (the default).
    pub prefix_hit_tokens: u64,
    /// Prefix blocks handed to a request from the shared index at
    /// admission — each such block's KV is used by more than one request
    /// over its lifetime.
    pub blocks_shared: u64,
    /// Reused-span tokens that had to be re-prefilled because the group
    /// owning the shared chain crashed: the per-holder cost of sharing,
    /// metered separately from the victim's own `reprefill_tokens`.
    pub reprefill_shared_tokens: u64,
    /// Active-yield audit trail, in event order; dropped (like `iters`)
    /// when `keep_iter_records` is off — the counter stays exact.
    pub preemption_events: Vec<PreemptionEvent>,
    /// Per-group busy seconds (sum of this group's iteration durations) —
    /// the utilization split behind the routed-vs-blind comparison.
    pub group_busy_s: Vec<f64>,
    /// Per-group prefill tokens executed.
    pub group_prefill_tokens: Vec<u64>,
    /// Per-group decode tokens executed.
    pub group_decode_tokens: Vec<u64>,
    /// Streaming-mode P² estimator for TBT p99: tracks the tail over the
    /// *full* sample stream, where a small reservoir holds too few tail
    /// points to resolve it.
    tbt_p99_stream: Option<P2Quantile>,
    /// Start time of the first recorded iteration (t - dur).
    first_iter_start: Option<f64>,
    /// Completion time of the last recorded iteration.
    last_iter_t: f64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            ttft: Samples::new(),
            tbt: Samples::new(),
            iters: Vec::new(),
            keep_iter_records: true,
            mfu: Samples::new(),
            mbu: Samples::new(),
            finished_requests: 0,
            decode_tokens: 0,
            prefill_tokens: 0,
            n_iters: 0,
            tbt_slo_s: f64::INFINITY,
            tbt_within_slo: 0,
            ttft_deadline_met: 0,
            ttft_deadline_missed: 0,
            slo_good_requests: 0,
            preemptions: 0,
            active_preemptions: 0,
            routing_refusals: 0,
            deferral_wait: Samples::new(),
            group_crashes: 0,
            shards_lost: 0,
            reprefill_tokens: 0,
            kv_overcommit_tokens: 0,
            recovery_wait: Samples::new(),
            n_shed: 0,
            n_shed_short: 0,
            n_shed_doc: 0,
            n_rejected_queue_full: 0,
            n_rejected_short: 0,
            n_rejected_doc: 0,
            prefix_hit_tokens: 0,
            blocks_shared: 0,
            reprefill_shared_tokens: 0,
            preemption_events: Vec::new(),
            group_busy_s: Vec::new(),
            group_prefill_tokens: Vec::new(),
            group_decode_tokens: Vec::new(),
            tbt_p99_stream: None,
            first_iter_start: None,
            last_iter_t: 0.0,
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Bounded-memory recorder for huge runs: latency/utilization samples
    /// are reservoir-capped at `reservoir` and the iteration trace is not
    /// retained. Counters, span, and throughput stay exact.
    pub fn streaming(reservoir: usize, seed: u64) -> Metrics {
        Metrics {
            ttft: Samples::reservoir(reservoir, seed ^ 0x7474_6674),
            tbt: Samples::reservoir(reservoir, seed ^ 0x0074_6274),
            mfu: Samples::reservoir(reservoir, seed ^ 0x0066_7564),
            mbu: Samples::reservoir(reservoir, seed ^ 0x0062_7564),
            deferral_wait: Samples::reservoir(reservoir, seed ^ 0x6465_6665),
            recovery_wait: Samples::reservoir(reservoir, seed ^ 0x7265_6376),
            keep_iter_records: false,
            tbt_p99_stream: Some(P2Quantile::new(0.99)),
            ..Metrics::default()
        }
    }

    pub fn record_iter(&mut self, rec: IterRecord) {
        self.decode_tokens += rec.n_decodes as u64;
        self.prefill_tokens += rec.chunk.unwrap_or(0);
        self.n_iters += 1;
        if self.first_iter_start.is_none() {
            self.first_iter_start = Some(rec.t - rec.dur_s);
        }
        // max, not assignment: pool-mode group iterations are recorded in
        // group order within a decision instant, not completion-time order.
        // For the blind barrier the stream is time-monotone, so this is
        // identical to assignment.
        self.last_iter_t = self.last_iter_t.max(rec.t);
        if self.keep_iter_records {
            self.iters.push(rec);
        }
    }

    pub fn record_ttft(&mut self, s: f64) {
        self.ttft.add(s);
    }

    /// Record a chunk-boundary yield of the active sharded long request.
    /// The counter is always exact; the per-event audit trail is an
    /// inspection feature like the iteration trace, so lean/streaming mode
    /// (`keep_iter_records` off) drops it to keep memory bounded by
    /// concurrency, not trace length.
    pub fn record_active_preemption(&mut self, t: f64, request: u64) {
        self.active_preemptions += 1;
        if self.keep_iter_records {
            self.preemption_events.push(PreemptionEvent {
                t,
                request,
                kind: PreemptionKind::ActiveYield,
            });
        }
    }

    /// Account one group's share of an iteration: `busy_s` of execution
    /// and the tokens it processed. Groups are dense ids; the vectors grow
    /// on first touch so single-group deployments pay nothing extra.
    pub fn record_group_iter(&mut self, g: usize, busy_s: f64, prefill: u64, decode: u64) {
        if self.group_busy_s.len() <= g {
            self.group_busy_s.resize(g + 1, 0.0);
            self.group_prefill_tokens.resize(g + 1, 0);
            self.group_decode_tokens.resize(g + 1, 0);
        }
        self.group_busy_s[g] += busy_s;
        self.group_prefill_tokens[g] += prefill;
        self.group_decode_tokens[g] += decode;
    }

    /// Per-group busy fraction over the recorded span (empty before any
    /// iteration ran).
    pub fn group_utilization(&self) -> Vec<f64> {
        let span = self.span_s();
        if span <= 0.0 {
            return vec![0.0; self.group_busy_s.len()];
        }
        self.group_busy_s.iter().map(|&b| b / span).collect()
    }

    /// Record the wait of one capacity-deferred admission, from deferral
    /// to successful placement. Call once per deferred request.
    pub fn record_deferral_wait(&mut self, s: f64) {
        self.deferral_wait.add(s);
    }

    /// Record one crash victim's recovery wait: the crash that cost it KV
    /// to its first re-prefill progress afterwards. Call once per victim.
    pub fn record_recovery_wait(&mut self, s: f64) {
        self.recovery_wait.add(s);
    }

    /// Record one arrival shed at the door by SLO-feedback admission
    /// control. `doc` selects the per-class breakdown counter.
    pub fn record_shed(&mut self, doc: bool) {
        self.n_shed += 1;
        if doc {
            self.n_shed_doc += 1;
        } else {
            self.n_shed_short += 1;
        }
    }

    /// Record one arrival rejected because its class's admission queue was
    /// full. `doc` selects the per-class breakdown counter.
    pub fn record_queue_reject(&mut self, doc: bool) {
        self.n_rejected_queue_full += 1;
        if doc {
            self.n_rejected_doc += 1;
        } else {
            self.n_rejected_short += 1;
        }
    }

    pub fn record_tbt(&mut self, s: f64) {
        self.tbt.add(s);
        if s <= self.tbt_slo_s {
            self.tbt_within_slo += 1;
        }
        if let Some(q) = &mut self.tbt_p99_stream {
            q.add(s);
        }
    }

    /// Record everything a finished request contributes — its TBT samples
    /// (each judged against the TBT SLO), its TTFT, its deadline verdict,
    /// and the finished count. One definition for every completion path,
    /// so the metric stream is bit-deterministic (asserted by the recorded
    /// golden snapshots in `tests/sim_golden.rs`). Call exactly once per
    /// finished request.
    pub fn record_finished_request(&mut self, r: &Request) {
        let mut tbt_ok = true;
        for &s in &r.tbt_samples {
            tbt_ok &= s <= self.tbt_slo_s;
            self.record_tbt(s);
        }
        if let Some(t) = r.ttft() {
            self.record_ttft(t);
        }
        self.record_request_slo(r.ttft(), r.ttft_budget_s(), tbt_ok);
        self.finished_requests += 1;
    }

    /// Record a finished request's SLO attainment: its TTFT against the
    /// length-aware budget it was admitted under, and whether every one of
    /// its TBT samples stayed within the TBT SLO (`tbt_ok`). Call exactly
    /// once per finished request.
    pub fn record_request_slo(&mut self, ttft: Option<f64>, ttft_budget_s: f64, tbt_ok: bool) {
        let ttft_ok = matches!(ttft, Some(t) if t <= ttft_budget_s);
        if ttft_ok {
            self.ttft_deadline_met += 1;
        } else {
            self.ttft_deadline_missed += 1;
        }
        if ttft_ok && tbt_ok {
            self.slo_good_requests += 1;
        }
    }

    /// Wall-clock span of the recorded iterations.
    pub fn span_s(&self) -> f64 {
        match self.first_iter_start {
            Some(start) => self.last_iter_t - start,
            None => 0.0,
        }
    }

    /// Decode throughput over the recorded span (tokens/s).
    pub fn decode_tokens_per_s(&self) -> f64 {
        let span = self.span_s();
        if span <= 0.0 {
            return 0.0;
        }
        self.decode_tokens as f64 / span
    }

    pub fn summary(&mut self) -> MetricsSummary {
        MetricsSummary {
            n_ttft: self.ttft.count() as usize,
            ttft_p50: self.ttft.median(),
            ttft_p95: self.ttft.p95(),
            n_tbt: self.tbt.count() as usize,
            tbt_p50: self.tbt.median(),
            tbt_p95: self.tbt.p95(),
            // In streaming mode the P² estimator saw every sample; the
            // reservoir's sparse tail is the fallback-only path. Exact mode
            // (no estimator) stays on the raw sample population.
            tbt_p99: match &self.tbt_p99_stream {
                Some(q) if q.count() > 0 => q.value(),
                _ => self.tbt.p99(),
            },
            tbt_max: self.tbt.max(),
            finished: self.finished_requests,
            decode_tps: self.decode_tokens_per_s(),
            mfu_mean: self.mfu.mean(),
            mbu_mean: self.mbu.mean(),
            ttft_attainment: {
                let n = self.ttft_deadline_met + self.ttft_deadline_missed;
                if n > 0 {
                    self.ttft_deadline_met as f64 / n as f64
                } else {
                    f64::NAN
                }
            },
            tbt_attainment: if self.tbt.count() > 0 {
                self.tbt_within_slo as f64 / self.tbt.count() as f64
            } else {
                f64::NAN
            },
            goodput_rps: {
                let span = self.span_s();
                if span > 0.0 {
                    self.slo_good_requests as f64 / span
                } else {
                    0.0
                }
            },
            preemptions: self.preemptions,
            active_preemptions: self.active_preemptions,
            routing_refusals: self.routing_refusals,
            n_deferred: self.deferral_wait.count(),
            deferral_wait_p95: self.deferral_wait.p95(),
            group_crashes: self.group_crashes,
            shards_lost: self.shards_lost,
            reprefill_tokens: self.reprefill_tokens,
            kv_overcommit_tokens: self.kv_overcommit_tokens,
            n_recovered: self.recovery_wait.count(),
            recovery_wait_p50: self.recovery_wait.median(),
            recovery_wait_p95: self.recovery_wait.p95(),
            n_shed: self.n_shed,
            n_shed_short: self.n_shed_short,
            n_shed_doc: self.n_shed_doc,
            n_rejected_queue_full: self.n_rejected_queue_full,
            n_rejected_short: self.n_rejected_short,
            n_rejected_doc: self.n_rejected_doc,
            prefix_hit_tokens: self.prefix_hit_tokens,
            blocks_shared: self.blocks_shared,
            reprefill_shared_tokens: self.reprefill_shared_tokens,
            prefix_hit_rate: {
                // Hit tokens over all prompt tokens the fleet saw: hits
                // skipped their prefill, so the denominator is hits plus
                // the prefill actually executed.
                let total = self.prefix_hit_tokens + self.prefill_tokens;
                if total > 0 {
                    self.prefix_hit_tokens as f64 / total as f64
                } else {
                    f64::NAN
                }
            },
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSummary {
    pub n_ttft: usize,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub n_tbt: usize,
    pub tbt_p50: f64,
    pub tbt_p95: f64,
    pub tbt_p99: f64,
    pub tbt_max: f64,
    pub finished: u64,
    pub decode_tps: f64,
    pub mfu_mean: f64,
    pub mbu_mean: f64,
    /// Fraction of finished requests whose TTFT met its length-aware
    /// deadline (NaN when no request carried a deadline verdict).
    pub ttft_attainment: f64,
    /// Fraction of TBT samples within the TBT SLO (NaN when no samples).
    pub tbt_attainment: f64,
    /// Requests per second that met both SLOs over the simulated span.
    pub goodput_rps: f64,
    /// Chunk-boundary prefill preemptions of *queued* requests
    /// (re-orderings in a ready set).
    pub preemptions: u64,
    /// Chunk-boundary yields of the *actively executing* sharded long
    /// request (KV shards retained, resume bit-exact).
    pub active_preemptions: u64,
    /// Capacity-refused admissions (deferred or overflow-placed); zero
    /// outside routed mode with finite KV capacity.
    pub routing_refusals: u64,
    /// Capacity-deferred admissions that were eventually placed (each
    /// contributes one `deferral_wait` sample).
    pub n_deferred: u64,
    /// p95 of the deferral→placement wait (NaN when nothing deferred).
    pub deferral_wait_p95: f64,
    /// KVP group crashes the fault plan applied; zero fault-free.
    pub group_crashes: u64,
    /// KV shards dropped by crashes (dead-group + post-hole survivors).
    pub shards_lost: u64,
    /// KV tokens recomputed from chunk boundaries after crashes.
    pub reprefill_tokens: u64,
    /// KV tokens absorbed past a group's free ledger room; zero whenever
    /// capacity is sized to the workload (asserted by the golden scenarios).
    pub kv_overcommit_tokens: u64,
    /// Crash victims that recorded a recovery wait.
    pub n_recovered: u64,
    /// p50 of crash→first-re-prefill-progress (NaN without crashes).
    pub recovery_wait_p50: f64,
    /// p95 of crash→first-re-prefill-progress (NaN without crashes).
    pub recovery_wait_p95: f64,
    /// Arrivals shed at the door by SLO-feedback admission control
    /// (open-loop serving only; zero in closed-loop replay).
    pub n_shed: u64,
    /// Shed arrivals that were short/interactive class.
    pub n_shed_short: u64,
    /// Shed arrivals that were document class.
    pub n_shed_doc: u64,
    /// Arrivals rejected at a full per-class admission queue.
    pub n_rejected_queue_full: u64,
    /// Queue-full rejections of short/interactive arrivals.
    pub n_rejected_short: u64,
    /// Queue-full rejections of document arrivals.
    pub n_rejected_doc: u64,
    /// Prompt tokens served from prefix-cache hits (prefill skipped).
    pub prefix_hit_tokens: u64,
    /// Prefix blocks served to requests out of the shared index.
    pub blocks_shared: u64,
    /// Reused-span tokens re-prefilled after a chain-owner crash.
    pub reprefill_shared_tokens: u64,
    /// Fraction of prompt tokens served from cache:
    /// `hit / (hit + executed prefill)`. NaN before any prompt token.
    pub prefix_hit_rate: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_accounting() {
        let mut m = Metrics::new();
        m.record_iter(IterRecord {
            t: 1.0,
            dur_s: 1.0,
            chunk: Some(512),
            n_decodes: 4,
            active_gpus: 8,
        });
        m.record_iter(IterRecord {
            t: 2.0,
            dur_s: 1.0,
            chunk: None,
            n_decodes: 8,
            active_gpus: 8,
        });
        assert_eq!(m.prefill_tokens, 512);
        assert_eq!(m.decode_tokens, 12);
        assert_eq!(m.n_iters, 2);
        assert!((m.span_s() - 2.0).abs() < 1e-12);
        assert!((m.decode_tokens_per_s() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record_tbt(i as f64 / 1000.0);
        }
        m.record_ttft(3.0);
        let s = m.summary();
        assert!((s.tbt_p50 - 0.0505).abs() < 1e-3);
        assert!(s.tbt_p95 > s.tbt_p50);
        assert_eq!(s.n_ttft, 1);
    }

    #[test]
    fn slo_attainment_and_goodput() {
        let mut m = Metrics::new();
        m.tbt_slo_s = 0.030;
        m.record_iter(IterRecord {
            t: 10.0,
            dur_s: 10.0,
            chunk: None,
            n_decodes: 0,
            active_gpus: 8,
        });
        m.record_tbt(0.010); // within
        m.record_tbt(0.050); // violation
        // req 1: met deadline, clean TBT -> goodput
        m.record_request_slo(Some(1.0), 2.0, true);
        // req 2: met deadline, TBT violation -> not goodput
        m.record_request_slo(Some(1.5), 2.0, false);
        // req 3: missed deadline
        m.record_request_slo(Some(5.0), 2.0, true);
        // req 4: never produced a token
        m.record_request_slo(None, 2.0, true);
        let s = m.summary();
        assert_eq!(m.ttft_deadline_met, 2);
        assert_eq!(m.ttft_deadline_missed, 2);
        assert_eq!(m.slo_good_requests, 1);
        assert!((s.ttft_attainment - 0.5).abs() < 1e-12);
        assert!((s.tbt_attainment - 0.5).abs() < 1e-12);
        assert!((s.goodput_rps - 0.1).abs() < 1e-12);
    }

    #[test]
    fn record_finished_request_aggregates_everything() {
        let mut m = Metrics::new();
        m.tbt_slo_s = 0.030;
        let mut r = Request::new(1, 10, 3, 0.0).with_slo(0.1, 1.0);
        r.complete_chunk(10, 0.5); // first token at 0.5 (deadline 1.0: met)
        r.complete_decode(0.52); // TBT 0.02 — within SLO
        r.complete_decode(0.60); // TBT 0.08 — violation
        assert!(r.is_finished());
        m.record_finished_request(&r);
        assert_eq!(m.finished_requests, 1);
        assert_eq!(m.ttft.count(), 1);
        assert_eq!(m.tbt.count(), 2);
        assert_eq!(m.tbt_within_slo, 1);
        assert_eq!(m.ttft_deadline_met, 1);
        // one dirty TBT sample disqualifies the request from goodput
        assert_eq!(m.slo_good_requests, 0);
    }

    #[test]
    fn attainment_is_nan_without_data() {
        let mut m = Metrics::new();
        let s = m.summary();
        assert!(s.ttft_attainment.is_nan());
        assert!(s.tbt_attainment.is_nan());
        assert_eq!(s.goodput_rps, 0.0);
        assert_eq!(s.preemptions, 0);
        assert_eq!(s.active_preemptions, 0);
        assert_eq!(s.routing_refusals, 0);
        assert_eq!(s.n_deferred, 0);
        assert!(s.deferral_wait_p95.is_nan());
        assert!(m.preemption_events.is_empty());
        assert!(m.group_utilization().is_empty());
    }

    #[test]
    fn deferral_waits_are_counted_and_summarized() {
        let mut m = Metrics::new();
        m.record_deferral_wait(0.5);
        m.record_deferral_wait(2.0);
        m.record_deferral_wait(1.0);
        let s = m.summary();
        assert_eq!(s.n_deferred, 3);
        assert!((s.deferral_wait_p95 - 2.0).abs() < 0.2, "p95={}", s.deferral_wait_p95);
        // streaming mode keeps the sample count exact under the reservoir
        let mut lean = Metrics::streaming(2, 9);
        for i in 0..10 {
            lean.record_deferral_wait(i as f64);
        }
        assert_eq!(lean.deferral_wait.count(), 10);
        assert!(lean.deferral_wait.len() <= 2);
    }

    #[test]
    fn degradation_counters_flow_into_the_summary() {
        let mut m = Metrics::new();
        let s = m.summary();
        assert_eq!(s.group_crashes, 0);
        assert_eq!(s.shards_lost, 0);
        assert_eq!(s.reprefill_tokens, 0);
        assert_eq!(s.kv_overcommit_tokens, 0);
        assert_eq!(s.n_recovered, 0);
        assert!(s.recovery_wait_p95.is_nan());
        m.group_crashes = 1;
        m.shards_lost = 3;
        m.reprefill_tokens = 8_192;
        m.kv_overcommit_tokens = 64;
        m.record_recovery_wait(0.5);
        m.record_recovery_wait(1.5);
        let s = m.summary();
        assert_eq!(s.group_crashes, 1);
        assert_eq!(s.shards_lost, 3);
        assert_eq!(s.reprefill_tokens, 8_192);
        assert_eq!(s.kv_overcommit_tokens, 64);
        assert_eq!(s.n_recovered, 2);
        assert!((s.recovery_wait_p50 - 1.0).abs() < 0.51);
        assert!(s.recovery_wait_p95 >= s.recovery_wait_p50);
        // streaming mode reservoirs the wait samples like every other set
        let mut lean = Metrics::streaming(4, 3);
        for i in 0..10 {
            lean.record_recovery_wait(i as f64);
        }
        assert_eq!(lean.recovery_wait.count(), 10);
        assert!(lean.recovery_wait.len() <= 4);
    }

    #[test]
    fn admission_counters_flow_into_the_summary() {
        let mut m = Metrics::new();
        let s = m.summary();
        assert_eq!(s.n_shed, 0);
        assert_eq!(s.n_rejected_queue_full, 0);
        m.record_shed(false);
        m.record_shed(true);
        m.record_shed(true);
        m.record_queue_reject(false);
        let s = m.summary();
        assert_eq!(s.n_shed, 3);
        assert_eq!(s.n_shed_short, 1);
        assert_eq!(s.n_shed_doc, 2);
        assert_eq!(s.n_rejected_queue_full, 1);
        assert_eq!(s.n_rejected_short, 1);
        assert_eq!(s.n_rejected_doc, 0);
        // the per-class splits always sum to the totals
        assert_eq!(s.n_shed, s.n_shed_short + s.n_shed_doc);
        assert_eq!(s.n_rejected_queue_full, s.n_rejected_short + s.n_rejected_doc);
    }

    #[test]
    fn prefix_reuse_counters_flow_into_the_summary() {
        let mut m = Metrics::new();
        let s = m.summary();
        assert_eq!(s.prefix_hit_tokens, 0);
        assert_eq!(s.blocks_shared, 0);
        assert_eq!(s.reprefill_shared_tokens, 0);
        assert!(s.prefix_hit_rate.is_nan(), "no prompt tokens yet");
        m.prefix_hit_tokens = 1_024;
        m.blocks_shared = 4;
        m.reprefill_shared_tokens = 256;
        m.prefill_tokens = 3_072; // executed prefill
        let s = m.summary();
        assert_eq!(s.prefix_hit_tokens, 1_024);
        assert_eq!(s.blocks_shared, 4);
        assert_eq!(s.reprefill_shared_tokens, 256);
        // 1024 of 4096 prompt tokens came from cache
        assert!((s.prefix_hit_rate - 0.25).abs() < 1e-12);
        // all-hit corner: rate pegs at 1 with no executed prefill
        let mut all_hit = Metrics::new();
        all_hit.prefix_hit_tokens = 10;
        assert!((all_hit.summary().prefix_hit_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn active_preemptions_are_counted_and_logged_separately() {
        let mut m = Metrics::new();
        m.preemptions = 3; // queued re-orderings, installed by the sim
        m.record_active_preemption(1.5, 42);
        m.record_active_preemption(2.5, 42);
        let s = m.summary();
        assert_eq!(s.preemptions, 3);
        assert_eq!(s.active_preemptions, 2);
        assert_eq!(
            m.preemption_events,
            vec![
                PreemptionEvent { t: 1.5, request: 42, kind: PreemptionKind::ActiveYield },
                PreemptionEvent { t: 2.5, request: 42, kind: PreemptionKind::ActiveYield },
            ]
        );
        // lean/streaming mode keeps the counter exact but drops the trail
        let mut lean = Metrics::streaming(16, 1);
        lean.record_active_preemption(1.0, 7);
        assert_eq!(lean.active_preemptions, 1);
        assert!(lean.preemption_events.is_empty());
    }

    #[test]
    fn group_utilization_tracks_busy_share_of_span() {
        let mut m = Metrics::new();
        m.record_iter(IterRecord { t: 10.0, dur_s: 10.0, chunk: None, n_decodes: 0, active_gpus: 8 });
        m.record_group_iter(0, 8.0, 1_000, 16);
        m.record_group_iter(2, 2.0, 0, 4); // group 1 untouched, grows zeroed
        m.record_group_iter(0, 1.0, 500, 0);
        assert_eq!(m.group_busy_s, vec![9.0, 0.0, 2.0]);
        assert_eq!(m.group_prefill_tokens, vec![1_500, 0, 0]);
        assert_eq!(m.group_decode_tokens, vec![16, 0, 4]);
        let u = m.group_utilization();
        assert!((u[0] - 0.9).abs() < 1e-12 && u[1] == 0.0 && (u[2] - 0.2).abs() < 1e-12);
    }

    #[test]
    fn streaming_mode_bounds_memory_keeps_counters_exact() {
        let mut m = Metrics::streaming(256, 7);
        for i in 0..10_000u64 {
            m.record_iter(IterRecord {
                t: i as f64 + 1.0,
                dur_s: 1.0,
                chunk: Some(64),
                n_decodes: 2,
                active_gpus: 8,
            });
            m.record_tbt(0.01 + (i % 100) as f64 * 1e-4);
        }
        assert!(m.iters.is_empty());
        assert_eq!(m.n_iters, 10_000);
        assert_eq!(m.decode_tokens, 20_000);
        assert_eq!(m.prefill_tokens, 640_000);
        assert!((m.span_s() - 10_000.0).abs() < 1e-9);
        assert!(m.tbt.len() <= 256);
        let s = m.summary();
        assert_eq!(s.n_tbt, 10_000);
        // p50 of the uniform 0.01..0.02 ramp, estimated from the reservoir
        assert!((s.tbt_p50 - 0.015).abs() < 0.002, "p50={}", s.tbt_p50);
        // p99 comes from the full-stream P² estimator in streaming mode
        assert!((s.tbt_p99 - 0.0199).abs() < 0.0005, "p99={}", s.tbt_p99);
    }
}
