//! Serving metrics: TTFT / TBT percentile recorders, per-iteration traces
//! (the Fig. 19 timeline), and MFU/MBU aggregation (Figs. 20–21).
//!
//! Ingestion is O(1) amortized: percentile sorting is deferred to query
//! time, and the wall-clock span is tracked incrementally instead of being
//! recomputed from the iteration trace. For multi-million-request runs,
//! [`Metrics::streaming`] bounds memory by reservoir-sampling the latency
//! populations and dropping the per-iteration trace (aggregate counters
//! are always exact).

use crate::util::stats::{P2Quantile, Samples};

/// One scheduler iteration's record (drives Figs. 8, 19, 22).
#[derive(Debug, Clone, PartialEq)]
pub struct IterRecord {
    /// Completion time of the iteration (sim seconds).
    pub t: f64,
    /// Iteration execution time.
    pub dur_s: f64,
    /// Prefill chunk size scheduled, if any.
    pub chunk: Option<u64>,
    /// Decode tokens in the batch.
    pub n_decodes: usize,
    /// GPUs participating at this time (KVP growth staircase).
    pub active_gpus: u32,
}

#[derive(Debug)]
pub struct Metrics {
    pub ttft: Samples,
    pub tbt: Samples,
    /// Full iteration trace; empty when `keep_iter_records` is off.
    pub iters: Vec<IterRecord>,
    /// Retain per-iteration records (figure reproduction needs them; the
    /// million-request throughput benches turn this off).
    pub keep_iter_records: bool,
    pub mfu: Samples,
    pub mbu: Samples,
    pub finished_requests: u64,
    pub decode_tokens: u64,
    pub prefill_tokens: u64,
    /// Iterations recorded (exact even when the trace is dropped).
    pub n_iters: u64,
    /// Streaming-mode P² estimator for TBT p99: tracks the tail over the
    /// *full* sample stream, where a small reservoir holds too few tail
    /// points to resolve it.
    tbt_p99_stream: Option<P2Quantile>,
    /// Start time of the first recorded iteration (t - dur).
    first_iter_start: Option<f64>,
    /// Completion time of the last recorded iteration.
    last_iter_t: f64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            ttft: Samples::new(),
            tbt: Samples::new(),
            iters: Vec::new(),
            keep_iter_records: true,
            mfu: Samples::new(),
            mbu: Samples::new(),
            finished_requests: 0,
            decode_tokens: 0,
            prefill_tokens: 0,
            n_iters: 0,
            tbt_p99_stream: None,
            first_iter_start: None,
            last_iter_t: 0.0,
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Bounded-memory recorder for huge runs: latency/utilization samples
    /// are reservoir-capped at `reservoir` and the iteration trace is not
    /// retained. Counters, span, and throughput stay exact.
    pub fn streaming(reservoir: usize, seed: u64) -> Metrics {
        Metrics {
            ttft: Samples::reservoir(reservoir, seed ^ 0x7474_6674),
            tbt: Samples::reservoir(reservoir, seed ^ 0x0074_6274),
            mfu: Samples::reservoir(reservoir, seed ^ 0x0066_7564),
            mbu: Samples::reservoir(reservoir, seed ^ 0x0062_7564),
            keep_iter_records: false,
            tbt_p99_stream: Some(P2Quantile::new(0.99)),
            ..Metrics::default()
        }
    }

    pub fn record_iter(&mut self, rec: IterRecord) {
        self.decode_tokens += rec.n_decodes as u64;
        self.prefill_tokens += rec.chunk.unwrap_or(0);
        self.n_iters += 1;
        if self.first_iter_start.is_none() {
            self.first_iter_start = Some(rec.t - rec.dur_s);
        }
        self.last_iter_t = rec.t;
        if self.keep_iter_records {
            self.iters.push(rec);
        }
    }

    pub fn record_ttft(&mut self, s: f64) {
        self.ttft.add(s);
    }

    pub fn record_tbt(&mut self, s: f64) {
        self.tbt.add(s);
        if let Some(q) = &mut self.tbt_p99_stream {
            q.add(s);
        }
    }

    /// Wall-clock span of the recorded iterations.
    pub fn span_s(&self) -> f64 {
        match self.first_iter_start {
            Some(start) => self.last_iter_t - start,
            None => 0.0,
        }
    }

    /// Decode throughput over the recorded span (tokens/s).
    pub fn decode_tokens_per_s(&self) -> f64 {
        let span = self.span_s();
        if span <= 0.0 {
            return 0.0;
        }
        self.decode_tokens as f64 / span
    }

    pub fn summary(&mut self) -> MetricsSummary {
        MetricsSummary {
            n_ttft: self.ttft.count() as usize,
            ttft_p50: self.ttft.median(),
            ttft_p95: self.ttft.p95(),
            n_tbt: self.tbt.count() as usize,
            tbt_p50: self.tbt.median(),
            tbt_p95: self.tbt.p95(),
            // In streaming mode the P² estimator saw every sample; the
            // reservoir's sparse tail is the fallback-only path. Exact mode
            // (no estimator) is untouched — bit-identical to the reference.
            tbt_p99: match &self.tbt_p99_stream {
                Some(q) if q.count() > 0 => q.value(),
                _ => self.tbt.p99(),
            },
            tbt_max: self.tbt.max(),
            finished: self.finished_requests,
            decode_tps: self.decode_tokens_per_s(),
            mfu_mean: self.mfu.mean(),
            mbu_mean: self.mbu.mean(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSummary {
    pub n_ttft: usize,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub n_tbt: usize,
    pub tbt_p50: f64,
    pub tbt_p95: f64,
    pub tbt_p99: f64,
    pub tbt_max: f64,
    pub finished: u64,
    pub decode_tps: f64,
    pub mfu_mean: f64,
    pub mbu_mean: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_accounting() {
        let mut m = Metrics::new();
        m.record_iter(IterRecord {
            t: 1.0,
            dur_s: 1.0,
            chunk: Some(512),
            n_decodes: 4,
            active_gpus: 8,
        });
        m.record_iter(IterRecord {
            t: 2.0,
            dur_s: 1.0,
            chunk: None,
            n_decodes: 8,
            active_gpus: 8,
        });
        assert_eq!(m.prefill_tokens, 512);
        assert_eq!(m.decode_tokens, 12);
        assert_eq!(m.n_iters, 2);
        assert!((m.span_s() - 2.0).abs() < 1e-12);
        assert!((m.decode_tokens_per_s() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record_tbt(i as f64 / 1000.0);
        }
        m.record_ttft(3.0);
        let s = m.summary();
        assert!((s.tbt_p50 - 0.0505).abs() < 1e-3);
        assert!(s.tbt_p95 > s.tbt_p50);
        assert_eq!(s.n_ttft, 1);
    }

    #[test]
    fn streaming_mode_bounds_memory_keeps_counters_exact() {
        let mut m = Metrics::streaming(256, 7);
        for i in 0..10_000u64 {
            m.record_iter(IterRecord {
                t: i as f64 + 1.0,
                dur_s: 1.0,
                chunk: Some(64),
                n_decodes: 2,
                active_gpus: 8,
            });
            m.record_tbt(0.01 + (i % 100) as f64 * 1e-4);
        }
        assert!(m.iters.is_empty());
        assert_eq!(m.n_iters, 10_000);
        assert_eq!(m.decode_tokens, 20_000);
        assert_eq!(m.prefill_tokens, 640_000);
        assert!((m.span_s() - 10_000.0).abs() < 1e-9);
        assert!(m.tbt.len() <= 256);
        let s = m.summary();
        assert_eq!(s.n_tbt, 10_000);
        // p50 of the uniform 0.01..0.02 ramp, estimated from the reservoir
        assert!((s.tbt_p50 - 0.015).abs() < 0.002, "p50={}", s.tbt_p50);
        // p99 comes from the full-stream P² estimator in streaming mode
        assert!((s.tbt_p99 - 0.0199).abs() < 0.0005, "p99={}", s.tbt_p99);
    }
}
