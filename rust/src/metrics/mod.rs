//! Serving metrics: TTFT / TBT percentile recorders, per-iteration traces
//! (the Fig. 19 timeline), and MFU/MBU aggregation (Figs. 20–21).

use crate::util::stats::Samples;

/// One scheduler iteration's record (drives Figs. 8, 19, 22).
#[derive(Debug, Clone, PartialEq)]
pub struct IterRecord {
    /// Completion time of the iteration (sim seconds).
    pub t: f64,
    /// Iteration execution time.
    pub dur_s: f64,
    /// Prefill chunk size scheduled, if any.
    pub chunk: Option<u64>,
    /// Decode tokens in the batch.
    pub n_decodes: usize,
    /// GPUs participating at this time (KVP growth staircase).
    pub active_gpus: u32,
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub ttft: Samples,
    pub tbt: Samples,
    pub iters: Vec<IterRecord>,
    pub mfu: Samples,
    pub mbu: Samples,
    pub finished_requests: u64,
    pub decode_tokens: u64,
    pub prefill_tokens: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_iter(&mut self, rec: IterRecord) {
        self.decode_tokens += rec.n_decodes as u64;
        self.prefill_tokens += rec.chunk.unwrap_or(0);
        self.iters.push(rec);
    }

    pub fn record_ttft(&mut self, s: f64) {
        self.ttft.add(s);
    }

    pub fn record_tbt(&mut self, s: f64) {
        self.tbt.add(s);
    }

    /// Wall-clock span of the recorded iterations.
    pub fn span_s(&self) -> f64 {
        match (self.iters.first(), self.iters.last()) {
            (Some(a), Some(b)) => b.t - (a.t - a.dur_s),
            _ => 0.0,
        }
    }

    /// Decode throughput over the recorded span (tokens/s).
    pub fn decode_tokens_per_s(&self) -> f64 {
        let span = self.span_s();
        if span <= 0.0 {
            return 0.0;
        }
        self.decode_tokens as f64 / span
    }

    pub fn summary(&mut self) -> MetricsSummary {
        MetricsSummary {
            n_ttft: self.ttft.len(),
            ttft_p50: self.ttft.median(),
            ttft_p95: self.ttft.p95(),
            n_tbt: self.tbt.len(),
            tbt_p50: self.tbt.median(),
            tbt_p95: self.tbt.p95(),
            tbt_p99: self.tbt.p99(),
            tbt_max: self.tbt.max(),
            finished: self.finished_requests,
            decode_tps: self.decode_tokens_per_s(),
            mfu_mean: self.mfu.mean(),
            mbu_mean: self.mbu.mean(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSummary {
    pub n_ttft: usize,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub n_tbt: usize,
    pub tbt_p50: f64,
    pub tbt_p95: f64,
    pub tbt_p99: f64,
    pub tbt_max: f64,
    pub finished: u64,
    pub decode_tps: f64,
    pub mfu_mean: f64,
    pub mbu_mean: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_accounting() {
        let mut m = Metrics::new();
        m.record_iter(IterRecord {
            t: 1.0,
            dur_s: 1.0,
            chunk: Some(512),
            n_decodes: 4,
            active_gpus: 8,
        });
        m.record_iter(IterRecord {
            t: 2.0,
            dur_s: 1.0,
            chunk: None,
            n_decodes: 8,
            active_gpus: 8,
        });
        assert_eq!(m.prefill_tokens, 512);
        assert_eq!(m.decode_tokens, 12);
        assert!((m.span_s() - 2.0).abs() < 1e-12);
        assert!((m.decode_tokens_per_s() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record_tbt(i as f64 / 1000.0);
        }
        m.record_ttft(3.0);
        let s = m.summary();
        assert!((s.tbt_p50 - 0.0505).abs() < 1e-3);
        assert!(s.tbt_p95 > s.tbt_p50);
        assert_eq!(s.n_ttft, 1);
    }
}
