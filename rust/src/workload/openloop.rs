//! Open-loop arrival generators for the online serving mode
//! ([`crate::sim::serve`]): unlike the closed-loop traces in the parent
//! module, these model an *offered load* the system does not control —
//! clients keep arriving whether or not the fleet keeps up, so the
//! coordinator must admit, queue, or shed. Three overload shapes:
//!
//! - [`flash_crowd`] — steady Poisson traffic with a multiplicative burst
//!   window (a link goes viral).
//! - [`diurnal`] — a sinusoidal ramp from trough to peak and back (the
//!   daily cycle compressed into one horizon).
//! - [`overcommit`] — sustained arrivals at a fixed multiple of the base
//!   rate (capacity planning got it wrong; nothing will drain the backlog).
//!
//! All generators are deterministic in `(config, seed)`. Time-varying
//! rates use Lewis–Shedler thinning against the peak rate, so the arrival
//! process is an exact inhomogeneous Poisson draw, not a piecewise
//! approximation. Like [`super::convoy`], every `doc_every`-th arrival is
//! deterministically a document, keeping the class mix stable across seeds.

use super::RequestSpec;
use crate::util::rng::Rng;

/// Shape of one open-loop scenario. One struct covers all three generators;
/// each reads the knobs for its own shape and ignores the rest.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Steady-state offered load (requests/s, both classes together).
    pub base_rate_per_s: f64,
    /// Arrivals stop after this horizon (the driver then drains).
    pub horizon_s: f64,
    pub short_prompt: u64,
    pub short_new_tokens: u64,
    /// Document prompt length — exceed the simulator's `long_threshold`
    /// so documents take the KVP-sharded long path.
    pub doc_prompt: u64,
    pub doc_new_tokens: u64,
    /// Every `doc_every`-th arrival is a document (0 = shorts only).
    pub doc_every: u64,
    /// Flash crowd: burst window start.
    pub burst_start_s: f64,
    /// Flash crowd: burst window length.
    pub burst_len_s: f64,
    /// Flash crowd: rate multiplier inside the burst window.
    pub burst_mult: f64,
    /// Diurnal: peak rate as a multiple of the base (trough) rate.
    pub peak_mult: f64,
    /// Overcommit: sustained rate as a multiple of the base rate.
    pub overcommit_mult: f64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        OpenLoopConfig {
            base_rate_per_s: 8.0,
            horizon_s: 40.0,
            short_prompt: 512,
            short_new_tokens: 32,
            doc_prompt: 131_072,
            doc_new_tokens: 8,
            doc_every: 32,
            burst_start_s: 10.0,
            burst_len_s: 8.0,
            burst_mult: 4.0,
            peak_mult: 3.0,
            overcommit_mult: 2.0,
        }
    }
}

impl OpenLoopConfig {
    /// Whether a request of this trace is a document (by prompt length) —
    /// the same class boundary the admission layer keys its buckets on.
    pub fn is_doc(&self, prompt_len: u64) -> bool {
        prompt_len >= self.doc_prompt
    }

    /// Down-scaled shape for CI smoke runs (`MEDHA_BENCH_SMOKE=1`): short
    /// horizon, smaller documents, same overload structure.
    pub fn smoke() -> OpenLoopConfig {
        OpenLoopConfig {
            base_rate_per_s: 4.0,
            horizon_s: 6.0,
            doc_prompt: 65_536,
            doc_every: 16,
            burst_start_s: 2.0,
            burst_len_s: 2.0,
            ..OpenLoopConfig::default()
        }
    }
}

/// Named open-loop scenario, as selected by `medha serve-sim --scenario`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    Flash,
    Diurnal,
    Overcommit,
}

impl Scenario {
    pub const ALL: [Scenario; 3] = [Scenario::Flash, Scenario::Diurnal, Scenario::Overcommit];

    pub fn parse(s: &str) -> Option<Scenario> {
        match s {
            "flash" => Some(Scenario::Flash),
            "diurnal" => Some(Scenario::Diurnal),
            "overcommit" => Some(Scenario::Overcommit),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Scenario::Flash => "flash",
            Scenario::Diurnal => "diurnal",
            Scenario::Overcommit => "overcommit",
        }
    }
}

/// Dispatch a named scenario to its generator.
pub fn generate(scenario: Scenario, cfg: &OpenLoopConfig, seed: u64) -> Vec<RequestSpec> {
    match scenario {
        Scenario::Flash => flash_crowd(cfg, seed),
        Scenario::Diurnal => diurnal(cfg, seed),
        Scenario::Overcommit => overcommit(cfg, seed),
    }
}

/// Steady base-rate traffic with a `burst_mult`× window at
/// `[burst_start_s, burst_start_s + burst_len_s)`.
pub fn flash_crowd(cfg: &OpenLoopConfig, seed: u64) -> Vec<RequestSpec> {
    let base = cfg.base_rate_per_s;
    let mult = cfg.burst_mult.max(1.0);
    let (b0, b1) = (cfg.burst_start_s, cfg.burst_start_s + cfg.burst_len_s);
    inhomogeneous(cfg, seed, base * mult, move |t| {
        if (b0..b1).contains(&t) {
            base * mult
        } else {
            base
        }
    })
}

/// Sinusoidal ramp: the rate starts at the base (trough), peaks at
/// `peak_mult`× mid-horizon, and returns to the trough by the end.
pub fn diurnal(cfg: &OpenLoopConfig, seed: u64) -> Vec<RequestSpec> {
    let base = cfg.base_rate_per_s;
    let peak = base * cfg.peak_mult.max(1.0);
    let horizon = cfg.horizon_s;
    inhomogeneous(cfg, seed, peak, move |t| {
        let phase = (std::f64::consts::TAU * t / horizon).cos();
        base + (peak - base) * 0.5 * (1.0 - phase)
    })
}

/// Sustained arrivals at `overcommit_mult`× the base rate for the whole
/// horizon — the backlog grows without bound unless admission sheds.
pub fn overcommit(cfg: &OpenLoopConfig, seed: u64) -> Vec<RequestSpec> {
    let rate = cfg.base_rate_per_s * cfg.overcommit_mult.max(0.0);
    inhomogeneous(cfg, seed, rate, move |_| rate)
}

/// Inhomogeneous Poisson draw by Lewis–Shedler thinning: candidate events
/// at the peak rate `rate_max`, each kept with probability
/// `rate_at(t) / rate_max`. Exact for any `rate_at <= rate_max`, and for a
/// constant rate it degenerates to the plain exponential-gap generator
/// (every candidate accepted).
fn inhomogeneous(
    cfg: &OpenLoopConfig,
    seed: u64,
    rate_max: f64,
    rate_at: impl Fn(f64) -> f64,
) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    if rate_max <= 0.0 {
        return out;
    }
    loop {
        t += rng.exponential(rate_max);
        if t >= cfg.horizon_s {
            break;
        }
        if rng.f64() * rate_max > rate_at(t) {
            continue; // thinned candidate — consumes RNG state, emits nothing
        }
        // Deterministic document injection (same idiom as `convoy`): the
        // doc_every/2 offset keeps the very first arrival a short.
        let doc = cfg.doc_every > 0 && id % cfg.doc_every == cfg.doc_every / 2;
        out.push(RequestSpec {
            id,
            prompt_len: if doc { cfg.doc_prompt } else { cfg.short_prompt },
            max_new_tokens: if doc {
                cfg.doc_new_tokens
            } else {
                cfg.short_new_tokens
            },
            prefix_ns: 0,
            sys_tokens: 0,
            arrival_s: t,
        });
        id += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_in(w: &[RequestSpec], lo: f64, hi: f64) -> usize {
        w.iter()
            .filter(|r| (lo..hi).contains(&r.arrival_s))
            .count()
    }

    fn assert_well_formed(w: &[RequestSpec], cfg: &OpenLoopConfig) {
        assert!(w.windows(2).all(|p| p[1].arrival_s >= p[0].arrival_s));
        let ids: Vec<u64> = w.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..w.len() as u64).collect::<Vec<_>>());
        assert!(w
            .iter()
            .all(|r| r.prompt_len == cfg.short_prompt || r.prompt_len == cfg.doc_prompt));
        assert!(w.iter().all(|r| r.arrival_s < cfg.horizon_s));
    }

    #[test]
    fn flash_crowd_bursts_inside_the_window() {
        let cfg = OpenLoopConfig::default();
        let w = flash_crowd(&cfg, 42);
        assert_well_formed(&w, &cfg);
        // density inside the burst window vs an equally long quiet stretch
        let burst = count_in(&w, cfg.burst_start_s, cfg.burst_start_s + cfg.burst_len_s);
        let quiet = count_in(&w, 30.0, 30.0 + cfg.burst_len_s);
        assert!(
            burst as f64 > 2.0 * quiet as f64,
            "burst={burst} quiet={quiet}"
        );
        assert_eq!(w, flash_crowd(&cfg, 42));
        assert_ne!(w, flash_crowd(&cfg, 43));
    }

    #[test]
    fn diurnal_peaks_mid_horizon() {
        let cfg = OpenLoopConfig {
            horizon_s: 60.0,
            ..OpenLoopConfig::default()
        };
        let w = diurnal(&cfg, 42);
        assert_well_formed(&w, &cfg);
        // peak quarter (centered mid-horizon) vs the leading trough quarter
        let peak = count_in(&w, 22.5, 37.5);
        let trough = count_in(&w, 0.0, 15.0);
        assert!(peak > trough, "peak={peak} trough={trough}");
        assert_eq!(w, diurnal(&cfg, 42));
    }

    #[test]
    fn overcommit_rate_scales_with_multiplier() {
        let cfg = OpenLoopConfig {
            base_rate_per_s: 10.0,
            horizon_s: 100.0,
            overcommit_mult: 2.0,
            ..OpenLoopConfig::default()
        };
        let w = overcommit(&cfg, 7);
        assert_well_formed(&w, &cfg);
        // ~2000 expected arrivals; allow generous Poisson slack
        assert!((1700..2300).contains(&w.len()), "{}", w.len());
        let base = overcommit(
            &OpenLoopConfig {
                overcommit_mult: 1.0,
                ..cfg.clone()
            },
            7,
        );
        assert!(w.len() > base.len() * 3 / 2, "{} vs {}", w.len(), base.len());
    }

    #[test]
    fn document_mix_is_deterministic_and_classed() {
        let cfg = OpenLoopConfig::default();
        let w = overcommit(&cfg, 11);
        let docs = w.iter().filter(|r| cfg.is_doc(r.prompt_len)).count();
        let expect = w.len() / cfg.doc_every as usize;
        assert!(docs >= expect.saturating_sub(1) && docs <= expect + 1, "docs={docs}");
        assert!(!cfg.is_doc(cfg.short_prompt));
        assert!(cfg.is_doc(cfg.doc_prompt));
    }

    #[test]
    fn scenario_names_round_trip() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::parse(s.name()), Some(s));
        }
        assert_eq!(Scenario::parse("nope"), None);
        // dispatch matches the direct generators
        let cfg = OpenLoopConfig::default();
        assert_eq!(generate(Scenario::Flash, &cfg, 5), flash_crowd(&cfg, 5));
        assert_eq!(generate(Scenario::Overcommit, &cfg, 5), overcommit(&cfg, 5));
    }

    #[test]
    fn zero_doc_every_is_all_short() {
        let cfg = OpenLoopConfig {
            doc_every: 0,
            ..OpenLoopConfig::default()
        };
        let w = diurnal(&cfg, 9);
        assert!(w.iter().all(|r| r.prompt_len == cfg.short_prompt));
    }
}
