//! Workload generators: the request populations behind each figure, plus
//! production-like mixed traffic (Poisson arrivals, skewed context lengths —
//! section 3's C3: inputs "ranging from 10s to 1000s, and now millions of
//! tokens"), and randomized-but-deterministic fleet fault schedules
//! ([`fault_storm`]) for the elastic-fleet robustness runs.

use crate::config::{FaultEvent, FaultKind, FaultPlan};
use crate::util::rng::Rng;

pub mod openloop;

/// A request as submitted by a client.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestSpec {
    pub id: u64,
    pub prompt_len: u64,
    pub max_new_tokens: u64,
    pub arrival_s: f64,
    /// Prefix namespace for KV reuse: requests sharing a namespace share a
    /// growing-history prefix (a multi-turn session). `0` — the default and
    /// every one-shot workload — opts out of prefix reuse entirely.
    pub prefix_ns: u64,
    /// Leading tokens of the prompt that are a fleet-wide shared system
    /// prompt: their KV blocks hash into a namespace shared across *all*
    /// sessions, so even a first turn can hit.
    pub sys_tokens: u64,
}

impl Default for RequestSpec {
    fn default() -> Self {
        RequestSpec {
            id: 0,
            prompt_len: 1,
            max_new_tokens: 1,
            arrival_s: 0.0,
            prefix_ns: 0,
            sys_tokens: 0,
        }
    }
}

/// One long request arriving at t=0 (Figs. 14a, 15: pure prefill scaling).
pub fn single_long(ctx: u64, new_tokens: u64) -> Vec<RequestSpec> {
    vec![RequestSpec {
        id: 0,
        prompt_len: ctx,
        max_new_tokens: new_tokens,
        ..RequestSpec::default()
    }]
}

/// One long prefill plus `n_decodes` short requests already decoding —
/// the mixed-batching scenario of Figs. 8, 18, 22. Short requests arrive
/// first (tiny prompts, long outputs) so they are mid-decode when the long
/// request lands.
pub fn long_plus_decodes(
    ctx: u64,
    n_decodes: usize,
    decode_ctx: u64,
    new_tokens: u64,
) -> Vec<RequestSpec> {
    let mut v = Vec::with_capacity(n_decodes + 1);
    for i in 0..n_decodes {
        v.push(RequestSpec {
            id: i as u64 + 1,
            prompt_len: decode_ctx.max(1),
            max_new_tokens: new_tokens,
            ..RequestSpec::default()
        });
    }
    v.push(RequestSpec {
        id: 0,
        prompt_len: ctx,
        max_new_tokens: 32,
        ..RequestSpec::default()
    });
    v
}

/// Decode-only population: requests with `ctx` tokens already prefilled
/// conceptually; modeled as prompt_len=ctx with long outputs (Figs. 16, 17).
pub fn decode_population(n: usize, ctx: u64, new_tokens: u64) -> Vec<RequestSpec> {
    (0..n)
        .map(|i| RequestSpec {
            id: i as u64,
            prompt_len: ctx,
            max_new_tokens: new_tokens,
            ..RequestSpec::default()
        })
        .collect()
}

/// Distribution over context lengths for mixed traffic.
#[derive(Debug, Clone)]
pub enum LengthDist {
    Fixed(u64),
    /// Log-uniform between lo and hi (orders-of-magnitude spread).
    LogUniform { lo: u64, hi: u64 },
    /// Zipf over explicit buckets (few huge, many small).
    ZipfBuckets { buckets: Vec<u64>, s: f64 },
}

impl LengthDist {
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        match self {
            LengthDist::Fixed(n) => *n,
            LengthDist::LogUniform { lo, hi } => rng.log_uniform(*lo, *hi),
            LengthDist::ZipfBuckets { buckets, s } => {
                // rank 0 = most common = the *smallest* context
                let mut sorted = buckets.clone();
                sorted.sort_unstable();
                sorted[rng.zipf(sorted.len() as u64, *s) as usize]
            }
        }
    }
}

/// Heterogeneous convoy trace: Poisson arrivals with **bimodal** lengths —
/// a stream of short interactive requests into which long document
/// prefills are periodically injected (every `long_every`-th arrival, so a
/// fixed-seed trace deterministically contains documents). This is the
/// workload where FCFS exhibits the convoy effect (section 3 / Fig. 2:
/// every short request behind a document waits out its entire multi-second
/// prefill) and LARS eliminates it via chunk-boundary preemption.
#[derive(Debug, Clone)]
pub struct ConvoyConfig {
    /// Total arrival rate (requests/s), both classes.
    pub rate_per_s: f64,
    /// Arrivals stop after this horizon (the simulation then drains).
    pub horizon_s: f64,
    /// Interactive-class prompt length.
    pub short_prompt: u64,
    pub short_new_tokens: u64,
    /// Document-class prompt length.
    pub long_prompt: u64,
    pub long_new_tokens: u64,
    /// Every `long_every`-th arrival is a document (0 = no documents).
    pub long_every: u64,
}

impl Default for ConvoyConfig {
    fn default() -> Self {
        ConvoyConfig {
            rate_per_s: 2.0,
            horizon_s: 60.0,
            short_prompt: 512,
            short_new_tokens: 64,
            long_prompt: 512_000,
            long_new_tokens: 16,
            long_every: 50,
        }
    }
}

impl ConvoyConfig {
    /// Whether a request of this trace is a document (by prompt length).
    pub fn is_long(&self, prompt_len: u64) -> bool {
        prompt_len >= self.long_prompt
    }
}

pub fn convoy(cfg: &ConvoyConfig, seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    let mut id = 0u64;
    loop {
        t += rng.exponential(cfg.rate_per_s);
        if t >= cfg.horizon_s {
            break;
        }
        // deterministic injection keeps the document count stable across
        // seeds; the long_every/4 offset keeps the first arrival short for
        // the shipped spacings (long_every >= 4 — below that it is 0 and
        // the trace leads with a document)
        let long = cfg.long_every > 0 && id % cfg.long_every == cfg.long_every / 4;
        out.push(RequestSpec {
            id,
            prompt_len: if long { cfg.long_prompt } else { cfg.short_prompt },
            max_new_tokens: if long {
                cfg.long_new_tokens
            } else {
                cfg.short_new_tokens
            },
            arrival_s: t,
            ..RequestSpec::default()
        });
        id += 1;
    }
    out
}

/// KVP convoy trace (section 4.4 + 7): a Poisson stream of short
/// interactive requests, plus a burst of **overlapping** document prefills
/// long enough to shard across KVP groups. Documents are injected at fixed
/// staggered times shorter than one document's service time, so a fresh
/// document always arrives while another is mid-prefill — the scenario
/// where policy-aware routing (shorts steered off the sharding groups) and
/// active-long-request preemption both matter. Arrivals are deterministic
/// given the seed; documents are at fixed offsets so every seed contains
/// the same overlap structure.
#[derive(Debug, Clone)]
pub struct KvpConvoyConfig {
    /// Short-request arrival rate (requests/s).
    pub rate_per_s: f64,
    /// Short arrivals stop after this horizon (the simulation then drains).
    pub horizon_s: f64,
    pub short_prompt: u64,
    pub short_new_tokens: u64,
    /// Document prompt length — must exceed the simulator's
    /// `long_threshold` so documents take the KVP-sharded path.
    pub doc_prompt: u64,
    pub doc_new_tokens: u64,
    /// Number of documents injected.
    pub n_docs: usize,
    /// First document's arrival time.
    pub doc_start_s: f64,
    /// Gap between consecutive document arrivals (chosen shorter than one
    /// document's prefill so their service windows overlap).
    pub doc_stagger_s: f64,
}

impl Default for KvpConvoyConfig {
    fn default() -> Self {
        KvpConvoyConfig {
            rate_per_s: 8.0,
            horizon_s: 40.0,
            short_prompt: 512,
            short_new_tokens: 32,
            doc_prompt: 512_000,
            doc_new_tokens: 8,
            n_docs: 3,
            doc_start_s: 2.0,
            doc_stagger_s: 12.0,
        }
    }
}

impl KvpConvoyConfig {
    /// Whether a request of this trace is a document (by prompt length).
    pub fn is_doc(&self, prompt_len: u64) -> bool {
        prompt_len >= self.doc_prompt
    }
}

pub fn kvp_convoy(cfg: &KvpConvoyConfig, seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        t += rng.exponential(cfg.rate_per_s);
        if t >= cfg.horizon_s {
            break;
        }
        out.push(RequestSpec {
            id,
            prompt_len: cfg.short_prompt,
            max_new_tokens: cfg.short_new_tokens,
            arrival_s: t,
            ..RequestSpec::default()
        });
        id += 1;
    }
    // Document ids continue the short sequence, keeping ids dense.
    for k in 0..cfg.n_docs {
        out.push(RequestSpec {
            id: id + k as u64,
            prompt_len: cfg.doc_prompt,
            max_new_tokens: cfg.doc_new_tokens,
            arrival_s: cfg.doc_start_s + k as f64 * cfg.doc_stagger_s,
            ..RequestSpec::default()
        });
    }
    out.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
    out
}

/// Configuration for [`fault_storm`]: serialized crash→rejoin cycles drawn
/// from a seeded RNG, the workload-style counterpart of a hand-written
/// [`FaultPlan`] JSON file.
#[derive(Debug, Clone)]
pub struct FaultStormConfig {
    /// Fleet size victims are drawn from. Group 0 is never crashed, so at
    /// least one group stays active through every outage.
    pub n_groups: u32,
    /// Maximum crash→rejoin cycles (fewer if the window runs out).
    pub n_cycles: usize,
    /// No crash before this time (lets the workload ramp up).
    pub start_s: f64,
    /// Crashes are drawn inside `[start_s, start_s + window_s)`.
    pub window_s: f64,
    /// Mean gap from one group's rejoin to the next crash (exponential).
    pub mean_gap_s: f64,
    /// Mean outage duration, crash to rejoin announcement (exponential).
    pub mean_outage_s: f64,
    /// Warm-up each rejoined group spends `Joining` before activating.
    pub warmup_s: f64,
}

impl Default for FaultStormConfig {
    fn default() -> Self {
        FaultStormConfig {
            n_groups: 4,
            n_cycles: 2,
            start_s: 4.0,
            window_s: 30.0,
            mean_gap_s: 4.0,
            mean_outage_s: 6.0,
            warmup_s: 1.0,
        }
    }
}

/// Deterministic random fault schedule: crash→rejoin cycles, serialized so
/// at most one group is ever down (each cycle's crash waits for the
/// previous rejoin plus warm-up), with group 0 never a victim. The plan is
/// therefore valid by construction — every crash targets a live group and
/// the fleet always keeps an active member — and identical for identical
/// `(config, seed)`.
pub fn fault_storm(cfg: &FaultStormConfig, seed: u64) -> FaultPlan {
    assert!(cfg.n_groups >= 2, "a fault storm needs a group to spare");
    let mut rng = Rng::new(seed);
    let mut events = Vec::new();
    let mut t = cfg.start_s;
    for _ in 0..cfg.n_cycles {
        t += rng.exponential(1.0 / cfg.mean_gap_s.max(1e-9));
        if t >= cfg.start_s + cfg.window_s {
            break;
        }
        let victim = 1 + rng.below((cfg.n_groups - 1) as u64) as u32;
        events.push(FaultEvent {
            t_s: t,
            group: Some(victim),
            kind: FaultKind::Crash,
        });
        t += rng.exponential(1.0 / cfg.mean_outage_s.max(1e-9));
        events.push(FaultEvent {
            t_s: t,
            group: Some(victim),
            kind: FaultKind::Join {
                warmup_s: cfg.warmup_s,
            },
        });
        t += cfg.warmup_s;
    }
    let mut plan = FaultPlan { events };
    plan.sort();
    plan.validate()
        .expect("fault_storm generates structurally valid plans");
    plan
}

/// Poisson arrivals with a context-length distribution — the production
/// mix of section 3 C3.
pub fn poisson_mixed(
    rate_per_s: f64,
    horizon_s: f64,
    lengths: LengthDist,
    new_tokens: u64,
    seed: u64,
) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    let mut id = 0;
    loop {
        t += rng.exponential(rate_per_s);
        if t >= horizon_s {
            break;
        }
        out.push(RequestSpec {
            id,
            prompt_len: lengths.sample(&mut rng).max(1),
            max_new_tokens: new_tokens,
            arrival_s: t,
            ..RequestSpec::default()
        });
        id += 1;
    }
    out
}

/// Multi-turn chat sessions over a shared system prompt — the workload
/// where prefix-aware KV reuse pays (section 3's conversational traffic):
/// every turn re-submits the whole conversation, so its prompt is the
/// previous turn's prompt plus the previous reply plus the new user
/// message. Without reuse each turn re-prefills a history that is already
/// resident; with the prefix index only the new suffix costs prefill, and
/// cache-affinity routing keeps a session's turns landing on the group
/// that holds its chain.
#[derive(Debug, Clone)]
pub struct MultiTurnConfig {
    /// Concurrent chat sessions.
    pub n_sessions: usize,
    /// Shared system-prompt tokens leading every session's every prompt
    /// (hashes into a fleet-wide namespace: sessions share these blocks).
    pub sys_prompt: u64,
    /// Turns per session.
    pub turns: usize,
    /// New user tokens appended per turn.
    pub user_tokens: u64,
    /// Reply budget per turn; the reply joins the history the next turn
    /// re-submits.
    pub reply_tokens: u64,
    /// Mean think time between a turn's arrival and the next (exponential —
    /// Poisson turn gaps).
    pub mean_gap_s: f64,
    /// Session `k` opens at `k * session_stagger_s`.
    pub session_stagger_s: f64,
    /// Background one-shot interactive shorts mixed in (0 = none) — the
    /// convoy-style traffic whose tail latency reuse must not hurt.
    pub shorts_rate_per_s: f64,
    pub short_prompt: u64,
    pub short_new_tokens: u64,
    /// Background shorts stop arriving after this horizon.
    pub horizon_s: f64,
}

impl Default for MultiTurnConfig {
    fn default() -> Self {
        MultiTurnConfig {
            n_sessions: 6,
            sys_prompt: 1_024,
            turns: 5,
            user_tokens: 256,
            reply_tokens: 128,
            mean_gap_s: 2.0,
            session_stagger_s: 1.0,
            shorts_rate_per_s: 4.0,
            short_prompt: 512,
            short_new_tokens: 32,
            horizon_s: 30.0,
        }
    }
}

impl MultiTurnConfig {
    /// Prompt length of turn `t` (0-based): system prompt, every prior
    /// user message and reply, plus the new user message.
    pub fn prompt_at(&self, t: usize) -> u64 {
        self.sys_prompt + (t as u64 + 1) * self.user_tokens + t as u64 * self.reply_tokens
    }
}

/// Deterministic multi-turn trace: session turns with Poisson think-time
/// gaps, interleaved with background one-shot shorts, sorted by arrival
/// with ids reassigned densely in arrival order. Session `k`'s turns carry
/// `prefix_ns = k + 1` (namespace 0 opts out of reuse) and
/// `sys_tokens = sys_prompt`; background shorts carry namespace 0.
pub fn multiturn(cfg: &MultiTurnConfig, seed: u64) -> Vec<RequestSpec> {
    let mut out = Vec::new();
    for k in 0..cfg.n_sessions {
        // per-session RNG stream: turn gaps are independent of how many
        // background shorts the horizon admits
        let mut rng = Rng::new(seed ^ (0x5e55_1011u64).wrapping_mul(k as u64 + 1));
        let mut t = k as f64 * cfg.session_stagger_s;
        for turn in 0..cfg.turns {
            out.push(RequestSpec {
                id: 0, // reassigned densely after the sort
                prompt_len: cfg.prompt_at(turn),
                max_new_tokens: cfg.reply_tokens.max(1),
                arrival_s: t,
                prefix_ns: k as u64 + 1,
                sys_tokens: cfg.sys_prompt,
            });
            t += rng.exponential(1.0 / cfg.mean_gap_s.max(1e-9));
        }
    }
    let n_turns = out.len();
    if cfg.shorts_rate_per_s > 0.0 {
        let mut rng = Rng::new(seed ^ 0x0b5e_55ed);
        let mut t = 0.0;
        loop {
            t += rng.exponential(cfg.shorts_rate_per_s);
            if t >= cfg.horizon_s {
                break;
            }
            out.push(RequestSpec {
                id: 0,
                prompt_len: cfg.short_prompt,
                max_new_tokens: cfg.short_new_tokens,
                arrival_s: t,
                ..RequestSpec::default()
            });
        }
    }
    // Stable tie-break before ids exist: namespace (shorts' 0 first), then
    // prompt length — turn prompts within a session are strictly growing,
    // so the key is total on any same-instant pair the generator can emit.
    out.sort_by(|a, b| {
        a.arrival_s
            .total_cmp(&b.arrival_s)
            .then(a.prefix_ns.cmp(&b.prefix_ns))
            .then(a.prompt_len.cmp(&b.prompt_len))
    });
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    debug_assert_eq!(
        out.iter().filter(|r| r.prefix_ns > 0).count(),
        n_turns,
        "session turns survived the sort"
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximates() {
        let w = poisson_mixed(10.0, 100.0, LengthDist::Fixed(128), 16, 7);
        assert!((800..1200).contains(&w.len()), "{}", w.len());
        assert!(w.windows(2).all(|p| p[1].arrival_s >= p[0].arrival_s));
    }

    #[test]
    fn zipf_buckets_prefer_small() {
        let mut rng = Rng::new(3);
        let d = LengthDist::ZipfBuckets {
            buckets: vec![1_000_000, 1_000, 128, 16_000],
            s: 1.2,
        };
        let mut small = 0;
        let mut huge = 0;
        for _ in 0..2_000 {
            match d.sample(&mut rng) {
                128 => small += 1,
                1_000_000 => huge += 1,
                _ => {}
            }
        }
        assert!(small > huge * 3, "small={small} huge={huge}");
    }

    #[test]
    fn mixed_scenario_shapes() {
        let w = long_plus_decodes(1_000_000, 16, 1_000, 100);
        assert_eq!(w.len(), 17);
        assert_eq!(w.iter().filter(|r| r.prompt_len == 1_000_000).count(), 1);
    }

    #[test]
    fn convoy_is_bimodal_with_deterministic_documents() {
        let cfg = ConvoyConfig::default();
        let w = convoy(&cfg, 42);
        let longs = w.iter().filter(|r| cfg.is_long(r.prompt_len)).count();
        let shorts = w.len() - longs;
        // rate 2/s over 60s: ~120 arrivals, documents every 50th
        assert!(shorts > 60, "shorts={shorts}");
        assert!((1..=5).contains(&longs), "longs={longs}");
        // only the two modes appear, arrivals are sorted, ids unique
        assert!(w
            .iter()
            .all(|r| r.prompt_len == cfg.short_prompt || r.prompt_len == cfg.long_prompt));
        assert!(w.windows(2).all(|p| p[1].arrival_s >= p[0].arrival_s));
        let same_seed = convoy(&cfg, 42);
        assert_eq!(w, same_seed);
    }

    #[test]
    fn kvp_convoy_has_overlapping_documents_and_is_deterministic() {
        let cfg = KvpConvoyConfig::default();
        let w = kvp_convoy(&cfg, 42);
        let docs: Vec<&RequestSpec> = w.iter().filter(|r| cfg.is_doc(r.prompt_len)).collect();
        assert_eq!(docs.len(), cfg.n_docs);
        // staggered starts, spaced by exactly the configured gap
        for (k, d) in docs.iter().enumerate() {
            let expect = cfg.doc_start_s + k as f64 * cfg.doc_stagger_s;
            assert!((d.arrival_s - expect).abs() < 1e-12);
        }
        // dense unique ids, sorted arrivals, bimodal lengths
        let mut ids: Vec<u64> = w.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..w.len() as u64).collect::<Vec<_>>());
        assert!(w.windows(2).all(|p| p[1].arrival_s >= p[0].arrival_s));
        assert!(w
            .iter()
            .all(|r| r.prompt_len == cfg.short_prompt || r.prompt_len == cfg.doc_prompt));
        assert!(w.len() > cfg.n_docs + 100, "degenerate: {} requests", w.len());
        assert_eq!(w, kvp_convoy(&cfg, 42));
        assert_ne!(w, kvp_convoy(&cfg, 43));
    }

    #[test]
    fn convoy_long_every_zero_is_all_short() {
        let cfg = ConvoyConfig {
            long_every: 0,
            ..ConvoyConfig::default()
        };
        let w = convoy(&cfg, 7);
        assert!(w.iter().all(|r| r.prompt_len == cfg.short_prompt));
    }

    #[test]
    fn fault_storm_is_deterministic_and_serialized() {
        let cfg = FaultStormConfig::default();
        let plan = fault_storm(&cfg, 42);
        assert_eq!(plan, fault_storm(&cfg, 42));
        assert!(!plan.is_empty());
        assert_eq!(plan.events.len() % 2, 0, "crashes pair with rejoins");
        // Cycles are serialized: crash, its rejoin, then the next crash —
        // the same victim each pair, never group 0, times non-decreasing.
        for pair in plan.events.chunks(2) {
            assert_eq!(pair[0].kind, FaultKind::Crash);
            assert!(matches!(pair[1].kind, FaultKind::Join { .. }));
            assert_eq!(pair[0].group, pair[1].group);
            let g = pair[0].group.unwrap();
            assert!(g >= 1 && g < cfg.n_groups);
            assert!(pair[1].t_s >= pair[0].t_s);
        }
        assert!(plan
            .events
            .windows(2)
            .all(|w| w[1].t_s >= w[0].t_s));
        // A different seed draws a different storm.
        assert_ne!(plan, fault_storm(&cfg, 43));
    }

    #[test]
    fn multiturn_sessions_grow_and_shorts_stay_namespace_free() {
        let cfg = MultiTurnConfig::default();
        let w = multiturn(&cfg, 42);
        // dense ids in arrival order
        assert!(w.windows(2).all(|p| p[1].arrival_s >= p[0].arrival_s));
        assert_eq!(
            w.iter().map(|r| r.id).collect::<Vec<_>>(),
            (0..w.len() as u64).collect::<Vec<_>>()
        );
        // every session contributes exactly `turns` requests with strictly
        // growing prompts and non-decreasing arrivals
        for k in 0..cfg.n_sessions as u64 {
            let turns: Vec<&RequestSpec> =
                w.iter().filter(|r| r.prefix_ns == k + 1).collect();
            assert_eq!(turns.len(), cfg.turns);
            assert!(turns.windows(2).all(|p| p[1].prompt_len > p[0].prompt_len));
            assert!(turns.windows(2).all(|p| p[1].arrival_s > p[0].arrival_s));
            assert!(turns.iter().all(|r| r.sys_tokens == cfg.sys_prompt));
            assert_eq!(turns[0].prompt_len, cfg.sys_prompt + cfg.user_tokens);
        }
        // background shorts opt out of reuse
        let shorts: Vec<&RequestSpec> = w.iter().filter(|r| r.prefix_ns == 0).collect();
        assert!(shorts.len() > 50, "shorts={}", shorts.len());
        assert!(shorts
            .iter()
            .all(|r| r.sys_tokens == 0 && r.prompt_len == cfg.short_prompt));
        // deterministic per (config, seed)
        assert_eq!(w, multiturn(&cfg, 42));
        assert_ne!(w, multiturn(&cfg, 43));
    }

    #[test]
    fn multiturn_without_shorts_is_pure_sessions() {
        let cfg = MultiTurnConfig {
            shorts_rate_per_s: 0.0,
            n_sessions: 2,
            turns: 3,
            ..MultiTurnConfig::default()
        };
        let w = multiturn(&cfg, 7);
        assert_eq!(w.len(), 6);
        assert!(w.iter().all(|r| r.prefix_ns > 0));
    }

    #[test]
    fn log_uniform_spans_decades() {
        let mut rng = Rng::new(11);
        let d = LengthDist::LogUniform { lo: 10, hi: 10_000_000 };
        let xs: Vec<u64> = (0..4_000).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().any(|&x| x < 100));
        assert!(xs.iter().any(|&x| x > 1_000_000));
    }
}
