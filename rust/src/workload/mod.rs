//! Workload generators: the request populations behind each figure, plus
//! production-like mixed traffic (Poisson arrivals, skewed context lengths —
//! section 3's C3: inputs "ranging from 10s to 1000s, and now millions of
//! tokens").

use crate::util::rng::Rng;

/// A request as submitted by a client.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestSpec {
    pub id: u64,
    pub prompt_len: u64,
    pub max_new_tokens: u64,
    pub arrival_s: f64,
}

/// One long request arriving at t=0 (Figs. 14a, 15: pure prefill scaling).
pub fn single_long(ctx: u64, new_tokens: u64) -> Vec<RequestSpec> {
    vec![RequestSpec {
        id: 0,
        prompt_len: ctx,
        max_new_tokens: new_tokens,
        arrival_s: 0.0,
    }]
}

/// One long prefill plus `n_decodes` short requests already decoding —
/// the mixed-batching scenario of Figs. 8, 18, 22. Short requests arrive
/// first (tiny prompts, long outputs) so they are mid-decode when the long
/// request lands.
pub fn long_plus_decodes(
    ctx: u64,
    n_decodes: usize,
    decode_ctx: u64,
    new_tokens: u64,
) -> Vec<RequestSpec> {
    let mut v = Vec::with_capacity(n_decodes + 1);
    for i in 0..n_decodes {
        v.push(RequestSpec {
            id: i as u64 + 1,
            prompt_len: decode_ctx.max(1),
            max_new_tokens: new_tokens,
            arrival_s: 0.0,
        });
    }
    v.push(RequestSpec {
        id: 0,
        prompt_len: ctx,
        max_new_tokens: 32,
        arrival_s: 0.0,
    });
    v
}

/// Decode-only population: requests with `ctx` tokens already prefilled
/// conceptually; modeled as prompt_len=ctx with long outputs (Figs. 16, 17).
pub fn decode_population(n: usize, ctx: u64, new_tokens: u64) -> Vec<RequestSpec> {
    (0..n)
        .map(|i| RequestSpec {
            id: i as u64,
            prompt_len: ctx,
            max_new_tokens: new_tokens,
            arrival_s: 0.0,
        })
        .collect()
}

/// Distribution over context lengths for mixed traffic.
#[derive(Debug, Clone)]
pub enum LengthDist {
    Fixed(u64),
    /// Log-uniform between lo and hi (orders-of-magnitude spread).
    LogUniform { lo: u64, hi: u64 },
    /// Zipf over explicit buckets (few huge, many small).
    ZipfBuckets { buckets: Vec<u64>, s: f64 },
}

impl LengthDist {
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        match self {
            LengthDist::Fixed(n) => *n,
            LengthDist::LogUniform { lo, hi } => rng.log_uniform(*lo, *hi),
            LengthDist::ZipfBuckets { buckets, s } => {
                // rank 0 = most common = the *smallest* context
                let mut sorted = buckets.clone();
                sorted.sort_unstable();
                sorted[rng.zipf(sorted.len() as u64, *s) as usize]
            }
        }
    }
}

/// Poisson arrivals with a context-length distribution — the production
/// mix of section 3 C3.
pub fn poisson_mixed(
    rate_per_s: f64,
    horizon_s: f64,
    lengths: LengthDist,
    new_tokens: u64,
    seed: u64,
) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    let mut id = 0;
    loop {
        t += rng.exponential(rate_per_s);
        if t >= horizon_s {
            break;
        }
        out.push(RequestSpec {
            id,
            prompt_len: lengths.sample(&mut rng).max(1),
            max_new_tokens: new_tokens,
            arrival_s: t,
        });
        id += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximates() {
        let w = poisson_mixed(10.0, 100.0, LengthDist::Fixed(128), 16, 7);
        assert!((800..1200).contains(&w.len()), "{}", w.len());
        assert!(w.windows(2).all(|p| p[1].arrival_s >= p[0].arrival_s));
    }

    #[test]
    fn zipf_buckets_prefer_small() {
        let mut rng = Rng::new(3);
        let d = LengthDist::ZipfBuckets {
            buckets: vec![1_000_000, 1_000, 128, 16_000],
            s: 1.2,
        };
        let mut small = 0;
        let mut huge = 0;
        for _ in 0..2_000 {
            match d.sample(&mut rng) {
                128 => small += 1,
                1_000_000 => huge += 1,
                _ => {}
            }
        }
        assert!(small > huge * 3, "small={small} huge={huge}");
    }

    #[test]
    fn mixed_scenario_shapes() {
        let w = long_plus_decodes(1_000_000, 16, 1_000, 100);
        assert_eq!(w.len(), 17);
        assert_eq!(w.iter().filter(|r| r.prompt_len == 1_000_000).count(), 1);
    }

    #[test]
    fn log_uniform_spans_decades() {
        let mut rng = Rng::new(11);
        let d = LengthDist::LogUniform { lo: 10, hi: 10_000_000 };
        let xs: Vec<u64> = (0..4_000).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().any(|&x| x < 100));
        assert!(xs.iter().any(|&x| x > 1_000_000));
    }
}
