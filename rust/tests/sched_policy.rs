//! Scheduling-policy subsystem integration tests: the convoy-effect
//! regression (LARS must bound short-request tail latency where FCFS lets
//! it blow up), the starvation-freedom invariant (LARS must not starve the
//! long documents it preempts), and end-to-end preemption correctness
//! through the simulator.

use medha::coordinator::SchedPolicyKind;
use medha::sim::{convoy_ttft_split, run_convoy_scenario, Simulation};
use medha::workload::{self, ConvoyConfig};

fn convoy_cfg() -> ConvoyConfig {
    ConvoyConfig::default()
}

/// The exact scenario the `sched` figure and `sched/policy_compare` bench
/// measure — one shared definition in `medha::sim`.
fn run_convoy(kind: SchedPolicyKind) -> (Simulation, ConvoyConfig) {
    let cfg = convoy_cfg();
    (run_convoy_scenario(kind, &cfg, 42), cfg)
}

#[test]
fn convoy_regression_lars_bounds_short_tail_fcfs_does_not() {
    let (fcfs, cfg) = run_convoy(SchedPolicyKind::Fcfs);
    let (lars, _) = run_convoy(SchedPolicyKind::Lars);

    // both policies drain the whole trace
    assert_eq!(fcfs.metrics.finished_requests, lars.metrics.finished_requests);
    assert!(fcfs.metrics.finished_requests > 60);

    let (mut fcfs_short, _) = convoy_ttft_split(&fcfs, &cfg);
    let (mut lars_short, lars_long) = convoy_ttft_split(&lars, &cfg);
    assert!(!lars_long.is_empty(), "trace must contain documents");

    let fcfs_p99 = fcfs_short.p99();
    let lars_p99 = lars_short.p99();
    // the headline: FCFS lets the convoy blow up short-request tails;
    // LARS preempts the documents at chunk boundaries and keeps them bounded
    assert!(
        fcfs_p99 >= 5.0 * lars_p99,
        "convoy not eliminated: fcfs p99 {fcfs_p99:.2}s vs lars p99 {lars_p99:.2}s"
    );
    // LARS actually preempted; FCFS never does
    assert!(lars.metrics.preemptions > 0);
    assert_eq!(fcfs.metrics.preemptions, 0);
}

#[test]
fn lars_never_starves_the_documents() {
    let (lars, cfg) = run_convoy(SchedPolicyKind::Lars);
    let docs: Vec<&medha::coordinator::Request> = lars
        .retired()
        .iter()
        .filter(|r| cfg.is_long(r.prompt_len))
        .collect();
    assert!(!docs.is_empty());
    for d in docs {
        // starvation freedom: every preempted document still finishes its
        // prefill within its own length-aware deadline
        let ttft = d.ttft().unwrap();
        assert!(
            ttft <= d.ttft_budget_s(),
            "document {} starved: ttft {ttft:.1}s > budget {:.1}s",
            d.id,
            d.ttft_budget_s()
        );
        assert!(d.is_finished());
    }
}

#[test]
fn lars_improves_ttft_attainment_over_fcfs_on_the_convoy() {
    let (mut fcfs, _) = run_convoy(SchedPolicyKind::Fcfs);
    let (mut lars, _) = run_convoy(SchedPolicyKind::Lars);
    let sf = fcfs.metrics.summary();
    let sl = lars.metrics.summary();
    assert!(
        sl.ttft_attainment > sf.ttft_attainment,
        "lars attainment {} <= fcfs {}",
        sl.ttft_attainment,
        sf.ttft_attainment
    );
    // most requests meet their length-aware deadline under LARS; under
    // FCFS the convoy makes that impossible
    assert!(sl.ttft_attainment > 0.75, "lars attainment {}", sl.ttft_attainment);
    // goodput (both SLOs met, per second) is reported for both runs
    assert!(sl.goodput_rps.is_finite() && sf.goodput_rps.is_finite());
}

#[test]
fn all_policies_complete_the_convoy_trace() {
    let expected = workload::convoy(&convoy_cfg(), 42).len() as u64;
    for kind in SchedPolicyKind::ALL {
        let (sim, _) = run_convoy(kind);
        assert_eq!(
            sim.metrics.finished_requests, expected,
            "{} left requests behind",
            kind.name()
        );
    }
}

/// End-to-end preemption correctness through the simulator: token-level
/// progress of a preempted document is exact (its prefill resumes from the
/// chunk boundary where it stopped — total prefilled tokens equal the
/// prompt, never recomputed, KV accounted once).
#[test]
fn preempted_document_prefill_is_exact() {
    let (lars, cfg) = run_convoy(SchedPolicyKind::Lars);
    for r in lars.retired() {
        assert_eq!(r.prefilled, r.prompt_len, "request {} prefill mismatch", r.id);
        if cfg.is_long(r.prompt_len) {
            assert_eq!(r.decoded, cfg.long_new_tokens);
        }
    }
    // sum of prefill tokens across all iterations equals the trace's total
    // prompt tokens: chunks were neither lost nor re-executed on preemption
    let total_prompt: u64 = workload::convoy(&cfg, 42).iter().map(|r| r.prompt_len).sum();
    assert_eq!(lars.metrics.prefill_tokens, total_prompt);
}
